"""L2 correctness: fused model entry points vs ref.py composition, shape
checks, and head/layer algebra."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def mats(seed, n, count):
    r = np.random.default_rng(seed)
    return [jnp.asarray(r.standard_normal((n, n)), jnp.float32) for _ in range(count)]


@pytest.mark.parametrize("beta", [32, 64, 128])
def test_head_matches_ref(beta):
    x, wq, wk, wv, wo = mats(beta, beta, 5)
    (got,) = model.head_fn(x, wq, wk, wv, wo)
    want = ref.scaled_dot_attention(x, wq, wk, wv, wo)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("beta", [32, 64])
def test_head_composition_equals_pipeline(beta):
    """Fused head == manually chaining the per-kernel entry points.

    This is exactly the equivalence the rust coordinator relies on: a DAG of
    per-kernel executables must reproduce the fused executable's numerics.
    """
    x, wq, wk, wv, wo = mats(100 + beta, beta, 5)
    (q,) = model.gemm_fn(x, wq)
    (k,) = model.gemm_fn(x, wk)
    (v,) = model.gemm_fn(x, wv)
    (kt,) = model.transpose_fn(k)
    (a,) = model.gemm_fn(q, kt)
    (b,) = model.softmax_fn(a)
    (c,) = model.gemm_fn(b, v)
    (z,) = model.gemm_fn(c, wo)
    (fused,) = model.head_fn(x, wq, wk, wv, wo)
    np.testing.assert_allclose(z, fused, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("heads", [1, 2, 4])
def test_layer_matches_ref(heads):
    beta = 32
    x = mats(0, beta, 1)[0]
    weights = [tuple(mats(10 * h + 1, beta, 4)) for h in range(heads)]
    flat = [w for ws in weights for w in ws]
    (got,) = model.layer_fn(x, *flat)
    want = ref.multi_head_layer(x, weights)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_layer_head_count_validation():
    x = mats(0, 16, 1)[0]
    with pytest.raises(AssertionError):
        model.layer_fn(x, x, x)  # not a multiple of 4 weights


def test_head_output_shape():
    beta = 32
    args = mats(3, beta, 5)
    (z,) = model.head_fn(*args)
    assert z.shape == (beta, beta)
    assert z.dtype == jnp.float32


def test_softmax_row_stochastic_inside_head():
    """The head's B matrix is row-stochastic -> C rows are convex combos of V
    rows; check Z is finite and bounded accordingly."""
    beta = 32
    x, wq, wk, wv, wo = mats(42, beta, 5)
    (z,) = model.head_fn(x, wq, wk, wv, wo)
    assert np.isfinite(np.asarray(z)).all()
