"""AOT path integrity: manifest completeness, HLO-text parseability markers,
and lowering determinism."""

import json
import os

import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_all_entry_points():
    names = {e["name"] for e in manifest()["artifacts"]}
    expected = {name for name, *_ in aot.entry_points()}
    assert names == expected


def test_manifest_files_exist_and_match_hash():
    import hashlib

    for e in manifest()["artifacts"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        text = open(path).read()
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]


def test_hlo_text_has_entry_computation():
    for e in manifest()["artifacts"][:6]:
        text = open(os.path.join(ART, e["file"])).read()
        assert "ENTRY" in text, f"{e['name']} missing ENTRY computation"
        # return_tuple=True => root is a tuple
        assert "tuple" in text.lower()


def test_paper_beta_sweep_present():
    """Expt 2/3 need gemm/softmax/transpose/head at every paper β."""
    names = {e["name"] for e in manifest()["artifacts"]}
    for b in (64, 128, 256, 512):
        for op in ("gemm", "softmax", "transpose", "head"):
            assert f"{op}_b{b}" in names


def test_lowering_is_deterministic():
    import jax
    import jax.numpy as jnp

    spec = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    from compile import model

    t1 = aot.to_hlo_text(jax.jit(model.gemm_fn).lower(spec, spec))
    t2 = aot.to_hlo_text(jax.jit(model.gemm_fn).lower(spec, spec))
    assert t1 == t2


def test_flops_metadata_sane():
    for e in manifest()["artifacts"]:
        assert e["flops"] >= 0
        assert e["bytes"] > 0
        if e["op"] == "gemm":
            b = e["beta"]
            assert e["flops"] == 2 * b**3
