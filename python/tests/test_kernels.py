"""L1 correctness: every Pallas kernel vs the pure-jnp oracle in ref.py.

hypothesis sweeps shapes (including ragged / non-128-divisible) and dtypes.
Tolerances: GEMM accumulates in a different order than jnp.matmul, so 1e-4
relative; element-wise ops are bit-for-bit comparable at 1e-6.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="shape-sweep tests need hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise, gemm, ref, softmax, transpose

DIMS = st.sampled_from([1, 2, 3, 8, 17, 32, 56, 64, 96, 128, 130, 192, 256])
SMALL_DIMS = st.sampled_from([1, 2, 7, 16, 33, 64])


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- GEMM


@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_gemm_matches_ref(m, k, n, seed):
    r = rng(seed)
    a = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(r.standard_normal((k, n)), jnp.float32)
    np.testing.assert_allclose(
        gemm.gemm(a, b), ref.gemm(a, b), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS)
def test_gemm_bf16(m, k, n):
    r = rng(7)
    a = jnp.asarray(r.standard_normal((m, k)), jnp.bfloat16)
    b = jnp.asarray(r.standard_normal((k, n)), jnp.bfloat16)
    got = gemm.gemm(a, b).astype(jnp.float32)
    want = ref.gemm(a, b).astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("beta", [64, 128, 256])
def test_gemm_paper_sizes(beta):
    r = rng(beta)
    a = jnp.asarray(r.standard_normal((beta, beta)), jnp.float32)
    b = jnp.asarray(r.standard_normal((beta, beta)), jnp.float32)
    np.testing.assert_allclose(
        gemm.gemm(a, b), ref.gemm(a, b), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 128, 32), (128, 128, 128)])
def test_gemm_block_shape_invariance(bm, bn, bk):
    """Output must not depend on the BlockSpec tiling choice."""
    r = rng(3)
    a = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    b = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    base = gemm.gemm(a, b, bm=128, bn=128, bk=128)
    tiled = gemm.gemm(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(base, tiled, rtol=1e-4, atol=1e-4)


def test_gemm_identity():
    eye = jnp.eye(64, dtype=jnp.float32)
    x = jnp.asarray(rng(0).standard_normal((64, 64)), jnp.float32)
    np.testing.assert_allclose(gemm.gemm(x, eye), x, rtol=1e-6, atol=1e-6)


def test_gemm_bias():
    r = rng(11)
    a = jnp.asarray(r.standard_normal((64, 32)), jnp.float32)
    b = jnp.asarray(r.standard_normal((32, 48)), jnp.float32)
    bias = jnp.asarray(r.standard_normal((48,)), jnp.float32)
    np.testing.assert_allclose(
        gemm.gemm_bias(a, b, bias), ref.gemm_bias(a, b, bias), rtol=1e-4, atol=1e-4
    )


def test_gemm_rect_contraction_mismatch():
    a = jnp.zeros((4, 5), jnp.float32)
    b = jnp.zeros((6, 4), jnp.float32)
    with pytest.raises(AssertionError):
        gemm.gemm(a, b)


# ---------------------------------------------------------------- softmax


@settings(max_examples=30, deadline=None)
@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_softmax_matches_ref(m, n, seed):
    x = jnp.asarray(rng(seed).standard_normal((m, n)) * 4, jnp.float32)
    np.testing.assert_allclose(
        softmax.softmax(x), ref.softmax(x), rtol=1e-5, atol=1e-6
    )


def test_softmax_rows_sum_to_one():
    x = jnp.asarray(rng(5).standard_normal((130, 67)), jnp.float32)
    s = np.asarray(softmax.softmax(x)).sum(axis=-1)
    np.testing.assert_allclose(s, np.ones(130), rtol=1e-5)


def test_softmax_large_logits_stable():
    """Stability: huge logits must not overflow (max-subtraction)."""
    x = jnp.asarray([[1e4, 1e4 + 1.0, 0.0]], jnp.float32)
    out = np.asarray(softmax.softmax(x))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out.sum(), 1.0, rtol=1e-5)


def test_softmax_translation_invariance():
    x = jnp.asarray(rng(9).standard_normal((16, 16)), jnp.float32)
    np.testing.assert_allclose(
        softmax.softmax(x), softmax.softmax(x + 37.0), rtol=1e-4, atol=1e-6
    )


# ---------------------------------------------------------------- transpose


@settings(max_examples=30, deadline=None)
@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_transpose_matches_ref(m, n, seed):
    x = jnp.asarray(rng(seed).standard_normal((m, n)), jnp.float32)
    np.testing.assert_allclose(transpose.transpose(x), x.T)


def test_transpose_involution():
    x = jnp.asarray(rng(1).standard_normal((96, 40)), jnp.float32)
    np.testing.assert_allclose(transpose.transpose(transpose.transpose(x)), x)


# ---------------------------------------------------------------- elementwise


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 5, 16, 100, 1024, 3000, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_vadd_matches_ref(n, seed):
    r = rng(seed)
    a = jnp.asarray(r.standard_normal(n), jnp.float32)
    b = jnp.asarray(r.standard_normal(n), jnp.float32)
    np.testing.assert_allclose(elementwise.vadd(a, b), ref.vadd(a, b))


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 5, 16, 100, 1024, 3000, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_vsin_matches_ref(n, seed):
    x = jnp.asarray(rng(seed).standard_normal(n) * 3, jnp.float32)
    np.testing.assert_allclose(
        elementwise.vsin(x), ref.vsin(x), rtol=1e-6, atol=1e-6
    )


def test_vadd_commutative():
    r = rng(2)
    a = jnp.asarray(r.standard_normal(512), jnp.float32)
    b = jnp.asarray(r.standard_normal(512), jnp.float32)
    np.testing.assert_allclose(elementwise.vadd(a, b), elementwise.vadd(b, a))
