"""Pytest bootstrap for the python/ tree.

* Puts this directory on sys.path so ``pytest python/tests`` works from the
  repo root (the tests import the ``compile`` package as a top-level name).
* Skips collection entirely when JAX is unavailable — the kernel layer is
  JAX/Pallas and has nothing to test without it.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

collect_ignore_glob = []
if importlib.util.find_spec("jax") is None:
    collect_ignore_glob = ["tests/*"]
