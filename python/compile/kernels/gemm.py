"""L1 Pallas tiled GEMM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's OpenCL GEMM
launches M×N work items, one output element each, with threadblock tiling into
shared memory on the GTX-970. On TPU the same insight — keep a reused tile of
A and B close to the compute unit — is expressed through the BlockSpec grid:

  grid = (M/bm, N/bn, K/bk); each (i, j) owns a (bm, bn) output tile held in a
  VMEM scratch accumulator while the k axis streams (bm, bk) / (bk, bn) tiles
  HBM→VMEM. The MXU consumes the (bm, bk) @ (bk, bn) products.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic custom-calls;
correctness is validated through the interpret path (see ref.py / pytest) and
real-TPU efficiency is argued from the VMEM footprint table in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# MXU-friendly defaults: 128-multiples keep the systolic array full and a
# (128, 128) f32 tile is 64 KiB — three tiles (A, B, acc) fit comfortably in
# the ~16 MiB VMEM budget even with double buffering.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += A[i,k] @ B[k,j]; flush on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_block(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= want (so ragged shapes work)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(a, b, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK):
    """C[M,N] = A[M,K] @ B[K,N] via the tiled Pallas kernel."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu_scratch((bm, bn))],
        interpret=True,
    )(a, b)


def pltpu_scratch(shape):
    """VMEM scratch allocation, version-portable."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _gemm_bias_kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] + bias_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm_bias(
    a, b, bias, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK
):
    """C = A @ B + bias, bias shape (N,) broadcast over rows."""
    m, k = a.shape
    _, n = b.shape
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_gemm_bias_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu_scratch((bm, bn))],
        interpret=True,
    )(a, b, bias)
