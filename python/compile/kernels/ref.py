"""Pure-jnp reference oracles for every L1 Pallas kernel.

These are the correctness ground truth: pytest asserts
``assert_allclose(pallas_kernel(x), ref(x))`` over hypothesis-driven shape
sweeps. Keep them dead simple — no tiling, no tricks.
"""

import jax.numpy as jnp


def gemm(a, b):
    """C = A @ B, float32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def gemm_bias(a, b, bias):
    """C = A @ B + bias (bias broadcast over rows)."""
    return (jnp.matmul(a, b, preferred_element_type=jnp.float32) + bias).astype(
        a.dtype
    )


def softmax(x):
    """Row-wise numerically stable softmax."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def transpose(x):
    """2-D transpose."""
    return x.T


def vadd(a, b):
    """Element-wise addition (Fig. 2 k0)."""
    return a + b


def vsin(x):
    """Element-wise sine (Fig. 2 k1)."""
    return jnp.sin(x)


def scaled_dot_attention(x, wq, wk, wv, wo):
    """One transformer attention head — the paper's 8-kernel DAG, fused.

    Q = X Wq ; K = X Wk ; V = X Wv            (3 projection GEMMs, level 1)
    Kt = K^T                                   (transpose)
    A = Q Kt                                   (score GEMM)
    B = softmax(A)                             (softmax)
    C = B V                                    (context GEMM)
    Z = C Wo                                   (output GEMM)
    """
    q = gemm(x, wq)
    k = gemm(x, wk)
    v = gemm(x, wv)
    kt = transpose(k)
    a = gemm(q, kt)
    b = softmax(a)
    c = gemm(b, v)
    return gemm(c, wo)


def multi_head_layer(x, weights):
    """H independent heads; outputs summed (proxy for concat+project).

    ``weights`` is a list of (wq, wk, wv, wo) tuples, one per head. The paper
    treats heads as fully independent DAG branches whose outputs are
    concatenated; summing keeps the output square (β×β) so the same kernel
    inventory covers the whole layer, and preserves the DAG shape exactly.
    """
    acc = None
    for (wq, wk, wv, wo) in weights:
        z = scaled_dot_attention(x, wq, wk, wv, wo)
        acc = z if acc is None else acc + z
    return acc
