"""L1 Pallas row-wise softmax.

Adaptation: the paper's OpenCL softmax assigns work items to rows; on TPU we
block rows so each grid step owns a (bm, N) slab resident in VMEM and the VPU
does max/exp/sum/div in one pass. Rows are independent, so the grid is 1-D.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def _pick_block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm",))
def softmax(x, *, bm: int = DEFAULT_BM):
    """Numerically stable softmax along the last axis of a 2-D array."""
    m, n = x.shape
    bm = _pick_block(m, bm)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x)
