"""L1 Pallas element-wise kernels: vadd and vsin (the paper's Fig. 2 pair).

These are the background/motivation kernels (k0 = vector add, k1 = in-place
sine). 1-D grids over VMEM-sized chunks; pure VPU work.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_B = 1024


def _vadd_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _vsin_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sin(x_ref[...])


def _pick_block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block",))
def vadd(a, b, *, block: int = DEFAULT_B):
    """Element-wise a + b over 1-D vectors (Fig. 2 kernel k0)."""
    (n,) = a.shape
    blk = _pick_block(n, block)
    return pl.pallas_call(
        _vadd_kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("block",))
def vsin(x, *, block: int = DEFAULT_B):
    """Element-wise sin(x) over 1-D vectors (Fig. 2 kernel k1)."""
    (n,) = x.shape
    blk = _pick_block(n, block)
    return pl.pallas_call(
        _vsin_kernel,
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)
