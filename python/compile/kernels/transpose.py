"""L1 Pallas block transpose.

Adaptation: the paper's OpenCL transpose stages tiles through shared memory to
keep global loads/stores coalesced. The VMEM analogue: the grid walks (i, j)
output tiles; BlockSpec index maps fetch the mirrored (j, i) input tile into
VMEM, and the in-register transpose is free on the VPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_B = 128


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def _pick_block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def transpose(x, *, bm: int = DEFAULT_B, bn: int = DEFAULT_B):
    """O[N,M] = X[M,N]^T via mirrored block tiles."""
    m, n = x.shape
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    return pl.pallas_call(
        _transpose_kernel,
        grid=(n // bn, m // bm),  # grid walks output tiles (N/bn, M/bm)
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,
    )(x)
