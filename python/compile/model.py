"""L2: the transformer attention model (build-time JAX), composed from L1
Pallas kernels.

Two granularities are exported:

* **per-kernel entry points** (`gemm_fn`, `softmax_fn`, `transpose_fn`,
  `vadd_fn`, `vsin_fn`) — one HLO executable per (op, size). These are what
  the L3 coordinator schedules: each DAG *ndrange* command runs exactly one
  of these, so the scheduler controls interleaving/concurrency, like the
  paper's per-kernel OpenCL dispatch.
* **fused entry points** (`head_fn`, `layer_fn`) — the whole attention head
  (the paper's 8-kernel DAG) or the H-head layer as a single XLA program.
  Used (a) as the numerics oracle for coordinator-composed execution in Rust
  integration tests, and (b) as the L2-fusion ablation in EXPERIMENTS.md.
"""

from .kernels import elementwise, gemm, softmax, transpose


def gemm_fn(a, b):
    """C = A @ B (Pallas tiled kernel)."""
    return (gemm.gemm(a, b),)


def softmax_fn(x):
    """Row softmax (Pallas kernel)."""
    return (softmax.softmax(x),)


def transpose_fn(x):
    """X^T (Pallas kernel)."""
    return (transpose.transpose(x),)


def vadd_fn(a, b):
    """a + b (Fig. 2 k0)."""
    return (elementwise.vadd(a, b),)


def vsin_fn(x):
    """sin(x) (Fig. 2 k1)."""
    return (elementwise.vsin(x),)


def head_fn(x, wq, wk, wv, wo):
    """One attention head: the paper's 8-kernel DAG fused into one program.

    Level structure (Fig. 3): 3 projection GEMMs -> transpose -> score GEMM
    -> softmax -> context GEMM -> output GEMM.
    """
    q = gemm.gemm(x, wq)
    k = gemm.gemm(x, wk)
    v = gemm.gemm(x, wv)
    kt = transpose.transpose(k)
    a = gemm.gemm(q, kt)
    b = softmax.softmax(a)
    c = gemm.gemm(b, v)
    z = gemm.gemm(c, wo)
    return (z,)


def layer_fn(x, *flat_weights):
    """H-head layer; heads independent, outputs summed (see ref.py note).

    ``flat_weights`` is H groups of (wq, wk, wv, wo).
    """
    assert len(flat_weights) % 4 == 0
    acc = None
    for h in range(len(flat_weights) // 4):
        wq, wk, wv, wo = flat_weights[4 * h : 4 * h + 4]
        (z,) = head_fn(x, wq, wk, wv, wo)
        acc = z if acc is None else elementwise_add2d(acc, z)
    return (acc,)


def elementwise_add2d(a, b):
    """2-D add via the vadd kernel semantics (kept trivially jnp: XLA fuses)."""
    return a + b
