"""AOT compile path: lower every (op, size) entry point to HLO *text* and
write ``artifacts/manifest.json``.

HLO text — NOT ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``
— is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's bundled XLA (xla_extension 0.5.1) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; the rust binary is self-contained afterwards.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# β sweep from the paper's evaluation (Expt 2/3 use 64..512; Expt 1 uses 256).
# 32 is an extra small size so rust unit/integration tests stay fast.
BETAS = (32, 64, 128, 256, 512)
# Vector sizes for the Fig. 2 motivation kernels.
VEC_SIZES = (4096, 1 << 20)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_points():
    """Yield (name, fn, example_args, meta) for every artifact."""
    for b in BETAS:
        sq = f32(b, b)
        yield (
            f"gemm_b{b}",
            model.gemm_fn,
            (sq, sq),
            {
                "op": "gemm",
                "beta": b,
                "flops": 2 * b**3,
                "bytes": 3 * 4 * b * b,
                "inputs": [[b, b], [b, b]],
                "outputs": [[b, b]],
            },
        )
        yield (
            f"softmax_b{b}",
            model.softmax_fn,
            (sq,),
            {
                "op": "softmax",
                "beta": b,
                "flops": 5 * b * b,
                "bytes": 2 * 4 * b * b,
                "inputs": [[b, b]],
                "outputs": [[b, b]],
            },
        )
        yield (
            f"transpose_b{b}",
            model.transpose_fn,
            (sq,),
            {
                "op": "transpose",
                "beta": b,
                "flops": 0,
                "bytes": 2 * 4 * b * b,
                "inputs": [[b, b]],
                "outputs": [[b, b]],
            },
        )
        yield (
            f"head_b{b}",
            model.head_fn,
            (sq,) * 5,
            {
                "op": "head",
                "beta": b,
                "flops": 6 * 2 * b**3,
                "bytes": 6 * 4 * b * b,
                "inputs": [[b, b]] * 5,
                "outputs": [[b, b]],
            },
        )
    for n in VEC_SIZES:
        v = f32(n)
        yield (
            f"vadd_n{n}",
            model.vadd_fn,
            (v, v),
            {
                "op": "vadd",
                "n": n,
                "flops": n,
                "bytes": 3 * 4 * n,
                "inputs": [[n], [n]],
                "outputs": [[n]],
            },
        )
        yield (
            f"vsin_n{n}",
            model.vsin_fn,
            (v,),
            {
                "op": "vsin",
                "n": n,
                "flops": 4 * n,
                "bytes": 2 * 4 * n,
                "inputs": [[n]],
                "outputs": [[n]],
            },
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact name filter"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {"format": "hlo-text", "artifacts": []}
    for name, fn, ex_args, meta in entry_points():
        if only is not None and name not in only:
            continue
        lowered = jax.jit(fn).lower(*ex_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry = dict(meta)
        entry.update(
            name=name,
            file=fname,
            sha256=hashlib.sha256(text.encode()).hexdigest(),
            n_inputs=len(ex_args),
        )
        manifest["artifacts"].append(entry)
        print(f"  wrote {fname} ({len(text)} chars)")

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path}: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
