//! Bench E1 — regenerates Fig. 11: clustering best-configuration speedup
//! over the default coarse configuration, H ∈ [1, 16], β=256.
//!
//! Paper shape: ≈15% flat region for H ≤ 10 (h_cpu = 0), jump with
//! h_cpu = 1 beyond.

use pyschedcl::benchkit::bench;
use pyschedcl::report::experiments::{expt1, format_expt1};

fn main() {
    println!("== Expt 1 (Fig. 11): clustering configuration sweep ==");
    let rows = expt1(16, 256, 2).expect("sweep runs");
    print!("{}", format_expt1(&rows));
    let crossover = rows
        .iter()
        .find(|r| r.best.h_cpu > 0)
        .map(|r| r.heads)
        .unwrap_or(0);
    println!("crossover to h_cpu=1 at H={crossover} (paper: >10)");

    println!("\nharness timing:");
    bench("sim/expt1_row(H=16,full_sweep)", 1, 5, || {
        expt1(16, 256, 1).unwrap()
    });
}
