//! Bench SERVE-CHAOS — the fault-injected serving proof (ISSUE 9): a
//! seeded Poisson stream of 10,000 deadline-carrying attention-head
//! requests walked through `serve_stream` under EDF on 4 scaled GPUs,
//! while a deterministic fault plan degrades the platform mid-stream:
//! one GPU slows to half speed, a second crashes outright, and a third
//! wedges (stalls, then recovers). In-flight work on the crashed device
//! is re-staged onto survivors under a per-request retry budget; queued
//! work whose deadline can no longer be met is shed by the deadline-aware
//! load shedder instead of rotting in the queue.
//!
//! Emits `BENCH_serve_chaos.json`, which `pyschedcl bench-check` gates
//! against `ci/bench_baselines/BENCH_serve_chaos.json`. The headline gate
//! is `lost == 0` **exactly** (tolerance 0): every offered request must be
//! accounted for as served, rejected, or shed — chaos may delay or shed
//! work, never silently drop it. `max_retries` must stay inside the
//! plan's budget, and `fault_events` pins that the plan really installed.

use pyschedcl::cost::PaperCost;
use pyschedcl::fault::{FaultEvent, FaultKind, FaultPlan};
use pyschedcl::platform::Platform;
use pyschedcl::report::serve_chaos_json;
use pyschedcl::sched::Edf;
use pyschedcl::serve::{NullSink, PoissonStream, ServeRequest, StreamingConfig, Workload};
use std::time::Instant;

fn main() {
    let n: usize = std::env::var("SERVE_CHAOS_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);
    // ~1200 req/s over 4 GPUs is comfortably stable on the healthy
    // platform (the soak bench sustains 1500), so every capacity loss
    // below is attributable to the injected faults.
    let rate = 1200.0;
    let window = 512usize;
    let platform = Platform::scaled(4, 1, 3, 1); // GPUs 0..=3, CPU 4

    // The chaos schedule, in virtual seconds (the 10k stream spans ~8.3s
    // of virtual time, so every event lands mid-stream):
    //   t=1.0  GPU 1 slows to half speed       (degraded, still serving)
    //   t=2.0  GPU 2 crashes                   (in-flight work re-staged)
    //   t=3.0  GPU 3 wedges for 0.5s           (watchdog-visible stall)
    let plan = FaultPlan {
        events: vec![
            FaultEvent {
                device: 1,
                at: 1.0,
                kind: FaultKind::Slowdown { factor: 0.5 },
            },
            FaultEvent {
                device: 2,
                at: 2.0,
                kind: FaultKind::Crash,
            },
            FaultEvent {
                device: 3,
                at: 3.0,
                kind: FaultKind::Wedge { dur: 0.5 },
            },
        ],
        retry_budget: 4,
        backoff_base: 1e-3,
        ..FaultPlan::default()
    }
    .normalized()
    .expect("chaos plan is valid");
    let n_events = plan.events.len();
    let retry_budget = plan.retry_budget;

    let cfg = StreamingConfig {
        window,
        faults: Some(plan),
        ..StreamingConfig::default()
    };

    // Every request carries a 250 ms latency budget: post-crash the
    // platform is overloaded, and the deadline-aware shedder — not an
    // unbounded backlog — absorbs the capacity gap.
    let requests = PoissonStream::new(29, rate)
        .expect("valid rate")
        .take(n)
        .enumerate()
        .map(|(i, t)| {
            let beta = if i % 4 == 3 { 128 } else { 64 };
            let mut r = ServeRequest::new(i, t, Workload::Head { beta });
            r.deadline = Some(0.25);
            r.priority = (i % 3) as u32;
            r
        });

    let t0 = Instant::now();
    let report = pyschedcl::serve::serve_stream(
        requests,
        &platform,
        &PaperCost,
        &mut Edf,
        &cfg,
        &mut NullSink,
    )
    .expect("chaos serve");
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "serve-chaos: {} offered @ {rate} req/s, {} fault event(s) -> \
         {} served, {} rejected, {} shed in {:.2}s wall (sim makespan {:.2}s)",
        report.offered,
        n_events,
        report.served,
        report.rejected,
        report.shed,
        wall,
        report.makespan
    );
    println!(
        "recovery: max {} crash retry(s) on one request (budget {}), {} preemption(s), \
         p99 {:.2} ms, miss rate {:.1}%",
        report.max_retries,
        retry_budget,
        report.preemptions,
        report.p99_latency * 1e3,
        report.deadline_miss_rate * 100.0
    );
    println!(
        "bounded state: peak {} live request(s), {} live component(s), {} event(s)",
        report.peak_live_requests, report.peak_live_components, report.events
    );

    // Belt and braces: the gates below re-check these from the JSON, but a
    // conservation break should fail loudly right here too.
    assert_eq!(
        report.served + report.rejected + report.shed,
        report.offered,
        "conservation violated: {} served + {} rejected + {} shed != {} offered",
        report.served,
        report.rejected,
        report.shed,
        report.offered
    );
    assert!(
        report.max_retries <= retry_budget,
        "retry budget breached: {} > {retry_budget}",
        report.max_retries
    );
    assert!(
        report.peak_live_requests <= window,
        "admission window breached: {} live > {window}",
        report.peak_live_requests
    );

    let json = serve_chaos_json(&report, wall, n_events);
    // Cargo runs benches with cwd = the package root (rust/); the CI gate
    // and artifact upload expect the JSON at the repository root.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_serve_chaos.json"))
        .unwrap_or_else(|| "BENCH_serve_chaos.json".into());
    std::fs::write(&path, json.to_string_pretty()).expect("write bench json");
    println!("wrote {}", path.display());
}
