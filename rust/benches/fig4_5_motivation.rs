//! Bench E-MOT — regenerates Figs. 4/5: coarse vs fine-grained scheduling
//! of one transformer head (β=256) on the simulated GTX-970.
//!
//! Paper rows: coarse 105 ms, fine 95 ms (≈8% faster).

use pyschedcl::benchkit::bench;
use pyschedcl::report::experiments::{motivation, run_clustering, MappingConfig};
use pyschedcl::cost::PaperCost;

fn main() {
    println!("== Figs. 4/5: coarse vs fine-grained (1 head, β=256) ==");
    let m = motivation(256).expect("motivation runs");
    println!(
        "simulated: coarse {:.1} ms | fine {:.1} ms | speedup {:.3}x  (paper: 105 / 95 ms, ~8%)",
        m.coarse_ms, m.fine_ms, m.speedup
    );
    println!(
        "fine-grained overlap: kernels {:.1} ms, copy/compute {:.1} ms",
        m.fine.trace.device_overlap(0) * 1e3,
        m.fine.trace.copy_compute_overlap(0) * 1e3
    );

    // Queue-count ablation (the q_gpu axis the paper sweeps).
    println!("\nqueue-count ablation (1 head, β=256):");
    for q in 1..=5 {
        let mc = MappingConfig {
            q_gpu: q,
            q_cpu: 0,
            h_cpu: 0,
        };
        let r = run_clustering(1, 256, mc, &PaperCost).unwrap();
        println!("  q_gpu={q}: {:>7.2} ms", r.makespan * 1e3);
    }

    // Harness cost: how fast the simulator regenerates the figure.
    println!("\nharness timing:");
    bench("sim/motivation_pair(beta=256)", 2, 20, || {
        motivation(256).unwrap()
    });
}
