//! Bench SERVE-OVERLOAD — the scaling proof for the event-driven
//! scheduler core (ISSUE 5): a sustained-overload stream (arrival rate ≫
//! service capacity, so thousands of requests are resident in the
//! frontier at once) served with the deadline-aware `edf` policy, the
//! worst case for the pre-indexed O(frontier)-per-select policies.
//! Emits `BENCH_serve_overload.json` (wall seconds, bench req/s,
//! preemption/rejection decision counts) which `pyschedcl bench-check`
//! gates against `ci/bench_baselines/BENCH_serve_overload.json`.
//!
//! A smaller slice (1k requests) additionally times the verbatim
//! pre-refactor stack — reference engine + view-based `sched::reference`
//! EDF, per-request instantiate + admitted-order merge — against the
//! indexed pipeline, so the policy-side speedup is measured (not
//! asserted) on every CI run, and the two slices are checked
//! bit-identical (same makespan) so the comparison is between equal work.

use pyschedcl::cost::PaperCost;
use pyschedcl::json::Json;
use pyschedcl::platform::Platform;
use pyschedcl::sched::{reference, Edf};
use pyschedcl::serve::{
    batch_requests, merge_apps, poisson_arrivals, serve_sim, ServeConfig, ServeRequest, Workload,
};
use pyschedcl::sim::reference::simulate_served_ref;
use pyschedcl::sim::CompMeta;
use std::time::Instant;

/// Arrival rate far above the single-GPU service capacity: the whole
/// stream lands within a fraction of a second of virtual time, so the
/// frontier holds a sustained multi-thousand-entry backlog.
const RATE: f64 = 50_000.0;
/// Generous deadline budget (seconds): everything passes laxity
/// admission, every component carries a finite deadline, and the EDF
/// urgency heap is exercised on every decision.
const BUDGET: f64 = 10.0;

fn stream(n: usize, seed: u64) -> Vec<ServeRequest> {
    poisson_arrivals(seed, n, RATE)
        .expect("valid rate")
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let mut r = ServeRequest::new(i, t, Workload::Head { beta: 64 });
            r.deadline = Some(BUDGET);
            if i % 3 == 0 {
                r.priority = 1;
            }
            r
        })
        .collect()
}

/// The pre-PR-5 stack, replayed by hand: admission order, per-request
/// instantiate, admitted-order `merge_apps`, reference engine driving the
/// view-based reference EDF (O(frontier) per select). Returns (wall
/// seconds, sim makespan).
fn old_stack_wall(requests: &[ServeRequest], platform: &Platform, cfg: &ServeConfig) -> (f64, f64) {
    let t0 = Instant::now();
    let mut admitted = requests.to_vec();
    admitted.sort_by(|a, b| {
        a.arrival
            .total_cmp(&b.arrival)
            .then_with(|| b.priority.cmp(&a.priority))
            .then_with(|| a.id.cmp(&b.id))
    });
    let apps: Vec<_> = admitted
        .iter()
        .map(|r| r.workload.instantiate().expect("valid workload"))
        .collect();
    let batches = batch_requests(&admitted, cfg.batch_window);
    let merged = merge_apps(&apps).expect("merge");
    let mut meta = vec![CompMeta::default(); merged.partition.components.len()];
    for b in &batches {
        for &m in &b.members {
            for c in merged.component_ranges[m].clone() {
                meta[c].release = b.release;
            }
        }
    }
    for (i, req) in admitted.iter().enumerate() {
        for c in merged.component_ranges[i].clone() {
            meta[c].deadline = req.arrival + req.deadline.expect("budget set");
            meta[c].priority = req.priority;
        }
    }
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = cfg.tenancy;
    let r = simulate_served_ref(
        &merged.dag,
        &merged.partition,
        platform,
        &PaperCost,
        &mut reference::Edf,
        &sim_cfg,
        &meta,
    )
    .expect("reference sim");
    (t0.elapsed().as_secs_f64(), r.makespan)
}

fn main() {
    let n = 6_000usize;
    let platform = Platform::scaled(1, 1, 3, 1); // one GPU: rate >> capacity
    let cfg = ServeConfig::default(); // tenancy 4, 2 ms batch window

    // Before/after slice: 1k requests through the old and new stacks.
    // Single-signature stream, so both pipelines assemble the same merged
    // application — the makespans must agree bitwise (equal work).
    let slice = stream(1_000, 23);
    let t0 = Instant::now();
    let slice_report = serve_sim(&slice, &platform, &PaperCost, &mut Edf, &cfg)
        .expect("slice serve");
    let new_slice_wall = t0.elapsed().as_secs_f64();
    let (old_slice_wall, old_makespan) = old_stack_wall(&slice, &platform, &cfg);
    assert_eq!(
        slice_report.makespan.to_bits(),
        old_makespan.to_bits(),
        "indexed and reference stacks simulated different schedules"
    );
    println!(
        "1k-slice before/after (edf, overload): old {:.2}s -> new {:.2}s ({:.1}x)",
        old_slice_wall,
        new_slice_wall,
        old_slice_wall / new_slice_wall.max(1e-9)
    );

    // The gated overload run: 6k resident-frontier requests, indexed EDF.
    let requests = stream(n, 23);
    let t0 = Instant::now();
    let report = serve_sim(&requests, &platform, &PaperCost, &mut Edf, &cfg)
        .expect("overload serve");
    let wall = t0.elapsed().as_secs_f64();
    let bench_rps = n as f64 / wall.max(1e-9);
    println!(
        "serve-overload: {} requests / 1 GPU in {:.2}s wall -> {:.0} req/s (bench), \
         sim makespan {:.2}s, miss rate {:.3}, preemptions {}, rejected {}",
        report.outcomes.len(),
        wall,
        bench_rps,
        report.makespan,
        report.deadline_miss_rate,
        report.preemptions,
        report.rejected.len()
    );

    let json = Json::obj(vec![
        ("schema", Json::str("pyschedcl-serve-overload-bench-v1")),
        ("requests", Json::num(n as f64)),
        ("gpus", Json::num(1.0)),
        ("arrival_rate_rps", Json::num(RATE)),
        ("wall_seconds", Json::num(wall)),
        ("bench_requests_per_second", Json::num(bench_rps)),
        ("old_policy_1k_wall_seconds", Json::num(old_slice_wall)),
        ("new_policy_1k_wall_seconds", Json::num(new_slice_wall)),
        (
            "policy_speedup_1k",
            Json::num(old_slice_wall / new_slice_wall.max(1e-9)),
        ),
        ("sim", report.to_json()),
    ]);
    // Cargo runs benches with cwd = the package root (rust/); the CI gate
    // and artifact upload expect the JSON at the repository root.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_serve_overload.json"))
        .unwrap_or_else(|| "BENCH_serve_overload.json".into());
    std::fs::write(&path, json.to_string_pretty()).expect("write bench json");
    println!("wrote {}", path.display());
}
