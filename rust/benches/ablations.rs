//! Ablation benches for the design choices DESIGN.md §Substitutions calls
//! out: each simulator mechanism is swept to show which paper effect it
//! generates (and that the headline results are not artifacts of one knob).

use pyschedcl::cost::PaperCost;
use pyschedcl::graph::Partition;
use pyschedcl::platform::{DeviceType, Platform};
use pyschedcl::report::experiments::{run_clustering, MappingConfig, DEFAULT_MC};
use pyschedcl::sched::{Clustering, Eager};
use pyschedcl::sim::{simulate, SimConfig};
use pyschedcl::transformer::{cluster_by_head, transformer_dag};

fn main() {
    let fine = MappingConfig {
        q_gpu: 3,
        q_cpu: 0,
        h_cpu: 0,
    };

    // ---- 1. contention efficiency η: generates the "individual kernels
    // slow down but total time drops" effect (Fig. 5).
    println!("== ablation: contention efficiency η (1 head, β=256, fine vs coarse) ==");
    let (dag1, ios1) = transformer_dag(1, 256, DeviceType::Gpu);
    let part1 = cluster_by_head(&dag1, &ios1, 0);
    for eta in [1.0, 0.92, 0.8, 0.6, 0.4] {
        let cfg = SimConfig {
            contention_efficiency: eta,
            ..SimConfig::default()
        };
        let coarse = simulate(
            &dag1,
            &part1,
            &Platform::paper_testbed(1, 0),
            &PaperCost,
            &mut Clustering,
            &cfg,
        )
        .unwrap()
        .makespan;
        let fine_t = simulate(
            &dag1,
            &part1,
            &Platform::paper_testbed(3, 0),
            &PaperCost,
            &mut Clustering,
            &cfg,
        )
        .unwrap()
        .makespan;
        println!(
            "  η={eta:<4}  coarse {:>6.1} ms  fine {:>6.1} ms  speedup {:.3}x",
            coarse * 1e3,
            fine_t * 1e3,
            coarse / fine_t
        );
    }
    println!("  (fine-grained gain persists until η collapses below ~0.5)");

    // ---- 2. callback latency: generates the HEFT/eager inter-kernel gaps
    // (Fig. 13b). Clustering is insensitive (blocking-wait path).
    println!("\n== ablation: callback latency (H=8, β=256) ==");
    let (dag8, ios8) = transformer_dag(8, 256, DeviceType::Gpu);
    let singles = Partition::singletons(&dag8);
    let part8 = cluster_by_head(&dag8, &ios8, 0);
    for cb_ms in [0.0, 0.6, 1.2, 2.4, 4.8] {
        let mut platform = Platform::paper_testbed(1, 1);
        platform.callback_latency = cb_ms * 1e-3;
        let eager = simulate(
            &dag8,
            &singles,
            &platform,
            &PaperCost,
            &mut Eager,
            &SimConfig::default(),
        )
        .unwrap()
        .makespan;
        let mut platform3 = Platform::paper_testbed(3, 1);
        platform3.callback_latency = cb_ms * 1e-3;
        let cl = simulate(
            &dag8,
            &part8,
            &platform3,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
        )
        .unwrap()
        .makespan;
        println!(
            "  cb={cb_ms:>3.1} ms  eager {:>7.1} ms  clustering {:>6.1} ms  ratio {:.2}x",
            eager * 1e3,
            cl * 1e3,
            eager / cl
        );
    }

    // ---- 3. host starvation fraction: generates eager's large GPU gaps
    // while the CPU crunches misplaced GEMMs (Fig. 13a).
    println!("\n== ablation: host starvation fraction (eager, H=8, β=256) ==");
    for f in [0.0, 0.25, 0.5, 1.0] {
        let cfg = SimConfig {
            host_starvation_fraction: f,
            ..SimConfig::default()
        };
        let r = simulate(
            &dag8,
            &singles,
            &Platform::paper_testbed(1, 1),
            &PaperCost,
            &mut Eager,
            &cfg,
        )
        .unwrap();
        println!(
            "  f={f:<4}  makespan {:>7.1} ms  max GPU gap {:>6.2} ms",
            r.makespan * 1e3,
            r.trace.max_gap(0) * 1e3
        );
    }

    // ---- 4. enqueue overhead: generates clustering's "kernels start
    // executing much later" effect (Fig. 13c commentary).
    println!("\n== ablation: enqueue overhead (clustering H=16, β=64) ==");
    for us in [0.0, 20.0, 100.0, 500.0] {
        let t = {
            let (dag, ios) = transformer_dag(16, 64, DeviceType::Gpu);
            let part = cluster_by_head(&dag, &ios, 0);
            let mut platform = Platform::paper_testbed(3, 1);
            platform.enqueue_overhead = us * 1e-6;
            simulate(
                &dag,
                &part,
                &platform,
                &PaperCost,
                &mut Clustering,
                &SimConfig::default(),
            )
            .unwrap()
            .makespan
        };
        println!("  enqueue={us:>5.0} µs  makespan {:>7.2} ms", t * 1e3);
    }

    // ---- 5. best-config robustness: the Fig. 11 conclusion (fine-grained
    // wins) must hold across the knob ranges above.
    println!("\n== ablation: fine vs default across knob extremes (1 head, β=256) ==");
    let base = run_clustering(1, 256, DEFAULT_MC, &PaperCost).unwrap().makespan;
    let best = run_clustering(1, 256, fine, &PaperCost).unwrap().makespan;
    println!(
        "  default {:.1} ms vs fine {:.1} ms  ({:.3}x) — stable conclusion",
        base * 1e3,
        best * 1e3,
        base / best
    );
}
