//! Bench SERVE-SCALE — the 10k-request scale proof for the allocation-free
//! serving hot path (ISSUE 4): a seeded Poisson stream of 10,000
//! attention-head requests served concurrently across 4 scaled GPUs via
//! `serve_sim`, emitting `BENCH_serve_scale.json` (wall seconds, bench
//! requests/second, template-cache hit/miss counts) which
//! `pyschedcl bench-check` gates against
//! `ci/bench_baselines/BENCH_serve_scale.json`.
//!
//! A smaller before/after slice (1k requests) additionally times the
//! verbatim pre-refactor engine + per-request merge pipeline
//! (`pyschedcl::sim::reference`) against the optimized path, so the
//! speedup is measured — not asserted — on every CI run. The old path is
//! quadratic in dispatches per event, which is exactly why the slice is
//! 1k and the gated run 10k.

use pyschedcl::cost::PaperCost;
use pyschedcl::json::Json;
use pyschedcl::platform::Platform;
use pyschedcl::sched::LeastLoaded;
use pyschedcl::serve::{
    batch_requests, merge_apps, poisson_arrivals, serve_sim, ServeConfig, ServeRequest, Workload,
};
use pyschedcl::sim::reference::simulate_served_ref;
use pyschedcl::sim::CompMeta;
use std::time::Instant;

fn stream(n: usize, seed: u64, rate: f64) -> Vec<ServeRequest> {
    poisson_arrivals(seed, n, rate)
        .expect("valid rate")
        .into_iter()
        .enumerate()
        .map(|(i, t)| ServeRequest::new(i, t, Workload::Head { beta: 64 }))
        .collect()
}

/// The pre-refactor serving pipeline, replayed by hand: per-request
/// instantiate, admitted-order `merge_apps`, reference engine. Returns its
/// wall seconds.
fn old_pipeline_wall(requests: &[ServeRequest], platform: &Platform, cfg: &ServeConfig) -> f64 {
    let t0 = Instant::now();
    let apps: Vec<_> = requests
        .iter()
        .map(|r| r.workload.instantiate().expect("valid workload"))
        .collect();
    let batches = batch_requests(requests, cfg.batch_window);
    let merged = merge_apps(&apps).expect("merge");
    let mut meta = vec![CompMeta::default(); merged.partition.components.len()];
    for b in &batches {
        for &m in &b.members {
            for c in merged.component_ranges[m].clone() {
                meta[c].release = b.release;
            }
        }
    }
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = cfg.tenancy;
    simulate_served_ref(
        &merged.dag,
        &merged.partition,
        platform,
        &PaperCost,
        &mut pyschedcl::sched::reference::LeastLoaded,
        &sim_cfg,
        &meta,
    )
    .expect("reference sim");
    t0.elapsed().as_secs_f64()
}

fn main() {
    let n = 10_000usize;
    let rate = 1000.0;
    let platform = Platform::scaled(4, 1, 3, 1);
    let cfg = ServeConfig::default(); // tenancy 4, 2 ms batch window

    // Before/after slice: 1k requests through the old and new pipelines.
    let slice = stream(1_000, 11, rate);
    let t0 = Instant::now();
    let slice_report = serve_sim(&slice, &platform, &PaperCost, &mut LeastLoaded, &cfg)
        .expect("slice serve");
    let new_slice_wall = t0.elapsed().as_secs_f64();
    let old_slice_wall = old_pipeline_wall(&slice, &platform, &cfg);
    println!(
        "1k-slice before/after: old {:.2}s -> new {:.2}s ({:.1}x), slice p99 {:.2} ms",
        old_slice_wall,
        new_slice_wall,
        old_slice_wall / new_slice_wall.max(1e-9),
        slice_report.p99_latency * 1e3
    );

    // The gated 10k run.
    let requests = stream(n, 11, rate);
    let t0 = Instant::now();
    let report = serve_sim(&requests, &platform, &PaperCost, &mut LeastLoaded, &cfg)
        .expect("scale serve");
    let wall = t0.elapsed().as_secs_f64();
    let bench_rps = n as f64 / wall.max(1e-9);
    println!(
        "serve-scale: {} requests / 4 GPUs in {:.2}s wall -> {:.0} req/s (bench), \
         sim makespan {:.2}s, sim throughput {:.0} req/s, p99 {:.2} ms",
        report.outcomes.len(),
        wall,
        bench_rps,
        report.makespan,
        report.throughput_rps,
        report.p99_latency * 1e3
    );
    println!(
        "template cache: {} hit(s), {} miss(es) over {} requests",
        report.template_cache_hits, report.template_cache_misses, n
    );

    let json = Json::obj(vec![
        ("schema", Json::str("pyschedcl-serve-scale-bench-v1")),
        ("requests", Json::num(n as f64)),
        ("gpus", Json::num(4.0)),
        ("arrival_rate_rps", Json::num(rate)),
        ("wall_seconds", Json::num(wall)),
        ("bench_requests_per_second", Json::num(bench_rps)),
        ("old_pipeline_1k_wall_seconds", Json::num(old_slice_wall)),
        ("new_pipeline_1k_wall_seconds", Json::num(new_slice_wall)),
        (
            "pipeline_speedup_1k",
            Json::num(old_slice_wall / new_slice_wall.max(1e-9)),
        ),
        ("sim", report.to_json()),
    ]);
    // Cargo runs benches with cwd = the package root (rust/); the CI gate
    // and artifact upload expect the JSON at the repository root, like the
    // serve smokes' outputs.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_serve_scale.json"))
        .unwrap_or_else(|| "BENCH_serve_scale.json".into());
    std::fs::write(&path, json.to_string_pretty()).expect("write bench json");
    println!("wrote {}", path.display());
}
