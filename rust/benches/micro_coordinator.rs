//! Coordinator micro-benchmarks: the L3 hot paths the §Perf pass tracks.
//!
//! * spec parsing (design frontend)
//! * `setup_cq` synthesis throughput
//! * simulator event rate
//! * real PJRT dispatch latency (skipped when artifacts are absent)

use pyschedcl::benchkit::bench;
use pyschedcl::cost::PaperCost;
use pyschedcl::exec::execute_dag;
use pyschedcl::graph::Partition;
use pyschedcl::platform::{Device, DeviceType, Platform};
use pyschedcl::queue::setup_cq;
use pyschedcl::runtime::Runtime;
use pyschedcl::sched::Clustering;
use pyschedcl::sim::{simulate, SimConfig};
use pyschedcl::spec::parse_spec;
use pyschedcl::transformer::{cluster_by_head, transformer_dag, vadd_vsin_dag};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));

    // ---- spec frontend
    let spec_text =
        std::fs::read_to_string(root.join("specs/transformer_head_b64.json")).unwrap();
    bench("spec/parse_transformer_head", 10, 200, || {
        parse_spec(&spec_text).unwrap()
    });

    // ---- queue synthesis
    let (dag16, ios16) = transformer_dag(16, 256, DeviceType::Gpu);
    let part16 = cluster_by_head(&dag16, &ios16, 1);
    let gpu = Device::gtx970(0, 3);
    bench("queue/setup_cq_one_head(8_kernels)", 10, 500, || {
        setup_cq(&dag16, &part16, 1, &gpu)
    });
    bench("queue/setup_cq_all_16_heads", 5, 100, || {
        for c in 0..16 {
            std::hint::black_box(setup_cq(&dag16, &part16, c, &gpu));
        }
    });

    // ---- simulator
    let platform = Platform::paper_testbed(3, 1);
    let cfg = SimConfig::default();
    bench("sim/transformer_H16_b256_clustering", 3, 30, || {
        simulate(&dag16, &part16, &platform, &PaperCost, &mut Clustering, &cfg).unwrap()
    });
    let singles = Partition::singletons(&dag16);
    let p1 = Platform::paper_testbed(1, 1);
    bench("sim/transformer_H16_b256_eager", 3, 30, || {
        simulate(
            &dag16,
            &singles,
            &p1,
            &PaperCost,
            &mut pyschedcl::sched::Eager,
            &cfg,
        )
        .unwrap()
    });

    // ---- real PJRT dispatch (end-to-end driver hot path)
    let Ok(rt) = Runtime::new(&root.join("artifacts")) else {
        println!("runtime/* skipped: artifacts not built");
        return;
    };
    let rt = Arc::new(rt);
    rt.load("gemm_b64").unwrap();
    let n = 64 * 64;
    let a: Vec<f32> = (0..n).map(|i| (i % 17) as f32 / 7.0).collect();
    bench("runtime/execute_gemm_b64", 5, 100, || {
        rt.execute_f32("gemm_b64", &[&a, &a]).unwrap()
    });
    rt.load("gemm_b256").unwrap();
    let big: Vec<f32> = (0..256 * 256).map(|i| (i % 23) as f32 / 9.0).collect();
    bench("runtime/execute_gemm_b256", 3, 30, || {
        rt.execute_f32("gemm_b256", &[&big, &big]).unwrap()
    });

    let (vdag, vks) = vadd_vsin_dag(4096);
    let vpart = Partition::singletons(&vdag);
    let vplat = Platform::paper_testbed(2, 1);
    let mut inputs = HashMap::new();
    inputs.insert(vdag.kernels[vks[0]].inputs[0], a[..4096.min(n)].to_vec());
    inputs.insert(vdag.kernels[vks[0]].inputs[1], a[..4096.min(n)].to_vec());
    let mut inputs2 = HashMap::new();
    let v: Vec<f32> = (0..4096).map(|i| (i % 13) as f32 / 5.0).collect();
    inputs2.insert(vdag.kernels[vks[0]].inputs[0], v.clone());
    inputs2.insert(vdag.kernels[vks[0]].inputs[1], v);
    rt.load("vadd_n4096").unwrap();
    rt.load("vsin_n4096").unwrap();
    bench("exec/execute_dag_vadd_vsin", 3, 30, || {
        execute_dag(
            &vdag,
            &vpart,
            &vplat,
            &PaperCost,
            &mut Clustering,
            &rt,
            &inputs2,
        )
        .unwrap()
    });
}
