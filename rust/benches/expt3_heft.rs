//! Bench E3 — regenerates Fig. 12(b): clustering (best mc) vs HEFT,
//! H=16, β ∈ {64, 128, 256, 512}, plus the Fig. 13 Gantt diagnostics.
//!
//! Paper claims: clustering > heft > eager; heft ≈2.4× over eager at β=512.

use pyschedcl::benchkit::bench;
use pyschedcl::report::experiments::{expt2, expt3, format_baseline, gantt};

fn main() {
    println!("== Expt 3 (Fig. 12b): clustering vs HEFT ==");
    let rows = expt3(16, &[64, 128, 256, 512]).expect("sweep runs");
    print!("{}", format_baseline(&rows, "heft"));

    // Cross-check the paper's heft-vs-eager factor at β=512.
    let e = expt2(16, &[512]).unwrap()[0];
    let h = &rows[3];
    println!(
        "heft over eager at β=512: {:.2}x (paper ≈2.4x)",
        e.baseline_ms / h.baseline_ms
    );

    println!("\n== Fig. 13 diagnostics (H=16, β=512) ==");
    for policy in ["eager", "heft", "clustering"] {
        let (r, _) = gantt(policy, 16, 512).unwrap();
        println!(
            "  {policy:<11} makespan {:>9.1} ms  max GPU gap {:>8.2} ms  overlap {:>7.1} ms",
            r.makespan * 1e3,
            r.trace.max_gap(0) * 1e3,
            r.trace.device_overlap(0) * 1e3
        );
    }

    println!("\nharness timing:");
    bench("sim/expt3_point(H=16,beta=256)", 1, 5, || {
        expt3(16, &[256]).unwrap()
    });
}
