//! Bench SERVE — the multi-DAG serving layer: sequential replay vs
//! concurrent multi-tenant serving of a seeded Poisson request stream, the
//! same configuration the CI bench smoke runs (`pyschedcl serve`).

use pyschedcl::benchkit::bench;
use pyschedcl::cost::PaperCost;
use pyschedcl::platform::Platform;
use pyschedcl::report::format_serve_comparison;
use pyschedcl::sched::{Clustering, LeastLoaded};
use pyschedcl::serve::{
    poisson_arrivals, serve_sequential, serve_sim, ServeConfig, ServeRequest, Workload,
};

fn stream(n: usize, seed: u64, beta: u64) -> Vec<ServeRequest> {
    poisson_arrivals(seed, n, 2000.0)
        .expect("valid rate")
        .into_iter()
        .enumerate()
        .map(|(i, t)| ServeRequest::new(i, t, Workload::Head { beta }))
        .collect()
}

fn main() {
    println!("== serve: 32 attention-head requests, Poisson(2000/s), seed 7 ==");
    let requests = stream(32, 7, 64);
    let platform = Platform::paper_testbed(3, 1);
    let cfg = ServeConfig::default();
    let conc = serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
    let seq = serve_sequential(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
    print!("{}", format_serve_comparison(&conc, &seq));

    println!("\n== scale-out: same stream on 2 GPUs (least-loaded) ==");
    let wide = Platform::scaled(2, 1, 3, 1);
    let conc2 = serve_sim(&requests, &wide, &PaperCost, &mut LeastLoaded, &cfg).unwrap();
    println!(
        "2-GPU concurrent: span {:.1} ms  thru {:.1} req/s  p99 {:.2} ms (1-GPU: {:.1} req/s)",
        conc2.makespan * 1e3,
        conc2.throughput_rps,
        conc2.p99_latency * 1e3,
        conc.throughput_rps
    );

    println!("\nharness timing:");
    bench("serve/sim_32req_concurrent", 2, 10, || {
        serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap()
    });
    bench("serve/sim_32req_sequential", 2, 10, || {
        serve_sequential(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap()
    });
}
