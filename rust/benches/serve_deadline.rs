//! Bench SERVE-DEADLINE — deadline-aware serving: least-loaded (deadline
//! blind) vs EDF (earliest absolute deadline first, with preemption) under
//! a tight-deadline seeded Poisson stream. Reports deadline-miss rate,
//! per-priority p99, and preemption counts — the SLO trajectory the CI
//! bench smoke tracks next to raw serving throughput.

use pyschedcl::benchkit::bench;
use pyschedcl::cost::PaperCost;
use pyschedcl::platform::Platform;
use pyschedcl::sched::{Edf, LeastLoaded, Policy};
use pyschedcl::serve::{
    poisson_arrivals, serve_sim, ServeConfig, ServeReport, ServeRequest, Workload,
};

/// Mixed-urgency stream: every 4th request is tight (priority 1, small
/// budget); the rest get a loose budget. Same shape as the CLI's
/// `--deadline-ms/--deadline-tight-ms/--deadline-tight-every` flags.
fn stream(n: usize, seed: u64, tight_s: f64, loose_s: f64) -> Vec<ServeRequest> {
    poisson_arrivals(seed, n, 2000.0)
        .expect("valid rate")
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let mut r = ServeRequest::new(i, t, Workload::Head { beta: 64 });
            if i % 4 == 0 {
                r.deadline = Some(tight_s);
                r.priority = 1;
            } else {
                r.deadline = Some(loose_s);
            }
            r
        })
        .collect()
}

fn summarize(label: &str, r: &ServeReport) {
    println!(
        "{label:<13} thru {:>7.1} req/s  p99 {:>7.2} ms  miss {:>2}/{:<2} ({:.0}%)  preemptions {}",
        r.throughput_rps,
        r.p99_latency * 1e3,
        r.deadline_misses,
        r.deadline_total,
        r.deadline_miss_rate * 100.0,
        r.preemptions
    );
    for (p, l) in &r.per_priority_p99 {
        println!("    priority {p}: p99 {:.2} ms", l * 1e3);
    }
}

fn main() {
    println!("== serve-deadline: 24 head requests, Poisson(2000/s), seed 7, tight deadlines ==");
    let requests = stream(24, 7, 0.020, 0.250);
    let platform = Platform::paper_testbed(3, 0);
    let cfg = ServeConfig {
        tenancy: 1,
        ..ServeConfig::default()
    };
    let run = |policy: &mut dyn Policy| {
        serve_sim(&requests, &platform, &PaperCost, policy, &cfg).unwrap()
    };
    let ll = run(&mut LeastLoaded);
    let edf = run(&mut Edf);
    summarize("least-loaded", &ll);
    summarize("edf", &edf);
    println!(
        "edf meets {} more deadline(s) than least-loaded",
        (ll.deadline_misses as i64 - edf.deadline_misses as i64).max(0)
    );

    println!("\nharness timing:");
    bench("serve/deadline_24req_least_loaded", 2, 10, || {
        serve_sim(&requests, &platform, &PaperCost, &mut LeastLoaded, &cfg).unwrap()
    });
    bench("serve/deadline_24req_edf", 2, 10, || {
        serve_sim(&requests, &platform, &PaperCost, &mut Edf, &cfg).unwrap()
    });
}
