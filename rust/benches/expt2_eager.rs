//! Bench E2 — regenerates Fig. 12(a): clustering (best mc) vs eager,
//! H=16, β ∈ {64, 128, 256, 512}.
//!
//! Paper band: 1.4–3.4× in clustering's favour.

use pyschedcl::benchkit::bench;
use pyschedcl::report::experiments::{expt2, format_baseline};

fn main() {
    println!("== Expt 2 (Fig. 12a): clustering vs eager ==");
    let rows = expt2(16, &[64, 128, 256, 512]).expect("sweep runs");
    print!("{}", format_baseline(&rows, "eager"));
    println!("(paper band: 1.4–3.4x; shape: speedup shrinks as β grows)");

    println!("\nharness timing:");
    bench("sim/expt2_point(H=16,beta=256)", 1, 5, || {
        expt2(16, &[256]).unwrap()
    });
}
