//! Bench SERVE-SHARD — the sharded multi-replica scaling proof (ISSUE 10):
//! a 4→16→64-GPU sweep holding the *per-shard* platform fixed at 4 scaled
//! GPUs (1 shard, 4 shards, 16 shards) and the per-shard offered load
//! fixed at 600 req/s over a 5-second virtual arrival window. Each config
//! streams its requests through `serve_sharded_stream`: the
//! signature-affinity router fans a 32-signature palette out to the
//! shards, every shard runs its own serve-core loop on its own scheduler
//! state and template cache, and the per-shard reports merge bin-wise.
//!
//! Because virtual stream duration and per-shard load are constant across
//! configs, near-linear scaling means the merged virtual throughput grows
//! with the shard count: `scaling_efficiency = rps_64 / (rps_4 × 16)`.
//! Throughputs are **virtual-time** (served / merged sim makespan), so the
//! gate is stable across CI hardware; only `wall_seconds` and the
//! router-overhead fraction (router wall seconds / config wall seconds)
//! touch the wall clock.
//!
//! Emits `BENCH_serve_shard.json`, which `pyschedcl bench-check` gates
//! against `ci/bench_baselines/BENCH_serve_shard.json`: conservation
//! (`lost_total == 0`), zero duplicate rejections, scaling efficiency
//! ≥ 0.7 at 64 GPUs, and router overhead ≤ 5% of wall.

use pyschedcl::cost::PaperCost;
use pyschedcl::error::Result;
use pyschedcl::json::Json;
use pyschedcl::sched::{LeastLoaded, Policy};
use pyschedcl::serve::{
    serve_sharded_stream, NullSink, PlatformShape, PoissonStream, ServeRequest, ShardSpec,
    StreamingConfig, Workload,
};
use std::time::Instant;

fn policy_factory() -> Result<Box<dyn Policy>> {
    Ok(Box::new(LeastLoaded))
}

struct ConfigResult {
    gpus: usize,
    shards: usize,
    requests: usize,
    wall_seconds: f64,
    virtual_rps: f64,
    makespan: f64,
    router_overhead_frac: f64,
    spills: usize,
    duplicates: usize,
    lost: f64,
    offered: usize,
    served: usize,
    per_shard_rps: Vec<f64>,
}

fn run_config(gpus: usize, shards: usize) -> ConfigResult {
    // Per-shard load is constant across the sweep: 600 req/s per 4-GPU
    // shard (well inside the soak bench's 1500 req/s stable regime, so a
    // 2x signature imbalance still drains) over a 5 s virtual window.
    let per_shard_rate = 600.0;
    let rate = per_shard_rate * shards as f64;
    let n = (rate * 5.0) as usize;
    let shape = PlatformShape {
        gpus,
        cpus: shards,
        queues_gpu: 3,
        queues_cpu: 1,
    };
    let spec = ShardSpec {
        shards,
        ..ShardSpec::default()
    };
    // The window bounds each shard's live requests independently.
    let cfg = StreamingConfig {
        window: 512,
        ..StreamingConfig::default()
    };
    // 32 workload signatures: enough distinct hash targets that every
    // shard count in the sweep sees work on all shards.
    let requests = PoissonStream::new(17 + shards as u64, rate)
        .expect("valid rate")
        .take(n)
        .enumerate()
        .map(|(i, t)| ServeRequest::new(i, t, Workload::Head { beta: 64 + 8 * (i as u64 % 32) }));

    let t0 = Instant::now();
    let r = serve_sharded_stream(
        requests,
        shape,
        &PaperCost,
        policy_factory,
        &cfg,
        &spec,
        &mut NullSink,
    )
    .expect("sharded serve");
    let wall = t0.elapsed().as_secs_f64();

    let m = &r.merged;
    let lost = (m.offered as f64) - (m.served as f64) - (m.rejected as f64) - (m.shed as f64);
    ConfigResult {
        gpus,
        shards,
        requests: n,
        wall_seconds: wall,
        virtual_rps: m.throughput_rps,
        makespan: m.makespan,
        router_overhead_frac: if wall > 0.0 {
            r.route_seconds / wall
        } else {
            0.0
        },
        spills: r.router.spills,
        duplicates: r.router.duplicate_rejections,
        lost,
        offered: m.offered,
        served: m.served,
        per_shard_rps: r.shards.iter().map(|s| s.throughput_rps).collect(),
    }
}

fn main() {
    let sweep: Vec<ConfigResult> = [(4usize, 1usize), (16, 4), (64, 16)]
        .iter()
        .map(|&(gpus, shards)| {
            let c = run_config(gpus, shards);
            println!(
                "serve-shard: {} GPUs / {} shard(s): {} requests in {:.2}s wall -> \
                 virtual {:.0} req/s (makespan {:.2}s), router {:.4}% of wall, \
                 {} spill(s), {} lost",
                c.gpus,
                c.shards,
                c.requests,
                c.wall_seconds,
                c.virtual_rps,
                c.makespan,
                c.router_overhead_frac * 100.0,
                c.spills,
                c.lost
            );
            assert_eq!(c.lost, 0.0, "conservation violated at {} shards", c.shards);
            c
        })
        .collect();

    let rps_4 = sweep[0].virtual_rps;
    let rps_16 = sweep[1].virtual_rps;
    let rps_64 = sweep[2].virtual_rps;
    // Perfect scaling would multiply the 4-GPU throughput by 16 at 64
    // GPUs (same per-shard platform and load).
    let efficiency = rps_64 / (rps_4 * 16.0);
    let overhead = sweep.iter().fold(0.0f64, |m, c| m.max(c.router_overhead_frac));
    let wall: f64 = sweep.iter().map(|c| c.wall_seconds).sum();
    let offered_total: usize = sweep.iter().map(|c| c.offered).sum();
    let lost_total: f64 = sweep.iter().map(|c| c.lost).sum();
    let duplicates: usize = sweep.iter().map(|c| c.duplicates).sum();

    println!(
        "serve-shard sweep: scaling efficiency {:.3} (rps 4/16/64 GPUs: \
         {:.0}/{:.0}/{:.0}), max router overhead {:.4}% of wall, {:.1}s total wall",
        efficiency,
        rps_4,
        rps_16,
        rps_64,
        overhead * 100.0,
        wall
    );

    let json = Json::obj(vec![
        ("schema", Json::str("pyschedcl-serve-shard-bench-v1")),
        ("wall_seconds", Json::num(wall)),
        ("offered_total", Json::num(offered_total as f64)),
        ("lost_total", Json::num(lost_total)),
        ("duplicate_rejections", Json::num(duplicates as f64)),
        ("rps_4", Json::num(rps_4)),
        ("rps_16", Json::num(rps_16)),
        ("rps_64", Json::num(rps_64)),
        ("scaling_efficiency", Json::num(efficiency)),
        ("router_overhead_frac", Json::num(overhead)),
        (
            "configs",
            Json::Arr(
                sweep
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("gpus", Json::num(c.gpus as f64)),
                            ("shards", Json::num(c.shards as f64)),
                            ("requests", Json::num(c.requests as f64)),
                            ("offered", Json::num(c.offered as f64)),
                            ("served", Json::num(c.served as f64)),
                            ("wall_seconds", Json::num(c.wall_seconds)),
                            ("virtual_rps", Json::num(c.virtual_rps)),
                            ("makespan_s", Json::num(c.makespan)),
                            ("router_overhead_frac", Json::num(c.router_overhead_frac)),
                            ("spills", Json::num(c.spills as f64)),
                            (
                                "per_shard_rps",
                                Json::Arr(c.per_shard_rps.iter().map(|&v| Json::num(v)).collect()),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_serve_shard.json"))
        .unwrap_or_else(|| "BENCH_serve_shard.json".into());
    std::fs::write(&path, json.to_string_pretty()).expect("write bench json");
    println!("wrote {}", path.display());
}
