//! Integration: the real executor — scheduled DAGs running actual AOT
//! Pallas/JAX artifacts on the PJRT CPU client.
//!
//! All tests skip (with a note) when `make artifacts` hasn't been run.

use pyschedcl::cost::PaperCost;
use pyschedcl::exec::execute_dag;
use pyschedcl::graph::Partition;
use pyschedcl::platform::{DeviceType, Platform};
use pyschedcl::runtime::Runtime;
use pyschedcl::sched::{Clustering, Eager};
use pyschedcl::transformer::{cluster_by_head, head_dag, transformer_dag, vadd_vsin_dag};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::new(&dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(_) => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

fn rng_vec(seed: u64, len: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
    (0..len)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect()
}

#[test]
fn composed_head_matches_fused_artifact() {
    let Some(rt) = runtime() else { return };
    let beta = 32u64;
    let (dag, io) = head_dag(beta, DeviceType::Gpu);
    let part = cluster_by_head(&dag, std::slice::from_ref(&io), 0);
    let platform = Platform::paper_testbed(3, 1);
    let n = (beta * beta) as usize;

    let x = rng_vec(1, n);
    let ws: Vec<Vec<f32>> = (0..4).map(|i| rng_vec(10 + i, n)).collect();
    let mut inputs = HashMap::new();
    for &xb in &io.x_inputs {
        inputs.insert(xb, x.clone());
    }
    for (&wb, w) in io.weights.iter().zip(&ws) {
        inputs.insert(wb, w.clone());
    }
    let report = execute_dag(
        &dag,
        &part,
        &platform,
        &PaperCost,
        &mut Clustering,
        &rt,
        &inputs,
    )
    .unwrap();
    let got = report.store.host(io.z_output).expect("output read back");
    let fused = rt
        .execute_f32("head_b32", &[&x, &ws[0], &ws[1], &ws[2], &ws[3]])
        .unwrap();
    let max_err = got
        .iter()
        .zip(&fused[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "composed vs fused: max err {max_err}");
}

#[test]
fn multi_head_layer_executes_all_heads() {
    let Some(rt) = runtime() else { return };
    let beta = 32u64;
    let heads = 3;
    let (dag, ios) = transformer_dag(heads, beta, DeviceType::Gpu);
    let part = cluster_by_head(&dag, &ios, 0);
    let platform = Platform::paper_testbed(2, 1);
    let n = (beta * beta) as usize;
    let mut inputs = HashMap::new();
    for (h, io) in ios.iter().enumerate() {
        for &xb in &io.x_inputs {
            inputs.insert(xb, rng_vec(100 + h as u64, n));
        }
        for (w, &wb) in io.weights.iter().enumerate() {
            inputs.insert(wb, rng_vec(200 + (h * 4 + w) as u64, n));
        }
    }
    let report = execute_dag(
        &dag,
        &part,
        &platform,
        &PaperCost,
        &mut Clustering,
        &rt,
        &inputs,
    )
    .unwrap();
    for io in &ios {
        let z = report.store.host(io.z_output).expect("each head read back");
        assert_eq!(z.len(), n);
        assert!(z.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn eager_policy_executes_correctly_too() {
    // Even a "bad" schedule must produce identical numerics.
    let Some(rt) = runtime() else { return };
    let (dag, ks) = vadd_vsin_dag(4096);
    let part = Partition::singletons(&dag);
    let platform = Platform::paper_testbed(1, 1);
    let a = rng_vec(5, 4096);
    let b = rng_vec(6, 4096);
    let mut inputs = HashMap::new();
    inputs.insert(dag.kernels[ks[0]].inputs[0], a.clone());
    inputs.insert(dag.kernels[ks[0]].inputs[1], b.clone());
    let report = execute_dag(
        &dag,
        &part,
        &platform,
        &PaperCost,
        &mut Eager,
        &rt,
        &inputs,
    )
    .unwrap();
    let out = report.store.host(dag.kernels[ks[1]].outputs[0]).unwrap();
    for i in 0..4096 {
        let want = (a[i] + b[i]).sin();
        assert!((out[i] - want).abs() < 1e-5);
    }
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let Some(rt) = runtime() else { return };
    // β=31 has no artifacts: execute_dag must refuse upfront.
    let (dag, io) = head_dag(31, DeviceType::Gpu);
    let part = cluster_by_head(&dag, std::slice::from_ref(&io), 0);
    let platform = Platform::paper_testbed(1, 1);
    let res = execute_dag(
        &dag,
        &part,
        &platform,
        &PaperCost,
        &mut Clustering,
        &rt,
        &HashMap::new(),
    );
    match res {
        Err(err) => assert!(err.to_string().contains("artifact"), "{err}"),
        Ok(_) => panic!("β=31 execution should fail (no artifacts)"),
    }
}

#[test]
fn missing_input_fails_not_hangs() {
    let Some(rt) = runtime() else { return };
    let (dag, _) = vadd_vsin_dag(4096);
    let part = Partition::singletons(&dag);
    let platform = Platform::paper_testbed(1, 1);
    // No inputs seeded: the first write command must fail cleanly.
    let res = execute_dag(
        &dag,
        &part,
        &platform,
        &PaperCost,
        &mut Clustering,
        &rt,
        &HashMap::new(),
    );
    assert!(res.is_err());
}

#[test]
fn repeated_execution_is_reproducible() {
    let Some(rt) = runtime() else { return };
    let (dag, ks) = vadd_vsin_dag(4096);
    let part = Partition::singletons(&dag);
    let platform = Platform::paper_testbed(2, 1);
    let mut inputs = HashMap::new();
    inputs.insert(dag.kernels[ks[0]].inputs[0], rng_vec(9, 4096));
    inputs.insert(dag.kernels[ks[0]].inputs[1], rng_vec(10, 4096));
    let run = || {
        execute_dag(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &rt,
            &inputs,
        )
        .unwrap()
        .store
        .host(dag.kernels[ks[1]].outputs[0])
        .unwrap()
    };
    assert_eq!(run(), run());
}
