//! Integration: spec files → DAG → schedulers → simulator, end to end.

use pyschedcl::cost::PaperCost;
use pyschedcl::graph::Partition;
use pyschedcl::platform::{DeviceType, Platform};
use pyschedcl::sched::{Clustering, Eager, Heft};
use pyschedcl::sim::{simulate, SimConfig};
use pyschedcl::spec::parse_spec;
use pyschedcl::trace::Lane;
use std::path::Path;

fn spec_text(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("specs").join(name);
    std::fs::read_to_string(path).expect("spec file readable")
}

#[test]
fn head_spec_simulates_under_all_policies() {
    let spec = parse_spec(&spec_text("transformer_head_b64.json")).unwrap();
    let platform = Platform::paper_testbed(3, 1);
    let cfg = SimConfig::default();

    let cl = simulate(
        &spec.dag,
        &spec.partition,
        &platform,
        &PaperCost,
        &mut Clustering,
        &cfg,
    )
    .unwrap();
    assert!(cl.makespan > 0.0);

    let singles = Partition::singletons(&spec.dag);
    let p1 = Platform::paper_testbed(1, 1);
    for policy in [
        &mut Eager as &mut dyn pyschedcl::sched::Policy,
        &mut Heft as &mut dyn pyschedcl::sched::Policy,
    ] {
        let r = simulate(&spec.dag, &singles, &p1, &PaperCost, policy, &cfg).unwrap();
        assert!(r.makespan > 0.0);
        // Dynamic coarse-grained schemes must be slower than clustering
        // on this DAG (the paper's core claim).
        assert!(r.makespan > cl.makespan, "{} faster than clustering?", r.policy);
    }
}

#[test]
fn vadd_vsin_spec_round_trip() {
    let spec = parse_spec(&spec_text("vadd_vsin.json")).unwrap();
    assert_eq!(spec.dag.num_kernels(), 2);
    assert_eq!(spec.partition.components.len(), 2);
    let platform = Platform::paper_testbed(2, 1);
    let r = simulate(
        &spec.dag,
        &spec.partition,
        &platform,
        &PaperCost,
        &mut Clustering,
        &SimConfig::default(),
    )
    .unwrap();
    // vsin's component depends on vadd's: strictly ordered spans.
    let span_of = |k: usize| {
        r.trace
            .spans
            .iter()
            .find(|s| s.kernel == Some(k) && matches!(s.lane, Lane::Device { .. }))
            .cloned()
            .unwrap()
    };
    assert!(span_of(1).start >= span_of(0).end);
}

#[test]
fn every_kernel_simulated_exactly_once() {
    let spec = parse_spec(&spec_text("transformer_head_b64.json")).unwrap();
    let platform = Platform::paper_testbed(4, 2);
    let r = simulate(
        &spec.dag,
        &spec.partition,
        &platform,
        &PaperCost,
        &mut Clustering,
        &SimConfig::default(),
    )
    .unwrap();
    for k in 0..spec.dag.num_kernels() {
        let count = r
            .trace
            .spans
            .iter()
            .filter(|s| s.kernel == Some(k) && matches!(s.lane, Lane::Device { .. }))
            .count();
        assert_eq!(count, 1, "kernel {k} simulated {count} times");
    }
}

#[test]
fn simulation_is_deterministic() {
    let spec = parse_spec(&spec_text("transformer_head_b64.json")).unwrap();
    let platform = Platform::paper_testbed(3, 1);
    let run = || {
        simulate(
            &spec.dag,
            &spec.partition,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
        )
        .unwrap()
        .makespan
    };
    assert_eq!(run(), run());
}

#[test]
fn dispatch_respects_topological_order() {
    let spec = parse_spec(&spec_text("transformer_head_b64.json")).unwrap();
    let singles = Partition::singletons(&spec.dag);
    let platform = Platform::paper_testbed(1, 1);
    let r = simulate(
        &spec.dag,
        &singles,
        &platform,
        &PaperCost,
        &mut Heft,
        &SimConfig::default(),
    )
    .unwrap();
    // Def 5 validity: each kernel starts only after all DAG predecessors end.
    let span = |k: usize| {
        r.trace
            .spans
            .iter()
            .find(|s| s.kernel == Some(k) && matches!(s.lane, Lane::Device { .. }))
            .unwrap()
    };
    for k in 0..spec.dag.num_kernels() {
        for p in spec.dag.kernel_preds(k) {
            assert!(
                span(k).start >= span(p).end - 1e-9,
                "kernel {k} started before predecessor {p} finished"
            );
        }
    }
}

#[test]
fn cpu_mapped_component_skips_dma() {
    // Map the whole head to the CPU: no copy-engine spans should appear.
    let text = spec_text("transformer_head_b64.json").replace("\"gpu\"", "\"cpu\"");
    let spec = parse_spec(&text).unwrap();
    let platform = Platform::paper_testbed(1, 2);
    let r = simulate(
        &spec.dag,
        &spec.partition,
        &platform,
        &PaperCost,
        &mut Clustering,
        &SimConfig::default(),
    )
    .unwrap();
    let dma_spans = r
        .trace
        .spans
        .iter()
        .filter(|s| matches!(s.lane, Lane::CopyEngine { .. }))
        .count();
    assert_eq!(dma_spans, 0, "CPU shares host memory: no DMA traffic");
    // And all kernels ran on the CPU device (id 1).
    for s in &r.trace.spans {
        if let Lane::Device { dev, .. } = s.lane {
            assert_eq!(platform.device(dev).dtype, DeviceType::Cpu);
        }
    }
}
