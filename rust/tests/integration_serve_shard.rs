//! Integration: sharded multi-replica serving (ISSUE 10).
//!
//! Pins the tentpole contracts end to end:
//!
//! * `--shards 1` is **byte-identical** to the unsharded [`serve_stream`]
//!   path — same outcomes (bit-for-bit floats), same report.
//! * The router's global in-flight set rejects a duplicate id exactly once
//!   even when the two submissions hash to *different* shards (where each
//!   shard's local check would admit both).
//! * Signature affinity is real: one signature's requests all land on its
//!   affine shard (deterministically predictable from
//!   [`Router::shard_for_signature`]) while the load stays below the spill
//!   threshold.

use pyschedcl::cost::PaperCost;
use pyschedcl::error::Result;
use pyschedcl::sched::{LeastLoaded, Policy};
use pyschedcl::serve::{
    poisson_arrivals, serve_sharded_stream, serve_stream, CollectSink, PlatformShape, Router,
    ServeRequest, ShardSpec, StreamingConfig, Workload,
};

fn stream(seed: u64, n: usize, rate: f64) -> Vec<ServeRequest> {
    poisson_arrivals(seed, n, rate)
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let beta = 64 + 8 * (i as u64 % 16);
            let mut r = ServeRequest::new(i, t, Workload::Head { beta });
            if i % 5 == 0 {
                r.deadline = Some(1.5);
                r.priority = 1;
            }
            r
        })
        .collect()
}

fn factory() -> Result<Box<dyn Policy>> {
    Ok(Box::new(LeastLoaded))
}

#[test]
fn single_shard_run_is_byte_identical_to_serve_stream() {
    let shape = PlatformShape {
        gpus: 2,
        cpus: 1,
        queues_gpu: 3,
        queues_cpu: 1,
    };
    let cfg = StreamingConfig {
        window: 64,
        ..StreamingConfig::default()
    };

    let mut base_sink = CollectSink::default();
    let base = serve_stream(
        stream(21, 120, 1500.0),
        &shape.full(),
        &PaperCost,
        &mut LeastLoaded,
        &cfg,
        &mut base_sink,
    )
    .unwrap();

    let mut shard_sink = CollectSink::default();
    let spec = ShardSpec {
        shards: 1,
        ..ShardSpec::default()
    };
    let sharded = serve_sharded_stream(
        stream(21, 120, 1500.0),
        shape,
        &PaperCost,
        factory,
        &cfg,
        &spec,
        &mut shard_sink,
    )
    .unwrap();
    let m = &sharded.merged;

    // Every emitted outcome matches, field by field, floats bit-for-bit.
    assert_eq!(base_sink.outcomes.len(), shard_sink.outcomes.len());
    for (a, b) in base_sink.outcomes.iter().zip(&shard_sink.outcomes) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        assert_eq!(a.release.to_bits(), b.release.to_bits());
        assert_eq!(a.finish.to_bits(), b.finish.to_bits());
        assert_eq!(a.latency.to_bits(), b.latency.to_bits());
        assert_eq!(a.deadline_met, b.deadline_met);
        assert_eq!(a.priority, b.priority);
    }

    // And the merged report is the single shard's report, unchanged.
    assert_eq!(base.served, m.served);
    assert_eq!(base.rejected, m.rejected);
    assert_eq!(base.shed, m.shed);
    assert_eq!(base.offered, m.offered);
    assert_eq!(base.laxity_rejections, m.laxity_rejections);
    assert_eq!(base.makespan.to_bits(), m.makespan.to_bits());
    assert_eq!(base.throughput_rps.to_bits(), m.throughput_rps.to_bits());
    assert_eq!(base.p50_latency.to_bits(), m.p50_latency.to_bits());
    assert_eq!(base.p99_latency.to_bits(), m.p99_latency.to_bits());
    assert_eq!(base.deadline_total, m.deadline_total);
    assert_eq!(base.deadline_misses, m.deadline_misses);
    assert_eq!(base.preemptions, m.preemptions);
    assert_eq!(base.peak_live_requests, m.peak_live_requests);
    assert_eq!(base.peak_live_components, m.peak_live_components);
    assert_eq!(base.events, m.events);
    assert_eq!(base.template_cache_hits, m.template_cache_hits);
    assert_eq!(base.template_cache_misses, m.template_cache_misses);
    assert_eq!(base.rejected_sample, m.rejected_sample);
    assert_eq!(base.device_util.len(), m.device_util.len());
    for (a, b) in base.device_util.iter().zip(&m.device_util) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // The router stayed out of the way.
    assert_eq!(sharded.shards.len(), 1);
    assert_eq!(sharded.router.spills, 0);
    assert_eq!(sharded.router.duplicate_rejections, 0);
    assert_eq!(sharded.router.routed, vec![120]);
}

#[test]
fn duplicate_ids_across_two_shards_reject_exactly_once() {
    // Pick two betas whose signatures hash to *different* shards, so the
    // duplicate submission reaches the other shard's sub-stream — the one
    // place only the router's global in-flight set can catch it.
    let probe = Router::new(2, 64, None);
    let sig = |beta: u64| Workload::Head { beta }.signature();
    let beta0 = (8u64..64)
        .map(|k| 8 * k)
        .find(|&b| probe.shard_for_signature(&sig(b)) == 0)
        .expect("some signature hashes to shard 0");
    let beta1 = (8u64..64)
        .map(|k| 8 * k)
        .find(|&b| probe.shard_for_signature(&sig(b)) == 1)
        .expect("some signature hashes to shard 1");

    let reqs = vec![
        ServeRequest::new(0, 0.0, Workload::Head { beta: beta0 }),
        ServeRequest::new(1, 1e-4, Workload::Head { beta: beta1 }),
        // Same id as the first but affine to the *other* shard.
        ServeRequest::new(0, 2e-4, Workload::Head { beta: beta1 }),
        ServeRequest::new(2, 3e-4, Workload::Head { beta: beta0 }),
    ];
    let shape = PlatformShape {
        gpus: 2,
        cpus: 2,
        queues_gpu: 3,
        queues_cpu: 1,
    };
    let spec = ShardSpec {
        shards: 2,
        ..ShardSpec::default()
    };
    let mut sink = CollectSink::default();
    let r = serve_sharded_stream(
        reqs,
        shape,
        &PaperCost,
        factory,
        &StreamingConfig::default(),
        &spec,
        &mut sink,
    )
    .unwrap();
    let m = &r.merged;

    // Exactly once: the duplicate is offered-and-rejected globally, the
    // three distinct requests all serve.
    assert_eq!(r.router.duplicate_rejections, 1);
    assert_eq!(m.offered, 4);
    assert_eq!(m.rejected, 1);
    assert_eq!(m.served, 3);
    assert_eq!(m.shed, 0);
    assert_eq!(m.served + m.rejected + m.shed, m.offered, "conservation");
    assert_eq!(sink.outcomes.len(), 3);
    assert!(
        m.rejected_sample
            .iter()
            .any(|(id, why)| *id == 0 && why.contains("router")),
        "rejection sample names the router: {:?}",
        m.rejected_sample
    );
}

#[test]
fn one_signature_stays_on_its_affine_shard() {
    // 40 same-signature requests, spill threshold 64: depth never crosses
    // the threshold, so every request lands on the signature's affine
    // shard — predicted, deterministically, by shard_for_signature.
    let shape = PlatformShape {
        gpus: 4,
        cpus: 2,
        queues_gpu: 3,
        queues_cpu: 1,
    };
    let spec = ShardSpec {
        shards: 2,
        ..ShardSpec::default()
    };
    let affine = Router::new(2, 64, None)
        .shard_for_signature(&Workload::Head { beta: 64 }.signature());
    let reqs: Vec<ServeRequest> = poisson_arrivals(5, 40, 1000.0)
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, t)| ServeRequest::new(i, t, Workload::Head { beta: 64 }))
        .collect();
    let mut sink = CollectSink::default();
    let r = serve_sharded_stream(
        reqs,
        shape,
        &PaperCost,
        factory,
        &StreamingConfig::default(),
        &spec,
        &mut sink,
    )
    .unwrap();
    assert_eq!(r.router.spills, 0);
    assert_eq!(r.router.routed[affine], 40);
    assert_eq!(r.router.routed[1 - affine], 0);
    assert_eq!(r.shards[affine].served, 40);
    assert_eq!(r.shards[1 - affine].served, 0);
    // Cache affinity is the payoff: the idle shard built nothing.
    assert_eq!(r.shards[1 - affine].template_cache_misses, 0);
}
