//! Property-based invariants over randomly generated DAGs, partitions and
//! platform configurations.
//!
//! The environment is offline (no proptest crate), so this file carries a
//! small deterministic harness: an xorshift64* generator drives structured
//! random cases; every failure message embeds the seed for replay.

use pyschedcl::cost::{CostModel, PaperCost};
use pyschedcl::graph::{Dag, DagBuilder, Partition};
use pyschedcl::platform::{Device, DeviceType, Platform};
use pyschedcl::queue::{setup_cq, CommandKind};
use pyschedcl::sched::{Clustering, Eager, Heft};
use pyschedcl::sim::{simulate, SimConfig};
use pyschedcl::trace::Lane;

// ------------------------------------------------------------- mini-harness

#[derive(Clone)]
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15).max(1))
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
    fn chance(&mut self, p_percent: usize) -> bool {
        self.below(100) < p_percent
    }
}

/// Random layered DAG: every cross-layer edge points forward, inputs have at
/// most one producer, sizes/flops vary by op.
fn random_dag(rng: &mut Rng) -> Dag {
    let layers = 2 + rng.below(4);
    let mut b = DagBuilder::new();
    let mut outputs: Vec<usize> = Vec::new(); // buffer ids of prior outputs
    let mut claimed: Vec<usize> = Vec::new(); // outputs already consumed
    for _ in 0..layers {
        let width = 1 + rng.below(3);
        let mut layer_outputs = Vec::new();
        for _ in 0..width {
            let (name, flops) = match rng.below(4) {
                0 => ("gemm", 2 * 64 * 64 * 64),
                1 => ("softmax", 5 * 64 * 64),
                2 => ("transpose", 64 * 64),
                _ => ("vadd", 64 * 64),
            };
            let dev = if rng.chance(70) {
                DeviceType::Gpu
            } else {
                DeviceType::Cpu
            };
            let k = b.kernel(name, dev, flops as u64, 3 * 4 * 64 * 64);
            let n_in = 1 + rng.below(2);
            for _ in 0..n_in {
                let bi = b.in_buf(k, 4 * 64 * 64);
                // Link to a random unclaimed earlier output half the time.
                if !outputs.is_empty() && rng.chance(60) {
                    let cand = outputs[rng.below(outputs.len())];
                    if !claimed.contains(&cand) {
                        b.edge(cand, bi);
                        claimed.push(cand);
                    }
                }
            }
            layer_outputs.push(b.out_buf(k, 4 * 64 * 64));
        }
        outputs.extend(layer_outputs);
    }
    b.build().expect("layered DAG is valid by construction")
}

/// Random partition from topo-order slices (cross-slice edges always point
/// forward, so the component graph is acyclic by construction).
fn random_partition(rng: &mut Rng, dag: &Dag) -> Partition {
    let order = pyschedcl::graph::topo_order(dag);
    let mut groups: Vec<(Vec<usize>, DeviceType)> = Vec::new();
    let mut i = 0;
    while i < order.len() {
        let take = (1 + rng.below(4)).min(order.len() - i);
        let ks: Vec<usize> = order[i..i + take].to_vec();
        let dev = if rng.chance(70) {
            DeviceType::Gpu
        } else {
            DeviceType::Cpu
        };
        groups.push((ks, dev));
        i += take;
    }
    Partition::new(dag, groups).expect("slice partition is valid")
}

const CASES: u64 = 60;

// ---------------------------------------------------------------- properties

#[test]
fn prop_setup_cq_invariants() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let dag = random_dag(&mut rng);
        let part = random_partition(&mut rng, &dag);
        for cid in 0..part.components.len() {
            let dev = if part.components[cid].dev == DeviceType::Gpu {
                Device::gtx970(0, 1 + rng.below(5))
            } else {
                Device::i5_4690k(1, 1 + rng.below(5))
            };
            let cq = setup_cq(&dag, &part, cid, &dev);
            cq.check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed} comp {cid}: {e}"));
            // Exactly one ndrange per member kernel.
            for &k in &part.components[cid].kernels {
                let nd = cq
                    .commands
                    .iter()
                    .filter(|c| c.kernel == k && c.is_ndrange())
                    .count();
                assert_eq!(nd, 1, "seed {seed}: kernel {k} has {nd} ndranges");
            }
        }
    }
}

#[test]
fn prop_no_redundant_intra_transfers() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let dag = random_dag(&mut rng);
        let part = random_partition(&mut rng, &dag);
        for cid in 0..part.components.len() {
            let dev = Device::gtx970(0, 2);
            let cq = setup_cq(&dag, &part, cid, &dev);
            for c in &cq.commands {
                match c.kind {
                    CommandKind::Write { buffer } => {
                        // A write is justified iff isolated, or fed by an
                        // inter edge into a FRONT kernel.
                        if let Some(p) = dag.buffer_pred(buffer) {
                            let pc = part.assignment[dag.buffers[p].kernel];
                            assert_ne!(
                                pc, cid,
                                "seed {seed}: intra-resident buffer {buffer} re-written"
                            );
                        }
                    }
                    CommandKind::Read { buffer } => {
                        // A read is justified iff isolated, or consumed by a
                        // different component.
                        let succs = dag.buffer_succs(buffer);
                        if !succs.is_empty() {
                            assert!(
                                succs.iter().any(|&s| {
                                    part.assignment[dag.buffers[s].kernel] != cid
                                }),
                                "seed {seed}: intra-only buffer {buffer} read back"
                            );
                        }
                    }
                    CommandKind::NdRange => {}
                }
            }
        }
    }
}

#[test]
fn prop_simulation_executes_every_kernel_in_topo_order() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let dag = random_dag(&mut rng);
        let part = random_partition(&mut rng, &dag);
        let platform = Platform::paper_testbed(1 + rng.below(5), 1 + rng.below(3));
        let r = simulate(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
        )
        .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let span = |k: usize| {
            r.trace
                .spans
                .iter()
                .find(|s| s.kernel == Some(k) && matches!(s.lane, Lane::Device { .. }))
                .unwrap_or_else(|| panic!("seed {seed}: kernel {k} never ran"))
        };
        for k in 0..dag.num_kernels() {
            let count = r
                .trace
                .spans
                .iter()
                .filter(|s| s.kernel == Some(k) && matches!(s.lane, Lane::Device { .. }))
                .count();
            assert_eq!(count, 1, "seed {seed}: kernel {k} ran {count} times");
            for p in dag.kernel_preds(k) {
                assert!(
                    span(k).start >= span(p).end - 1e-9,
                    "seed {seed}: kernel {k} before pred {p}"
                );
            }
        }
    }
}

#[test]
fn prop_makespan_at_least_critical_path() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let dag = random_dag(&mut rng);
        let part = random_partition(&mut rng, &dag);
        let platform = Platform::paper_testbed(1 + rng.below(5), 1 + rng.below(3));
        let r = simulate(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
        )
        .unwrap();
        // Lower bound: critical path under per-kernel best-device solo time
        // (contention and queues can only slow kernels down).
        let weights: Vec<f64> = dag
            .kernels
            .iter()
            .map(|k| {
                platform
                    .devices
                    .iter()
                    .map(|d| PaperCost.exec_time(k, d))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let cp = pyschedcl::graph::rank::critical_path(&dag, &weights);
        assert!(
            r.makespan >= cp - 1e-9,
            "seed {seed}: makespan {} < critical path {cp}",
            r.makespan
        );
    }
}

#[test]
fn prop_dynamic_policies_also_complete() {
    for seed in 0..CASES / 2 {
        let mut rng = Rng::new(seed);
        let dag = random_dag(&mut rng);
        let singles = Partition::singletons(&dag);
        let platform = Platform::paper_testbed(1, 1);
        for policy in [
            &mut Eager as &mut dyn pyschedcl::sched::Policy,
            &mut Heft as &mut dyn pyschedcl::sched::Policy,
        ] {
            let r = simulate(&dag, &singles, &platform, &PaperCost, policy, &SimConfig::default())
                .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", policy.name()));
            let ran = r
                .trace
                .spans
                .iter()
                .filter(|s| matches!(s.lane, Lane::Device { .. }))
                .count();
            assert_eq!(ran, dag.num_kernels(), "seed {seed} {}", r.policy);
        }
    }
}

#[test]
fn prop_fine_grained_never_slower_than_serialized_same_mapping() {
    // More queues on the same device may reorder but must not increase the
    // makespan beyond noise (the paper's core premise at fixed mapping).
    for seed in 0..CASES / 2 {
        let mut rng = Rng::new(seed);
        let dag = random_dag(&mut rng);
        // Whole DAG as one GPU component, like the motivation example.
        let all: Vec<usize> = (0..dag.num_kernels()).collect();
        let part = Partition::new(&dag, vec![(all, DeviceType::Gpu)]).unwrap();
        let run = |q: usize| {
            simulate(
                &dag,
                &part,
                &Platform::paper_testbed(q, 0),
                &PaperCost,
                &mut Clustering,
                &SimConfig::default(),
            )
            .unwrap()
            .makespan
        };
        let coarse = run(1);
        let fine = run(4);
        assert!(
            fine <= coarse * 1.02,
            "seed {seed}: fine {fine} vs coarse {coarse}"
        );
    }
}

#[test]
fn prop_queue_structures_execute_all_commands() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let dag = random_dag(&mut rng);
        let part = random_partition(&mut rng, &dag);
        let platform = Platform::paper_testbed(3, 2);
        let r = simulate(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
        )
        .unwrap();
        // Every component finished with a recorded device + finish time.
        for (c, t) in r.component_finish.iter().enumerate() {
            assert!(t.is_finite(), "seed {seed}: component {c} never finished");
        }
    }
}

// ------------------------------------------ scheduler-state reconstruction

#[test]
fn prop_sched_state_rebuilds_equal_incremental_state() {
    // The fuzz oracle drives a random ready/dispatch/complete/preempt event
    // stream against an incrementally maintained `SchedState`, periodically
    // rebuilding a fresh state from the recorded chronology and comparing
    // heads, ranks, frontier membership, and invariants. Runnable standalone
    // of the full fuzzer: `cargo test prop_sched_state_rebuilds`.
    for seed in 0..CASES {
        let stats = pyschedcl::sched::fuzz::fuzz_state_events(seed, 120)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(stats.steps >= 120, "seed {seed}: only {} steps", stats.steps);
        assert!(stats.rebuilds > 0, "seed {seed}: oracle never rebuilt");
    }
}

// ------------------------------------------------- serving-layer batching

/// Random request stream: arrival-sorted, signatures drawn from a small
/// workload pool, occasional simultaneous arrivals.
fn random_stream(rng: &mut Rng, n: usize) -> Vec<pyschedcl::serve::ServeRequest> {
    use pyschedcl::serve::{ServeRequest, Workload};
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            if !rng.chance(20) {
                t += rng.below(30) as f64 * 1e-4; // 0..3 ms gaps
            }
            let workload = match rng.below(3) {
                0 => Workload::Head { beta: 64 },
                1 => Workload::Head { beta: 128 },
                _ => Workload::Mm2 { beta: 64 },
            };
            ServeRequest::new(i, t, workload)
        })
        .collect()
}

#[test]
fn prop_batching_invariants() {
    use pyschedcl::serve::batch_requests;
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 1000);
        let n = 1 + rng.below(24);
        let requests = random_stream(&mut rng, n);
        let window = [0.0, 1e-3, 5e-3][rng.below(3)];
        let batches = batch_requests(&requests, window);

        // Every request lands in exactly one batch.
        let mut seen = vec![0usize; n];
        for b in &batches {
            for &m in &b.members {
                seen[m] += 1;
            }
        }
        assert!(
            seen.iter().all(|&c| c == 1),
            "seed {seed}: membership counts {seen:?}"
        );

        for (bi, b) in batches.iter().enumerate() {
            // Members stay in arrival (index) order within a batch.
            assert!(
                b.members.windows(2).all(|w| w[0] < w[1]),
                "seed {seed}: batch {bi} members unsorted {:?}",
                b.members
            );
            // Release = max member arrival (never travels back in time).
            let max_arrival = b
                .members
                .iter()
                .map(|&m| requests[m].arrival)
                .fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(
                b.release, max_arrival,
                "seed {seed}: batch {bi} release mismatch"
            );
            // No cross-signature mixing.
            let sig = requests[b.members[0]].workload.signature();
            assert!(
                b.members
                    .iter()
                    .all(|&m| requests[m].workload.signature() == sig),
                "seed {seed}: batch {bi} mixes signatures"
            );
            // Every member arrives within `window` of the batch opener.
            let opener = requests[b.members[0]].arrival;
            assert!(
                b.members
                    .iter()
                    .all(|&m| requests[m].arrival <= opener + window),
                "seed {seed}: batch {bi} exceeds its window"
            );
        }

        // window = 0 disables coalescing entirely.
        if window == 0.0 {
            assert_eq!(batches.len(), n, "seed {seed}: zero window must singleton");
        }
    }
}

#[test]
fn prop_interleaved_signatures_coalesce_per_signature() {
    // For any stream, batching must be *at least* as dense as per-signature
    // sub-streams batched independently would be fragmented by a
    // single-open-batch scheme: count batches per signature and check each
    // equals batching that signature's sub-stream alone.
    use pyschedcl::serve::batch_requests;
    for seed in 0..CASES {
        let mut rng = Rng::new(seed + 5000);
        let n = 2 + rng.below(20);
        let requests = random_stream(&mut rng, n);
        let window = 2e-3;
        let batches = batch_requests(&requests, window);
        let mut sigs: Vec<String> = requests
            .iter()
            .map(|r| r.workload.signature())
            .collect::<Vec<_>>();
        sigs.sort();
        sigs.dedup();
        for sig in &sigs {
            let sub: Vec<pyschedcl::serve::ServeRequest> = requests
                .iter()
                .filter(|r| r.workload.signature() == *sig)
                .cloned()
                .collect();
            let sub_batches = batch_requests(&sub, window);
            let full_count = batches
                .iter()
                .filter(|b| requests[b.members[0]].workload.signature() == *sig)
                .count();
            assert_eq!(
                full_count,
                sub_batches.len(),
                "seed {seed}: signature {sig} fragmented by interleaving"
            );
        }
    }
}
