//! Property test (ISSUE 5 acceptance): on randomized frontier event
//! streams — enter / dispatch / complete / preempt at random instants,
//! with randomized deadlines (including exact bitwise ties), priorities,
//! and mixed device preferences — every **indexed** policy must produce
//! exactly the `(component, device)` decision sequence of its view-based
//! reference twin, and EDF must pick identical preemption victims.
//!
//! The indexed side drives a live [`SchedState`] through its event API;
//! the reference side maintains the pre-PR-5 scheduler bookkeeping (a
//! rank-sorted frontier `Vec` with binary insertion, an order-preserving
//! available `Vec`) and materializes a `SchedView` per decision — the
//! exact structures the old engines owned.

use pyschedcl::cost::PaperCost;
use pyschedcl::graph::{Dag, Partition};
use pyschedcl::platform::{DeviceId, Platform};
use pyschedcl::sched::{component_ranks, reference, ResidentTenant, SchedState};
use pyschedcl::serve::{merge_apps, Workload};

/// xorshift64* — deterministic, dependency-free.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut s = self.0;
        s ^= s >> 12;
        s ^= s << 25;
        s ^= s >> 27;
        self.0 = s;
        s.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }

    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// The pre-PR-5 scheduler bookkeeping, verbatim semantics: rank-sorted
/// frontier with stable binary insertion, FIFO available set.
struct Mirror {
    frontier: Vec<usize>,
    available: Vec<DeviceId>,
    est_free: Vec<f64>,
    device_load: Vec<f64>,
    tenants: Vec<usize>,
    comp_rank: Vec<f64>,
    tenancy: usize,
}

impl Mirror {
    fn new(platform: &Platform, comp_rank: Vec<f64>, tenancy: usize) -> Mirror {
        let ndev = platform.devices.len();
        Mirror {
            frontier: Vec::new(),
            available: platform
                .devices
                .iter()
                .filter(|d| d.num_queues > 0)
                .map(|d| d.id)
                .collect(),
            est_free: vec![0.0; ndev],
            device_load: vec![0.0; ndev],
            tenants: vec![0; ndev],
            comp_rank,
            tenancy,
        }
    }

    fn enter(&mut self, comp: usize) {
        if self.frontier.contains(&comp) {
            return;
        }
        let rank = self.comp_rank[comp];
        let ranks = &self.comp_rank;
        let idx = self
            .frontier
            .partition_point(|&c| ranks[c].total_cmp(&rank).is_ge());
        self.frontier.insert(idx, comp);
    }

    fn dispatch(&mut self, comp: usize, dev: DeviceId) {
        self.frontier.retain(|&c| c != comp);
        self.tenants[dev] += 1;
        if self.tenants[dev] >= self.tenancy {
            self.available.retain(|&d| d != dev);
        }
    }

    fn free(&mut self, dev: DeviceId) {
        self.tenants[dev] -= 1;
        if !self.available.contains(&dev) {
            self.available.push(dev);
        }
    }
}

/// Mixed-preference component pool: heads (GPU), mm2 chains (GPU), and a
/// layer with one CPU-preferring head.
fn mixed_app(n_blocks: usize) -> (Dag, Partition) {
    let workloads = [
        Workload::Head { beta: 64 },
        Workload::Mm2 { beta: 64 },
        Workload::Layer {
            heads: 2,
            beta: 64,
            h_cpu: 1,
        },
    ];
    let apps: Vec<_> = (0..n_blocks)
        .map(|i| workloads[i % workloads.len()].instantiate().unwrap())
        .collect();
    let merged = merge_apps(&apps).unwrap();
    (merged.dag, merged.partition)
}

/// Deadline pool with forced exact bitwise ties plus ∞, and priorities
/// 0..=3 — exercises every branch of the urgency order.
fn random_meta(rng: &mut Rng, ncomp: usize) -> (Vec<f64>, Vec<u32>) {
    let pool = [
        f64::INFINITY,
        f64::INFINITY,
        0.2,
        0.35,
        0.35, // exact tie with the previous entry
        0.5,
    ];
    let deadline = (0..ncomp).map(|_| pool[rng.below(pool.len())]).collect();
    let priority = (0..ncomp).map(|_| rng.below(4) as u32).collect();
    (deadline, priority)
}

enum Pair {
    Clustering,
    Eager,
    Heft,
    LeastLoaded,
    Edf,
}

impl Pair {
    fn indexed(&self) -> Box<dyn pyschedcl::sched::Policy> {
        match self {
            Pair::Clustering => Box::new(pyschedcl::sched::Clustering),
            Pair::Eager => Box::new(pyschedcl::sched::Eager),
            Pair::Heft => Box::new(pyschedcl::sched::Heft),
            Pair::LeastLoaded => Box::new(pyschedcl::sched::LeastLoaded),
            Pair::Edf => Box::new(pyschedcl::sched::Edf),
        }
    }

    fn view_based(&self) -> Box<dyn reference::Policy> {
        match self {
            Pair::Clustering => Box::new(reference::Clustering),
            Pair::Eager => Box::new(reference::Eager),
            Pair::Heft => Box::new(reference::Heft),
            Pair::LeastLoaded => Box::new(reference::LeastLoaded),
            Pair::Edf => Box::new(reference::Edf),
        }
    }
}

/// Drive one policy pair over one randomized event stream, asserting the
/// decision sequences match at every step. Returns the number of
/// dispatches and preemptions the stream produced (so callers can assert
/// the streams actually exercised the machinery).
fn drive(pair: &Pair, seed: u64, steps: usize, tenancy: usize) -> (usize, usize) {
    let (dag, part) = mixed_app(6);
    let platform = Platform::scaled(2, 1, 3, 1);
    let ncomp = part.components.len();
    let mut rng = Rng(seed | 1);
    let (deadline, priority) = random_meta(&mut rng, ncomp);

    let mut new_pol = pair.indexed();
    let mut old_pol = pair.view_based();
    let mut st = SchedState::new(
        &dag,
        &part,
        &platform,
        &PaperCost,
        tenancy,
        deadline.clone(),
        priority.clone(),
    )
    .unwrap();
    let comp_rank = component_ranks(&dag, &part, &platform, &PaperCost);
    let mut mir = Mirror::new(&platform, comp_rank, tenancy);

    let mut dispatched = vec![false; ncomp];
    let mut resident: Vec<(usize, DeviceId)> = Vec::new();
    let mut now = 0.0f64;
    let mut dispatches = 0usize;
    let mut preemptions = 0usize;

    for step in 0..steps {
        // --- one random event ---
        match rng.below(4) {
            0 | 3 => {
                // A component becomes ready (release/unblock).
                let candidates: Vec<usize> = (0..ncomp)
                    .filter(|&c| !dispatched[c] && !st.in_frontier(c))
                    .collect();
                if !candidates.is_empty() {
                    let c = candidates[rng.below(candidates.len())];
                    st.on_ready(c);
                    mir.enter(c);
                }
            }
            1 => {
                // A resident component completes.
                if !resident.is_empty() {
                    let i = rng.below(resident.len());
                    let (_, dev) = resident.swap_remove(i);
                    st.on_complete(dev);
                    mir.free(dev);
                    let frac = st.tenants[dev] as f64 / tenancy as f64;
                    st.device_load[dev] = frac;
                    mir.device_load[dev] = frac;
                    if st.tenants[dev] == 0 {
                        st.est_free[dev] = now;
                        mir.est_free[dev] = now;
                    }
                }
            }
            _ => {
                // Time advances.
                now += rng.f64() * 0.01;
            }
        }

        // --- drain: both sides must agree on every decision ---
        loop {
            st.now = now;
            let view = reference::SchedView {
                now,
                frontier: &mir.frontier,
                available: &mir.available,
                platform: &platform,
                partition: &part,
                dag: &dag,
                est_free: &mir.est_free,
                device_load: &mir.device_load,
                deadline: &deadline,
                priority: &priority,
                cost: &PaperCost,
            };
            let old = old_pol.select(&view);
            let new = new_pol.select(&mut st);
            assert_eq!(
                new, old,
                "decision diverged (policy step {step}, seed {seed}): \
                 indexed {new:?} vs reference {old:?}\n frontier={:?}",
                mir.frontier
            );
            let Some((comp, dev)) = new else { break };
            st.on_dispatch(comp, dev);
            mir.dispatch(comp, dev);
            dispatched[comp] = true;
            resident.push((comp, dev));
            dispatches += 1;
            // Identical EFT/load bookkeeping on both sides.
            let device = platform.device(dev);
            let solo: f64 = part.components[comp]
                .kernels
                .iter()
                .map(|&k| PaperCost.exec_time(&dag.kernels[k], device))
                .sum();
            let booked = mir.est_free[dev].max(now) + solo;
            st.est_free[dev] = booked;
            mir.est_free[dev] = booked;
            let frac = st.tenants[dev] as f64 / tenancy as f64;
            st.device_load[dev] = frac;
            mir.device_load[dev] = frac;
        }

        // --- blocked: compare preemption verdicts ---
        if new_pol.can_preempt() && !mir.frontier.is_empty() && !resident.is_empty() {
            let mut tenants_list: Vec<ResidentTenant> = resident
                .iter()
                .map(|&(comp, device)| ResidentTenant { comp, device })
                .collect();
            tenants_list.sort_by_key(|r| r.comp);
            let view = reference::SchedView {
                now,
                frontier: &mir.frontier,
                available: &mir.available,
                platform: &platform,
                partition: &part,
                dag: &dag,
                est_free: &mir.est_free,
                device_load: &mir.device_load,
                deadline: &deadline,
                priority: &priority,
                cost: &PaperCost,
            };
            let old_v = old_pol.preempt(&view, &tenants_list);
            st.now = now;
            let new_v = new_pol.preempt(&mut st, &tenants_list);
            assert_eq!(
                new_v, old_v,
                "preemption verdict diverged (step {step}, seed {seed})"
            );
            if let Some(victim) = new_v {
                let i = resident
                    .iter()
                    .position(|&(c, _)| c == victim)
                    .expect("victim must be resident");
                let (_, dev) = resident.swap_remove(i);
                st.on_preempt(dev);
                mir.free(dev);
                dispatched[victim] = false;
                st.est_free[dev] = now;
                mir.est_free[dev] = now;
                let frac = st.tenants[dev] as f64 / tenancy as f64;
                st.device_load[dev] = frac;
                mir.device_load[dev] = frac;
                st.on_ready(victim);
                mir.enter(victim);
                preemptions += 1;
            }
        }
    }
    (dispatches, preemptions)
}

#[test]
fn clustering_decisions_match_reference_on_random_streams() {
    for seed in [3, 17, 91] {
        let (d, _) = drive(&Pair::Clustering, seed, 300, 2);
        assert!(d > 0, "stream produced no dispatches (seed {seed})");
    }
}

#[test]
fn eager_decisions_match_reference_on_random_streams() {
    for seed in [5, 23, 77] {
        let (d, _) = drive(&Pair::Eager, seed, 300, 2);
        assert!(d > 0, "stream produced no dispatches (seed {seed})");
    }
}

#[test]
fn heft_decisions_match_reference_on_random_streams() {
    for seed in [7, 29, 63] {
        let (d, _) = drive(&Pair::Heft, seed, 300, 2);
        assert!(d > 0, "stream produced no dispatches (seed {seed})");
    }
}

#[test]
fn least_loaded_decisions_match_reference_on_random_streams() {
    for seed in [11, 31, 59] {
        let (d, _) = drive(&Pair::LeastLoaded, seed, 300, 2);
        assert!(d > 0, "stream produced no dispatches (seed {seed})");
    }
}

#[test]
fn edf_decisions_and_preemptions_match_reference_on_random_streams() {
    let mut total_preempts = 0usize;
    for seed in [13, 37, 83, 113] {
        let (d, p) = drive(&Pair::Edf, seed, 400, 1);
        assert!(d > 0, "stream produced no dispatches (seed {seed})");
        total_preempts += p;
    }
    // Exclusive tenancy + mixed urgency metadata must displace someone at
    // least once across the seeds, or the preempt path went untested.
    assert!(total_preempts > 0, "no preemption was ever exercised");
}
