//! Property-based conservation invariants for fault-injected serving
//! (ISSUE 9): across random request streams × fault plans × admission
//! windows, every offered request is accounted for —
//! `served + rejected + shed == offered` — crash retries never exceed the
//! plan's budget, fault-free runs never report fault bookkeeping, and a
//! zero-event plan is bitwise-identical to no plan at all.
//!
//! The environment is offline (no proptest crate), so this file carries
//! the repo's small deterministic harness: an xorshift64* generator
//! drives structured random cases; every failure message embeds the seed
//! for replay.

use pyschedcl::cost::PaperCost;
use pyschedcl::fault::{FaultEvent, FaultKind, FaultPlan};
use pyschedcl::platform::Platform;
use pyschedcl::sched::{Edf, LeastLoaded, Policy};
use pyschedcl::serve::{serve_stream, NullSink, ServeRequest, StreamingConfig, Workload};

// ------------------------------------------------------------- mini-harness

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15).max(1))
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

/// The fuzzer's coarse time lattice: gridded gaps make same-instant
/// arrivals common, so fault instants collide with releases for real.
const GRID: f64 = 1.5e-3;

/// A random arrival-ordered stream: gridded inter-arrival gaps (including
/// zero — simultaneous arrivals), mixed head widths, most requests
/// carrying a finite relative deadline, priorities spread over 0..3.
fn random_requests(rng: &mut Rng, n: usize) -> Vec<ServeRequest> {
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.below(4) as f64 * GRID;
            let beta = [32u64, 64, 128][rng.below(3)];
            let mut r = ServeRequest::new(i, t, Workload::Head { beta });
            if rng.below(3) != 0 {
                r.deadline = Some((1 + rng.below(4)) as f64 * 0.02);
            }
            r.priority = rng.below(3) as u32;
            r
        })
        .collect()
}

/// The fault plans a case sweeps, all survivable (at least one device of
/// the 2-GPU/1-CPU platform stays up): no plan, a single mid-run crash, a
/// wedge+slowdown pair, a double crash leaving only the CPU, and a
/// zero-budget crash that forces the shed path.
fn plans(rng: &mut Rng) -> Vec<Option<FaultPlan>> {
    let crash_at = (1 + rng.below(4)) as f64 * GRID;
    let single = FaultPlan {
        events: vec![FaultEvent {
            device: rng.below(2),
            at: crash_at,
            kind: FaultKind::Crash,
        }],
        retry_budget: 2,
        backoff_base: 1e-4,
        ..FaultPlan::default()
    };
    let wedge_slow = FaultPlan {
        events: vec![
            FaultEvent {
                device: rng.below(3),
                at: (1 + rng.below(3)) as f64 * GRID,
                kind: FaultKind::Wedge { dur: 2.0 * GRID },
            },
            FaultEvent {
                device: rng.below(3),
                at: (1 + rng.below(4)) as f64 * GRID,
                kind: FaultKind::Slowdown { factor: 0.5 },
            },
        ],
        retry_budget: 3,
        backoff_base: 1e-4,
        ..FaultPlan::default()
    };
    let double = FaultPlan {
        events: vec![
            FaultEvent {
                device: 0,
                at: crash_at,
                kind: FaultKind::Crash,
            },
            FaultEvent {
                device: 1,
                at: crash_at + GRID,
                kind: FaultKind::Crash,
            },
        ],
        retry_budget: 2,
        backoff_base: 1e-4,
        ..FaultPlan::default()
    };
    let no_budget = FaultPlan {
        events: vec![FaultEvent {
            device: rng.below(2),
            at: crash_at,
            kind: FaultKind::Crash,
        }],
        retry_budget: 0,
        backoff_base: 0.0,
        ..FaultPlan::default()
    };
    vec![
        None,
        Some(single.normalized().expect("single crash plan")),
        Some(wedge_slow.normalized().expect("wedge+slowdown plan")),
        Some(double.normalized().expect("double crash plan")),
        Some(no_budget.normalized().expect("zero-budget plan")),
    ]
}

fn run_case(
    requests: &[ServeRequest],
    plan: Option<&FaultPlan>,
    window: usize,
    use_edf: bool,
    ctx: &str,
) {
    let platform = Platform::scaled(2, 1, 2, 1);
    let cfg = StreamingConfig {
        window,
        faults: plan.cloned(),
        ..StreamingConfig::default()
    };
    let mut edf = Edf;
    let mut ll = LeastLoaded;
    let policy: &mut dyn Policy = if use_edf { &mut edf } else { &mut ll };
    let report = serve_stream(
        requests.to_vec(),
        &platform,
        &PaperCost,
        policy,
        &cfg,
        &mut NullSink,
    )
    .unwrap_or_else(|e| panic!("{ctx}: serve_stream failed: {e}"));

    assert_eq!(report.offered, requests.len(), "{ctx}: offered != sent");
    assert_eq!(
        report.served + report.rejected + report.shed,
        report.offered,
        "{ctx}: conservation violated ({} served + {} rejected + {} shed != {} offered)",
        report.served,
        report.rejected,
        report.shed,
        report.offered
    );
    if window > 0 {
        assert!(
            report.peak_live_requests <= window,
            "{ctx}: window breached ({} live > {window})",
            report.peak_live_requests
        );
    }
    match plan {
        Some(p) => assert!(
            report.max_retries <= p.retry_budget,
            "{ctx}: retry budget breached ({} > {})",
            report.max_retries,
            p.retry_budget
        ),
        None => {
            assert_eq!(report.shed, 0, "{ctx}: shed without a fault plan");
            assert_eq!(report.max_retries, 0, "{ctx}: retries without a fault plan");
        }
    }
}

// ------------------------------------------------------------------- props

#[test]
fn conservation_holds_across_seeds_plans_and_windows() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed);
        let n = 8 + rng.below(17); // 8..=24 requests
        let requests = random_requests(&mut rng, n);
        for (pi, plan) in plans(&mut rng).iter().enumerate() {
            for &window in &[0usize, 4, 16] {
                let ctx = format!("seed {seed} plan {pi} window {window}");
                run_case(&requests, plan.as_ref(), window, seed % 2 == 0, &ctx);
            }
        }
    }
}

#[test]
fn crashing_every_device_still_accounts_for_every_request() {
    let mut rng = Rng::new(97);
    let requests = random_requests(&mut rng, 12);
    let plan = FaultPlan {
        events: (0..3)
            .map(|d| FaultEvent {
                device: d,
                at: 2.0 * GRID,
                kind: FaultKind::Crash,
            })
            .collect(),
        retry_budget: 1,
        backoff_base: 1e-4,
        ..FaultPlan::default()
    }
    .normalized()
    .expect("all-down plan");
    run_case(&requests, Some(&plan), 8, true, "all-down");
}

#[test]
fn zero_event_plan_is_bitwise_identical_to_no_plan() {
    for seed in [3u64, 11, 42] {
        let mut rng = Rng::new(seed);
        // Loose 10 s deadlines: installing a plan (even an empty one) arms
        // the deadline-aware queue shedder, so an expirable deadline could
        // legitimately diverge the two runs. With nothing expirable the
        // zero-event plan must be bit-for-bit the fault-free build.
        let mut requests = random_requests(&mut rng, 16);
        for r in &mut requests {
            if r.deadline.is_some() {
                r.deadline = Some(10.0);
            }
        }
        let platform = Platform::scaled(2, 1, 2, 1);
        let run = |faults: Option<FaultPlan>| {
            let cfg = StreamingConfig {
                window: 8,
                faults,
                ..StreamingConfig::default()
            };
            serve_stream(
                requests.clone(),
                &platform,
                &PaperCost,
                &mut Edf,
                &cfg,
                &mut NullSink,
            )
            .expect("serve")
        };
        let plain = run(None);
        let empty = run(Some(FaultPlan::default().normalized().expect("empty plan")));
        assert_eq!(
            plain.makespan.to_bits(),
            empty.makespan.to_bits(),
            "seed {seed}: makespan drifted under a zero-event plan"
        );
        assert_eq!(plain.served, empty.served, "seed {seed}: served drifted");
        assert_eq!(plain.rejected, empty.rejected, "seed {seed}: rejected drifted");
        assert_eq!(plain.preemptions, empty.preemptions, "seed {seed}: preemptions drifted");
        assert_eq!(plain.events, empty.events, "seed {seed}: events drifted");
        assert_eq!(
            plain.p99_latency.to_bits(),
            empty.p99_latency.to_bits(),
            "seed {seed}: p99 drifted"
        );
        assert_eq!(empty.shed, 0, "seed {seed}: zero-event plan shed work");
        assert_eq!(empty.max_retries, 0, "seed {seed}: zero-event plan retried work");
    }
}
