//! Replays the committed fuzz corpus (`ci/fuzz_corpus/*.json`) through the
//! deterministic concurrency fuzzer — the per-PR regression gate in test
//! form, so `cargo test` alone catches an ordering-dependent regression
//! before CI does.
//!
//! Each corpus entry is `{"seed": N, "orderings": K, "note": "..."}`. A
//! seed must (a) pass every permuted ordering of both the engine and
//! streaming paths and (b) produce a byte-identical report when replayed —
//! the determinism contract the shrinker and CI artifacts rely on.

use pyschedcl::json::Json;
use pyschedcl::sched::fuzz::{run_seed, FuzzConfig};
use std::path::PathBuf;

fn corpus_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../ci/fuzz_corpus"))
}

fn corpus_entries() -> Vec<(PathBuf, u64, usize, String)> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(corpus_dir())
        .expect("ci/fuzz_corpus must exist next to the crate")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    paths.sort();
    paths
        .into_iter()
        .map(|p| {
            let text = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("cannot read {}: {e}", p.display()));
            let json = Json::parse(&text)
                .unwrap_or_else(|e| panic!("{} is not valid JSON: {e}", p.display()));
            let seed = json
                .get("seed")
                .and_then(|s| s.as_u64())
                .unwrap_or_else(|| panic!("{}: bad 'seed'", p.display()));
            let orderings = json
                .get("orderings")
                .and_then(|o| o.as_usize())
                .unwrap_or_else(|| panic!("{}: bad 'orderings'", p.display()));
            let note = json
                .get("note")
                .and_then(|n| n.as_str())
                .unwrap_or("")
                .to_string();
            (p, seed, orderings, note)
        })
        .collect()
}

#[test]
fn corpus_is_nonempty_and_well_formed() {
    let entries = corpus_entries();
    assert!(
        entries.len() >= 4,
        "corpus unexpectedly small: {} entries",
        entries.len()
    );
    // The two crafted shapes must stay pinned in the corpus.
    assert!(entries.iter().any(|(_, s, ..)| *s == 0), "seed 0 missing");
    assert!(entries.iter().any(|(_, s, ..)| *s == 1), "seed 1 missing");
    for (p, _, orderings, note) in &entries {
        assert!(*orderings >= 2, "{}: fewer than 2 orderings", p.display());
        assert!(!note.is_empty(), "{}: corpus seeds document why", p.display());
    }
}

#[test]
fn corpus_seeds_replay_green_and_deterministically() {
    for (path, seed, orderings, _) in corpus_entries() {
        let cfg = FuzzConfig {
            orderings,
            ..FuzzConfig::default()
        };
        let a = run_seed(seed, &cfg);
        assert!(
            a.ok(),
            "{} regressed:\n{}",
            path.display(),
            a.log
        );
        let b = run_seed(seed, &cfg);
        assert_eq!(
            a.log,
            b.log,
            "{}: replay of seed {seed} diverged",
            path.display()
        );
    }
}
