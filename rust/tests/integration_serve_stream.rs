//! Integration: the always-on streaming server (PR 6) against its two
//! contracts, through the public API only:
//!
//! 1. **Equivalence** — with an unbounded admission window (`window: 0`),
//!    `serve_stream` must reproduce the build-once pipeline
//!    (`serve_sim_cached`) bit for bit on the same seeded arrival-ordered
//!    stream: identical per-request outcomes, makespan, preemption count,
//!    device utilization, and template-cache counters. Retirement changes
//!    memory, never outcomes.
//! 2. **Bounded state** — with a finite window, the count of live
//!    (admitted, unfinished) requests never exceeds the window, across
//!    window sizes and seeds, while every request is still accounted for
//!    (served + rejected == offered).

use std::collections::HashMap;

use pyschedcl::cost::PaperCost;
use pyschedcl::platform::Platform;
use pyschedcl::sched::{Clustering, LeastLoaded};
use pyschedcl::serve::{
    poisson_arrivals, serve_sim_cached, serve_stream, serve_stream_cached, CollectSink,
    RequestOutcome, ServeConfig, ServeRequest, StreamingConfig, TemplateCache, Workload,
};

/// Seeded mixed stream: two batch signatures (β=64 / β=128), every fifth
/// request deadline-bearing at priority 1 — exercises merged-template
/// batching, the laxity gate, and per-priority accounting on both paths.
fn stream(n: usize, seed: u64, rate: f64) -> Vec<ServeRequest> {
    poisson_arrivals(seed, n, rate)
        .expect("valid rate")
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let beta = if i % 4 == 3 { 128 } else { 64 };
            let mut r = ServeRequest::new(i, t, Workload::Head { beta });
            if i % 5 == 0 {
                r.deadline = Some(2.0);
                r.priority = 1;
            }
            r
        })
        .collect()
}

fn by_id(outcomes: &[RequestOutcome]) -> HashMap<usize, &RequestOutcome> {
    outcomes.iter().map(|o| (o.id, o)).collect()
}

#[test]
fn unbounded_streaming_reproduces_the_batch_pipeline_bit_for_bit() {
    let requests = stream(120, 42, 1500.0);
    let platform = Platform::scaled(2, 1, 3, 1);
    let scfg = StreamingConfig {
        window: 0, // unbounded: the exact-equivalence regime
        ..StreamingConfig::default()
    };
    let bcfg = ServeConfig {
        batch_window: scfg.batch_window,
        tenancy: scfg.tenancy,
        laxity_admission: scfg.laxity_admission,
        ..ServeConfig::default()
    };

    let mut stream_cache = TemplateCache::new();
    let mut sink = CollectSink::default();
    let streamed = serve_stream_cached(
        requests.clone(),
        &platform,
        &PaperCost,
        &mut LeastLoaded,
        &scfg,
        &mut stream_cache,
        &mut sink,
    )
    .unwrap();

    let mut batch_cache = TemplateCache::new();
    let batch = serve_sim_cached(
        &requests,
        &platform,
        &PaperCost,
        &mut LeastLoaded,
        &bcfg,
        &mut batch_cache,
    )
    .unwrap();

    assert_eq!(streamed.served, batch.outcomes.len());
    assert_eq!(streamed.rejected, batch.rejected.len());
    assert_eq!(sink.outcomes.len(), streamed.served);

    // Per-request outcomes are bit-identical (streaming emits in completion
    // order, the pipeline in admission order — compare by id).
    let streamed_by_id = by_id(&sink.outcomes);
    for b in &batch.outcomes {
        let s = streamed_by_id
            .get(&b.id)
            .unwrap_or_else(|| panic!("request {} missing from stream", b.id));
        assert_eq!(s.release.to_bits(), b.release.to_bits(), "id {}", b.id);
        assert_eq!(s.finish.to_bits(), b.finish.to_bits(), "id {}", b.id);
        assert_eq!(s.latency.to_bits(), b.latency.to_bits(), "id {}", b.id);
        assert_eq!(s.deadline_met, b.deadline_met, "id {}", b.id);
    }

    // Aggregates too: schedule identity, not just per-request agreement.
    assert_eq!(streamed.makespan.to_bits(), batch.makespan.to_bits());
    assert_eq!(streamed.preemptions, batch.preemptions);
    assert_eq!(streamed.device_util.len(), batch.device_util.len());
    for (s, b) in streamed.device_util.iter().zip(&batch.device_util) {
        assert_eq!(s.to_bits(), b.to_bits());
    }
    assert_eq!(streamed.template_cache_hits, batch.template_cache_hits);
    assert_eq!(streamed.template_cache_misses, batch.template_cache_misses);
}

#[test]
fn streaming_is_deterministic_and_independent_of_the_sink() {
    // Same seed, different sinks → identical reports: the sink observes
    // outcomes, it never influences the schedule.
    let platform = Platform::paper_testbed(3, 1);
    let cfg = StreamingConfig::default();
    let run = |sink: &mut CollectSink| {
        serve_stream(
            stream(48, 7, 2000.0),
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            sink,
        )
        .unwrap()
    };
    let mut sink_a = CollectSink::default();
    let mut sink_b = CollectSink::default();
    let a = run(&mut sink_a);
    let b = run(&mut sink_b);
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.served, b.served);
    assert_eq!(sink_a.outcomes.len(), sink_b.outcomes.len());
    for (oa, ob) in sink_a.outcomes.iter().zip(&sink_b.outcomes) {
        assert_eq!(oa.id, ob.id);
        assert_eq!(oa.finish.to_bits(), ob.finish.to_bits());
    }
}

/// Property: across window sizes and seeds, the number of live requests
/// never exceeds the admission window, and no request is lost to
/// backpressure — everything offered is either served or rejected.
///
/// `batch_window: 0.0` keeps every admission unit a singleton, so the
/// window bound is airtight (a same-signature batch larger than the window
/// is otherwise admitted whole once the server idles — by design).
#[test]
fn live_requests_never_exceed_the_admission_window() {
    let platform = Platform::paper_testbed(3, 1);
    for &window in &[1usize, 2, 5, 16] {
        for &seed in &[3u64, 11, 29] {
            let n = 60;
            let cfg = StreamingConfig {
                window,
                batch_window: 0.0,
                ..StreamingConfig::default()
            };
            // High rate so arrivals outpace service: the window must
            // actually exert backpressure for the bound to mean anything.
            let report = serve_stream(
                stream(n, seed, 6000.0),
                &platform,
                &PaperCost,
                &mut Clustering,
                &cfg,
                &mut pyschedcl::serve::NullSink,
            )
            .unwrap();
            assert!(
                report.peak_live_requests <= window,
                "window {window} seed {seed}: peak {} live requests",
                report.peak_live_requests
            );
            assert_eq!(
                report.served + report.rejected,
                n,
                "window {window} seed {seed}: lost requests"
            );
            assert!(report.served > 0, "window {window} seed {seed}");
            // The window was genuinely reached under this load — the bound
            // above is a real constraint, not slack.
            assert!(
                window >= n || report.peak_live_requests == window,
                "window {window} seed {seed}: peak {} never hit the window",
                report.peak_live_requests
            );
        }
    }
}
