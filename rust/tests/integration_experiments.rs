//! Integration: the experiment harness reproduces the paper's §5 *shapes*
//! (who wins, by roughly what factor, where crossovers fall).

use pyschedcl::report::experiments::*;

#[test]
fn fig4_5_coarse_vs_fine() {
    let m = motivation(256).unwrap();
    // Paper: coarse 105 ms, fine 95 ms, ≈8% gain.
    assert!(m.coarse_ms > 85.0 && m.coarse_ms < 125.0, "{}", m.coarse_ms);
    assert!(m.fine_ms < m.coarse_ms);
    assert!(m.speedup > 1.04 && m.speedup < 1.35, "{}", m.speedup);
    // Fine-grained must actually overlap kernels and transfers.
    assert!(m.fine.trace.device_overlap(0) > 0.0);
    assert!(m.fine.trace.copy_compute_overlap(0) > 0.0);
    assert_eq!(m.coarse.trace.device_overlap(0), 0.0);
}

#[test]
fn fig11_crossover_at_h10() {
    // Expt 1 shape: h_cpu = 0 below the crossover, 1 at H=16; speedups >1.
    let rows = expt1(16, 256, 2).unwrap();
    assert_eq!(rows.len(), 16);
    for r in &rows {
        assert!(r.speedup >= 1.0, "H={} speedup {}", r.heads, r.speedup);
    }
    assert_eq!(rows[0].best.h_cpu, 0, "H=1 must stay on the GPU");
    assert_eq!(rows[3].best.h_cpu, 0, "H=4 must stay on the GPU");
    let crossover = rows.iter().find(|r| r.best.h_cpu > 0).map(|r| r.heads);
    let c = crossover.expect("offloading should win at some H");
    assert!((8..=12).contains(&c), "crossover at H={c}, paper says ≈10");
    assert_eq!(rows[15].best.h_cpu, 1, "H=16: exactly one CPU head (paper)");
    // The jump: best speedup above the crossover exceeds the flat region.
    let below: f64 = rows[..c - 1].iter().map(|r| r.speedup).fold(0.0, f64::max);
    let above: f64 = rows[c - 1..].iter().map(|r| r.speedup).fold(0.0, f64::max);
    assert!(above > below, "no jump after crossover: {below} vs {above}");
}

#[test]
fn fig12a_clustering_vs_eager_band() {
    let rows = expt2(16, &[64, 256]).unwrap();
    for r in &rows {
        assert!(
            r.speedup > 1.4 && r.speedup < 5.0,
            "β={}: {}x outside band",
            r.beta,
            r.speedup
        );
    }
    // Speedup shrinks as β grows (kernels dwarf scheduling overheads).
    assert!(rows[0].speedup > rows[1].speedup);
}

#[test]
fn fig12b_clustering_vs_heft_band() {
    let rows = expt3(16, &[256, 512]).unwrap();
    for r in &rows {
        assert!(r.speedup > 1.0, "clustering must beat heft at β={}", r.beta);
    }
}

#[test]
fn heft_beats_eager_at_large_beta() {
    // Paper: "heft ... is approximately 2.4x faster than eager" (H=16, β=512).
    let e = expt2(16, &[512]).unwrap()[0];
    let h = expt3(16, &[512]).unwrap()[0];
    let heft_over_eager = e.baseline_ms / h.baseline_ms;
    assert!(
        heft_over_eager > 1.5 && heft_over_eager < 3.5,
        "heft over eager = {heft_over_eager:.2} (paper ≈2.4)"
    );
}

#[test]
fn fig13_gantt_diagnostics() {
    // Reduced scale for test speed (H=8, β=256); ordering is scale-free.
    let (eager, _) = gantt("eager", 8, 256).unwrap();
    let (heft, _) = gantt("heft", 8, 256).unwrap();
    let (cl, _) = gantt("clustering", 8, 256).unwrap();
    // Makespans: eager > heft > clustering.
    assert!(eager.makespan > heft.makespan);
    assert!(heft.makespan > cl.makespan);
    // Gaps: clustering gapless relative to the dynamic schemes.
    assert!(cl.trace.max_gap(0) < heft.trace.max_gap(0));
    assert!(cl.trace.max_gap(0) < eager.trace.max_gap(0));
    // Eager strands work on the CPU device (GEMMs on dev 1).
    let eager_cpu_spans = eager
        .trace
        .spans
        .iter()
        .filter(|s| matches!(s.lane, pyschedcl::trace::Lane::Device { dev: 1, .. }))
        .count();
    assert!(eager_cpu_spans > 0, "eager must misplace kernels on the CPU");
}
