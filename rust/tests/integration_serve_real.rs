//! Integration: the real serving path at scale (ISSUE 3 acceptance).
//!
//! Artifact-gated — every test skips when the AOT artifacts are absent
//! (build them with `cd python && python -m compile.aot`), exactly like the
//! other real-execution tests. With artifacts present (the CI bench job
//! builds them) these exercise the real thread-per-queue executor:
//!
//! * real-path `edf` with threaded deadline metadata meets strictly more
//!   deadlines than deadline-blind dispatch on a tight-deadline stream;
//! * the warm executable cache spans policy runs of one process.

mod common;

use common::{artifact_runtime, met_count};
use pyschedcl::cost::PaperCost;
use pyschedcl::platform::Platform;
use pyschedcl::sched::{Clustering, Edf, Policy};
use pyschedcl::serve::{serve_real, ServeConfig, ServeRequest, Workload};

/// Real-path `edf` must reorder dispatch by urgency now that per-component
/// deadline metadata reaches the executor's scheduler state. Scenario: eight
/// simultaneous arrivals of one signature coalesce into a single batch on
/// an exclusive single-GPU platform (tenancy 1 ⇒ strictly sequential
/// service). Only the *last* admitted request carries a deadline of 2.5
/// warm service cycles: a deadline-blind policy serves in rank order and
/// finishes it after ~8 cycles (miss); `edf` serves it first (~1 cycle,
/// met) — strictly more deadlines met, from scheduling alone.
#[test]
fn real_edf_meets_strictly_more_deadlines_than_deadline_blind() {
    let Some(rt) = artifact_runtime() else {
        return;
    };
    let platform = Platform::paper_testbed(3, 0);
    let cfg = ServeConfig {
        tenancy: 1,
        // Decouple the scheduling comparison from the admission estimate.
        laxity_admission: false,
        ..ServeConfig::default()
    };

    // Calibrate one warm service cycle: first run pays compilation (cold),
    // the second reflects steady-state service — the unit the deadline is
    // phrased in, so the test holds across machines.
    let calibrate = || {
        let req = ServeRequest::new(0, 0.0, Workload::Head { beta: 128 });
        serve_real(
            std::slice::from_ref(&req),
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            7,
        )
        .unwrap()
        .makespan
    };
    let _cold = calibrate();
    let cycle = calibrate();
    assert!(cycle > 0.0);

    let requests: Vec<ServeRequest> = (0..8)
        .map(|i| {
            let mut r = ServeRequest::new(i, 0.0, Workload::Head { beta: 128 });
            if i == 7 {
                r.deadline = Some(2.5 * cycle);
            }
            r
        })
        .collect();
    let run = |policy: &mut dyn Policy| {
        serve_real(&requests, &rt, &platform, &PaperCost, policy, &cfg, 7).unwrap()
    };
    let edf = run(&mut Edf);
    let blind = run(&mut Clustering);
    assert_eq!(edf.outcomes.len(), 8);
    assert_eq!(blind.outcomes.len(), 8);
    assert_eq!(edf.deadline_total, 1);
    assert_eq!(blind.deadline_total, 1);
    assert!(
        met_count(&edf) > met_count(&blind),
        "edf met {} deadline(s), deadline-blind met {} (cycle {:.4}s, edf tight latency {:.4}s, \
         blind tight latency {:.4}s)",
        met_count(&edf),
        met_count(&blind),
        cycle,
        edf.outcomes.iter().find(|o| o.id == 7).unwrap().latency,
        blind.outcomes.iter().find(|o| o.id == 7).unwrap().latency
    );
    // Both policy runs were served from the warm executable cache (the
    // calibration runs compiled every artifact): all hits, no misses.
    assert!(edf.exec_cache_hits > 0);
    assert_eq!(edf.exec_cache_misses, 0);
    assert_eq!(blind.exec_cache_misses, 0);
}
