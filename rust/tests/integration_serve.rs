//! Integration: the multi-DAG serving layer — deterministic seeded sim
//! tests for the ISSUE acceptance matrix: (a) concurrent serving beats
//! sequential replay, (b) a single served request reproduces single-DAG
//! `simulate` exactly, (c) admission rejects malformed specs with a typed
//! error, plus determinism and multi-tenant overlap evidence.

mod common;

use common::met_count;
use pyschedcl::cost::PaperCost;
use pyschedcl::error::Error;
use pyschedcl::graph::Partition;
use pyschedcl::platform::Platform;
use pyschedcl::sched::{Clustering, Edf, LeastLoaded};
use pyschedcl::serve::{
    admit, poisson_arrivals, serve_sequential, serve_sim, ServeConfig, ServeReport, ServeRequest,
    Workload,
};
use pyschedcl::sim::{simulate, SimConfig};

fn head_stream(n: usize, seed: u64, rate: f64) -> Vec<ServeRequest> {
    poisson_arrivals(seed, n, rate)
        .expect("valid rate")
        .into_iter()
        .enumerate()
        .map(|(i, t)| ServeRequest::new(i, t, Workload::Head { beta: 64 }))
        .collect()
}

#[test]
fn concurrent_serving_beats_sequential_replay() {
    // (a) K independent DAGs served concurrently must finish strictly
    // earlier than sequential replay of the same trace.
    let requests = head_stream(16, 42, 2000.0);
    let platform = Platform::paper_testbed(3, 1);
    let cfg = ServeConfig::default();
    let conc = serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
    let seq = serve_sequential(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
    assert_eq!(conc.outcomes.len(), 16);
    assert_eq!(seq.outcomes.len(), 16);
    assert!(
        conc.makespan < seq.makespan,
        "concurrent {} !< sequential {}",
        conc.makespan,
        seq.makespan
    );
    assert!(
        conc.throughput_rps > seq.throughput_rps,
        "throughput {} !> {}",
        conc.throughput_rps,
        seq.throughput_rps
    );
    // Tail latency should improve too on this independent-DAG stream.
    assert!(conc.p99_latency < seq.p99_latency);
}

#[test]
fn single_request_matches_single_dag_simulate() {
    // (b) One request, arrival 0, exclusive tenancy: the serving layer is
    // exactly the single-shot simulator.
    let req = ServeRequest::new(0, 0.0, Workload::Head { beta: 64 });
    let platform = Platform::paper_testbed(3, 1);
    let cfg = ServeConfig {
        tenancy: 1,
        ..ServeConfig::default()
    };
    let report = serve_sim(
        std::slice::from_ref(&req),
        &platform,
        &PaperCost,
        &mut Clustering,
        &cfg,
    )
    .unwrap();
    let (dag, part) = req.workload.instantiate().unwrap();
    let solo = simulate(
        &dag,
        &part,
        &platform,
        &PaperCost,
        &mut Clustering,
        &SimConfig::default(),
    )
    .unwrap();
    assert_eq!(report.outcomes.len(), 1);
    // Gantt makespan (last command) is identical...
    assert!(
        (report.makespan - solo.makespan).abs() < 1e-12,
        "served makespan {} vs single-DAG {}",
        report.makespan,
        solo.makespan
    );
    // ...and so is the request's completion (last component callback).
    let solo_finish = solo
        .component_finish
        .iter()
        .copied()
        .fold(0.0f64, f64::max);
    assert!(
        (report.outcomes[0].finish - solo_finish).abs() < 1e-12,
        "served finish {} vs single-DAG component finish {solo_finish}",
        report.outcomes[0].finish
    );
}

#[test]
fn admission_rejects_malformed_specs_with_typed_error() {
    // (c) Malformed spec workload → Error::Admission, both from admit()
    // directly and as a non-fatal rejection in a mixed stream.
    let (dag, _) = Workload::Head { beta: 64 }.instantiate().unwrap();
    let malformed = ServeRequest::new(
        3,
        0.0,
        Workload::Spec {
            dag,
            partition: Partition {
                components: vec![],
                assignment: vec![],
            },
        },
    );
    let err = admit(&malformed).unwrap_err();
    assert!(matches!(err, Error::Admission(_)), "{err}");
    assert!(err.to_string().contains("request 3"), "{err}");

    let platform = Platform::paper_testbed(3, 1);
    let stream = vec![
        ServeRequest::new(0, 0.0, Workload::Head { beta: 64 }),
        malformed,
    ];
    let report = serve_sim(
        &stream,
        &platform,
        &PaperCost,
        &mut Clustering,
        &ServeConfig::default(),
    )
    .unwrap();
    assert_eq!(report.outcomes.len(), 1);
    assert_eq!(report.rejected.len(), 1);
    assert_eq!(report.rejected[0].0, 3);
}

#[test]
fn serving_is_deterministic_under_a_fixed_seed() {
    let platform = Platform::paper_testbed(3, 1);
    let cfg = ServeConfig::default();
    let run = || {
        let requests = head_stream(32, 7, 2000.0);
        serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.throughput_rps, b.throughput_rps);
    let lat = |r: &ServeReport| -> Vec<f64> {
        r.outcomes.iter().map(|o| o.latency).collect()
    };
    assert_eq!(lat(&a), lat(&b));
}

#[test]
fn requests_never_start_before_arrival() {
    let requests = head_stream(8, 11, 2000.0);
    let platform = Platform::paper_testbed(3, 1);
    let report = serve_sim(
        &requests,
        &platform,
        &PaperCost,
        &mut Clustering,
        &ServeConfig::default(),
    )
    .unwrap();
    for o in &report.outcomes {
        assert!(o.release >= o.arrival - 1e-12, "request {} released early", o.id);
        assert!(o.finish >= o.release, "request {} finished before release", o.id);
        assert!(o.latency > 0.0);
    }
}

#[test]
fn multi_tenancy_produces_cross_request_overlap() {
    // Tenancy 1 serializes components on the single GPU; tenancy 4 lets
    // requests share it — measurably faster and genuinely overlapped.
    let requests = head_stream(8, 5, 5000.0);
    let platform = Platform::paper_testbed(3, 0);
    let run = |tenancy: usize| {
        let cfg = ServeConfig {
            tenancy,
            ..ServeConfig::default()
        };
        serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap()
    };
    let exclusive = run(1);
    let shared = run(4);
    assert!(
        shared.makespan < exclusive.makespan,
        "tenancy 4 {} !< tenancy 1 {}",
        shared.makespan,
        exclusive.makespan
    );
    assert!(shared.device_util[0] > 0.0);
}

#[test]
fn least_loaded_spreads_requests_over_scaled_platform() {
    // Two GPUs: the serving policy must use both.
    let requests = head_stream(12, 3, 5000.0);
    let platform = Platform::scaled(2, 1, 3, 1);
    let report = serve_sim(
        &requests,
        &platform,
        &PaperCost,
        &mut LeastLoaded,
        &ServeConfig::default(),
    )
    .unwrap();
    assert_eq!(report.outcomes.len(), 12);
    assert!(report.device_util[0] > 0.0, "GPU 0 unused");
    assert!(report.device_util[1] > 0.0, "GPU 1 unused");
    // And two GPUs must beat one under the same stream.
    let one_gpu = serve_sim(
        &requests,
        &Platform::scaled(1, 1, 3, 1),
        &PaperCost,
        &mut LeastLoaded,
        &ServeConfig::default(),
    )
    .unwrap();
    assert!(report.makespan < one_gpu.makespan);
}

#[test]
fn deadlines_are_accounted_per_request() {
    let mut requests = head_stream(4, 9, 1000.0);
    for r in &mut requests {
        r.deadline = Some(10.0); // generous: everything meets it
    }
    let platform = Platform::paper_testbed(3, 1);
    let report = serve_sim(
        &requests,
        &platform,
        &PaperCost,
        &mut Clustering,
        &ServeConfig::default(),
    )
    .unwrap();
    assert!(report
        .outcomes
        .iter()
        .all(|o| o.deadline_met == Some(true)));
    assert_eq!(report.deadline_total, 4);
    assert_eq!(report.deadline_misses, 0);
    assert_eq!(report.deadline_miss_rate, 0.0);
}

/// Single-request service cycle (dispatch → setup → exec → callback) on an
/// exclusive single-GPU platform — the calibration unit the deadline tests
/// below are phrased in, so they hold regardless of cost-model constants.
fn solo_cycle(beta: u64, cfg: &ServeConfig, platform: &Platform) -> f64 {
    let req = ServeRequest::new(0, 0.0, Workload::Head { beta });
    let r = serve_sim(
        std::slice::from_ref(&req),
        platform,
        &PaperCost,
        &mut Clustering,
        cfg,
    )
    .unwrap();
    r.outcomes[0].finish
}

/// ISSUE acceptance: under a tight-deadline seeded stream on a contended
/// GPU, `edf` meets strictly more deadlines than `least-loaded`, and the
/// report carries deadline-miss rate and preemption count.
#[test]
fn edf_meets_strictly_more_deadlines_than_least_loaded() {
    let platform = Platform::paper_testbed(3, 0); // one GPU, exclusive CPU off
    let cfg = ServeConfig {
        tenancy: 1, // exclusive leases: service is strictly sequential
        ..ServeConfig::default()
    };
    let cycle = solo_cycle(64, &cfg, &platform);
    assert!(cycle > 0.0);
    // Eight simultaneous arrivals; odd ids carry a deadline of 5.5 service
    // cycles, even ids a generous 10 s. A deadline-blind policy serves in
    // id order (tights finish after 2, 4, 6, 8 cycles: two misses); EDF
    // serves the tight ones first (1..4 cycles: all met).
    let requests: Vec<ServeRequest> = (0..8)
        .map(|i| {
            let mut r = ServeRequest::new(i, 0.0, Workload::Head { beta: 64 });
            r.deadline = Some(if i % 2 == 1 { 5.5 * cycle } else { 10.0 });
            r
        })
        .collect();
    let edf = serve_sim(&requests, &platform, &PaperCost, &mut Edf, &cfg).unwrap();
    let ll = serve_sim(&requests, &platform, &PaperCost, &mut LeastLoaded, &cfg).unwrap();
    assert_eq!(edf.outcomes.len(), 8);
    assert_eq!(ll.outcomes.len(), 8);
    assert_eq!(edf.deadline_total, 8);
    assert_eq!(ll.deadline_total, 8);
    assert!(
        met_count(&edf) > met_count(&ll),
        "edf met {} deadlines, least-loaded {} — expected strictly more \
         (edf miss rate {}, ll miss rate {})",
        met_count(&edf),
        met_count(&ll),
        edf.deadline_miss_rate,
        ll.deadline_miss_rate
    );
    assert!(edf.deadline_miss_rate < ll.deadline_miss_rate);
    // The report carries the new accounting fields.
    assert_eq!(edf.deadline_misses + met_count(&edf), edf.deadline_total);
    assert!(!edf.per_priority_p99.is_empty());
}

/// An urgent high-priority late arrival must displace a deadline-free
/// resident on an exclusive GPU (preemption at command-queue granularity),
/// meet its deadline, and the displaced request must still complete.
#[test]
fn edf_preemption_rescues_urgent_late_arrival() {
    let platform = Platform::paper_testbed(3, 0);
    let cfg = ServeConfig {
        tenancy: 1,
        batch_window: 0.0, // keep the two requests in separate batches
        ..ServeConfig::default()
    };
    let cycle = solo_cycle(256, &cfg, &platform);
    // Arrival offset in cycle units so the scenario survives cost-model
    // changes: the background request is 5% into its work — resident with
    // commands outstanding — when the urgent one arrives.
    let offset = 0.05 * cycle;
    let mut background = ServeRequest::new(0, 0.0, Workload::Head { beta: 256 });
    background.priority = 0;
    let mut urgent = ServeRequest::new(1, offset, Workload::Head { beta: 256 });
    urgent.deadline = Some(1.5 * cycle);
    urgent.priority = 1;
    let requests = vec![background, urgent];

    let edf = serve_sim(&requests, &platform, &PaperCost, &mut Edf, &cfg).unwrap();
    assert!(edf.preemptions >= 1, "expected a preemption, got none");
    let urgent_out = edf.outcomes.iter().find(|o| o.id == 1).unwrap();
    assert_eq!(
        urgent_out.deadline_met,
        Some(true),
        "urgent latency {} vs budget {}",
        urgent_out.latency,
        1.5 * cycle
    );
    // The displaced background request still completes.
    let bg = edf.outcomes.iter().find(|o| o.id == 0).unwrap();
    assert!(bg.finish.is_finite() && bg.finish > urgent_out.finish);

    // Deadline-blind least-loaded leaves the urgent request queued behind
    // the resident: deadline missed, no preemptions.
    let ll = serve_sim(&requests, &platform, &PaperCost, &mut LeastLoaded, &cfg).unwrap();
    assert_eq!(ll.preemptions, 0);
    let urgent_ll = ll.outcomes.iter().find(|o| o.id == 1).unwrap();
    assert_eq!(urgent_ll.deadline_met, Some(false));
    assert!(met_count(&edf) > met_count(&ll));
}
