//! Integration: the multi-DAG serving layer — deterministic seeded sim
//! tests for the ISSUE acceptance matrix: (a) concurrent serving beats
//! sequential replay, (b) a single served request reproduces single-DAG
//! `simulate` exactly, (c) admission rejects malformed specs with a typed
//! error, plus determinism and multi-tenant overlap evidence.

use pyschedcl::cost::PaperCost;
use pyschedcl::error::Error;
use pyschedcl::graph::Partition;
use pyschedcl::platform::Platform;
use pyschedcl::sched::{Clustering, LeastLoaded};
use pyschedcl::serve::{
    admit, poisson_arrivals, serve_sequential, serve_sim, ServeConfig, ServeRequest, Workload,
};
use pyschedcl::sim::{simulate, SimConfig};

fn head_stream(n: usize, seed: u64, rate: f64) -> Vec<ServeRequest> {
    poisson_arrivals(seed, n, rate)
        .into_iter()
        .enumerate()
        .map(|(i, t)| ServeRequest::new(i, t, Workload::Head { beta: 64 }))
        .collect()
}

#[test]
fn concurrent_serving_beats_sequential_replay() {
    // (a) K independent DAGs served concurrently must finish strictly
    // earlier than sequential replay of the same trace.
    let requests = head_stream(16, 42, 2000.0);
    let platform = Platform::paper_testbed(3, 1);
    let cfg = ServeConfig::default();
    let conc = serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
    let seq = serve_sequential(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
    assert_eq!(conc.outcomes.len(), 16);
    assert_eq!(seq.outcomes.len(), 16);
    assert!(
        conc.makespan < seq.makespan,
        "concurrent {} !< sequential {}",
        conc.makespan,
        seq.makespan
    );
    assert!(
        conc.throughput_rps > seq.throughput_rps,
        "throughput {} !> {}",
        conc.throughput_rps,
        seq.throughput_rps
    );
    // Tail latency should improve too on this independent-DAG stream.
    assert!(conc.p99_latency < seq.p99_latency);
}

#[test]
fn single_request_matches_single_dag_simulate() {
    // (b) One request, arrival 0, exclusive tenancy: the serving layer is
    // exactly the single-shot simulator.
    let req = ServeRequest::new(0, 0.0, Workload::Head { beta: 64 });
    let platform = Platform::paper_testbed(3, 1);
    let cfg = ServeConfig {
        tenancy: 1,
        ..ServeConfig::default()
    };
    let report = serve_sim(
        std::slice::from_ref(&req),
        &platform,
        &PaperCost,
        &mut Clustering,
        &cfg,
    )
    .unwrap();
    let (dag, part) = req.workload.instantiate().unwrap();
    let solo = simulate(
        &dag,
        &part,
        &platform,
        &PaperCost,
        &mut Clustering,
        &SimConfig::default(),
    )
    .unwrap();
    assert_eq!(report.outcomes.len(), 1);
    // Gantt makespan (last command) is identical...
    assert!(
        (report.makespan - solo.makespan).abs() < 1e-12,
        "served makespan {} vs single-DAG {}",
        report.makespan,
        solo.makespan
    );
    // ...and so is the request's completion (last component callback).
    let solo_finish = solo
        .component_finish
        .iter()
        .copied()
        .fold(0.0f64, f64::max);
    assert!(
        (report.outcomes[0].finish - solo_finish).abs() < 1e-12,
        "served finish {} vs single-DAG component finish {solo_finish}",
        report.outcomes[0].finish
    );
}

#[test]
fn admission_rejects_malformed_specs_with_typed_error() {
    // (c) Malformed spec workload → Error::Admission, both from admit()
    // directly and as a non-fatal rejection in a mixed stream.
    let (dag, _) = Workload::Head { beta: 64 }.instantiate().unwrap();
    let malformed = ServeRequest::new(
        3,
        0.0,
        Workload::Spec {
            dag,
            partition: Partition {
                components: vec![],
                assignment: vec![],
            },
        },
    );
    let err = admit(&malformed).unwrap_err();
    assert!(matches!(err, Error::Admission(_)), "{err}");
    assert!(err.to_string().contains("request 3"), "{err}");

    let platform = Platform::paper_testbed(3, 1);
    let stream = vec![
        ServeRequest::new(0, 0.0, Workload::Head { beta: 64 }),
        malformed,
    ];
    let report = serve_sim(
        &stream,
        &platform,
        &PaperCost,
        &mut Clustering,
        &ServeConfig::default(),
    )
    .unwrap();
    assert_eq!(report.outcomes.len(), 1);
    assert_eq!(report.rejected.len(), 1);
    assert_eq!(report.rejected[0].0, 3);
}

#[test]
fn serving_is_deterministic_under_a_fixed_seed() {
    let platform = Platform::paper_testbed(3, 1);
    let cfg = ServeConfig::default();
    let run = || {
        let requests = head_stream(32, 7, 2000.0);
        serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.throughput_rps, b.throughput_rps);
    let lat = |r: &pyschedcl::serve::ServeReport| -> Vec<f64> {
        r.outcomes.iter().map(|o| o.latency).collect()
    };
    assert_eq!(lat(&a), lat(&b));
}

#[test]
fn requests_never_start_before_arrival() {
    let requests = head_stream(8, 11, 2000.0);
    let platform = Platform::paper_testbed(3, 1);
    let report = serve_sim(
        &requests,
        &platform,
        &PaperCost,
        &mut Clustering,
        &ServeConfig::default(),
    )
    .unwrap();
    for o in &report.outcomes {
        assert!(o.release >= o.arrival - 1e-12, "request {} released early", o.id);
        assert!(o.finish >= o.release, "request {} finished before release", o.id);
        assert!(o.latency > 0.0);
    }
}

#[test]
fn multi_tenancy_produces_cross_request_overlap() {
    // Tenancy 1 serializes components on the single GPU; tenancy 4 lets
    // requests share it — measurably faster and genuinely overlapped.
    let requests = head_stream(8, 5, 5000.0);
    let platform = Platform::paper_testbed(3, 0);
    let run = |tenancy: usize| {
        let cfg = ServeConfig {
            tenancy,
            ..ServeConfig::default()
        };
        serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap()
    };
    let exclusive = run(1);
    let shared = run(4);
    assert!(
        shared.makespan < exclusive.makespan,
        "tenancy 4 {} !< tenancy 1 {}",
        shared.makespan,
        exclusive.makespan
    );
    assert!(shared.device_util[0] > 0.0);
}

#[test]
fn least_loaded_spreads_requests_over_scaled_platform() {
    // Two GPUs: the serving policy must use both.
    let requests = head_stream(12, 3, 5000.0);
    let platform = Platform::scaled(2, 1, 3, 1);
    let report = serve_sim(
        &requests,
        &platform,
        &PaperCost,
        &mut LeastLoaded,
        &ServeConfig::default(),
    )
    .unwrap();
    assert_eq!(report.outcomes.len(), 12);
    assert!(report.device_util[0] > 0.0, "GPU 0 unused");
    assert!(report.device_util[1] > 0.0, "GPU 1 unused");
    // And two GPUs must beat one under the same stream.
    let one_gpu = serve_sim(
        &requests,
        &Platform::scaled(1, 1, 3, 1),
        &PaperCost,
        &mut LeastLoaded,
        &ServeConfig::default(),
    )
    .unwrap();
    assert!(report.makespan < one_gpu.makespan);
}

#[test]
fn deadlines_are_accounted_per_request() {
    let mut requests = head_stream(4, 9, 1000.0);
    for r in &mut requests {
        r.deadline = Some(10.0); // generous: everything meets it
    }
    let platform = Platform::paper_testbed(3, 1);
    let report = serve_sim(
        &requests,
        &platform,
        &PaperCost,
        &mut Clustering,
        &ServeConfig::default(),
    )
    .unwrap();
    assert!(report
        .outcomes
        .iter()
        .all(|o| o.deadline_met == Some(true)));
}
