//! Helpers shared by the serving integration suites. Each test binary only
//! uses the helpers it needs, hence the file-level dead_code allowance.
#![allow(dead_code)]

use pyschedcl::runtime::Runtime;
use pyschedcl::serve::ServeReport;
use std::path::Path;
use std::sync::Arc;

/// The AOT runtime when artifacts are built, else `None` (tests skip).
/// Build with `cd python && python -m compile.aot` — the CI bench job does.
pub fn artifact_runtime() -> Option<Arc<Runtime>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::new(&dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(_) => {
            eprintln!("skipping: artifacts not built (cd python && python -m compile.aot)");
            None
        }
    }
}

/// Requests that met their deadline in a serving report.
pub fn met_count(r: &ServeReport) -> usize {
    r.outcomes
        .iter()
        .filter(|o| o.deadline_met == Some(true))
        .count()
}
