//! ISSUE acceptance: the indexed stack — the allocation-free engine
//! driving the event-driven `SchedState` with **indexed policies** — must
//! yield **byte-identical** `SimResult`s (makespan, per-component
//! finish/device, preemption count) to the verbatim pre-refactor stack
//! (`pyschedcl::sim::reference` engine + `pyschedcl::sched::reference`
//! view-based policies) on seeded serve streams — including EDF with
//! preemption — and the batch-block + template-cache serving pipeline
//! must reproduce the old admitted-order pipeline bit-for-bit on
//! single-signature streams (where the old assembly order is well-defined
//! to be identical).

use pyschedcl::cost::PaperCost;
use pyschedcl::platform::Platform;
use pyschedcl::sched::{reference, Clustering, Edf, LeastLoaded, Policy};
use pyschedcl::serve::{
    batch_requests, merge_apps, poisson_arrivals, serve_sim, ServeConfig, ServeRequest, Workload,
};
use pyschedcl::sim::reference::simulate_served_ref;
use pyschedcl::sim::{simulate_served, CompMeta, SimConfig, SimResult};

fn assert_bit_identical(new: &SimResult, old: &SimResult, what: &str) {
    assert_eq!(
        new.makespan.to_bits(),
        old.makespan.to_bits(),
        "{what}: makespan diverged ({} vs {})",
        new.makespan,
        old.makespan
    );
    assert_eq!(new.preemptions, old.preemptions, "{what}: preemption count");
    assert_eq!(
        new.component_device, old.component_device,
        "{what}: component device placement"
    );
    let bits = |v: &[f64]| -> Vec<u64> { v.iter().map(|x| x.to_bits()).collect() };
    assert_eq!(
        bits(&new.component_finish),
        bits(&old.component_finish),
        "{what}: component finish times"
    );
}

/// Run both full stacks — the indexed engine + indexed policy vs the
/// reference engine + view-based reference policy — on one merged serve
/// input and compare bitwise.
#[allow(clippy::too_many_arguments)]
fn both(
    dag: &pyschedcl::graph::Dag,
    part: &pyschedcl::graph::Partition,
    platform: &Platform,
    mk_new: impl Fn() -> Box<dyn Policy>,
    mk_old: impl Fn() -> Box<dyn reference::Policy>,
    cfg: &SimConfig,
    meta: &[CompMeta],
    what: &str,
) -> (SimResult, SimResult) {
    let mut p_new = mk_new();
    let new = simulate_served(dag, part, platform, &PaperCost, p_new.as_mut(), cfg, meta)
        .expect("optimized engine");
    let mut p_old = mk_old();
    let old = simulate_served_ref(dag, part, platform, &PaperCost, p_old.as_mut(), cfg, meta)
        .expect("reference engine");
    assert_bit_identical(&new, &old, what);
    (new, old)
}

/// Stream 1: seeded Poisson head stream, clustering, multi-tenant GPU+CPU.
#[test]
fn equivalence_poisson_head_stream_clustering() {
    let arrivals = poisson_arrivals(7, 16, 2000.0).unwrap();
    let apps: Vec<_> = arrivals
        .iter()
        .map(|_| Workload::Head { beta: 64 }.instantiate().unwrap())
        .collect();
    let merged = merge_apps(&apps).unwrap();
    let meta: Vec<CompMeta> = (0..merged.partition.components.len())
        .map(|c| {
            // One component per head app: component c belongs to request c.
            CompMeta {
                release: arrivals[c],
                ..CompMeta::default()
            }
        })
        .collect();
    let platform = Platform::paper_testbed(3, 1);
    let cfg = SimConfig {
        max_tenants: 4,
        ..SimConfig::default()
    };
    let (new, _) = both(
        &merged.dag,
        &merged.partition,
        &platform,
        || Box::new(Clustering),
        || Box::new(reference::Clustering),
        &cfg,
        &meta,
        "poisson head stream",
    );
    assert!(new.component_finish.iter().all(|t| t.is_finite()));
}

/// Stream 2: mixed workloads with deadlines/priorities on a 2-GPU scaled
/// platform under least-loaded.
#[test]
fn equivalence_mixed_stream_least_loaded() {
    let arrivals = poisson_arrivals(11, 12, 3000.0).unwrap();
    let workloads = [
        Workload::Head { beta: 64 },
        Workload::Mm2 { beta: 64 },
        Workload::Layer {
            heads: 2,
            beta: 64,
            h_cpu: 0,
        },
    ];
    let apps: Vec<_> = (0..12)
        .map(|i| workloads[i % 3].instantiate().unwrap())
        .collect();
    let merged = merge_apps(&apps).unwrap();
    let mut meta = vec![CompMeta::default(); merged.partition.components.len()];
    for (i, r) in merged.component_ranges.iter().enumerate() {
        for c in r.clone() {
            meta[c].release = arrivals[i];
            meta[c].deadline = arrivals[i] + 0.25;
            meta[c].priority = (i % 2) as u32;
        }
    }
    let platform = Platform::scaled(2, 1, 3, 1);
    let cfg = SimConfig {
        max_tenants: 2,
        ..SimConfig::default()
    };
    both(
        &merged.dag,
        &merged.partition,
        &platform,
        || Box::new(LeastLoaded),
        || Box::new(reference::LeastLoaded),
        &cfg,
        &meta,
        "mixed stream",
    );
}

/// Stream 3: EDF with a genuine preemption — an urgent late arrival
/// displaces a deadline-free resident on an exclusive GPU. Both engines
/// must preempt, and everything must match bitwise.
#[test]
fn equivalence_edf_stream_with_preemption() {
    let apps: Vec<_> = (0..2)
        .map(|_| Workload::Head { beta: 256 }.instantiate().unwrap())
        .collect();
    let merged = merge_apps(&apps).unwrap();
    let platform = Platform::paper_testbed(3, 0);
    let cfg = SimConfig::default(); // max_tenants = 1: exclusive GPU
    // Calibrate in solo units so the scenario survives cost-model changes.
    let solo = simulate_served(
        &apps[0].0,
        &apps[0].1,
        &platform,
        &PaperCost,
        &mut Clustering,
        &cfg,
        &[CompMeta::default()],
    )
    .unwrap()
    .makespan;
    let meta = [
        CompMeta::default(),
        CompMeta {
            release: 0.05 * solo,
            deadline: 1.5 * solo,
            priority: 1,
        },
    ];
    let (new, old) = both(
        &merged.dag,
        &merged.partition,
        &platform,
        || Box::new(Edf),
        || Box::new(reference::Edf),
        &cfg,
        &meta,
        "edf preemption stream",
    );
    assert!(new.preemptions >= 1, "scenario must actually preempt");
    assert_eq!(new.preemptions, old.preemptions);
}

/// Pipeline-level equivalence: on a single-signature stream the batch-block
/// assembly appends components in exactly the old admitted order, so the
/// whole optimized `serve_sim` (template cache included) must reproduce an
/// old-style pipeline — admitted-order `merge_apps`, old-style meta, and
/// the *reference* engine — bit-for-bit, per request.
#[test]
fn serve_sim_matches_old_pipeline_on_single_signature_stream() {
    let requests: Vec<ServeRequest> = poisson_arrivals(42, 20, 2500.0)
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let mut r = ServeRequest::new(i, t, Workload::Head { beta: 64 });
            r.deadline = Some(0.5);
            r.priority = (i % 3) as u32;
            r
        })
        .collect();
    let platform = Platform::paper_testbed(3, 1);
    let cfg = ServeConfig::default();

    // New pipeline.
    let report = serve_sim(&requests, &platform, &PaperCost, &mut Edf, &cfg).unwrap();
    assert_eq!(report.outcomes.len(), 20);
    assert!(
        report.template_cache_misses > 0,
        "cache must have built blocks"
    );

    // Old pipeline, replayed by hand: admission order (arrival, priority
    // desc, id), per-request instantiate, admitted-order merge, reference
    // engine.
    let mut admitted = requests.clone();
    admitted.sort_by(|a, b| {
        a.arrival
            .total_cmp(&b.arrival)
            .then_with(|| b.priority.cmp(&a.priority))
            .then_with(|| a.id.cmp(&b.id))
    });
    let apps: Vec<_> = admitted
        .iter()
        .map(|r| r.workload.instantiate().unwrap())
        .collect();
    let batches = batch_requests(&admitted, cfg.batch_window);
    let merged = merge_apps(&apps).unwrap();
    let mut meta = vec![CompMeta::default(); merged.partition.components.len()];
    for b in &batches {
        for &m in &b.members {
            for c in merged.component_ranges[m].clone() {
                meta[c].release = b.release;
            }
        }
    }
    for (i, req) in admitted.iter().enumerate() {
        for c in merged.component_ranges[i].clone() {
            meta[c].deadline = req.arrival + req.deadline.unwrap();
            meta[c].priority = req.priority;
        }
    }
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = cfg.tenancy;
    let old = simulate_served_ref(
        &merged.dag,
        &merged.partition,
        &platform,
        &PaperCost,
        &mut reference::Edf,
        &sim_cfg,
        &meta,
    )
    .unwrap();

    assert_eq!(report.makespan.to_bits(), old.makespan.to_bits());
    assert_eq!(report.preemptions, old.preemptions);
    for (i, req) in admitted.iter().enumerate() {
        let finish = merged.component_ranges[i]
            .clone()
            .map(|c| old.component_finish[c])
            .fold(0.0f64, f64::max);
        let outcome = report
            .outcomes
            .iter()
            .find(|o| o.id == req.id)
            .expect("request served");
        assert_eq!(
            outcome.finish.to_bits(),
            finish.to_bits(),
            "request {} finish diverged",
            req.id
        );
    }
}
