//! Property: sharded serving conserves requests (ISSUE 10).
//!
//! Over a grid of arrival seeds × shard counts × admission windows ×
//! fault plans, every sharded run must satisfy, with nothing lost and
//! nothing double-counted across the router and the per-shard loops:
//!
//! * globally: `served + rejected + shed == offered == n` (router-level
//!   duplicate rejections are offered-and-rejected; this grid offers no
//!   duplicates, so `offered` is exactly the stream length);
//! * per shard: `served + rejected + shed == offered`;
//! * across layers: per-shard `offered` sums to the merged `offered`, and
//!   the router's per-shard routed counts sum to `n`;
//! * the merged latency histogram holds exactly the served population.

use pyschedcl::cost::PaperCost;
use pyschedcl::error::Result;
use pyschedcl::fault::{FaultEvent, FaultKind, FaultPlan};
use pyschedcl::sched::{LeastLoaded, Policy};
use pyschedcl::serve::{
    poisson_arrivals, serve_sharded_stream, NullSink, PlatformShape, ServeRequest, ShardSpec,
    StreamingConfig, Workload,
};

fn stream(seed: u64, n: usize, rate: f64) -> Vec<ServeRequest> {
    poisson_arrivals(seed, n, rate)
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let beta = 64 + 8 * (i as u64 % 12);
            let mut r = ServeRequest::new(i, t, Workload::Head { beta });
            // A mix of deadline pressure: every 4th request carries a tight
            // budget (sheddable under faults, rejectable at admission).
            if i % 4 == 0 {
                r.deadline = Some(if i % 8 == 0 { 0.01 } else { 1.0 });
                r.priority = 1;
            }
            r
        })
        .collect()
}

fn factory() -> Result<Box<dyn Policy>> {
    Ok(Box::new(LeastLoaded))
}

/// Crash each shard's GPU 0 early, with a small retry budget — the
/// recovery machinery (retry, re-stage, shed) must still account for every
/// request.
fn crash_plan() -> FaultPlan {
    FaultPlan {
        events: vec![FaultEvent {
            device: 0,
            at: 0.002,
            kind: FaultKind::Crash,
        }],
        retry_budget: 2,
        backoff_base: 0.0,
        ..FaultPlan::default()
    }
    .normalized()
    .expect("valid plan")
}

#[test]
fn conservation_holds_across_shards_windows_and_fault_plans() {
    let n = 120;
    for &seed in &[1u64, 7, 23] {
        for &shards in &[1usize, 2, 4] {
            for &window in &[0usize, 8, 512] {
                for faults in [None, Some(crash_plan())] {
                    let with_faults = faults.is_some();
                    let cfg = StreamingConfig {
                        window,
                        faults,
                        ..StreamingConfig::default()
                    };
                    let shape = PlatformShape {
                        gpus: 4,
                        cpus: 4,
                        queues_gpu: 3,
                        queues_cpu: 1,
                    };
                    let spec = ShardSpec {
                        shards,
                        ..ShardSpec::default()
                    };
                    let label = format!(
                        "seed {seed}, {shards} shard(s), window {window}, faults {with_faults}"
                    );
                    let r = serve_sharded_stream(
                        stream(seed, n, 3000.0),
                        shape,
                        &PaperCost,
                        factory,
                        &cfg,
                        &spec,
                        &mut NullSink,
                    )
                    .unwrap_or_else(|e| panic!("{label}: {e}"));
                    let m = &r.merged;

                    assert_eq!(m.offered, n, "{label}: offered");
                    assert_eq!(
                        m.served + m.rejected + m.shed,
                        m.offered,
                        "{label}: global conservation"
                    );
                    assert_eq!(r.router.duplicate_rejections, 0, "{label}");
                    for s in &r.shards {
                        assert_eq!(
                            s.served + s.rejected + s.shed,
                            s.offered,
                            "{label}: shard {} conservation",
                            s.shard
                        );
                    }
                    let shard_offered: usize = r.shards.iter().map(|s| s.offered).sum();
                    assert_eq!(shard_offered, m.offered, "{label}: offered sums");
                    let routed: usize = r.router.routed.iter().sum();
                    assert_eq!(routed, n, "{label}: routed sums");
                    assert_eq!(
                        m.latency_hist.count(),
                        m.served,
                        "{label}: histogram population"
                    );
                }
            }
        }
    }
}
