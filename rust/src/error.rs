//! Unified error type for the PySchedCL coordinator.

use std::fmt;

/// Library-wide error.
#[derive(Debug)]
pub enum Error {
    /// Malformed or inconsistent DAG specification.
    Spec(String),
    /// DAG structural violation (cycle, dangling edge, ...).
    Graph(String),
    /// Invalid task-component partition (mixed device prefs, overlap, ...).
    Partition(String),
    /// Command-queue synthesis failure.
    Queue(String),
    /// Scheduling failure (deadlock, no matching device, ...).
    Sched(String),
    /// Serving-layer admission rejection (malformed request spec, invalid
    /// deadline/arrival, inconsistent partition, ...).
    Admission(String),
    /// PJRT runtime failure (load/compile/execute).
    Runtime(String),
    /// Real-executor failure.
    Exec(String),
    /// Bench-regression gate failure (`pyschedcl bench-check`): a metric
    /// moved beyond the committed baseline's tolerance.
    Bench(String),
    /// I/O error with context.
    Io(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Spec(m) => write!(f, "spec error: {m}"),
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Partition(m) => write!(f, "partition error: {m}"),
            Error::Queue(m) => write!(f, "queue error: {m}"),
            Error::Sched(m) => write!(f, "sched error: {m}"),
            Error::Admission(m) => write!(f, "admission error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Exec(m) => write!(f, "exec error: {m}"),
            Error::Bench(m) => write!(f, "bench regression: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
