//! Fault injection and recovery plumbing: seeded device-fault plans and
//! the runtime clock that replays them against an executing schedule.
//!
//! The paper's scheduling claims assume devices that always complete the
//! kernels dispatched to them. A serving deployment must instead survive
//! devices that *crash* (all resident work lost), *wedge* (kernels stop
//! progressing but never complete), or silently *slow down* — and degrade
//! gracefully instead of collapsing. A [`FaultPlan`] describes such
//! faults at deterministic instants (virtual seconds in the simulators,
//! wall seconds from the serve epoch on the real path); the same plan is
//! honored by [`crate::sim::engine`], [`crate::sim::stream`], and the
//! watchdog-guarded [`crate::exec`] executor, so a chaos scenario replays
//! identically across execution targets.
//!
//! Recovery rides the existing preemption re-stage semantics
//! ([`crate::sched::SchedState::on_preempt`]): work lost to a fault
//! re-enters the frontier with a per-request retry budget and exponential
//! backoff, crashed devices leave the available set
//! ([`crate::sched::SchedState::on_device_down`]), and slowdowns feed the
//! contention-model run rates. When retries are exhausted (or no device
//! survives), the affected requests are *shed* — a typed outcome distinct
//! from rejection, conserving `served + rejected + shed == offered`.
//!
//! With no plan installed every execution path is byte-identical to the
//! fault-free build; an installed plan with zero events is equivalent to
//! no plan (the clock never fires and rates multiply by exactly 1.0).

use crate::error::{Error, Result};
use crate::json::Json;
use crate::platform::DeviceId;

/// Tolerance for "due at this instant" comparisons — matches the event
/// loops' `EPS`.
const EPS: f64 = 1e-12;

/// What happens to a device at a fault instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Device dies: every resident component is lost and the device never
    /// returns to the available set.
    Crash,
    /// Kernels on the device stop progressing for `dur` seconds but do not
    /// complete (rate 0); progress resumes when the wedge expires.
    Wedge { dur: f64 },
    /// Device runs at `factor` of its calibrated speed from this instant
    /// on (`factor` in `(0, 1]`; a later Slowdown event replaces it).
    Slowdown { factor: f64 },
}

impl FaultKind {
    /// Stable report/JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Wedge { .. } => "wedge",
            FaultKind::Slowdown { .. } => "slowdown",
        }
    }
}

/// One injected fault: `kind` strikes `device` at instant `at` (seconds on
/// the executing path's clock).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub device: DeviceId,
    pub at: f64,
    pub kind: FaultKind,
}

/// Which queued work the server sheds first when degradation is required.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedPolicy {
    /// Shed the lowest-priority queued work first (ties: latest deadline).
    #[default]
    LowestPriority,
    /// Shed the latest-deadline queued work first (ties: lowest priority).
    LatestDeadline,
}

impl ShedPolicy {
    pub fn name(self) -> &'static str {
        match self {
            ShedPolicy::LowestPriority => "lowest-priority",
            ShedPolicy::LatestDeadline => "latest-deadline",
        }
    }

    /// Parse a CLI/JSON policy name.
    pub fn parse(s: &str) -> Result<ShedPolicy> {
        match s {
            "lowest-priority" => Ok(ShedPolicy::LowestPriority),
            "latest-deadline" => Ok(ShedPolicy::LatestDeadline),
            other => Err(Error::Spec(format!(
                "unknown shed policy '{other}' (expected lowest-priority or latest-deadline)"
            ))),
        }
    }
}

/// A deterministic fault-injection scenario plus the recovery knobs that
/// govern the response to it.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Injected faults, sorted by `at` (construction/parse sorts; ties keep
    /// input order).
    pub events: Vec<FaultEvent>,
    /// Max fault-triggered retries per request before it is shed.
    pub retry_budget: u32,
    /// Base of the exponential backoff before a fault-displaced component
    /// re-enters the frontier: retry `k` waits `backoff_base * 2^(k-1)`.
    pub backoff_base: f64,
    /// Degradation policy for queued work that can no longer meet its
    /// deadline.
    pub shed_policy: ShedPolicy,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            events: Vec::new(),
            retry_budget: 3,
            backoff_base: 1e-3,
            shed_policy: ShedPolicy::default(),
        }
    }
}

impl FaultPlan {
    /// Sort events by instant (stable: same-instant events keep input
    /// order) and validate.
    pub fn normalized(mut self) -> Result<FaultPlan> {
        self.events.sort_by(|a, b| a.at.total_cmp(&b.at));
        self.validate()?;
        Ok(self)
    }

    /// Structural validation: finite non-negative instants, positive wedge
    /// durations, slowdown factors in `(0, 1]`, finite positive backoff.
    pub fn validate(&self) -> Result<()> {
        if !(self.backoff_base.is_finite() && self.backoff_base >= 0.0) {
            return Err(Error::Spec(format!(
                "fault plan: backoff_base_s must be finite and >= 0, got {}",
                self.backoff_base
            )));
        }
        for (i, e) in self.events.iter().enumerate() {
            if !(e.at.is_finite() && e.at >= 0.0) {
                return Err(Error::Spec(format!(
                    "fault plan event {i}: instant must be finite and >= 0, got {}",
                    e.at
                )));
            }
            match e.kind {
                FaultKind::Crash => {}
                FaultKind::Wedge { dur } => {
                    if !(dur.is_finite() && dur > 0.0) {
                        return Err(Error::Spec(format!(
                            "fault plan event {i}: wedge dur_s must be finite and > 0, got {dur}"
                        )));
                    }
                }
                FaultKind::Slowdown { factor } => {
                    if !(factor.is_finite() && factor > 0.0 && factor <= 1.0) {
                        return Err(Error::Spec(format!(
                            "fault plan event {i}: slowdown factor must be in (0, 1], got {factor}"
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Check every event's device index against a platform size.
    pub fn validate_devices(&self, ndev: usize) -> Result<()> {
        for (i, e) in self.events.iter().enumerate() {
            if e.device >= ndev {
                return Err(Error::Spec(format!(
                    "fault plan event {i}: device {} out of range (platform has {ndev})",
                    e.device
                )));
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------- queries
    //
    // Point-in-time views for the real executor, which cannot replay a
    // clock — it asks "what is true of this device at wall instant t?".

    /// Is `dev` crashed at instant `t`?
    pub fn down_at(&self, dev: DeviceId, t: f64) -> bool {
        self.events
            .iter()
            .any(|e| e.device == dev && e.at <= t + EPS && matches!(e.kind, FaultKind::Crash))
    }

    /// Seconds of wedge remaining on `dev` at instant `t` (0 when none).
    pub fn wedge_remaining_at(&self, dev: DeviceId, t: f64) -> f64 {
        let mut rem: f64 = 0.0;
        for e in &self.events {
            if e.device == dev && e.at <= t + EPS {
                if let FaultKind::Wedge { dur } = e.kind {
                    rem = rem.max(e.at + dur - t);
                }
            }
        }
        rem.max(0.0)
    }

    /// Speed factor of `dev` at instant `t` (last Slowdown at or before
    /// `t` wins; 1.0 when none).
    pub fn slow_factor_at(&self, dev: DeviceId, t: f64) -> f64 {
        let mut f = 1.0;
        for e in &self.events {
            if e.device == dev && e.at <= t + EPS {
                if let FaultKind::Slowdown { factor } = e.kind {
                    f = factor;
                }
            }
        }
        f
    }

    // ---------------------------------------------------------------- json

    /// Parse a plan from its JSON object form (see the README "Fault
    /// tolerance" section for the schema).
    pub fn from_json(v: &Json) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        if let Some(n) = v.get("retry_budget") {
            let b = n.as_u64().ok_or_else(|| {
                Error::Spec("fault plan: retry_budget must be a non-negative integer".into())
            })?;
            plan.retry_budget = b as u32;
        }
        if let Some(n) = v.get("backoff_base_s") {
            plan.backoff_base = n
                .as_f64()
                .ok_or_else(|| Error::Spec("fault plan: backoff_base_s must be a number".into()))?;
        }
        if let Some(s) = v.get("shed_policy") {
            let s = s
                .as_str()
                .ok_or_else(|| Error::Spec("fault plan: shed_policy must be a string".into()))?;
            plan.shed_policy = ShedPolicy::parse(s)?;
        }
        if let Some(events) = v.get("events") {
            let arr = events
                .as_arr()
                .ok_or_else(|| Error::Spec("fault plan: events must be an array".into()))?;
            for (i, e) in arr.iter().enumerate() {
                let num = |key: &str| -> Result<f64> {
                    e.field(key)?.as_f64().ok_or_else(|| {
                        Error::Spec(format!("fault plan event {i}: {key} must be a number"))
                    })
                };
                let device = e.field("device")?.as_usize().ok_or_else(|| {
                    Error::Spec(format!("fault plan event {i}: device must be an index"))
                })?;
                let at = num("at_s")?;
                let kind = match e.field("kind")?.as_str() {
                    Some("crash") => FaultKind::Crash,
                    Some("wedge") => FaultKind::Wedge { dur: num("dur_s")? },
                    Some("slowdown") => FaultKind::Slowdown {
                        factor: num("factor")?,
                    },
                    other => {
                        return Err(Error::Spec(format!(
                            "fault plan event {i}: unknown kind {other:?} \
                             (expected crash, wedge, or slowdown)"
                        )))
                    }
                };
                plan.events.push(FaultEvent { device, at, kind });
            }
        }
        plan.normalized()
    }

    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        FaultPlan::from_json(&Json::parse(text)?)
    }

    /// Load a plan from a JSON file, naming the path in the error.
    pub fn from_file(path: &str) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("cannot read fault plan {path}: {e}")))?;
        FaultPlan::parse(&text)
            .map_err(|e| Error::Spec(format!("fault plan {path}: {e}")))
    }

    /// JSON object form (round-trips through [`from_json`](Self::from_json)).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("retry_budget", Json::num(self.retry_budget as f64)),
            ("backoff_base_s", Json::num(self.backoff_base)),
            ("shed_policy", Json::str(self.shed_policy.name())),
            (
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            let mut fields = vec![
                                ("device", Json::num(e.device as f64)),
                                ("at_s", Json::num(e.at)),
                                ("kind", Json::str(e.kind.name())),
                            ];
                            match e.kind {
                                FaultKind::Crash => {}
                                FaultKind::Wedge { dur } => fields.push(("dur_s", Json::num(dur))),
                                FaultKind::Slowdown { factor } => {
                                    fields.push(("factor", Json::num(factor)))
                                }
                            }
                            Json::obj(fields)
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Runtime replay state of a [`FaultPlan`] inside an event loop: a cursor
/// over the (sorted) events plus the per-device condition they have
/// established so far. Pure function of the plan and the sequence of
/// `take_due`/`apply` calls — deterministic by construction.
#[derive(Debug, Clone)]
pub struct FaultClock {
    events: Vec<FaultEvent>,
    cursor: usize,
    down: Vec<bool>,
    wedged_until: Vec<f64>,
    slow: Vec<f64>,
}

impl FaultClock {
    /// Clock over `plan` for a platform of `ndev` devices. The plan should
    /// already be [`normalized`](FaultPlan::normalized).
    pub fn new(plan: &FaultPlan, ndev: usize) -> FaultClock {
        let mut events = plan.events.clone();
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        FaultClock {
            events,
            cursor: 0,
            down: vec![false; ndev],
            wedged_until: vec![0.0; ndev],
            slow: vec![1.0; ndev],
        }
    }

    /// The next instant at which fault state changes: the earliest
    /// unapplied event (which may be `<= now` if the caller has not yet
    /// drained it) or the earliest wedge expiry strictly after `now`.
    pub fn next_change_at(&self, now: f64) -> Option<f64> {
        let mut t = self.events.get(self.cursor).map(|e| e.at);
        for (d, &until) in self.wedged_until.iter().enumerate() {
            if !self.down[d] && until > now + EPS {
                t = Some(match t {
                    Some(x) => x.min(until),
                    None => until,
                });
            }
        }
        t
    }

    /// Are unapplied events due at or before `now`?
    pub fn any_due(&self, now: f64) -> bool {
        self.events
            .get(self.cursor)
            .map(|e| e.at <= now + EPS)
            .unwrap_or(false)
    }

    /// Pop every event due at or before `now` into `out` (in plan order)
    /// without applying it — the caller decides the interleaving with
    /// same-instant completions, then calls [`apply`](Self::apply).
    pub fn take_due(&mut self, now: f64, out: &mut Vec<FaultEvent>) {
        while let Some(e) = self.events.get(self.cursor) {
            if e.at > now + EPS {
                break;
            }
            out.push(*e);
            self.cursor += 1;
        }
    }

    /// Fold one event into the per-device condition.
    pub fn apply(&mut self, e: &FaultEvent) {
        match e.kind {
            FaultKind::Crash => self.down[e.device] = true,
            FaultKind::Wedge { dur } => {
                self.wedged_until[e.device] = self.wedged_until[e.device].max(e.at + dur)
            }
            FaultKind::Slowdown { factor } => self.slow[e.device] = factor,
        }
    }

    /// Is `dev` crashed (as of the applied events)?
    pub fn is_down(&self, dev: DeviceId) -> bool {
        self.down[dev]
    }

    /// Run-rate multiplier for `dev` at instant `now`: 0 while wedged (or
    /// crashed), the slowdown factor otherwise (1.0 when healthy).
    pub fn rate_factor(&self, dev: DeviceId, now: f64) -> f64 {
        if self.down[dev] || self.wedged_until[dev] > now + EPS {
            0.0
        } else {
            self.slow[dev]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan3() -> FaultPlan {
        FaultPlan {
            events: vec![
                FaultEvent {
                    device: 1,
                    at: 0.05,
                    kind: FaultKind::Crash,
                },
                FaultEvent {
                    device: 0,
                    at: 0.02,
                    kind: FaultKind::Wedge { dur: 0.01 },
                },
                FaultEvent {
                    device: 2,
                    at: 0.0,
                    kind: FaultKind::Slowdown { factor: 0.5 },
                },
            ],
            retry_budget: 2,
            backoff_base: 1e-4,
            shed_policy: ShedPolicy::LatestDeadline,
        }
        .normalized()
        .unwrap()
    }

    #[test]
    fn normalize_sorts_and_json_round_trips() {
        let p = plan3();
        assert!(p.events.windows(2).all(|w| w[0].at <= w[1].at));
        let back = FaultPlan::parse(&p.to_json().to_string()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.shed_policy.name(), "latest-deadline");
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let bad_at = FaultPlan {
            events: vec![FaultEvent {
                device: 0,
                at: -1.0,
                kind: FaultKind::Crash,
            }],
            ..FaultPlan::default()
        };
        assert!(matches!(bad_at.validate(), Err(Error::Spec(_))));
        let bad_factor = FaultPlan {
            events: vec![FaultEvent {
                device: 0,
                at: 0.0,
                kind: FaultKind::Slowdown { factor: 1.5 },
            }],
            ..FaultPlan::default()
        };
        assert!(matches!(bad_factor.validate(), Err(Error::Spec(_))));
        let bad_dur = FaultPlan {
            events: vec![FaultEvent {
                device: 0,
                at: 0.0,
                kind: FaultKind::Wedge { dur: 0.0 },
            }],
            ..FaultPlan::default()
        };
        assert!(matches!(bad_dur.validate(), Err(Error::Spec(_))));
        assert!(plan3().validate_devices(2).is_err());
        assert!(plan3().validate_devices(3).is_ok());
        assert!(ShedPolicy::parse("nope").is_err());
    }

    #[test]
    fn clock_replays_conditions_in_order() {
        let p = plan3();
        let mut c = FaultClock::new(&p, 3);
        assert_eq!(c.next_change_at(0.0), Some(0.0));
        let mut due = Vec::new();
        c.take_due(0.0, &mut due);
        assert_eq!(due.len(), 1);
        for e in &due {
            c.apply(e);
        }
        assert_eq!(c.rate_factor(2, 0.0), 0.5);
        assert_eq!(c.rate_factor(0, 0.0), 1.0);

        // Wedge at 0.02: rate 0 during, restored after expiry at 0.03.
        due.clear();
        c.take_due(0.02, &mut due);
        assert_eq!(due.len(), 1);
        for e in &due {
            c.apply(e);
        }
        assert_eq!(c.rate_factor(0, 0.025), 0.0);
        assert_eq!(c.rate_factor(0, 0.031), 1.0);
        // Next change: the wedge expiry, then the crash.
        assert_eq!(c.next_change_at(0.025), Some(0.03));

        due.clear();
        c.take_due(0.05, &mut due);
        assert_eq!(due.len(), 1);
        for e in &due {
            c.apply(e);
        }
        assert!(c.is_down(1));
        assert_eq!(c.rate_factor(1, 1.0), 0.0);
        assert_eq!(c.next_change_at(0.05), None);
    }

    #[test]
    fn point_in_time_queries_match_the_clock() {
        let p = plan3();
        assert!(!p.down_at(1, 0.049));
        assert!(p.down_at(1, 0.05));
        assert!(p.wedge_remaining_at(0, 0.025) > 0.004);
        assert_eq!(p.wedge_remaining_at(0, 0.05), 0.0);
        assert_eq!(p.slow_factor_at(2, 0.0), 0.5);
        assert_eq!(p.slow_factor_at(2, f64::INFINITY), 0.5);
        assert_eq!(p.slow_factor_at(0, 1.0), 1.0);
    }

    #[test]
    fn empty_plan_is_inert() {
        let p = FaultPlan::default();
        let c = FaultClock::new(&p, 4);
        assert_eq!(c.next_change_at(0.0), None);
        for d in 0..4 {
            assert_eq!(c.rate_factor(d, 123.0), 1.0);
            assert!(!c.is_down(d));
        }
    }

    #[test]
    fn from_file_names_the_path_on_error() {
        let e = FaultPlan::from_file("/nonexistent/plan.json").unwrap_err();
        assert!(matches!(e, Error::Io(_)), "{e}");
        assert!(e.to_string().contains("/nonexistent/plan.json"), "{e}");
    }
}
