//! Command-queue structure `Q = ⟨Q, E_Q⟩` (paper §3 Def 4) and its
//! correct-by-construction synthesis (`setup_cq`, paper §4B / Fig 9).
//!
//! * [`command`] — write / ndrange / read commands and their event ids.
//! * [`structure`] — the per-(component, device) queue set plus the explicit
//!   cross-queue precedence set `E_Q` and callback registrations.
//! * [`enq`] — the paper's `enq(k, q)` rule set, round-robin queue selection
//!   (`sel_rr`), `set_dependencies`, and `set_callbacks`.

pub mod command;
pub mod enq;
pub mod structure;

pub use command::{CmdId, Command, CommandKind};
pub use enq::setup_cq;
pub use structure::CommandQueues;
