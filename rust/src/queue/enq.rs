//! `setup_cq` (paper §4B, Algorithm 1 lines 7–12): synthesize the
//! command-queue structure for one task component on one device using the
//! `enq(k, q)` rule set of §3, `sel_rr` round-robin queue selection,
//! `set_dependencies` for `E_Q`, and `set_callbacks` for completion
//! notification.

use super::command::CommandKind;
use super::structure::CommandQueues;
use crate::graph::{CopyClass, Dag, KernelId, Partition};
use crate::platform::Device;
use std::collections::{HashSet, VecDeque};

/// Synthesize `Q = ⟨Q, E_Q⟩` for component `cid` of `partition` on `device`.
///
/// Enqueue rules (paper §3):
/// 1. `k ∈ FRONT(T)`: dependent writes for inter-edge-fed input buffers,
///    then the ndrange.
/// 2. `k ∈ END(T)`: the ndrange, then dependent reads for inter-edge-read
///    output buffers.
/// 3. `k ∈ IN(T)`: only the ndrange.
/// 4. Every kernel additionally gets all *isolated* writes before and all
///    *isolated* reads after its ndrange.
///
/// Kernels are processed in intra-component BFS order starting from
/// `FRONT(T) ∪` component-local sources, each assigned a queue by `sel_rr`.
pub fn setup_cq(
    dag: &Dag,
    partition: &Partition,
    cid: usize,
    device: &Device,
) -> CommandQueues {
    let comp = &partition.components[cid];
    let mut cq = CommandQueues::new(cid, device.id, device.num_queues);
    let front: HashSet<KernelId> = partition.front(dag, cid).into_iter().collect();
    let end: HashSet<KernelId> = partition.end(dag, cid).into_iter().collect();
    let members: HashSet<KernelId> = comp.kernels.iter().copied().collect();

    // `unprocessed ← FRONT(T)` plus component-local sources (kernels with no
    // intra-component predecessor at all — FRONT is empty for components with
    // no inter inputs, e.g. independent transformer heads).
    let mut order: Vec<KernelId> = Vec::with_capacity(comp.kernels.len());
    let mut queued: HashSet<KernelId> = HashSet::new();
    let mut unprocessed: VecDeque<KernelId> = VecDeque::new();
    let intra_preds = |k: KernelId| -> Vec<KernelId> {
        dag.kernel_preds(k)
            .into_iter()
            .filter(|p| members.contains(p))
            .collect()
    };
    for &k in &comp.kernels {
        if front.contains(&k) || intra_preds(k).is_empty() {
            unprocessed.push_back(k);
            queued.insert(k);
        }
    }
    // BFS respecting intra-component topology: a kernel is processed only
    // once all its intra predecessors are processed (the paper's `update`).
    let mut processed: HashSet<KernelId> = HashSet::new();
    while let Some(k) = unprocessed.pop_front() {
        if intra_preds(k).iter().any(|p| !processed.contains(p)) {
            unprocessed.push_back(k); // not ready yet; re-queue
            continue;
        }
        processed.insert(k);
        order.push(k);
        for s in dag.kernel_succs(k) {
            if members.contains(&s) && queued.insert(s) {
                unprocessed.push_back(s);
            }
        }
    }
    debug_assert_eq!(order.len(), comp.kernels.len(), "component not connected?");

    // Round-robin queue selector (paper's sel_rr).
    let nq = cq.queues.len();
    let mut rr = 0usize;
    let mut ndrange_of = vec![usize::MAX; dag.num_kernels()];

    for k in order {
        let q = rr % nq;
        rr += 1;
        // enq(k, q) — writes.
        let mut write_cmds = Vec::new();
        for &bi in &dag.kernels[k].inputs {
            // Io buffers appear in both lists; writes keyed off input role.
            let needs_write = match dag.write_class(bi) {
                CopyClass::Isolated => true,
                CopyClass::Dependent => {
                    // Only FRONT kernels re-materialize dependent writes, and
                    // only for inter-fed buffers (intra data stays resident).
                    front.contains(&k) && {
                        let bp = dag.buffer_pred(bi).expect("dependent write has pred");
                        partition.assignment[dag.buffers[bp].kernel] != cid
                    }
                }
            };
            if needs_write {
                write_cmds.push(cq.push(q, CommandKind::Write { buffer: bi }, k));
            }
        }
        // enq(k, q) — ndrange.
        let nd = cq.push(q, CommandKind::NdRange, k);
        ndrange_of[k] = nd;
        // set_dependencies rule (i): writes before their ndrange (implicit —
        // same queue — but recorded for clarity via add_dep's filter).
        for w in write_cmds {
            cq.add_dep(w, nd);
        }
        // set_dependencies rule (iii): intra-edge ndrange → ndrange.
        for p in dag.kernel_preds(k) {
            if members.contains(&p) {
                debug_assert_ne!(ndrange_of[p], usize::MAX, "BFS order violated");
                cq.add_dep(ndrange_of[p], nd);
            }
        }
        // enq(k, q) — reads.
        for &bo in &dag.kernels[k].outputs {
            let needs_read = match dag.read_class(bo) {
                CopyClass::Isolated => true,
                CopyClass::Dependent => {
                    end.contains(&k)
                        && dag.buffer_succs(bo).iter().any(|&bs| {
                            partition.assignment[dag.buffers[bs].kernel] != cid
                        })
                }
            };
            if needs_read {
                let r = cq.push(q, CommandKind::Read { buffer: bo }, k);
                // set_dependencies rule (ii).
                cq.add_dep(nd, r);
            }
        }
    }

    set_callbacks(dag, partition, cid, device, &mut cq);
    debug_assert!(cq.check_invariants().is_ok());
    cq
}

/// Register completion callbacks (paper §4B "Callback Assignment"):
/// * GPU device: on every read command of a callback kernel (END kernels'
///   dependent reads pertaining to inter edges, plus terminal isolated reads
///   — cf. Fig. 2's `cb` on the final read).
/// * CPU device (shares host memory): on the ndrange of callback kernels.
fn set_callbacks(
    dag: &Dag,
    partition: &Partition,
    cid: usize,
    device: &Device,
    cq: &mut CommandQueues,
) {
    let targets = partition.callback_kernels(dag, cid);
    for k in targets {
        if device.shares_host_memory {
            if let Some(nd) = cq.ndrange_of(k) {
                cq.callbacks.push(nd);
            }
        } else {
            let mut any_read = false;
            for c in cq.commands_of(k) {
                if matches!(cq.commands[c].kind, CommandKind::Read { .. }) {
                    cq.callbacks.push(c);
                    any_read = true;
                }
            }
            // Kernels whose results stay device-resident (no reads enqueued)
            // still need completion tracking: fall back to the ndrange.
            if !any_read {
                if let Some(nd) = cq.ndrange_of(k) {
                    cq.callbacks.push(nd);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagBuilder;
    use crate::platform::{Device, DeviceType};

    /// The Fig. 6/9 component: kp → {k0,k1,k2,k3,k4} → kn, mapped to a GPU
    /// with 3 command queues, exactly as in the paper's Fig. 9 walkthrough.
    fn fig9() -> (Dag, Partition, Vec<KernelId>) {
        let mut b = DagBuilder::new();
        let kp = b.kernel("kp", DeviceType::Cpu, 1, 1);
        let k0 = b.kernel("k0", DeviceType::Gpu, 1, 1);
        let k1 = b.kernel("k1", DeviceType::Gpu, 1, 1);
        let k2 = b.kernel("k2", DeviceType::Gpu, 1, 1);
        let k3 = b.kernel("k3", DeviceType::Gpu, 1, 1);
        let k4 = b.kernel("k4", DeviceType::Gpu, 1, 1);
        let kn = b.kernel("kn", DeviceType::Cpu, 1, 1);
        let b0 = b.out_buf(kp, 4);
        let b1 = b.out_buf(kp, 4);
        let b2 = b.in_buf(k0, 4);
        let b3 = b.in_buf(k0, 4);
        let b4 = b.out_buf(k0, 4);
        let b5 = b.in_buf(k1, 4); // isolated write w3
        let b6 = b.in_buf(k1, 4);
        let b7 = b.in_buf(k2, 4);
        let b8 = b.in_buf(k2, 4); // isolated write
        let b9 = b.out_buf(k1, 4);
        let b10 = b.out_buf(k2, 4);
        let b11 = b.in_buf(k3, 4);
        let b12 = b.in_buf(k4, 4);
        let b13 = b.out_buf(k3, 4);
        let b14 = b.out_buf(k4, 4);
        let b15 = b.in_buf(kn, 4);
        let b16 = b.in_buf(kn, 4);
        b.edge(b0, b2);
        b.edge(b1, b3);
        b.edge(b4, b6);
        b.edge(b4, b7);
        b.edge(b9, b11);
        b.edge(b10, b12);
        b.edge(b13, b15);
        b.edge(b14, b16);
        let _ = (b5, b8);
        let dag = b.build().unwrap();
        let part = Partition::new(
            &dag,
            vec![
                (vec![kp], DeviceType::Cpu),
                (vec![k0, k1, k2, k3, k4], DeviceType::Gpu),
                (vec![kn], DeviceType::Cpu),
            ],
        )
        .unwrap();
        (dag, part, vec![kp, k0, k1, k2, k3, k4, kn])
    }

    #[test]
    fn fig9_command_census() {
        let (dag, part, _) = fig9();
        let dev = Device::gtx970(0, 3);
        let cq = setup_cq(&dag, &part, 1, &dev);
        // Paper Fig. 9: w1,w2 (k0 dependent writes), w3 (k1 isolated),
        // w4 (k2 isolated), e1..e5, r1 (k3), r2 (k4) => 4 writes, 5 ndrange,
        // 2 reads.
        assert_eq!(cq.kind_census(), (4, 5, 2));
        cq.check_invariants().unwrap();
    }

    #[test]
    fn fig9_round_robin_queue_assignment() {
        let (dag, part, ks) = fig9();
        let dev = Device::gtx970(0, 3);
        let cq = setup_cq(&dag, &part, 1, &dev);
        // BFS order k0,k1,k2,k3,k4 → queues 0,1,2,0,1 (paper Fig. 9).
        let q_of = |k| cq.commands[cq.ndrange_of(k).unwrap()].queue;
        assert_eq!(q_of(ks[1]), 0);
        assert_eq!(q_of(ks[2]), 1);
        assert_eq!(q_of(ks[3]), 2);
        assert_eq!(q_of(ks[4]), 0);
        assert_eq!(q_of(ks[5]), 1);
    }

    #[test]
    fn fig9_eq_contains_paper_deps() {
        let (dag, part, ks) = fig9();
        let dev = Device::gtx970(0, 3);
        let cq = setup_cq(&dag, &part, 1, &dev);
        let nd = |k| cq.ndrange_of(k).unwrap();
        // E_Q: e1→e2, e1→e3, e2→e4, e3→e5 (cross-queue intra deps).
        let expect = [
            (nd(ks[1]), nd(ks[2])),
            (nd(ks[1]), nd(ks[3])),
            (nd(ks[2]), nd(ks[4])),
            (nd(ks[3]), nd(ks[5])),
        ];
        for pair in expect {
            assert!(cq.e_q.contains(&pair), "missing dep {pair:?} in {:?}", cq.e_q);
        }
        // k3/k4's reads depend on their own ndranges only when cross-queue;
        // enq puts them in the same queue, so E_Q is exactly the 4 above.
        assert_eq!(cq.e_q.len(), 4);
    }

    #[test]
    fn intra_resident_buffers_skip_transfers() {
        let (dag, part, ks) = fig9();
        let dev = Device::gtx970(0, 3);
        let cq = setup_cq(&dag, &part, 1, &dev);
        // k1's intra-fed input b6 must NOT get a write; k1's output b9 must
        // NOT get a read (consumed by k3 in-component).
        for c in cq.commands_of(ks[2]) {
            match cq.commands[c].kind {
                CommandKind::Write { buffer } => {
                    assert_eq!(dag.buffers[buffer].pos, 0, "only isolated b5 write");
                }
                CommandKind::Read { .. } => panic!("k1 must not read"),
                CommandKind::NdRange => {}
            }
        }
    }

    #[test]
    fn gpu_callbacks_on_reads_cpu_on_ndrange() {
        let (dag, part, ks) = fig9();
        let gpu = Device::gtx970(0, 3);
        let cq = setup_cq(&dag, &part, 1, &gpu);
        // END = {k3, k4}: callbacks on their read commands (r1, r2).
        assert_eq!(cq.callbacks.len(), 2);
        for &c in &cq.callbacks {
            assert!(matches!(cq.commands[c].kind, CommandKind::Read { .. }));
            assert!(cq.commands[c].kernel == ks[4] || cq.commands[c].kernel == ks[5]);
        }
        // Same component on a CPU: callbacks move to the ndrange events.
        let mut cpu_part_groups = vec![
            (vec![ks[0]], DeviceType::Cpu),
            (vec![ks[1], ks[2], ks[3], ks[4], ks[5]], DeviceType::Cpu),
            (vec![ks[6]], DeviceType::Cpu),
        ];
        let part_cpu = Partition::new(&dag, cpu_part_groups.drain(..).collect()).unwrap();
        let cpu = Device::i5_4690k(1, 2);
        let cq2 = setup_cq(&dag, &part_cpu, 1, &cpu);
        for &c in &cq2.callbacks {
            assert!(cq2.commands[c].is_ndrange());
        }
    }

    #[test]
    fn single_queue_is_fully_serial() {
        let (dag, part, _) = fig9();
        let dev = Device::gtx970(0, 1);
        let cq = setup_cq(&dag, &part, 1, &dev);
        assert_eq!(cq.queues.len(), 1);
        assert_eq!(cq.queues[0].len(), cq.num_commands());
        // All deps implicit: E_Q empty.
        assert!(cq.e_q.is_empty());
    }
}
