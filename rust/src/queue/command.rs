//! OpenCL-style commands. Each enqueued command owns an implicit event
//! object (its [`CmdId`]) used for cross-queue dependencies and callbacks —
//! mirroring `clEnqueue*`'s trailing event argument in the paper's host
//! programs.

use crate::graph::{BufferId, KernelId};

/// Event / command identifier, unique within one [`super::CommandQueues`].
pub type CmdId = usize;

/// The three OpenCL command kinds of Def 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommandKind {
    /// H2D transfer (`clEnqueueWriteBuffer`) of an input buffer.
    Write { buffer: BufferId },
    /// Kernel launch (`clEnqueueNDRangeKernel`).
    NdRange,
    /// D2H transfer (`clEnqueueReadBuffer`) of an output buffer.
    Read { buffer: BufferId },
}

/// One enqueued command.
#[derive(Debug, Clone)]
pub struct Command {
    pub id: CmdId,
    pub kind: CommandKind,
    /// The kernel this command belongs to.
    pub kernel: KernelId,
    /// Which command queue it was enqueued to.
    pub queue: usize,
    /// Position within that queue (in-order execution index).
    pub seq: usize,
}

impl Command {
    pub fn is_ndrange(&self) -> bool {
        matches!(self.kind, CommandKind::NdRange)
    }

    pub fn is_transfer(&self) -> bool {
        !self.is_ndrange()
    }

    /// Bytes moved if this is a transfer command.
    pub fn transfer_buffer(&self) -> Option<BufferId> {
        match self.kind {
            CommandKind::Write { buffer } | CommandKind::Read { buffer } => Some(buffer),
            CommandKind::NdRange => None,
        }
    }
}
