//! The per-(task-component, device) command-queue structure.

use super::command::{CmdId, Command, CommandKind};
use crate::graph::KernelId;
use crate::platform::DeviceId;

/// `Q = ⟨Q, E_Q⟩` bound to a concrete device: the output of `setup_cq` and
/// the unit of dispatch. Executed by both the simulator and the real
/// executor.
#[derive(Debug, Clone)]
pub struct CommandQueues {
    /// Task component this structure was synthesized for.
    pub component: usize,
    /// Device the component was dispatched to.
    pub device: DeviceId,
    /// `Q`: each inner vec is an in-order command queue (list of CmdIds).
    pub queues: Vec<Vec<CmdId>>,
    /// Command storage indexed by CmdId.
    pub commands: Vec<Command>,
    /// `E_Q`: explicit precedence constraints `(before, after)`. Only
    /// cross-queue pairs are recorded — same-queue ordering is implicit via
    /// in-order execution (the paper assumes barrier-free in-order queues).
    pub e_q: Vec<(CmdId, CmdId)>,
    /// Commands carrying a registered completion callback (`cb` instances
    /// from `set_callbacks`). Their completion feeds `update_status`.
    pub callbacks: Vec<CmdId>,
}

impl CommandQueues {
    pub fn new(component: usize, device: DeviceId, num_queues: usize) -> Self {
        CommandQueues {
            component,
            device,
            queues: vec![Vec::new(); num_queues.max(1)],
            commands: Vec::new(),
            e_q: Vec::new(),
            callbacks: Vec::new(),
        }
    }

    /// Append a command to queue `q`, returning its event id.
    pub fn push(&mut self, q: usize, kind: CommandKind, kernel: KernelId) -> CmdId {
        let id = self.commands.len();
        let seq = self.queues[q].len();
        self.commands.push(Command {
            id,
            kind,
            kernel,
            queue: q,
            seq,
        });
        self.queues[q].push(id);
        id
    }

    /// Record a cross-queue precedence constraint; same-queue pairs are
    /// dropped (implicit in in-order execution).
    pub fn add_dep(&mut self, before: CmdId, after: CmdId) {
        if self.commands[before].queue != self.commands[after].queue
            && !self.e_q.contains(&(before, after))
        {
            self.e_q.push((before, after));
        }
    }

    /// All explicit dependencies of `cmd`.
    pub fn deps_of(&self, cmd: CmdId) -> Vec<CmdId> {
        self.e_q
            .iter()
            .filter(|&&(_, a)| a == cmd)
            .map(|&(b, _)| b)
            .collect()
    }

    /// The ndrange command of kernel `k`, if enqueued.
    pub fn ndrange_of(&self, k: KernelId) -> Option<CmdId> {
        self.commands
            .iter()
            .find(|c| c.kernel == k && c.is_ndrange())
            .map(|c| c.id)
    }

    /// All commands belonging to kernel `k`.
    pub fn commands_of(&self, k: KernelId) -> Vec<CmdId> {
        self.commands
            .iter()
            .filter(|c| c.kernel == k)
            .map(|c| c.id)
            .collect()
    }

    pub fn num_commands(&self) -> usize {
        self.commands.len()
    }

    /// Count of commands per kind: (writes, ndranges, reads).
    pub fn kind_census(&self) -> (usize, usize, usize) {
        let mut w = 0;
        let mut n = 0;
        let mut r = 0;
        for c in &self.commands {
            match c.kind {
                CommandKind::Write { .. } => w += 1,
                CommandKind::NdRange => n += 1,
                CommandKind::Read { .. } => r += 1,
            }
        }
        (w, n, r)
    }

    /// Structural invariants used by property tests:
    /// every command in exactly one queue slot, E_Q endpoints valid and
    /// strictly cross-queue, and the dependency relation acyclic when
    /// combined with in-order queue edges.
    pub fn check_invariants(&self) -> crate::error::Result<()> {
        use crate::error::Error;
        let mut seen = vec![false; self.commands.len()];
        for (qi, q) in self.queues.iter().enumerate() {
            for (seq, &c) in q.iter().enumerate() {
                let cmd = &self.commands[c];
                if cmd.queue != qi || cmd.seq != seq {
                    return Err(Error::Queue(format!(
                        "command {c} misfiled: queue {}/{qi} seq {}/{seq}",
                        cmd.queue, cmd.seq
                    )));
                }
                if seen[c] {
                    return Err(Error::Queue(format!("command {c} in two slots")));
                }
                seen[c] = true;
            }
        }
        if seen.iter().any(|&s| !s) {
            return Err(Error::Queue("orphan command".into()));
        }
        for &(b, a) in &self.e_q {
            if b >= self.commands.len() || a >= self.commands.len() {
                return Err(Error::Queue(format!("dangling E_Q edge ({b},{a})")));
            }
            if self.commands[b].queue == self.commands[a].queue {
                return Err(Error::Queue(format!(
                    "same-queue E_Q edge ({b},{a}) should be implicit"
                )));
            }
        }
        // Acyclicity of (E_Q ∪ in-order edges).
        let n = self.commands.len();
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for q in &self.queues {
            for w in q.windows(2) {
                adj[w[0]].push(w[1]);
                indeg[w[1]] += 1;
            }
        }
        for &(b, a) in &self.e_q {
            adj[b].push(a);
            indeg[a] += 1;
        }
        let mut stack: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut visited = 0;
        while let Some(c) = stack.pop() {
            visited += 1;
            for &s in &adj[c] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    stack.push(s);
                }
            }
        }
        if visited != n {
            return Err(Error::Queue("cyclic command dependencies".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_maintains_order() {
        let mut cq = CommandQueues::new(0, 0, 2);
        let a = cq.push(0, CommandKind::Write { buffer: 0 }, 0);
        let b = cq.push(0, CommandKind::NdRange, 0);
        let c = cq.push(1, CommandKind::NdRange, 1);
        assert_eq!(cq.queues[0], vec![a, b]);
        assert_eq!(cq.queues[1], vec![c]);
        assert_eq!(cq.commands[b].seq, 1);
        cq.check_invariants().unwrap();
    }

    #[test]
    fn same_queue_deps_are_implicit() {
        let mut cq = CommandQueues::new(0, 0, 2);
        let a = cq.push(0, CommandKind::Write { buffer: 0 }, 0);
        let b = cq.push(0, CommandKind::NdRange, 0);
        cq.add_dep(a, b);
        assert!(cq.e_q.is_empty());
        let c = cq.push(1, CommandKind::NdRange, 1);
        cq.add_dep(b, c);
        assert_eq!(cq.e_q, vec![(b, c)]);
        cq.check_invariants().unwrap();
    }

    #[test]
    fn invariants_catch_cycles() {
        let mut cq = CommandQueues::new(0, 0, 2);
        let a = cq.push(0, CommandKind::NdRange, 0);
        let b = cq.push(1, CommandKind::NdRange, 1);
        cq.add_dep(a, b);
        cq.add_dep(b, a);
        assert!(cq.check_invariants().is_err());
    }
}
