//! Real execution of scheduled DAGs on the PJRT CPU client.
//!
//! This is the proof that the three layers compose: the same Algorithm-1
//! scheduling loop and command-queue structures that drive the simulator
//! here drive *actual* kernel executions (AOT Pallas/JAX artifacts through
//! [`crate::runtime`]), with OS threads standing in for command queues and
//! events implemented as condvars — the substitution for the OpenCL runtime
//! documented in DESIGN.md (the "GPU" device is a worker pool with
//! GPU-shaped concurrency limits; numerics are bit-real).
//!
//! * [`events`] — OpenCL-style event objects (complete/wait/callback).
//! * [`memory`] — host + per-device buffer stores.
//! * [`executor`] — the threaded Algorithm-1 loop.

pub mod events;
pub mod executor;
pub mod memory;

pub use events::Event;
pub use executor::{
    execute_dag, execute_dag_multi, execute_dag_served, execute_dag_served_faulted,
    is_fault_error, ExecFaults, ExecReport,
};
pub use memory::BufferStore;
