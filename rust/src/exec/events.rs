//! OpenCL-style event objects.
//!
//! An [`Event`] mirrors the `cl_event` lifecycle the paper's host programs
//! manipulate: commands complete it, dependent commands `wait` on it, and
//! registered callbacks run on completion (on the completer's thread — the
//! "separate thread in parallel with the host program" of §2).

use std::sync::{Arc, Condvar, Mutex};

type Callback = Box<dyn FnOnce() + Send>;

struct Inner {
    state: Mutex<(bool, Vec<Callback>)>,
    cv: Condvar,
}

/// A one-shot completion event.
#[derive(Clone)]
pub struct Event {
    inner: Arc<Inner>,
}

impl Default for Event {
    fn default() -> Self {
        Self::new()
    }
}

impl Event {
    pub fn new() -> Self {
        Event {
            inner: Arc::new(Inner {
                state: Mutex::new((false, Vec::new())),
                cv: Condvar::new(),
            }),
        }
    }

    /// Mark complete; wakes waiters and runs registered callbacks.
    pub fn complete(&self) {
        let cbs = {
            let mut g = self.inner.state.lock().unwrap();
            g.0 = true;
            self.inner.cv.notify_all();
            std::mem::take(&mut g.1)
        };
        for cb in cbs {
            cb();
        }
    }

    /// Block until complete (the executor's cross-queue `clWaitForEvents`).
    pub fn wait(&self) {
        let mut g = self.inner.state.lock().unwrap();
        while !g.0 {
            g = self.inner.cv.wait(g).unwrap();
        }
    }

    pub fn is_complete(&self) -> bool {
        self.inner.state.lock().unwrap().0
    }

    /// Register `cb` to run on completion (immediately if already complete)
    /// — `clSetEventCallback`.
    pub fn on_complete(&self, cb: impl FnOnce() + Send + 'static) {
        let mut g = self.inner.state.lock().unwrap();
        if g.0 {
            drop(g);
            cb();
        } else {
            g.1.push(Box::new(cb));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn wait_blocks_until_complete() {
        let ev = Event::new();
        let ev2 = ev.clone();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            ev2.complete();
        });
        ev.wait();
        assert!(ev.is_complete());
        t.join().unwrap();
    }

    #[test]
    fn callbacks_fire_once_each() {
        let ev = Event::new();
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..3 {
            let h = hits.clone();
            ev.on_complete(move || {
                h.fetch_add(1, Ordering::SeqCst);
            });
        }
        ev.complete();
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        // Late registration runs immediately.
        let h = hits.clone();
        ev.on_complete(move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn many_waiters_all_wake() {
        let ev = Event::new();
        let mut joins = Vec::new();
        for _ in 0..8 {
            let e = ev.clone();
            joins.push(thread::spawn(move || e.wait()));
        }
        thread::sleep(Duration::from_millis(5));
        ev.complete();
        for j in joins {
            j.join().unwrap();
        }
    }
}
