//! Host + device buffer stores.
//!
//! Mirrors the paper's memory model: host memory holds user inputs and
//! read-back results; each device has its own buffer space populated by
//! write commands and kernel outputs. Intra-component edges keep data
//! device-resident (`enq` elides those transfers), which the input
//! resolution rule below honours.

use crate::error::{Error, Result};
use crate::graph::{BufferId, Dag};
use crate::platform::DeviceId;
use std::collections::HashMap;
use std::sync::Mutex;

/// Thread-safe buffer contents, keyed by DAG buffer id.
#[derive(Default)]
pub struct BufferStore {
    host: Mutex<HashMap<BufferId, Vec<f32>>>,
    device: Mutex<HashMap<(DeviceId, BufferId), Vec<f32>>>,
}

impl BufferStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed a host buffer (user input).
    pub fn set_host(&self, b: BufferId, data: Vec<f32>) {
        self.host.lock().unwrap().insert(b, data);
    }

    pub fn host(&self, b: BufferId) -> Option<Vec<f32>> {
        self.host.lock().unwrap().get(&b).cloned()
    }

    pub fn set_device(&self, dev: DeviceId, b: BufferId, data: Vec<f32>) {
        self.device.lock().unwrap().insert((dev, b), data);
    }

    pub fn device(&self, dev: DeviceId, b: BufferId) -> Option<Vec<f32>> {
        self.device.lock().unwrap().get(&(dev, b)).cloned()
    }

    /// H2D write command: source is the host copy of `b` itself, or — for a
    /// dependent write — the host copy of its predecessor output.
    pub fn h2d(&self, dag: &Dag, dev: DeviceId, b: BufferId) -> Result<()> {
        let data = self
            .host(b)
            .or_else(|| dag.buffer_pred(b).and_then(|p| self.host(p)))
            .ok_or_else(|| {
                Error::Exec(format!("write of buffer {b}: no host data available"))
            })?;
        self.set_device(dev, b, data);
        Ok(())
    }

    /// D2H read command.
    pub fn d2h(&self, dev: DeviceId, b: BufferId) -> Result<()> {
        let data = self.device(dev, b).ok_or_else(|| {
            Error::Exec(format!("read of buffer {b}: not resident on device {dev}"))
        })?;
        self.set_host(b, data);
        Ok(())
    }

    /// Resolve a kernel input on `dev`: the buffer itself if written, else
    /// its predecessor's output left device-resident by an intra edge.
    pub fn resolve_input(&self, dag: &Dag, dev: DeviceId, b: BufferId) -> Result<Vec<f32>> {
        if let Some(d) = self.device(dev, b) {
            return Ok(d);
        }
        if let Some(p) = dag.buffer_pred(b) {
            if let Some(d) = self.device(dev, p) {
                return Ok(d);
            }
        }
        Err(Error::Exec(format!(
            "kernel input buffer {b} not resident on device {dev}"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagBuilder;
    use crate::platform::DeviceType;

    fn chain() -> (Dag, BufferId, BufferId) {
        let mut bld = DagBuilder::new();
        let k0 = bld.kernel("a", DeviceType::Gpu, 1, 1);
        let k1 = bld.kernel("b", DeviceType::Gpu, 1, 1);
        let o = bld.out_buf(k0, 8);
        let i = bld.in_buf(k1, 8);
        bld.edge(o, i);
        (bld.build().unwrap(), o, i)
    }

    #[test]
    fn h2d_uses_predecessor_host_copy() {
        let (dag, o, i) = chain();
        let store = BufferStore::new();
        store.set_host(o, vec![1.0, 2.0]);
        // Dependent write of i: pulls from host copy of o.
        store.h2d(&dag, 0, i).unwrap();
        assert_eq!(store.device(0, i), Some(vec![1.0, 2.0]));
    }

    #[test]
    fn resolve_input_prefers_own_then_pred() {
        let (dag, o, i) = chain();
        let store = BufferStore::new();
        store.set_device(0, o, vec![3.0]);
        // Intra-resident predecessor output.
        assert_eq!(store.resolve_input(&dag, 0, i).unwrap(), vec![3.0]);
        store.set_device(0, i, vec![4.0]);
        assert_eq!(store.resolve_input(&dag, 0, i).unwrap(), vec![4.0]);
        // Different device: nothing resident.
        assert!(store.resolve_input(&dag, 1, i).is_err());
    }

    #[test]
    fn d2h_requires_residency() {
        let (_, o, _) = chain();
        let store = BufferStore::new();
        assert!(store.d2h(0, o).is_err());
        store.set_device(0, o, vec![5.0]);
        store.d2h(0, o).unwrap();
        assert_eq!(store.host(o), Some(vec![5.0]));
    }
}
