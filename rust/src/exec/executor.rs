//! The threaded real executor: Algorithm 1 over OS threads + PJRT kernels.
//!
//! Thread topology mirrors the paper's host program:
//! * the caller's thread runs the `schedule` loop (select → dispatch);
//! * each dispatch spawns a child that runs `setup_cq` and then one worker
//!   thread per command queue (in-order execution, cross-queue waits via
//!   [`Event`]s — exactly the `E_Q` constraints);
//! * completion updates the frontier/device set under a lock and notifies
//!   the scheduler, like the thread-safe callback `cb` of Algorithm 1.
//!
//! [`execute_dag_served`] is the serving entry point: per-component
//! deadline/priority metadata threaded into the scheduler state plus a
//! tenancy bound — it backs both batch `serve_real` and the always-on
//! `RealBackend` of the unified serve core ([`crate::serve::serve_core`]),
//! which calls it once per admitted unit.

use super::events::Event;
use super::memory::BufferStore;
use crate::cost::CostModel;
use crate::error::{Error, Result};
use crate::fault::FaultPlan;
use crate::graph::{BufferId, Dag, Partition};
use crate::platform::{DeviceId, Platform};
use crate::queue::{setup_cq, CommandKind};
use crate::runtime::Runtime;
use crate::sched::{Policy, SchedState};
use crate::sim::CompMeta;
use crate::trace::{Lane, Span, Trace};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Ceiling on one injected stall (wedge remainder or slowdown stretch):
/// keeps a mis-authored wall-clock plan from pinning a worker thread for
/// hours — the watchdog has long since flagged the command by then.
const MAX_FAULT_STALL_S: f64 = 5.0;

/// Wall-clock fault-injection context for real execution. The plan's
/// instants are on the *serving epoch*; the executor's own clock starts at
/// zero per call, so `epoch_offset` (seconds already elapsed on the serving
/// clock when this call starts) aligns the two.
#[derive(Clone, Copy)]
pub struct ExecFaults<'p> {
    pub plan: &'p FaultPlan,
    /// Serving-epoch seconds at this call's t = 0.
    pub epoch_offset: f64,
    /// Watchdog slack multiplier over the per-kernel cost estimate.
    pub slack: f64,
    /// Watchdog floor, seconds — calibration estimates can be microscopic
    /// and real kernels pay dispatch overhead the model does not.
    pub floor: f64,
}

/// Whether a real-execution error came from injected faults or the
/// watchdog — the serve layer's retry-or-shed recovery keys off this;
/// genuine executor failures (missing artifact, shape mismatch) still
/// abort the run.
pub fn is_fault_error(e: &Error) -> bool {
    matches!(e, Error::Exec(m) if m.contains("fault:"))
}

/// Outcome of a real execution.
pub struct ExecReport {
    /// Wall-clock makespan, seconds.
    pub makespan: f64,
    pub trace: Trace,
    /// Device each component ran on.
    pub component_device: Vec<DeviceId>,
    /// Final host-visible buffer contents (outputs read back by D2H).
    pub store: BufferStore,
}

struct State<'a> {
    /// The shared scheduler core — the *same* incrementally indexed
    /// [`SchedState`] the simulator drives (PR 5): frontier buckets,
    /// availability, tenancy, `est_free`, and the resident-fraction
    /// device-load signal. Policies query it in O(log frontier) under the
    /// scheduler lock instead of scanning a per-select view.
    sched: SchedState<'a>,
    ext_preds_left: Vec<usize>,
    comp_dispatched: Vec<bool>,
    comp_device: Vec<DeviceId>,
    comps_done: usize,
    failed: Option<String>,
}

struct Shared<'a> {
    dag: &'a Dag,
    partition: &'a Partition,
    state: Mutex<State<'a>>,
    cv: Condvar,
    store: BufferStore,
    trace: Mutex<Trace>,
    t0: Instant,
    unblocks: Vec<Vec<usize>>,
    /// Per-device resident cap (for the resident-fraction load signal).
    tenancy: usize,
    /// Fault-injection context (`None` on the fault-free path — every hook
    /// below short-circuits, keeping that path byte-identical).
    faults: Option<ExecFaults<'a>>,
}

impl<'a> Shared<'a> {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn fail(&self, msg: String) {
        let mut st = self.state.lock().unwrap();
        if st.failed.is_none() {
            st.failed = Some(msg);
        }
        self.cv.notify_all();
    }

    fn push_span(&self, span: Span) {
        self.trace.lock().unwrap().push(span);
    }
}

/// Execute `partition` of `dag` for real: kernels run as AOT PJRT programs,
/// `inputs` seeds the host buffers (keyed by DAG buffer id). Devices are
/// leased exclusively per component (the paper's Algorithm 1).
pub fn execute_dag(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    runtime: &Arc<Runtime>,
    inputs: &HashMap<BufferId, Vec<f32>>,
) -> Result<ExecReport> {
    execute_dag_multi(dag, partition, platform, cost, policy, runtime, inputs, 1)
}

/// Multi-tenant variant of [`execute_dag`] for the serving layer: up to
/// `tenancy` components may be resident on one device concurrently, so
/// independent DAG requests merged into one partition genuinely share the
/// device's worker pool (bounded by its hardware queue cap). Serving
/// metadata is neutral — deadline-aware policies degrade to their rank
/// fallback; use [`execute_dag_served`] to schedule by urgency.
#[allow(clippy::too_many_arguments)]
pub fn execute_dag_multi(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    runtime: &Arc<Runtime>,
    inputs: &HashMap<BufferId, Vec<f32>>,
    tenancy: usize,
) -> Result<ExecReport> {
    let meta = vec![CompMeta::default(); partition.components.len()];
    execute_dag_served(
        dag, partition, platform, cost, policy, runtime, inputs, tenancy, &meta,
    )
}

/// Serving variant of [`execute_dag_multi`]: per-component [`CompMeta`]
/// (absolute deadline + priority, **on the caller's clock starting at this
/// call** — the serving loop re-bases per batch) is threaded into the
/// shared [`SchedState`] every `select` queries, so deadline-aware policies (`edf`)
/// order real dispatch by urgency exactly as they do in the simulator.
/// `CompMeta::release` is ignored here: arrival pacing is the serving
/// loop's job (`--pacing open` sleeps until each batch's release instant),
/// and preemption stays sim-only — OS threads cannot be displaced
/// mid-kernel, so [`crate::sched::Policy::preempt`] is never consulted.
#[allow(clippy::too_many_arguments)]
pub fn execute_dag_served(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    runtime: &Arc<Runtime>,
    inputs: &HashMap<BufferId, Vec<f32>>,
    tenancy: usize,
    meta: &[CompMeta],
) -> Result<ExecReport> {
    execute_dag_served_faulted(
        dag, partition, platform, cost, policy, runtime, inputs, tenancy, meta, None,
    )
}

/// [`execute_dag_served`] under fault injection: crashed devices are masked
/// from dispatch (and a run left with every device down fails typed),
/// wedges stall commands until they expire, slowdowns stretch command
/// wall time by `1/factor`, and a per-kernel watchdog (cost estimate ×
/// `slack` + `floor`) turns a command that stopped progressing into a typed
/// `fault:` error — the signal [`is_fault_error`] recognizes and the serve
/// layer's retry/re-stage recovery consumes. With `faults: None` this is
/// exactly [`execute_dag_served`].
#[allow(clippy::too_many_arguments)]
pub fn execute_dag_served_faulted(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    runtime: &Arc<Runtime>,
    inputs: &HashMap<BufferId, Vec<f32>>,
    tenancy: usize,
    meta: &[CompMeta],
    faults: Option<ExecFaults<'_>>,
) -> Result<ExecReport> {
    let tenancy = tenancy.max(1);
    if meta.len() != partition.components.len() {
        return Err(Error::Exec(format!(
            "serving metadata covers {} components, partition has {}",
            meta.len(),
            partition.components.len()
        )));
    }
    // Every kernel needs a bound artifact for real execution.
    for k in &dag.kernels {
        if k.artifact.is_none() {
            return Err(Error::Exec(format!(
                "kernel {} ('{}') has no AOT artifact bound",
                k.id, k.name
            )));
        }
    }
    let ncomp = partition.components.len();
    let mut unblocks: Vec<Vec<usize>> = vec![Vec::new(); dag.num_kernels()];
    let mut ext_preds_left = vec![0usize; ncomp];
    let mut seen: Vec<Vec<usize>> = vec![Vec::new(); ncomp];
    for &(src, dst) in &dag.buffer_edges {
        let pk = dag.buffers[src].kernel;
        let ck = dag.buffers[dst].kernel;
        let (pc, cc) = (partition.assignment[pk], partition.assignment[ck]);
        if pc != cc {
            if !unblocks[pk].contains(&cc) {
                unblocks[pk].push(cc);
            }
            if !seen[cc].contains(&pk) {
                seen[cc].push(pk);
                ext_preds_left[cc] += 1;
            }
        }
    }
    // Serving metadata threaded into the shared scheduler state:
    // deadline-aware policies order real dispatch by urgency (preemption
    // stays sim-only — OS threads cannot be displaced).
    let deadline: Vec<f64> = meta.iter().map(|m| m.deadline).collect();
    let priority: Vec<u32> = meta.iter().map(|m| m.priority).collect();
    let mut sched = SchedState::new(dag, partition, platform, cost, tenancy, deadline, priority)?;
    // Initially ready components enter in ascending id order — FIFO seqs
    // reproduce the stable rank sort the pre-indexed frontier used.
    for c in 0..ncomp {
        if ext_preds_left[c] == 0 {
            sched.on_ready(c);
        }
    }
    let shared = Shared {
        dag,
        partition,
        state: Mutex::new(State {
            sched,
            ext_preds_left,
            comp_dispatched: vec![false; ncomp],
            comp_device: vec![usize::MAX; ncomp],
            comps_done: 0,
            failed: None,
        }),
        cv: Condvar::new(),
        store: BufferStore::new(),
        trace: Mutex::new(Trace::default()),
        t0: Instant::now(),
        unblocks,
        tenancy,
        faults,
    };
    for (&b, data) in inputs {
        shared.store.set_host(b, data.clone());
    }

    std::thread::scope(|scope| -> Result<()> {
        // ----- Algorithm 1's schedule loop on the caller thread.
        loop {
            let mut st = shared.state.lock().unwrap();
            if let Some(msg) = st.failed.clone() {
                drop(st);
                return Err(Error::Exec(msg));
            }
            if st.comps_done == ncomp {
                break;
            }
            // Down-device masking: a crashed device must never receive new
            // dispatches. Components already resident on it fail at their
            // next command with a typed `fault:` error instead.
            if let Some(f) = shared.faults {
                let pt = f.epoch_offset + shared.now();
                let ndev = platform.devices.len();
                let mut all_down = ndev > 0;
                for d in 0..ndev {
                    if f.plan.down_at(d, pt) {
                        if !st.sched.is_down(d) {
                            st.sched.on_device_down(d);
                        }
                    } else {
                        all_down = false;
                    }
                }
                if all_down {
                    let left = ncomp - st.comps_done;
                    drop(st);
                    return Err(Error::Exec(format!(
                        "fault: every device is down with {left} component(s) unfinished"
                    )));
                }
            }
            let selection = {
                st.sched.now = shared.now();
                policy.select(&mut st.sched)
            };
            match selection {
                Some((comp, dev)) => {
                    // Frontier exit + tenant/availability accounting, then
                    // the resident-fraction cross-DAG load signal.
                    st.sched.on_dispatch(comp, dev);
                    let frac = st.sched.tenants[dev] as f64 / tenancy as f64;
                    st.sched.device_load[dev] = frac;
                    st.comp_dispatched[comp] = true;
                    st.comp_device[comp] = dev;
                    // EFT bookkeeping for HEFT; the backlog accumulates
                    // across residents under multi-tenancy.
                    let device = platform.device(dev);
                    let solo: f64 = partition.components[comp]
                        .kernels
                        .iter()
                        .map(|&k| cost.exec_time(&dag.kernels[k], device))
                        .sum();
                    st.sched.est_free[dev] = st.sched.est_free[dev].max(shared.now()) + solo;
                    drop(st);
                    // Watchdog budgets, fixed at dispatch: per-kernel cost
                    // estimate on the chosen device × slack + floor. A real
                    // command that exceeds its budget is treated as wedged.
                    let budgets: Option<HashMap<usize, f64>> = shared.faults.map(|f| {
                        partition.components[comp]
                            .kernels
                            .iter()
                            .map(|&k| {
                                let est = cost.exec_time(&dag.kernels[k], device);
                                (k, est * f.slack + f.floor)
                            })
                            .collect()
                    });
                    let sh = &shared;
                    let pf = platform;
                    let rt = runtime.clone();
                    let queues = policy.queues_for(device);
                    scope.spawn(move || run_component(sh, pf, rt, comp, dev, queues, budgets));
                }
                None => {
                    // sleep_till_cb_update(): callbacks wake us.
                    let (g, _) = shared
                        .cv
                        .wait_timeout(st, std::time::Duration::from_millis(50))
                        .unwrap();
                    drop(g);
                }
            }
        }
        Ok(())
    })?;

    let st = shared.state.into_inner().unwrap();
    if let Some(msg) = st.failed {
        return Err(Error::Exec(msg));
    }
    let trace = shared.trace.into_inner().unwrap();
    Ok(ExecReport {
        makespan: trace.makespan(),
        trace,
        component_device: st.comp_device,
        store: shared.store,
    })
}

/// Dispatch child thread: setup_cq + one worker per command queue + the
/// completion callback.
fn run_component(
    shared: &Shared<'_>,
    platform: &Platform,
    runtime: Arc<Runtime>,
    comp: usize,
    dev: DeviceId,
    queues: usize,
    budgets: Option<HashMap<usize, f64>>,
) {
    let mut device = platform.device(dev).clone();
    device.num_queues = queues;
    let cq = setup_cq(shared.dag, shared.partition, comp, &device);
    let events: Vec<Event> = (0..cq.num_commands()).map(|_| Event::new()).collect();

    let result = std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for q in 0..cq.queues.len() {
            let cq_ref = &cq;
            let events_ref = &events;
            let budgets_ref = &budgets;
            let rt = runtime.clone();
            handles.push(scope.spawn(move || -> Result<()> {
                for &cmd in &cq_ref.queues[q] {
                    // Cross-queue E_Q waits (in-order is this loop itself).
                    for dep in cq_ref.deps_of(cmd) {
                        events_ref[dep].wait();
                    }
                    let start = shared.now();
                    let c = &cq_ref.commands[cmd];
                    // Pre-command fault gates: a down device fails the
                    // command typed; a wedged one stalls until the wedge
                    // expires (the stall counts against the watchdog
                    // budget, so a long wedge surfaces as a timeout).
                    if let Some(f) = shared.faults {
                        let pt = f.epoch_offset + start;
                        if f.plan.down_at(dev, pt) {
                            events_ref[cmd].complete();
                            return Err(Error::Exec(format!(
                                "fault: device {dev} is down at t={pt:.6}"
                            )));
                        }
                        let rem = f.plan.wedge_remaining_at(dev, pt);
                        if rem > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(
                                rem.min(MAX_FAULT_STALL_S),
                            ));
                        }
                    }
                    let outcome = match c.kind {
                        CommandKind::Write { buffer } => shared
                            .store
                            .h2d(shared.dag, dev, buffer)
                            .map(|_| (format!("w{buffer}"), true)),
                        CommandKind::Read { buffer } => shared
                            .store
                            .d2h(dev, buffer)
                            .map(|_| (format!("r{buffer}"), true)),
                        CommandKind::NdRange => run_kernel(shared, &rt, dev, c.kernel)
                            .map(|_| (shared.dag.kernels[c.kernel].name.clone(), false)),
                    };
                    // Post-command fault gates: stretch by the slowdown
                    // factor, then let the watchdog judge total command
                    // wall time against its dispatch-time budget.
                    let outcome = match (outcome, shared.faults) {
                        (Ok(ok), Some(f)) => {
                            let pt = f.epoch_offset + start;
                            let sf = f.plan.slow_factor_at(dev, pt);
                            if sf > 0.0 && sf < 1.0 {
                                let dt = shared.now() - start;
                                std::thread::sleep(Duration::from_secs_f64(
                                    (dt * (1.0 / sf - 1.0)).clamp(0.0, MAX_FAULT_STALL_S),
                                ));
                            }
                            let over = matches!(c.kind, CommandKind::NdRange)
                                .then(|| budgets_ref.as_ref().and_then(|b| b.get(&c.kernel)))
                                .flatten()
                                .filter(|&&budget| shared.now() - start > budget);
                            match over {
                                Some(&budget) => Err(Error::Exec(format!(
                                    "fault: watchdog timeout on kernel {} (device {dev}): \
                                     {:.6}s exceeds the {budget:.6}s budget — treating the \
                                     command as wedged",
                                    c.kernel,
                                    shared.now() - start,
                                ))),
                                None => Ok(ok),
                            }
                        }
                        (o, _) => o,
                    };
                    match outcome {
                        Ok((label, is_transfer)) => {
                            shared.push_span(Span {
                                label,
                                lane: if is_transfer {
                                    Lane::CopyEngine { idx: 0 }
                                } else {
                                    Lane::Device { dev, slot: q }
                                },
                                start,
                                end: shared.now(),
                                cmd: Some(cmd),
                                kernel: Some(c.kernel),
                            });
                            events_ref[cmd].complete();
                        }
                        Err(e) => {
                            // Complete the event anyway to avoid deadlock,
                            // then surface the failure.
                            events_ref[cmd].complete();
                            return Err(e);
                        }
                    }
                }
                Ok(())
            }));
        }
        let mut first_err = None;
        for h in handles {
            if let Err(e) = h.join().expect("queue thread panicked") {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    });

    match result {
        Ok(()) => {
            // Thread-safe callback cb: update F and A, notify schedule.
            let mut st = shared.state.lock().unwrap();
            for &k in &shared.partition.components[comp].kernels {
                for &uc in &shared.unblocks[k] {
                    st.ext_preds_left[uc] -= 1;
                    if st.ext_preds_left[uc] == 0 && !st.comp_dispatched[uc] {
                        st.sched.on_ready(uc);
                    }
                }
            }
            st.sched.on_complete(dev);
            let frac = st.sched.tenants[dev] as f64 / shared.tenancy as f64;
            st.sched.device_load[dev] = frac;
            if st.sched.tenants[dev] == 0 {
                st.sched.est_free[dev] = shared.now();
            }
            st.comps_done += 1;
            shared.cv.notify_all();
        }
        Err(e) => shared.fail(format!("component {comp}: {e}")),
    }
}

/// Execute one kernel's AOT artifact with device-resident inputs.
fn run_kernel(
    shared: &Shared<'_>,
    runtime: &Runtime,
    dev: DeviceId,
    kernel: usize,
) -> Result<()> {
    let node = &shared.dag.kernels[kernel];
    let artifact = node.artifact.as_deref().expect("checked in execute_dag");
    let mut inputs = Vec::with_capacity(node.inputs.len());
    for &b in &node.inputs {
        inputs.push(shared.store.resolve_input(shared.dag, dev, b)?);
    }
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let outputs = runtime.execute_f32(artifact, &refs)?;
    if outputs.len() != node.outputs.len() {
        return Err(Error::Exec(format!(
            "kernel {kernel} ({artifact}): {} outputs, DAG expects {}",
            outputs.len(),
            node.outputs.len()
        )));
    }
    for (&b, data) in node.outputs.iter().zip(outputs) {
        shared.store.set_device(dev, b, data);
    }
    Ok(())
}
