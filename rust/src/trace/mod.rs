//! Gantt-chart traces (the paper's Figs. 4, 5 and 13) plus overlap
//! statistics used by the experiment harness and tests.

use crate::json::Json;
use crate::queue::CmdId;

/// Resource lane a traced span executed on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Compute on device `dev`, hardware slot `slot`.
    Device { dev: usize, slot: usize },
    /// DMA copy engine `idx`.
    CopyEngine { idx: usize },
    /// Host scheduler thread activity (setup_cq, callbacks).
    Host,
}

/// One executed span.
#[derive(Debug, Clone)]
pub struct Span {
    pub label: String,
    pub lane: Lane,
    /// Start/end, seconds from t=0.
    pub start: f64,
    pub end: f64,
    /// Originating command, if any.
    pub cmd: Option<CmdId>,
    /// Originating kernel id in the application DAG, if any.
    pub kernel: Option<usize>,
}

/// A complete execution trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
}

impl Trace {
    pub fn push(&mut self, span: Span) {
        self.spans.push(span);
    }

    /// Schedule makespan: latest span end.
    pub fn makespan(&self) -> f64 {
        self.spans.iter().map(|s| s.end).fold(0.0, f64::max)
    }

    /// Total busy time on a lane predicate.
    pub fn busy_time(&self, pred: impl Fn(&Lane) -> bool) -> f64 {
        self.spans
            .iter()
            .filter(|s| pred(&s.lane))
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Seconds during which ≥2 compute spans on device `dev` overlap —
    /// the "fine-grained concurrency actually happened" metric.
    pub fn device_overlap(&self, dev: usize) -> f64 {
        let mut events: Vec<(f64, i32)> = Vec::new();
        for s in &self.spans {
            if let Lane::Device { dev: d, .. } = s.lane {
                if d == dev {
                    events.push((s.start, 1));
                    events.push((s.end, -1));
                }
            }
        }
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut depth = 0;
        let mut last = 0.0;
        let mut overlap = 0.0;
        for (t, d) in events {
            if depth >= 2 {
                overlap += t - last;
            }
            depth += d;
            last = t;
        }
        overlap
    }

    /// Seconds during which a compute span on `dev` overlaps a copy-engine
    /// span — the transfer/compute interleaving metric (Fig. 5).
    pub fn copy_compute_overlap(&self, dev: usize) -> f64 {
        let mut total = 0.0;
        for c in &self.spans {
            if !matches!(c.lane, Lane::CopyEngine { .. }) {
                continue;
            }
            for k in &self.spans {
                if let Lane::Device { dev: d, .. } = k.lane {
                    if d == dev {
                        let lo = c.start.max(k.start);
                        let hi = c.end.min(k.end);
                        if hi > lo {
                            total += hi - lo;
                        }
                    }
                }
            }
        }
        total
    }

    /// Largest idle gap between consecutive compute spans on `dev` —
    /// the paper's Fig. 13 "gaps between kernels" diagnostic.
    pub fn max_gap(&self, dev: usize) -> f64 {
        let mut spans: Vec<(f64, f64)> = self
            .spans
            .iter()
            .filter_map(|s| match s.lane {
                Lane::Device { dev: d, .. } if d == dev => Some((s.start, s.end)),
                _ => None,
            })
            .collect();
        if spans.is_empty() {
            return 0.0;
        }
        spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut frontier = spans[0].1;
        let mut gap = 0.0f64;
        for &(s, e) in &spans[1..] {
            if s > frontier {
                gap = gap.max(s - frontier);
            }
            frontier = frontier.max(e);
        }
        gap
    }

    /// Render an ASCII Gantt chart with `width` columns.
    pub fn ascii(&self, width: usize) -> String {
        let make = self.makespan();
        if make <= 0.0 || self.spans.is_empty() {
            return String::from("(empty trace)\n");
        }
        let mut lanes: Vec<Lane> = Vec::new();
        for s in &self.spans {
            if !lanes.contains(&s.lane) {
                lanes.push(s.lane);
            }
        }
        lanes.sort_by_key(|l| match l {
            Lane::Device { dev, slot } => (0, *dev, *slot),
            Lane::CopyEngine { idx } => (1, *idx, 0),
            Lane::Host => (2, 0, 0),
        });
        let mut out = String::new();
        out.push_str(&format!("makespan = {:.3} ms\n", make * 1e3));
        for lane in lanes {
            let name = match lane {
                Lane::Device { dev, slot } => format!("dev{dev}.q{slot}"),
                Lane::CopyEngine { idx } => format!("dma{idx}   "),
                Lane::Host => "host   ".to_string(),
            };
            let mut row = vec![b'.'; width];
            for s in self.spans.iter().filter(|s| s.lane == lane) {
                let a = ((s.start / make) * width as f64) as usize;
                let b = (((s.end / make) * width as f64).ceil() as usize).min(width);
                let ch = s.label.bytes().next().unwrap_or(b'#');
                for slot in row.iter_mut().take(b).skip(a) {
                    *slot = ch;
                }
            }
            out.push_str(&format!("{name:>8} |{}|\n", String::from_utf8(row).unwrap()));
        }
        out
    }

    /// JSON export for external plotting.
    pub fn to_json(&self) -> String {
        let spans = self
            .spans
            .iter()
            .map(|s| {
                let lane = match s.lane {
                    Lane::Device { dev, slot } => Json::obj(vec![
                        ("kind", Json::str("device")),
                        ("dev", Json::num(dev as f64)),
                        ("slot", Json::num(slot as f64)),
                    ]),
                    Lane::CopyEngine { idx } => Json::obj(vec![
                        ("kind", Json::str("copy_engine")),
                        ("idx", Json::num(idx as f64)),
                    ]),
                    Lane::Host => Json::obj(vec![("kind", Json::str("host"))]),
                };
                Json::obj(vec![
                    ("label", Json::str(s.label.clone())),
                    ("lane", lane),
                    ("start", Json::num(s.start)),
                    ("end", Json::num(s.end)),
                    (
                        "kernel",
                        s.kernel.map(|k| Json::num(k as f64)).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![("spans", Json::Arr(spans))]).to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(lane: Lane, s: f64, e: f64) -> Span {
        Span {
            label: "k".into(),
            lane,
            start: s,
            end: e,
            cmd: None,
            kernel: None,
        }
    }

    #[test]
    fn makespan_and_busy() {
        let mut t = Trace::default();
        t.push(span(Lane::Device { dev: 0, slot: 0 }, 0.0, 1.0));
        t.push(span(Lane::Device { dev: 0, slot: 1 }, 0.5, 2.0));
        assert_eq!(t.makespan(), 2.0);
        assert_eq!(
            t.busy_time(|l| matches!(l, Lane::Device { .. })),
            2.5
        );
    }

    #[test]
    fn overlap_detection() {
        let mut t = Trace::default();
        t.push(span(Lane::Device { dev: 0, slot: 0 }, 0.0, 1.0));
        t.push(span(Lane::Device { dev: 0, slot: 1 }, 0.5, 1.5));
        assert!((t.device_overlap(0) - 0.5).abs() < 1e-12);
        assert_eq!(t.device_overlap(1), 0.0);
    }

    #[test]
    fn copy_compute_overlap_counts() {
        let mut t = Trace::default();
        t.push(span(Lane::Device { dev: 0, slot: 0 }, 0.0, 1.0));
        t.push(span(Lane::CopyEngine { idx: 0 }, 0.25, 0.75));
        assert!((t.copy_compute_overlap(0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gap_detection() {
        let mut t = Trace::default();
        t.push(span(Lane::Device { dev: 0, slot: 0 }, 0.0, 1.0));
        t.push(span(Lane::Device { dev: 0, slot: 0 }, 3.0, 4.0));
        assert!((t.max_gap(0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ascii_renders_all_lanes() {
        let mut t = Trace::default();
        t.push(span(Lane::Device { dev: 0, slot: 0 }, 0.0, 1.0));
        t.push(span(Lane::CopyEngine { idx: 0 }, 0.0, 0.5));
        let art = t.ascii(40);
        assert!(art.contains("dev0.q0"));
        assert!(art.contains("dma0"));
    }
}
