//! # PySchedCL (reproduction)
//!
//! A Rust + JAX + Pallas reproduction of *"PySchedCL: Leveraging Concurrency
//! in Heterogeneous Data-Parallel Systems"* (Ghose et al., 2020).
//!
//! The library schedules data-parallel application DAGs (kernels + buffers)
//! onto a heterogeneous CPU/GPU platform, synthesizing OpenCL-style
//! command-queue programs with fine-grained concurrency: multiple queues per
//! device, transfer/compute interleaving, and task-component clustering that
//! elides redundant copies and callbacks.
//!
//! Layer map (see DESIGN.md):
//! * kernels are AOT-compiled JAX/Pallas programs (`artifacts/*.hlo.txt`)
//!   loaded through PJRT ([`runtime`]);
//! * [`graph`], [`spec`], [`queue`], [`sched`] implement the paper's §3–§4
//!   formalism and Algorithm 1;
//! * [`sim`] reproduces the paper's GTX-970 + i5-4690K testbed as a
//!   discrete-event model; [`exec`] runs the same schedules for real on the
//!   PJRT CPU client;
//! * [`serve`] turns the single-shot machinery into a multi-DAG serving
//!   runtime: admission/batching of a request stream, multi-tenant device
//!   sharing, per-request latency accounting;
//! * [`report`] regenerates every table/figure of §5 plus the serving
//!   comparison.

pub mod benchkit;
pub mod cost;
pub mod error;
pub mod exec;
pub mod fault;
pub mod graph;
pub mod json;
pub mod platform;
pub mod queue;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod spec;
pub mod trace;
pub mod transformer;

pub use error::{Error, Result};
