//! Serving requests and their workloads.

use crate::error::{Error, Result};
use crate::graph::{Dag, Partition};
use crate::platform::DeviceType;
use crate::transformer::{cluster_by_head, head_dag, polybench, transformer_dag};

/// One DAG request in the serving stream.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Caller-assigned id (unique within one serving run).
    pub id: usize,
    /// Arrival instant, seconds since the serving epoch.
    pub arrival: f64,
    /// Optional latency budget (seconds from arrival).
    pub deadline: Option<f64>,
    /// Larger = more urgent; tie-breaker within a batch window.
    pub priority: u32,
    pub workload: Workload,
}

impl ServeRequest {
    /// A plain request: arrival only, no deadline, default priority.
    pub fn new(id: usize, arrival: f64, workload: Workload) -> Self {
        ServeRequest {
            id,
            arrival,
            deadline: None,
            priority: 0,
            workload,
        }
    }
}

/// What a request wants executed. Generator variants instantiate the
/// paper's workloads; `Spec` carries a pre-built application (e.g. from a
/// parsed spec file) and is validated at admission.
#[derive(Debug, Clone)]
pub enum Workload {
    /// One attention head (the Figs. 4/5 DAG), clustered as one component.
    Head { beta: u64 },
    /// An H-head transformer layer, one component per head, the first
    /// `h_cpu` heads preferring the CPU (Expt 1's knob).
    Layer { heads: usize, beta: u64, h_cpu: usize },
    /// Polybench pipelines, each clustered as one GPU component.
    Mm2 { beta: u64 },
    Mm3 { beta: u64 },
    Atax { beta: u64 },
    Bicg { beta: u64 },
    Mvt { beta: u64 },
    /// A pre-built application (dag + partition), e.g. from a spec file.
    Spec { dag: Dag, partition: Partition },
}

impl Workload {
    /// Batching compatibility key: requests with equal signatures arriving
    /// close together may be coalesced into one dispatch group.
    pub fn signature(&self) -> String {
        match self {
            Workload::Head { beta } => format!("head_b{beta}"),
            Workload::Layer { heads, beta, h_cpu } => {
                format!("layer_h{heads}_b{beta}_c{h_cpu}")
            }
            Workload::Mm2 { beta } => format!("mm2_b{beta}"),
            Workload::Mm3 { beta } => format!("mm3_b{beta}"),
            Workload::Atax { beta } => format!("atax_b{beta}"),
            Workload::Bicg { beta } => format!("bicg_b{beta}"),
            Workload::Mvt { beta } => format!("mvt_b{beta}"),
            Workload::Spec { dag, .. } => format!("spec_k{}", dag.num_kernels()),
        }
    }

    /// Whether [`Workload::signature`] uniquely identifies the
    /// instantiated application, i.e. whether the template cache may key
    /// this workload by signature. Generator variants are pure functions
    /// of their parameters (all of which the signature encodes); `Spec`
    /// carries an arbitrary pre-built app whose signature (kernel count)
    /// is *not* injective, so it is never cached.
    pub fn cacheable(&self) -> bool {
        !matches!(self, Workload::Spec { .. })
    }

    /// Materialize the application DAG and its task-component partition.
    pub fn instantiate(&self) -> Result<(Dag, Partition)> {
        let whole_gpu = |dag: Dag| -> Result<(Dag, Partition)> {
            let all: Vec<usize> = (0..dag.num_kernels()).collect();
            let part = Partition::new(&dag, vec![(all, DeviceType::Gpu)])?;
            Ok((dag, part))
        };
        match self {
            Workload::Head { beta } => {
                let (dag, io) = head_dag(*beta, DeviceType::Gpu);
                let part = cluster_by_head(&dag, std::slice::from_ref(&io), 0);
                Ok((dag, part))
            }
            Workload::Layer { heads, beta, h_cpu } => {
                let (dag, ios) = transformer_dag(*heads, *beta, DeviceType::Gpu);
                let part = cluster_by_head(&dag, &ios, *h_cpu);
                Ok((dag, part))
            }
            Workload::Mm2 { beta } => whole_gpu(polybench::mm2_dag(*beta, DeviceType::Gpu).0),
            Workload::Mm3 { beta } => whole_gpu(polybench::mm3_dag(*beta, DeviceType::Gpu).0),
            Workload::Atax { beta } => whole_gpu(polybench::atax_dag(*beta, DeviceType::Gpu).0),
            Workload::Bicg { beta } => whole_gpu(polybench::bicg_dag(*beta, DeviceType::Gpu).0),
            Workload::Mvt { beta } => whole_gpu(polybench::mvt_dag(*beta, DeviceType::Gpu).0),
            Workload::Spec { dag, partition } => Ok((dag.clone(), partition.clone())),
        }
    }

    /// CLI name → workload (`head`, `layer`, `mm2`, `mm3`, `atax`, `bicg`,
    /// `mvt`).
    pub fn parse(name: &str, heads: usize, beta: u64, h_cpu: usize) -> Result<Workload> {
        match name {
            "head" => Ok(Workload::Head { beta }),
            "layer" | "transformer" => Ok(Workload::Layer { heads, beta, h_cpu }),
            "mm2" | "2mm" => Ok(Workload::Mm2 { beta }),
            "mm3" | "3mm" => Ok(Workload::Mm3 { beta }),
            "atax" => Ok(Workload::Atax { beta }),
            "bicg" => Ok(Workload::Bicg { beta }),
            "mvt" => Ok(Workload::Mvt { beta }),
            other => Err(Error::Admission(format!("unknown workload '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_workloads_instantiate_valid_apps() {
        for w in [
            Workload::Head { beta: 64 },
            Workload::Layer {
                heads: 2,
                beta: 64,
                h_cpu: 1,
            },
            Workload::Mm2 { beta: 64 },
            Workload::Mm3 { beta: 64 },
            Workload::Atax { beta: 64 },
            Workload::Bicg { beta: 64 },
            Workload::Mvt { beta: 64 },
        ] {
            let (dag, part) = w.instantiate().unwrap();
            dag.validate().unwrap();
            assert_eq!(part.assignment.len(), dag.num_kernels());
        }
    }

    #[test]
    fn signatures_distinguish_batching_classes() {
        let a = Workload::Head { beta: 64 }.signature();
        let b = Workload::Head { beta: 128 }.signature();
        let c = Workload::Mm2 { beta: 64 }.signature();
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, Workload::Head { beta: 64 }.signature());
    }

    #[test]
    fn unknown_cli_workload_is_admission_error() {
        let e = Workload::parse("fft", 1, 64, 0).unwrap_err();
        assert!(matches!(e, Error::Admission(_)));
    }
}
