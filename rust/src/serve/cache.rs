//! The merged-template cache — the sim-side analog of the real path's PJRT
//! executable cache (PR 3).
//!
//! Serving a stream means instantiating the *same* workload signature over
//! and over: every request used to pay a fresh `Workload::instantiate` +
//! structural validation, and every batch a fresh `merge_apps` deep-clone
//! of all member apps. Both are pure functions of (signature) and
//! (signature, batch size) respectively, so [`TemplateCache`] memoizes
//! them:
//!
//! * **App templates**, keyed by workload signature: instantiated and
//!   validated once, shared via `Arc` — a 10k-request single-signature
//!   stream builds its DAG once instead of 10k times.
//! * **Merged batch blocks**, keyed by `(signature, batch size)`: a
//!   pre-merged [`MergedApp`] of `B` template instances, built once and
//!   appended to the run-wide assembly as one contiguous block
//!   ([`crate::serve::merge::MergedAssembly::append_merged`]) for every
//!   later batch of the same shape. Hit/miss counters surface in
//!   [`crate::serve::ServeReport`].
//!
//! `Workload::Spec` is never cached — its signature is not injective
//! ([`crate::serve::Workload::cacheable`]); such requests take the
//! uncached instantiate +
//! per-app append path, bit-identical to the cached one (proven by the
//! `block_append_equals_per_app_append` merge test and the warm-vs-cold
//! serve equivalence test).

use super::admission::{validate_app, validate_request};
use super::merge::{merge_apps_refs, MergedApp};
use super::request::ServeRequest;
use crate::error::Result;
use crate::graph::{Dag, Partition};
use std::collections::HashMap;
use std::sync::Arc;

/// Signature-keyed app-template + merged-batch-block cache. One instance
/// serves one `serve_*` run by default (hits accrue across batches within
/// the run); hold it across runs for cross-stream reuse.
#[derive(Debug, Default)]
pub struct TemplateCache {
    /// Workload signature → instantiated, validated application template.
    apps: HashMap<String, Arc<(Dag, Partition)>>,
    /// Signature → batch size → pre-merged block of that many templates.
    /// Nested so a hit probes by `&str` without allocating an owned key.
    merged: HashMap<String, HashMap<usize, Arc<MergedApp>>>,
    merged_hits: usize,
    merged_misses: usize,
}

impl TemplateCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit one request through the cache: request-level checks always
    /// run; the application template is instantiated + validated only on
    /// the first encounter of a cacheable signature (uncacheable workloads
    /// instantiate fresh every time). Rejections are the same typed
    /// [`crate::error::Error::Admission`] values `admit` produces.
    pub fn admit_app(&mut self, req: &ServeRequest) -> Result<Arc<(Dag, Partition)>> {
        validate_request(req)?;
        if !req.workload.cacheable() {
            let (dag, partition) = req
                .workload
                .instantiate()
                .map_err(|e| crate::error::Error::Admission(format!("request {}: {e}", req.id)))?;
            validate_app(req, &dag, &partition)?;
            return Ok(Arc::new((dag, partition)));
        }
        let sig = req.workload.signature();
        if let Some(app) = self.apps.get(&sig) {
            return Ok(Arc::clone(app));
        }
        let (dag, partition) = req
            .workload
            .instantiate()
            .map_err(|e| crate::error::Error::Admission(format!("request {}: {e}", req.id)))?;
        validate_app(req, &dag, &partition)?;
        let app = Arc::new((dag, partition));
        self.apps.insert(sig, Arc::clone(&app));
        Ok(app)
    }

    /// The pre-merged block of `batch` instances of `template`, building
    /// (and validating) it on first encounter of this `(signature, batch)`
    /// shape. Counts a hit or a miss.
    pub fn merged_block(
        &mut self,
        signature: &str,
        batch: usize,
        template: &Arc<(Dag, Partition)>,
    ) -> Result<Arc<MergedApp>> {
        if let Some(block) = self.merged.get(signature).and_then(|m| m.get(&batch)) {
            self.merged_hits += 1;
            return Ok(Arc::clone(block));
        }
        self.merged_misses += 1;
        let refs: Vec<&(Dag, Partition)> = (0..batch).map(|_| template.as_ref()).collect();
        let block = Arc::new(merge_apps_refs(&refs)?);
        self.merged
            .entry(signature.to_string())
            .or_default()
            .insert(batch, Arc::clone(&block));
        Ok(block)
    }

    /// (merged-block hits, merged-block misses) so far.
    pub fn stats(&self) -> (usize, usize) {
        (self.merged_hits, self.merged_misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::Workload;

    #[test]
    fn app_templates_are_shared_per_signature() {
        let mut cache = TemplateCache::new();
        let a = cache
            .admit_app(&ServeRequest::new(0, 0.0, Workload::Head { beta: 64 }))
            .unwrap();
        let b = cache
            .admit_app(&ServeRequest::new(1, 0.001, Workload::Head { beta: 64 }))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same signature must share one template");
        let c = cache
            .admit_app(&ServeRequest::new(2, 0.002, Workload::Head { beta: 128 }))
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "different signatures must not alias");
    }

    #[test]
    fn request_level_rejections_still_fire_on_cached_signatures() {
        let mut cache = TemplateCache::new();
        cache
            .admit_app(&ServeRequest::new(0, 0.0, Workload::Head { beta: 64 }))
            .unwrap();
        // Same (cached) signature, bad deadline: rejected before the cache.
        let mut bad = ServeRequest::new(1, 0.0, Workload::Head { beta: 64 });
        bad.deadline = Some(-1.0);
        let e = cache.admit_app(&bad).unwrap_err();
        assert!(e.to_string().contains("request 1"), "{e}");
    }

    #[test]
    fn merged_blocks_hit_per_signature_and_size() {
        let mut cache = TemplateCache::new();
        let app = cache
            .admit_app(&ServeRequest::new(0, 0.0, Workload::Head { beta: 64 }))
            .unwrap();
        let b1 = cache.merged_block("head_b64", 3, &app).unwrap();
        assert_eq!(cache.stats(), (0, 1));
        let b2 = cache.merged_block("head_b64", 3, &app).unwrap();
        assert_eq!(cache.stats(), (1, 1));
        assert!(Arc::ptr_eq(&b1, &b2));
        // A different batch size is a different block.
        let b3 = cache.merged_block("head_b64", 2, &app).unwrap();
        assert_eq!(cache.stats(), (1, 2));
        assert_eq!(b3.partition.components.len(), 2);
        assert_eq!(b1.partition.components.len(), 3);
    }

    #[test]
    fn spec_workloads_are_never_cached() {
        let (dag, partition) = Workload::Head { beta: 64 }.instantiate().unwrap();
        let spec = Workload::Spec { dag, partition };
        assert!(!spec.cacheable());
        let mut cache = TemplateCache::new();
        let a = cache.admit_app(&ServeRequest::new(0, 0.0, spec.clone())).unwrap();
        let b = cache.admit_app(&ServeRequest::new(1, 0.0, spec)).unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "Spec templates must stay per-request");
    }
}
