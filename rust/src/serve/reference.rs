//! Frozen pre-refactor serving pipeline, kept verbatim as the equivalence
//! oracle for the unified serve core (the PR 4–6 proof pattern:
//! `sim::reference` gates the indexed engine, `sched::reference` gates the
//! incremental scheduler state — this module gates `serve::core`).
//!
//! [`serve_sim_cached_ref`] is the monolithic batch-mode sim pipeline
//! exactly as it shipped before `serve_sim_cached` became a thin wrapper
//! over [`super::core::serve_core`]: sort-everything admission
//! ([`admit_all_ref`]), whole-run [`MergedAssembly`] construction, one
//! [`simulate_served`] call. The tests below demand **bit** equality
//! (latency/makespan/utilization `to_bits()`, exact rejection lists, exact
//! cache counters) between this frozen path and the core-routed wrapper.
//!
//! Nothing here is part of the public API; it exists so a schedule-changing
//! regression in the core refactor fails a test instead of silently
//! shifting benchmark numbers.

use super::admission::{batch_requests, check_laxity_estimate};
use super::cache::TemplateCache;
use super::engine::{build_report, request_outcome, Pacing, ServeConfig, ServeReport};
use super::merge::MergedAssembly;
use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::Result;
use crate::graph::{Dag, Partition};
use crate::platform::Platform;
use crate::sched::{app_solo_estimate, Policy};
use crate::sim::{simulate_served, CompMeta};
use crate::trace::Lane;
use std::collections::HashMap;
use std::ops::Range;
use std::sync::Arc;

type AdmittedRef = (
    Vec<ServeRequest>,
    Vec<Arc<(Dag, Partition)>>,
    Vec<(usize, String)>,
    usize,
);

/// The pre-refactor admission front-end, verbatim (the live path now
/// routes the same checks through `AdmissionGate`).
fn admit_all_ref(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    laxity_admission: bool,
    cache: &mut TemplateCache,
) -> AdmittedRef {
    let mut order: Vec<usize> = (0..requests.len()).collect();
    order.sort_by(|&a, &b| {
        requests[a]
            .arrival
            .total_cmp(&requests[b].arrival)
            .then_with(|| requests[b].priority.cmp(&requests[a].priority))
            .then_with(|| requests[a].id.cmp(&requests[b].id))
    });
    let mut admitted = Vec::new();
    let mut apps = Vec::new();
    let mut rejected = Vec::new();
    let mut laxity_rejections = 0usize;
    let mut solo_memo: HashMap<String, f64> = HashMap::new();
    for &ri in &order {
        let req = &requests[ri];
        match cache.admit_app(req) {
            Ok(app) => {
                if laxity_admission && req.deadline.is_some() {
                    let estimate = if req.workload.cacheable() {
                        *solo_memo
                            .entry(req.workload.signature())
                            .or_insert_with(|| app_solo_estimate(&app.0, &app.1, platform, cost))
                    } else {
                        app_solo_estimate(&app.0, &app.1, platform, cost)
                    };
                    if let Err(e) = check_laxity_estimate(req, estimate) {
                        laxity_rejections += 1;
                        rejected.push((req.id, e.to_string()));
                        continue;
                    }
                }
                admitted.push(req.clone());
                apps.push(app);
            }
            Err(e) => rejected.push((req.id, e.to_string())),
        }
    }
    (admitted, apps, rejected, laxity_rejections)
}

/// The pre-refactor `serve_sim_cached`, verbatim: admit everything up
/// front, assemble the whole run into one merged application, simulate
/// once, and read outcomes back out of the component-finish array.
pub fn serve_sim_cached_ref(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
    cache: &mut TemplateCache,
) -> Result<ServeReport> {
    let (hits0, misses0) = cache.stats();
    let (admitted, apps, rejected, laxity_rejections) =
        admit_all_ref(requests, platform, cost, cfg.laxity_admission, cache);
    if admitted.is_empty() {
        let mut report = build_report(
            "concurrent",
            policy.name(),
            Vec::new(),
            rejected,
            laxity_rejections,
            0.0,
            vec![0.0; platform.devices.len()],
            0,
        );
        let (hits1, misses1) = cache.stats();
        report.template_cache_hits = hits1 - hits0;
        report.template_cache_misses = misses1 - misses0;
        return Ok(report);
    }
    let batches = batch_requests(&admitted, cfg.batch_window);
    // Batch-block assembly. Requests of one batch occupy one contiguous
    // component run; `req_range[i]` maps admitted request `i` back to its
    // components, whatever order its batch was appended in.
    let mut asm = MergedAssembly::new();
    let mut req_range: Vec<Range<usize>> = vec![0..0; admitted.len()];
    for b in &batches {
        let cacheable = b.members.iter().all(|&m| admitted[m].workload.cacheable());
        if cacheable {
            // All members share the signature (batching invariant), hence
            // the same cached template.
            let sig = admitted[b.members[0]].workload.signature();
            let block = cache.merged_block(&sig, b.members.len(), &apps[b.members[0]])?;
            let ranges = asm.append_merged(&block);
            for (r, &m) in ranges.into_iter().zip(&b.members) {
                req_range[m] = r;
            }
        } else {
            for &m in &b.members {
                req_range[m] = asm.append_app(&apps[m]);
            }
        }
    }
    let merged = asm.finish()?;
    let mut meta = vec![CompMeta::default(); merged.partition.components.len()];
    for b in &batches {
        for &m in &b.members {
            for c in req_range[m].clone() {
                meta[c].release = b.release;
            }
        }
    }
    // Deadlines are absolute (arrival + budget) so EDF compares requests on
    // one clock; priorities ride along per component.
    for (i, req) in admitted.iter().enumerate() {
        for c in req_range[i].clone() {
            meta[c].deadline = req.deadline.map(|d| req.arrival + d).unwrap_or(f64::INFINITY);
            meta[c].priority = req.priority;
        }
    }
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = cfg.tenancy.max(1);
    let sim = simulate_served(
        &merged.dag,
        &merged.partition,
        platform,
        cost,
        policy,
        &sim_cfg,
        &meta,
    )?;

    let outcomes = admitted
        .iter()
        .enumerate()
        .map(|(i, req)| {
            let range = req_range[i].clone();
            let release = meta[range.start].release;
            let finish = range
                .map(|c| sim.component_finish[c])
                .fold(0.0f64, f64::max);
            request_outcome(req, release, finish, Pacing::Open)
        })
        .collect();

    let makespan = sim.makespan;
    let device_util = (0..platform.devices.len())
        .map(|d| {
            let busy = sim
                .trace
                .busy_time(|l| matches!(l, Lane::Device { dev, .. } if *dev == d));
            if makespan > 0.0 {
                busy / makespan
            } else {
                0.0
            }
        })
        .collect();
    let mut report = build_report(
        "concurrent",
        &sim.policy,
        outcomes,
        rejected,
        laxity_rejections,
        makespan,
        device_util,
        sim.preemptions,
    );
    let (hits1, misses1) = cache.stats();
    report.template_cache_hits = hits1 - hits0;
    report.template_cache_misses = misses1 - misses0;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::sched::{Edf, LeastLoaded};
    use crate::serve::arrival::poisson_arrivals;
    use crate::serve::engine::serve_sim_cached;
    use crate::serve::request::Workload;

    /// Mixed stream exercising every admission path: two batch signatures,
    /// deadline-bearing high-priority requests, one malformed deadline
    /// (admission rejection), one unmeetable deadline (laxity rejection).
    fn stream(n: usize, seed: u64, rate: f64) -> Vec<ServeRequest> {
        let mut requests: Vec<ServeRequest> = poisson_arrivals(seed, n, rate)
            .expect("valid rate")
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let beta = if i % 4 == 3 { 128 } else { 64 };
                let mut r = ServeRequest::new(i, t, Workload::Head { beta });
                if i % 5 == 0 {
                    r.deadline = Some(2.0);
                    r.priority = 1;
                }
                if i % 7 == 3 {
                    r.deadline = Some(0.05);
                    r.priority = 2;
                }
                r
            })
            .collect();
        let mut bad = ServeRequest::new(n, 0.015, Workload::Head { beta: 64 });
        bad.deadline = Some(-1.0); // admission rejection
        requests.push(bad);
        let mut hopeless = ServeRequest::new(n + 1, 0.016, Workload::Head { beta: 64 });
        hopeless.deadline = Some(1e-9); // laxity rejection
        requests.push(hopeless);
        requests
    }

    fn assert_bit_equal(policy: &mut dyn Policy, reference: &mut dyn Policy) {
        let requests = stream(96, 13, 2500.0);
        let platform = Platform::scaled(2, 1, 3, 1);
        let cfg = ServeConfig::default();

        let mut cache_new = TemplateCache::new();
        let new = serve_sim_cached(
            &requests,
            &platform,
            &PaperCost,
            policy,
            &cfg,
            &mut cache_new,
        )
        .unwrap();
        let mut cache_ref = TemplateCache::new();
        let old = serve_sim_cached_ref(
            &requests,
            &platform,
            &PaperCost,
            reference,
            &cfg,
            &mut cache_ref,
        )
        .unwrap();

        // Both report in admission order: compare positionally, bit for bit.
        assert_eq!(new.outcomes.len(), old.outcomes.len());
        for (a, b) in new.outcomes.iter().zip(&old.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.release.to_bits(), b.release.to_bits(), "id {}", a.id);
            assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "id {}", a.id);
            assert_eq!(a.latency.to_bits(), b.latency.to_bits(), "id {}", a.id);
            assert_eq!(a.deadline_met, b.deadline_met, "id {}", a.id);
        }
        assert_eq!(new.rejected, old.rejected);
        assert!(!new.rejected.is_empty(), "stream must exercise rejection");
        assert_eq!(new.laxity_rejections, old.laxity_rejections);
        assert_eq!(new.laxity_rejections, 1);
        assert_eq!(new.makespan.to_bits(), old.makespan.to_bits());
        assert_eq!(new.preemptions, old.preemptions);
        assert_eq!(new.device_util.len(), old.device_util.len());
        for (a, b) in new.device_util.iter().zip(&old.device_util) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(new.template_cache_hits, old.template_cache_hits);
        assert_eq!(new.template_cache_misses, old.template_cache_misses);
    }

    #[test]
    fn core_routed_serve_sim_matches_reference_least_loaded() {
        assert_bit_equal(&mut LeastLoaded, &mut LeastLoaded);
    }

    #[test]
    fn core_routed_serve_sim_matches_reference_edf() {
        // Deadline-aware ordering (and possible preemption) must survive
        // the core refactor identically too.
        assert_bit_equal(&mut Edf, &mut Edf);
    }
}
