//! SLO-aware capacity search: the smallest GPU count whose deadline-miss
//! rate meets a target.
//!
//! `--autoscale-target F` used to scan GPU counts linearly, serving the
//! whole request stream once per scale — O(max_gpus) full simulations.
//! [`autoscale_search`] replaces that with **binary search over the scale
//! axis** plus a per-scale report cache: the cap is probed once (target
//! unreachable → serve at the cap, same contract as the scan), then the
//! search narrows in O(log max_gpus) evaluations, and every evaluated
//! scale's report is retained so the caller reuses the chosen scale's
//! report instead of re-serving.
//!
//! # Monotonicity assumption
//!
//! Binary search finds the *smallest feasible scale* exactly when the
//! miss rate is non-increasing in the GPU count — more replicas of the
//! same GPU never hurt a deadline under the simulator's scheduling model.
//! This is the same assumption the linear scan's early `break` made (it
//! stopped at the first feasible scale without probing larger ones); the
//! search just exploits it from both ends.

use std::collections::HashMap;

use crate::error::Result;

/// Search outcome: the chosen scale plus the evaluation transcript and the
/// per-scale report cache.
#[derive(Debug)]
pub struct Autoscale<R> {
    /// Smallest scale meeting the target, or the cap when unreachable.
    pub chosen: usize,
    /// Whether the target was met within the cap.
    pub reached: bool,
    /// `(scale, miss_rate)` in evaluation order — the search transcript
    /// (each scale appears at most once).
    pub evaluations: Vec<(usize, f64)>,
    /// Every evaluated scale's report, keyed by GPU count. Always contains
    /// `chosen` — the caller serves nothing twice.
    pub reports: HashMap<usize, R>,
}

/// Binary-search the smallest `gpus ∈ [1, max_gpus]` with
/// `miss(eval(gpus)) <= target`. `eval` runs the full serving simulation
/// at one scale (expensive — memoized); `miss` projects its report to the
/// deadline-miss rate.
pub fn autoscale_search<R>(
    max_gpus: usize,
    target: f64,
    mut eval: impl FnMut(usize) -> Result<R>,
    miss: impl Fn(&R) -> f64,
) -> Result<Autoscale<R>> {
    let max_gpus = max_gpus.max(1);
    let mut reports: HashMap<usize, R> = HashMap::new();
    let mut evaluations: Vec<(usize, f64)> = Vec::new();
    let mut probe = |gpus: usize,
                     reports: &mut HashMap<usize, R>,
                     evaluations: &mut Vec<(usize, f64)>|
     -> Result<f64> {
        if let Some(r) = reports.get(&gpus) {
            return Ok(miss(r));
        }
        let r = eval(gpus)?;
        let rate = miss(&r);
        evaluations.push((gpus, rate));
        reports.insert(gpus, r);
        Ok(rate)
    };

    // Probe the cap first: if even max_gpus misses the target, the target
    // is unreachable and the caller serves at the cap (the scan's
    // contract). This also seeds the search's feasible upper bound.
    if probe(max_gpus, &mut reports, &mut evaluations)? > target {
        return Ok(Autoscale {
            chosen: max_gpus,
            reached: false,
            evaluations,
            reports,
        });
    }
    let (mut lo, mut hi) = (1usize, max_gpus);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if probe(mid, &mut reports, &mut evaluations)? <= target {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Ok(Autoscale {
        chosen: hi,
        reached: true,
        evaluations,
        reports,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    /// A synthetic monotone miss-rate curve: feasible at `first_ok` and
    /// above. The "report" is the scale itself.
    fn curve(first_ok: usize) -> impl Fn(usize) -> f64 {
        move |gpus| {
            if gpus >= first_ok {
                0.0
            } else {
                1.0
            }
        }
    }

    #[test]
    fn finds_the_smallest_feasible_scale_like_the_linear_scan() {
        for max in [1usize, 2, 3, 7, 8, 64] {
            for first_ok in 1..=max {
                let f = curve(first_ok);
                let out = autoscale_search(max, 0.1, Ok, |&g| f(g)).unwrap();
                assert!(out.reached);
                assert_eq!(
                    out.chosen, first_ok,
                    "max={max} first_ok={first_ok}: binary search must agree \
                     with the linear scan"
                );
                assert_eq!(out.reports[&out.chosen], out.chosen);
            }
        }
    }

    #[test]
    fn unreachable_target_serves_at_the_cap() {
        let out = autoscale_search(8, 0.1, Ok, |_| 1.0).unwrap();
        assert!(!out.reached);
        assert_eq!(out.chosen, 8);
        // Exactly one expensive evaluation: the cap probe.
        assert_eq!(out.evaluations.len(), 1);
        assert!(out.reports.contains_key(&8));
    }

    #[test]
    fn evaluation_count_is_logarithmic_and_memoized() {
        let calls = Cell::new(0usize);
        let f = curve(37);
        let out = autoscale_search(
            64,
            0.0,
            |g| {
                calls.set(calls.get() + 1);
                Ok(g)
            },
            |&g| f(g),
        )
        .unwrap();
        assert_eq!(out.chosen, 37);
        // log2(64) = 6 bisection probes + the cap probe; memoization means
        // evaluations == distinct eval calls.
        assert!(calls.get() <= 7, "{} eval calls for max 64", calls.get());
        assert_eq!(out.evaluations.len(), calls.get());
        let mut scales: Vec<usize> = out.evaluations.iter().map(|&(g, _)| g).collect();
        scales.sort_unstable();
        scales.dedup();
        assert_eq!(scales.len(), out.evaluations.len(), "no scale evaluated twice");
    }

    #[test]
    fn errors_from_eval_propagate() {
        let e = autoscale_search(
            4,
            0.1,
            |_| -> Result<usize> { Err(crate::error::Error::Sched("boom".into())) },
            |_| 0.0,
        )
        .unwrap_err();
        assert!(matches!(e, crate::error::Error::Sched(_)), "{e}");
    }
}
