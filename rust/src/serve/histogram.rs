//! Streaming latency percentiles in O(1) memory per priority class.
//!
//! The streaming server used to retain one `(priority, latency)` pair per
//! served request (16 bytes/request) so the final report could cut exact
//! p50/p99 percentiles — flat-slope, but still O(served). This module
//! replaces that with a **fixed-bin log-scale histogram**
//! ([`LatencyHistogram`]): latencies are counted into geometrically spaced
//! bins, and a percentile query walks the cumulative counts and returns the
//! geometric midpoint of the bin holding the requested rank.
//!
//! # Error bound
//!
//! Bin edges grow by [`GROWTH`] (2% per bin) across the representable range
//! `[`[`MIN_LATENCY`]`, `[`MAX_LATENCY`]`)`. A value in bin `i` lies in
//! `[MIN·G^i, MIN·G^(i+1))` and is reported as the geometric midpoint
//! `MIN·G^(i+0.5)`, so the multiplicative error is at most `G^0.5 ≈ 1.00995`
//! — **≤ 1% relative error** for any in-range latency, at any quantile.
//! Latencies outside the range clamp to the first/last bin: below a
//! microsecond or above ~2.8 hours the reported percentile saturates (no
//! real serving latency lives there; the bound is documented, not silent).
//!
//! # Memory
//!
//! ~1.2k `u64` bins (≈ 9 KiB) per **distinct priority class**, independent
//! of the stream length — the soak bench's RSS ceiling tightens on the back
//! of this (`ci/bench_baselines/BENCH_serve_soak.json`).

use std::collections::BTreeMap;

/// Lower edge of the first bin: 1 µs. Smaller latencies clamp here.
const MIN_LATENCY: f64 = 1e-6;
/// Upper edge of the last bin: 10 000 s. Larger latencies clamp here.
const MAX_LATENCY: f64 = 1e4;
/// Geometric bin growth factor; `sqrt(GROWTH)` bounds the relative error.
const GROWTH: f64 = 1.02;

/// Fixed-bin log-scale latency histogram, bucketed per priority class so
/// one structure serves both the merged p50/p99 cuts and the per-priority
/// tail report.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bin counts per priority, ascending priority (BTreeMap order is the
    /// report order).
    per_priority: BTreeMap<u32, Vec<u64>>,
    nbins: usize,
    count: usize,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // ln(MAX/MIN)/ln(G) ≈ 1163 bins; +1 absorbs the ceil boundary.
        let nbins = ((MAX_LATENCY / MIN_LATENCY).ln() / GROWTH.ln()).ceil() as usize + 1;
        LatencyHistogram {
            per_priority: BTreeMap::new(),
            nbins,
            count: 0,
        }
    }

    fn bin(&self, latency: f64) -> usize {
        if !(latency > MIN_LATENCY) {
            // Sub-microsecond, zero, or NaN: clamp to the first bin.
            return 0;
        }
        (((latency / MIN_LATENCY).ln() / GROWTH.ln()) as usize).min(self.nbins - 1)
    }

    /// Geometric midpoint of bin `i` — the value a percentile query reports.
    fn representative(&self, i: usize) -> f64 {
        MIN_LATENCY * GROWTH.powf(i as f64 + 0.5)
    }

    /// Count one served request's latency under its priority class.
    pub fn record(&mut self, priority: u32, latency: f64) {
        let nbins = self.nbins;
        let bins = self
            .per_priority
            .entry(priority)
            .or_insert_with(|| vec![0u64; nbins]);
        let b = self.bin(latency);
        bins[b] += 1;
        self.count += 1;
    }

    /// Total recorded latencies across every priority.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Fold another histogram into this one, bin-wise per priority class.
    ///
    /// Because every histogram uses the same fixed bin edges
    /// ([`MIN_LATENCY`], [`MAX_LATENCY`], [`GROWTH`]), merging is exact:
    /// quantiles cut from the merged histogram equal quantiles cut from a
    /// single histogram that recorded every sample directly — this is what
    /// lets the sharded server keep one histogram per shard thread and
    /// still report global percentiles with the same ≤1% error bound.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        let nbins = self.nbins;
        for (&p, obins) in &other.per_priority {
            let bins = self
                .per_priority
                .entry(p)
                .or_insert_with(|| vec![0u64; nbins]);
            for (b, &c) in bins.iter_mut().zip(obins.iter()) {
                *b += c;
            }
        }
        self.count += other.count;
    }

    /// Nearest-rank quantile over **all** priorities merged, matching
    /// [`super::percentile_sorted`]'s rank convention
    /// (`round((n-1)·q)`); 0.0 when empty, representative within 1% of the
    /// exact order statistic otherwise.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((self.count as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
        let mut seen = 0usize;
        for i in 0..self.nbins {
            let c: u64 = self
                .per_priority
                .values()
                .map(|bins| bins[i])
                .sum();
            seen += c as usize;
            if seen > rank {
                return self.representative(i);
            }
        }
        self.representative(self.nbins - 1)
    }

    /// Nearest-rank quantile per distinct priority, ascending priority —
    /// the shape of `per_priority_p99` in the streaming report.
    pub fn per_priority_quantile(&self, q: f64) -> Vec<(u32, f64)> {
        let mut out = Vec::with_capacity(self.per_priority.len());
        for (&p, bins) in &self.per_priority {
            let total: u64 = bins.iter().sum();
            if total == 0 {
                continue;
            }
            let rank = ((total as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as u64;
            let mut seen = 0u64;
            for (i, &c) in bins.iter().enumerate() {
                seen += c;
                if seen > rank {
                    out.push((p, self.representative(i)));
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exact nearest-rank percentile (the shape the histogram approximates).
    fn exact(values: &[f64], q: f64) -> f64 {
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        if sorted.is_empty() {
            return 0.0;
        }
        let idx = ((sorted.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
        sorted[idx]
    }

    #[test]
    fn quantiles_are_within_one_percent_of_exact() {
        // 300 values spanning ~4 decades (0.1 ms .. 0.7 s) — every serving
        // regime the reports see.
        let values: Vec<f64> = (0..300).map(|i| 1e-4 * 1.03f64.powi(i)).collect();
        let mut h = LatencyHistogram::new();
        for &v in &values {
            h.record(0, v);
        }
        assert_eq!(h.count(), 300);
        for q in [0.0, 0.1, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let e = exact(&values, q);
            let got = h.quantile(q);
            let rel = (got - e).abs() / e;
            assert!(rel <= 0.0101, "q={q}: exact {e}, histogram {got}, rel {rel}");
        }
    }

    #[test]
    fn empty_histogram_cuts_zero_like_percentile_sorted() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert!(h.per_priority_quantile(0.99).is_empty());
    }

    #[test]
    fn out_of_range_latencies_clamp_to_the_edge_bins() {
        let mut h = LatencyHistogram::new();
        h.record(0, 1e-9); // below MIN: first bin
        h.record(0, 1e9); // above MAX: last bin
        assert_eq!(h.count(), 2);
        let lo = h.quantile(0.0);
        let hi = h.quantile(1.0);
        assert!((MIN_LATENCY..MIN_LATENCY * 1.1).contains(&lo), "{lo}");
        assert!((MAX_LATENCY * 0.97..=MAX_LATENCY * 1.02).contains(&hi), "{hi}");
    }

    #[test]
    fn merge_is_bin_exact_vs_a_single_global_histogram() {
        // Samples spanning several decades and 3 priority classes, split
        // across 4 "shards" round-robin — the sharded report's shape.
        let samples: Vec<(u32, f64)> = (0..500)
            .map(|i| ((i % 3) as u32, 5e-5 * 1.025f64.powi(i % 400)))
            .collect();
        let mut global = LatencyHistogram::new();
        let mut shards: Vec<LatencyHistogram> =
            (0..4).map(|_| LatencyHistogram::new()).collect();
        for (i, &(p, v)) in samples.iter().enumerate() {
            global.record(p, v);
            shards[i % 4].record(p, v);
        }
        let mut merged = LatencyHistogram::new();
        for s in &shards {
            merged.merge(s);
        }
        assert_eq!(merged.count(), global.count());
        // Exact bin equality, not just close quantiles.
        assert_eq!(merged.per_priority, global.per_priority);
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(
                merged.quantile(q).to_bits(),
                global.quantile(q).to_bits(),
                "q={q}"
            );
        }
        assert_eq!(
            merged.per_priority_quantile(0.99),
            global.per_priority_quantile(0.99)
        );
    }

    #[test]
    fn per_priority_quantiles_track_each_class() {
        let fast: Vec<f64> = (0..100).map(|i| 1e-3 + i as f64 * 1e-5).collect();
        let slow: Vec<f64> = (0..100).map(|i| 1e-1 + i as f64 * 1e-3).collect();
        let mut h = LatencyHistogram::new();
        for &v in &fast {
            h.record(0, v);
        }
        for &v in &slow {
            h.record(2, v);
        }
        let cuts = h.per_priority_quantile(0.99);
        assert_eq!(cuts.len(), 2);
        assert_eq!(cuts[0].0, 0);
        assert_eq!(cuts[1].0, 2);
        for (vals, &(_, got)) in [(&fast, &cuts[0]), (&slow, &cuts[1])] {
            let e = exact(vals, 0.99);
            assert!((got - e).abs() / e <= 0.0101, "exact {e}, got {got}");
        }
        // The merged cut sits in the slow class's range (it owns the tail).
        assert!(h.quantile(0.99) > 0.1);
    }
}
