//! The unified serve core: **one** admission/backpressure loop for every
//! serving mode, parameterized by a [`ServeBackend`].
//!
//! PySchedCL's premise is that concurrency-aware scheduling should be
//! written once and applied uniformly across heterogeneous execution
//! targets. The serve layer had drifted into three near-duplicate
//! pipelines (batch sim, batch real, streaming sim), each re-implementing
//! admission, batching, outcome emission, and accounting. This module is
//! the EngineCL-style consolidation: [`serve_core`] owns the pipeline —
//! arrival-iterator ingestion, [`StreamBatcher`] batching, memoized
//! template + laxity admission ([`AdmissionGate`]), `window`-bounded
//! backpressure, [`OutcomeSink`] emission, and report accounting — and a
//! [`ServeBackend`] owns only *execution*: take an [`AdmitUnit`], make
//! progress, hand back [`FinishedRequest`]s.
//!
//! Two backends exist:
//!
//! * [`SimBackend`](super::streaming::SimBackend) — virtual time through
//!   the long-lived [`crate::sim::StreamSim`];
//! * [`RealBackend`](super::real::RealBackend) — wall-clock execution
//!   through [`crate::exec::execute_dag_served`] and the PJRT stand-in
//!   [`crate::runtime::Runtime`], with open/closed pacing.
//!
//! The batch entry points (`serve_sim_cached`, `serve_real`) are thin
//! wrappers: sort the request vector into admission order and run the core
//! at `window: 0`. Equivalence with the pre-refactor monoliths is enforced
//! bit-for-bit by `serve::reference` and the artifact-gated real-path
//! tests.
//!
//! # Memory profile
//!
//! Held for the whole run: the latency histogram (fixed bins per priority
//! class — [`LatencyHistogram`]), the template cache, and the backend's
//! live state (bounded by the window). Held transiently: pending request
//! records between admission and batch close, and queued [`AdmitUnit`]s
//! under backpressure (the inherent arrival backlog of an open-loop system
//! in overload).

use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::sync::Arc;

use super::admission::{AdmissionGate, OpenBatch, StreamBatcher};
use super::cache::TemplateCache;
use super::engine::{outcome_fields, Pacing, RequestOutcome};
use super::histogram::LatencyHistogram;
use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::{Error, Result};
use crate::fault::{FaultPlan, ShedPolicy};
use crate::graph::{Dag, Partition};
use crate::json::Json;
use crate::platform::{DeviceId, Platform};
use crate::sim::{AdmitUnit, FinishedRequest, MemberSpec, PumpStop, SimConfig, Template};

/// Streaming-server knobs. The subset of [`super::ServeConfig`] that is
/// meaningful for an always-on run, plus the admission window.
#[derive(Debug, Clone)]
pub struct StreamingConfig {
    /// Admission window: max requests live in the backend at once
    /// (`0` = unbounded, the equivalence-test setting). A closed batch
    /// larger than the window is admitted whole once the server drains
    /// idle, so oversized batches stall but never wedge.
    pub window: usize,
    /// Batching window (seconds from a batch opener), as in
    /// [`super::ServeConfig::batch_window`].
    pub batch_window: f64,
    /// Max task components resident per device (multi-tenancy).
    pub tenancy: usize,
    /// Laxity-based admission control (see [`super::admission::admit_slo`]).
    pub laxity_admission: bool,
    /// Underlying simulator knobs (sim backend only). `max_events` is the
    /// per-pump runaway guard here, not a whole-run cap.
    pub sim: SimConfig,
    /// Fault-injection plan: crash/wedge/slowdown events plus the retry
    /// budget, backoff base, and shedding policy ([`FaultPlan`]). `None` —
    /// the default — keeps every serving path byte-identical to the
    /// fault-free build.
    pub faults: Option<FaultPlan>,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig {
            window: 512,
            batch_window: 2e-3,
            tenancy: 4,
            laxity_admission: true,
            sim: SimConfig::default(),
            faults: None,
        }
    }
}

/// Where per-request outcomes go, one call per completion, in completion
/// order. The serve core never accumulates an outcome vector — this sink
/// is the only place results exist.
pub trait OutcomeSink {
    /// `devices` is the device each of the request's components ran on,
    /// in component order (last device for preempted components).
    fn emit(&mut self, outcome: &RequestOutcome, devices: &[DeviceId]) -> Result<()>;

    /// A request shed by graceful degradation under faults (retry budget
    /// exhausted, every device down, or negative projected laxity in the
    /// admit queue). Never counted as served; `outcome.finish` is the shed
    /// instant and `devices` lists only components that actually ran. The
    /// default discards, so fault-free sinks are untouched.
    fn emit_shed(&mut self, _outcome: &RequestOutcome, _devices: &[DeviceId]) -> Result<()> {
        Ok(())
    }

    /// A request rejected at admission (duplicate id in flight, negative
    /// laxity, malformed workload), reported with the typed error. Called
    /// once per rejection, before the next arrival is ingested. The default
    /// discards — accounting stays in the report; the sharded router's
    /// per-shard sink overrides this to release the id from the global
    /// in-flight set so a rejected id can legitimately be resubmitted.
    fn emit_rejected(&mut self, _id: usize, _err: &Error) -> Result<()> {
        Ok(())
    }

    /// Flush any buffered output; called once at end of stream.
    fn flush(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Discards outcomes (throughput benches: accounting without I/O).
#[derive(Debug, Default)]
pub struct NullSink;

impl OutcomeSink for NullSink {
    fn emit(&mut self, _outcome: &RequestOutcome, _devices: &[DeviceId]) -> Result<()> {
        Ok(())
    }
}

/// Collects outcomes in memory — for tests and for the batch-mode wrappers
/// that still return an outcome vector (which defeats bounded memory;
/// don't use it on unbounded streams).
#[derive(Debug, Default)]
pub struct CollectSink {
    pub outcomes: Vec<RequestOutcome>,
}

impl OutcomeSink for CollectSink {
    fn emit(&mut self, outcome: &RequestOutcome, _devices: &[DeviceId]) -> Result<()> {
        self.outcomes.push(outcome.clone());
        Ok(())
    }
}

/// Streams outcomes as JSON Lines: one object per request with fixed keys
/// `id`, `arrival`, `release`, `finish`, `latency_s`, `deadline_met`
/// (bool or null), `priority`, `devices` (array of device ids). Wrap the
/// writer in a `BufWriter` for file targets — emit is called per request.
///
/// Write and flush failures surface as typed [`Error::Io`] from
/// [`emit`](OutcomeSink::emit)/[`flush`](OutcomeSink::flush), aborting the
/// run rather than silently dropping outcomes; dropping the sink flushes
/// whatever buffered output remains (best-effort — drop cannot report).
#[derive(Debug)]
pub struct JsonlSink<W: Write> {
    w: W,
}

impl<W: Write> JsonlSink<W> {
    pub fn new(w: W) -> Self {
        JsonlSink { w }
    }
}

impl<W: Write> Drop for JsonlSink<W> {
    fn drop(&mut self) {
        let _ = self.w.flush();
    }
}

impl<W: Write> JsonlSink<W> {
    /// Shared line writer. Served lines are byte-identical to the
    /// pre-fault format; shed lines append a single `"outcome":"shed"`
    /// field so consumers can separate degradation from service.
    fn write_line(&mut self, o: &RequestOutcome, devices: &[DeviceId], shed: bool) -> Result<()> {
        let met = match o.deadline_met {
            Some(true) => "true",
            Some(false) => "false",
            None => "null",
        };
        write!(
            self.w,
            "{{\"id\":{},\"arrival\":{},\"release\":{},\"finish\":{},\"latency_s\":{},\"deadline_met\":{},\"priority\":{},\"devices\":[",
            o.id, o.arrival, o.release, o.finish, o.latency, met, o.priority
        )?;
        for (i, d) in devices.iter().enumerate() {
            if i > 0 {
                write!(self.w, ",")?;
            }
            write!(self.w, "{d}")?;
        }
        if shed {
            writeln!(self.w, "],\"outcome\":\"shed\"}}")?;
        } else {
            writeln!(self.w, "]}}")?;
        }
        Ok(())
    }
}

impl<W: Write> OutcomeSink for JsonlSink<W> {
    fn emit(&mut self, o: &RequestOutcome, devices: &[DeviceId]) -> Result<()> {
        self.write_line(o, devices, false)
    }

    fn emit_shed(&mut self, o: &RequestOutcome, devices: &[DeviceId]) -> Result<()> {
        self.write_line(o, devices, true)
    }

    fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Aggregate statistics of one core-driven serving run — the scalars a
/// long-lived server can afford to keep (no per-request vectors at all;
/// percentiles come from the fixed-bin [`LatencyHistogram`]).
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub policy: String,
    /// Requests that completed (every admitted request completes — the
    /// stream is drained before returning).
    pub served: usize,
    /// Total admission rejections over the stream.
    pub rejected: usize,
    /// Requests shed by graceful degradation under faults: retry budget
    /// exhausted, every schedulable device crashed, or negative projected
    /// laxity while queued behind the window. Conservation holds over every
    /// run: `served + rejected + shed == offered`.
    pub shed: usize,
    /// Requests pulled from the arrival stream, whatever became of them.
    pub offered: usize,
    /// Highest per-request crash-retry count observed (≤ the fault plan's
    /// `retry_budget`; 0 on fault-free runs).
    pub max_retries: u32,
    /// First few `(request id, admission error)` rejections, capped — the
    /// full list would grow with the stream.
    pub rejected_sample: Vec<(usize, String)>,
    /// ... of the rejections, how many were laxity-based.
    pub laxity_rejections: usize,
    /// Last completion instant (virtual seconds on the sim backend, wall
    /// seconds from the epoch on the real backend).
    pub makespan: f64,
    pub throughput_rps: f64,
    /// p50/p99 latency from the log-scale histogram (≤1% relative error —
    /// [`LatencyHistogram`]).
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub deadline_total: usize,
    pub deadline_misses: usize,
    pub deadline_miss_rate: f64,
    /// p99 latency per distinct priority, ascending priority.
    pub per_priority_p99: Vec<(u32, f64)>,
    pub preemptions: usize,
    /// Compute busy fraction per device over the makespan.
    pub device_util: Vec<f64>,
    /// The admission window the run used (0 = unbounded).
    pub window: usize,
    /// High-water mark of requests live in the backend at once — the
    /// bounded-memory witness (≤ window when the window binds).
    pub peak_live_requests: usize,
    /// High-water mark of live components (slots) — what the soak bench
    /// gates in CI.
    pub peak_live_components: usize,
    /// Events processed (simulated events on the sim backend, executed
    /// kernel spans on the real backend).
    pub events: u64,
    /// Arrival pacing: `"virtual"` on the sim backend (virtual time is
    /// always open-loop), `"open"`/`"closed"` on the real backend.
    pub pacing: &'static str,
    /// Real backend: PJRT executable-cache hits/misses over the run
    /// (0 in sim) — see [`super::ServeReport::exec_cache_hits`].
    pub exec_cache_hits: usize,
    pub exec_cache_misses: usize,
    /// Real backend: mean service latency of cold / warm batches
    /// (0 when none, and always 0 in sim).
    pub cold_batch_latency: f64,
    pub warm_batch_latency: f64,
    /// Merged-template cache hits/misses over this run.
    pub template_cache_hits: usize,
    pub template_cache_misses: usize,
    /// The full latency histogram behind `p50/p99_latency` — carried so a
    /// sharded run can merge per-shard histograms **bin-wise**
    /// ([`LatencyHistogram::merge`]) and cut exact global percentiles
    /// instead of averaging per-shard quantiles (which has no error bound).
    /// O(1) in the stream length, like every other field.
    pub latency_hist: LatencyHistogram,
}

impl StreamReport {
    /// The BENCH_serve_soak.json / BENCH_serve_real_stream.json building
    /// block.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str("streaming")),
            ("policy", Json::str(self.policy.clone())),
            ("pacing", Json::str(self.pacing)),
            ("requests", Json::num(self.served as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("offered", Json::num(self.offered as f64)),
            (
                "lost",
                Json::num(
                    (self.offered as f64)
                        - (self.served as f64)
                        - (self.rejected as f64)
                        - (self.shed as f64),
                ),
            ),
            ("max_retries", Json::num(self.max_retries as f64)),
            ("laxity_rejections", Json::num(self.laxity_rejections as f64)),
            ("makespan_s", Json::num(self.makespan)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("p50_latency_s", Json::num(self.p50_latency)),
            ("p99_latency_s", Json::num(self.p99_latency)),
            ("deadline_total", Json::num(self.deadline_total as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("deadline_miss_rate", Json::num(self.deadline_miss_rate)),
            (
                "per_priority_p99_s",
                Json::Arr(
                    self.per_priority_p99
                        .iter()
                        .map(|&(p, l)| {
                            Json::obj(vec![
                                ("priority", Json::num(p as f64)),
                                ("p99_latency_s", Json::num(l)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("preemptions", Json::num(self.preemptions as f64)),
            (
                "device_util",
                Json::Arr(self.device_util.iter().map(|&u| Json::num(u)).collect()),
            ),
            ("window", Json::num(self.window as f64)),
            (
                "peak_live_requests",
                Json::num(self.peak_live_requests as f64),
            ),
            (
                "peak_live_components",
                Json::num(self.peak_live_components as f64),
            ),
            ("events", Json::num(self.events as f64)),
            ("exec_cache_hits", Json::num(self.exec_cache_hits as f64)),
            ("exec_cache_misses", Json::num(self.exec_cache_misses as f64)),
            ("cold_batch_latency_s", Json::num(self.cold_batch_latency)),
            ("warm_batch_latency_s", Json::num(self.warm_batch_latency)),
            (
                "template_cache_hits",
                Json::num(self.template_cache_hits as f64),
            ),
            (
                "template_cache_misses",
                Json::num(self.template_cache_misses as f64),
            ),
        ])
    }
}

/// A request admitted but not yet batch-closed: the scalars the core keeps
/// between admission and batch close (the `ServeRequest` itself — workload
/// payload included — is dropped at admission).
struct PendingReq {
    arrival: f64,
    deadline: Option<f64>,
    priority: u32,
    cacheable: bool,
    app: Arc<(Dag, Partition)>,
}

/// Rejection-sample cap for the always-on entry points (the batch-mode
/// wrappers pass `usize::MAX` — their reports have always carried the full
/// rejection list).
pub(crate) const REJECT_SAMPLE_CAP: usize = 32;

/// End-of-run execution statistics a backend reports to the core.
#[derive(Debug, Clone)]
pub struct BackendStats {
    /// Last completion instant (virtual or wall seconds from the epoch).
    pub makespan: f64,
    /// Resident components displaced mid-flight (0 where unsupported).
    pub preemptions: usize,
    /// Compute busy seconds per device (the core divides by makespan).
    pub device_busy: Vec<f64>,
    /// Events processed (simulated events / executed kernel spans).
    pub events: u64,
    /// High-water mark of live requests.
    pub peak_live_requests: usize,
    /// High-water mark of live components.
    pub peak_live_components: usize,
}

/// An execution target for [`serve_core`]: the core owns admission,
/// batching, backpressure, and accounting; the backend owns only how
/// admitted units actually run.
///
/// # Contract
///
/// * [`admit`](Self::admit) takes ownership of a unit; its members count as
///   *live* until they reappear via
///   [`drain_finished_into`](Self::drain_finished_into).
/// * [`pump`](Self::pump) makes progress up to `horizon` (a time on the
///   backend's own clock; `INFINITY` = run until something completes or
///   nothing is left). Returning [`PumpStop::Idle`] means *nothing left to
///   execute*; the core treats Idle-with-queued-work after end of stream as
///   a stall and aborts rather than spinning.
/// * A backend may defer execution of an admitted unit whose release lies
///   beyond `horizon` — the core always pumps again after ingesting more
///   arrivals, and pumps to `INFINITY` once the stream is exhausted.
pub trait ServeBackend {
    /// Accept one admission unit (a closed batch or a single uncacheable
    /// request) for execution at-or-after `unit.release`.
    fn admit(&mut self, unit: AdmitUnit) -> Result<()>;

    /// Make execution progress up to `horizon` on this backend's clock.
    fn pump(&mut self, horizon: f64) -> Result<PumpStop>;

    /// Move every request completed since the last call into `out`,
    /// retiring its live state.
    fn drain_finished_into(&mut self, out: &mut Vec<FinishedRequest>);

    /// Requests admitted and not yet drained — what the admission window
    /// bounds.
    fn live_requests(&self) -> usize;

    /// Current instant on this backend's clock (virtual seconds in sim,
    /// wall seconds from the serve epoch on the real backend) — the clock
    /// the core's deadline-aware queue shedding compares laxity against.
    /// The default places "now" before every deadline, so a backend that
    /// does not override it never triggers queue shedding.
    fn now(&self) -> f64 {
        f64::NEG_INFINITY
    }

    /// Release execution resources after a typed mid-stream abort: called
    /// once, only on [`serve_core`]'s error path, before the error
    /// propagates. The real backend drains and retires in-flight executor
    /// work here so no execution outlives the serve call; backends without
    /// background execution need not override.
    fn abort(&mut self) {}

    /// Pacing label for latency semantics ([`outcome_fields`]): sim time is
    /// inherently open-loop ([`Pacing::Open`]); a closed-loop real replay
    /// returns [`Pacing::Closed`] so outcomes get the service-latency
    /// clamp.
    fn pacing(&self) -> Pacing;

    /// End-of-run execution statistics.
    fn stats(&self) -> BackendStats;

    /// Backend-specific report fields (pacing label, executable-cache
    /// counters, cold/warm batch latency). Called once, last.
    fn finalize_report(&self, _report: &mut StreamReport) {}
}

/// Drive an arrival-ordered request stream through `backend` — the one
/// serving loop behind every mode (`serve_sim_cached` / `serve_real` at
/// `window: 0`, `serve_stream*` / `serve_real_stream` with a finite
/// window).
///
/// The loop interleaves four activities until the stream and the backend
/// are both drained:
///
/// 1. **admit** queued closed batches while live requests fit the window
///    (an idle backend takes any unit, so oversized batches stall but
///    never wedge);
/// 2. **pump** the backend to the next admission boundary — the earliest
///    of the first open batch's opener and the next arrival instant (so
///    execution never overtakes a batch that is still coalescing);
/// 3. **drain** completed requests into the sink, retiring their state;
/// 4. **ingest** one arrival: admission checks (template cache + laxity
///    gate, both memoized per signature exactly as the batch path's
///    `admit_all` does), then offer it to the [`StreamBatcher`]; batches
///    it closes become [`AdmitUnit`]s.
///
/// Arrivals must be non-decreasing (an arrival stream, not a request bag);
/// an out-of-order arrival is a typed [`Error::Admission`] that aborts the
/// run — incremental batching is ill-defined on it.
#[allow(clippy::too_many_arguments)]
pub fn serve_core<I>(
    requests: I,
    platform: &Platform,
    cost: &dyn CostModel,
    backend: &mut dyn ServeBackend,
    cfg: &StreamingConfig,
    cache: &mut TemplateCache,
    sink: &mut dyn OutcomeSink,
    policy_name: &str,
    reject_sample_cap: usize,
) -> Result<StreamReport>
where
    I: IntoIterator<Item = ServeRequest>,
{
    let r = serve_core_inner(
        requests,
        platform,
        cost,
        backend,
        cfg,
        cache,
        sink,
        policy_name,
        reject_sample_cap,
    );
    if r.is_err() {
        // A typed mid-stream abort must not leak execution state: give the
        // backend the chance to drain and retire in-flight work before the
        // error propagates (the real backend joins its executor thread
        // here so nothing outlives the serve call).
        backend.abort();
    }
    r
}

#[allow(clippy::too_many_arguments)]
fn serve_core_inner<I>(
    requests: I,
    platform: &Platform,
    cost: &dyn CostModel,
    backend: &mut dyn ServeBackend,
    cfg: &StreamingConfig,
    cache: &mut TemplateCache,
    sink: &mut dyn OutcomeSink,
    policy_name: &str,
    reject_sample_cap: usize,
) -> Result<StreamReport>
where
    I: IntoIterator<Item = ServeRequest>,
{
    let (hits0, misses0) = cache.stats();
    let pacing = backend.pacing();

    let mut it = requests.into_iter();
    let mut next_arr = it.next();
    let mut last_arrival = f64::NEG_INFINITY;
    let mut batcher = StreamBatcher::new(cfg.batch_window);
    let mut closed: Vec<OpenBatch> = Vec::new();
    let mut admit_q: VecDeque<AdmitUnit> = VecDeque::new();
    let mut pending: HashMap<usize, PendingReq> = HashMap::new();
    let mut gate = AdmissionGate::new(cfg.laxity_admission);
    let mut finished: Vec<FinishedRequest> = Vec::new();

    let mut served = 0usize;
    let mut rejected = 0usize;
    let mut shed = 0usize;
    let mut offered = 0usize;
    let mut max_retries = 0u32;
    let mut rejected_sample: Vec<(usize, String)> = Vec::new();
    let mut laxity_rejections = 0usize;
    let mut deadline_total = 0usize;
    let mut deadline_misses = 0usize;
    // Fixed-bin log-scale histogram: the only latency state kept to the
    // end, O(1) in the stream length.
    let mut hist = LatencyHistogram::new();

    // `sink` is passed per call (not captured): the loop body also emits
    // completions through it.
    let mut reject =
        |id: usize, e: Error, rejected: &mut usize, sink: &mut dyn OutcomeSink| -> Result<()> {
            *rejected += 1;
            if rejected_sample.len() < reject_sample_cap {
                rejected_sample.push((id, e.to_string()));
            }
            sink.emit_rejected(id, &e)
        };

    loop {
        // (1) Admit queued units while the window admits them. An idle
        // backend takes any unit (oversized batches must not wedge).
        let mut admitted_any = false;
        while let Some(u) = admit_q.front() {
            let live = backend.live_requests();
            if cfg.window == 0 || live == 0 || live + u.members.len() <= cfg.window {
                let u = admit_q.pop_front().expect("front() was Some");
                backend.admit(u)?;
                admitted_any = true;
            } else {
                break;
            }
        }

        // (1b) Deadline-aware load shedding. Under fault pressure the
        // window can stay pinned for whole retry/backoff epochs; a queued
        // unit whose every deadline has already passed on the backend
        // clock has negative projected laxity and can only miss. Shed the
        // plan's preferred victim — typed, accounted — instead of letting
        // it rot behind the window. One victim per pass keeps shedding
        // interleaved with (and subordinate to) real progress.
        if let Some(plan) = cfg.faults.as_ref() {
            if !admit_q.is_empty() {
                let bnow = backend.now();
                if let Some(i) = shed_victim(&admit_q, bnow, plan.shed_policy) {
                    let u = admit_q.remove(i).expect("victim index in bounds");
                    for m in &u.members {
                        let o = outcome_fields(
                            m.id,
                            m.arrival,
                            m.deadline,
                            m.priority,
                            u.release,
                            bnow.max(u.release),
                            pacing,
                        );
                        shed += 1;
                        sink.emit_shed(&o, &[])?;
                    }
                    continue;
                }
            }
        }

        // (2) Advance the backend to the next admission boundary. While a
        // batch is open its *opener* is the bound: the batch may close with
        // a release at or after the opener, and admission must happen
        // before backend time reaches it (the monolithic run has had the
        // release event queued since t = 0).
        let h_arr = next_arr
            .as_ref()
            .map(|r: &ServeRequest| r.arrival)
            .unwrap_or(f64::INFINITY);
        let stop = backend.pump(batcher.horizon().min(h_arr))?;

        // (3) Retire completions into the sink.
        backend.drain_finished_into(&mut finished);
        let emitted = finished.len();
        for f in finished.drain(..) {
            let o = outcome_fields(
                f.id, f.arrival, f.deadline, f.priority, f.release, f.finish, pacing,
            );
            max_retries = max_retries.max(f.retries);
            if f.shed {
                shed += 1;
                sink.emit_shed(&o, &f.devices)?;
                continue;
            }
            if let Some(met) = o.deadline_met {
                deadline_total += 1;
                if !met {
                    deadline_misses += 1;
                }
            }
            hist.record(o.priority, o.latency);
            served += 1;
            sink.emit(&o, &f.devices)?;
        }
        if admitted_any || emitted > 0 {
            // Progress was made — capacity may have freed or new units may
            // now fit; go admit/pump again before touching the arrival
            // stream.
            continue;
        }

        // (4) Ingest exactly one arrival, mirroring admit_all's per-request
        // admission pipeline.
        if let Some(req) = next_arr.take() {
            next_arr = it.next();
            offered += 1;
            match cache.admit_app(&req) {
                Ok(app) => {
                    if req.arrival < last_arrival {
                        return Err(Error::Admission(format!(
                            "streaming arrivals must be non-decreasing: request {} \
                             arrived at {} after {}",
                            req.id, req.arrival, last_arrival
                        )));
                    }
                    last_arrival = req.arrival;
                    if pending.contains_key(&req.id) {
                        reject(
                            req.id,
                            Error::Admission(format!(
                                "request {}: duplicate id in flight",
                                req.id
                            )),
                            &mut rejected,
                            &mut *sink,
                        )?;
                        continue;
                    }
                    if let Err(e) = gate.check(&req, app.as_ref(), platform, cost) {
                        laxity_rejections += 1;
                        reject(req.id, e, &mut rejected, &mut *sink)?;
                        continue;
                    }
                    let sig = req.workload.signature();
                    batcher.offer(req.id, &sig, req.arrival, &mut closed);
                    pending.insert(
                        req.id,
                        PendingReq {
                            arrival: req.arrival,
                            deadline: req.deadline,
                            priority: req.priority,
                            cacheable: req.workload.cacheable(),
                            app,
                        },
                    );
                    units_from_closed(&mut closed, &mut pending, cache, &mut admit_q)?;
                }
                Err(e) => reject(req.id, e, &mut rejected, &mut *sink)?,
            }
            continue;
        }

        // (5) End of stream: close the still-open batches, once.
        if batcher.open_len() > 0 {
            batcher.flush(&mut closed);
            units_from_closed(&mut closed, &mut pending, cache, &mut admit_q)?;
            continue;
        }

        // (6) Drained?
        if admit_q.is_empty() && backend.live_requests() == 0 {
            break;
        }

        // (7) Work remains but nothing was admitted, nothing completed, and
        // the stream is exhausted. An idle backend here is a wedge.
        if stop == PumpStop::Idle {
            return Err(Error::Sched(format!(
                "streaming stall: {} queued unit(s), {} live request(s), \
                 backend idle",
                admit_q.len(),
                backend.live_requests()
            )));
        }
    }
    sink.flush()?;

    debug_assert!(pending.is_empty(), "requests left pending at end of stream");

    let stats = backend.stats();
    let makespan = stats.makespan;
    let device_util = stats
        .device_busy
        .iter()
        .map(|&busy| if makespan > 0.0 { busy / makespan } else { 0.0 })
        .collect();
    let (hits1, misses1) = cache.stats();
    let mut report = StreamReport {
        policy: policy_name.to_string(),
        served,
        rejected,
        shed,
        offered,
        max_retries,
        rejected_sample,
        laxity_rejections,
        makespan,
        throughput_rps: if makespan > 0.0 {
            served as f64 / makespan
        } else {
            0.0
        },
        p50_latency: hist.quantile(0.50),
        p99_latency: hist.quantile(0.99),
        deadline_total,
        deadline_misses,
        deadline_miss_rate: if deadline_total > 0 {
            deadline_misses as f64 / deadline_total as f64
        } else {
            0.0
        },
        per_priority_p99: hist.per_priority_quantile(0.99),
        preemptions: stats.preemptions,
        device_util,
        window: cfg.window,
        peak_live_requests: stats.peak_live_requests,
        peak_live_components: stats.peak_live_components,
        events: stats.events,
        pacing: "virtual",
        exec_cache_hits: 0,
        exec_cache_misses: 0,
        cold_batch_latency: 0.0,
        warm_batch_latency: 0.0,
        template_cache_hits: hits1 - hits0,
        template_cache_misses: misses1 - misses0,
        latency_hist: hist,
    };
    backend.finalize_report(&mut report);
    Ok(report)
}

/// Turn closed batches into admission units, in close order. A fully
/// cacheable batch becomes **one** merged-block unit (all sizes go through
/// the template cache, size-1 included — counter parity with
/// [`super::serve_sim_cached`]); a batch with any uncacheable member
/// becomes one single-app unit **per member**, in member order — exactly
/// the component layout the monolithic assembly would append.
pub(crate) fn units_from_closed(
    closed: &mut Vec<OpenBatch>,
    pending: &mut HashMap<usize, PendingReq>,
    cache: &mut TemplateCache,
    out: &mut VecDeque<AdmitUnit>,
) -> Result<()> {
    for b in closed.drain(..) {
        let missing = || Error::Admission("internal: batch member not pending".into());
        let cacheable = b
            .members
            .iter()
            .all(|id| pending.get(id).map(|p| p.cacheable).unwrap_or(false));
        if cacheable {
            let first = pending.get(&b.members[0]).ok_or_else(missing)?;
            let block = cache.merged_block(&b.signature, b.members.len(), &first.app)?;
            let mut members = Vec::with_capacity(b.members.len());
            for (i, &id) in b.members.iter().enumerate() {
                let p = pending.remove(&id).ok_or_else(missing)?;
                members.push(MemberSpec {
                    id,
                    arrival: p.arrival,
                    deadline: p.deadline,
                    priority: p.priority,
                    comps: block.component_ranges[i].clone(),
                });
            }
            out.push_back(AdmitUnit {
                tmpl: Template::Merged(block),
                release: b.release,
                members,
            });
        } else {
            for &id in &b.members {
                let p = pending.remove(&id).ok_or_else(missing)?;
                let ncomp = p.app.1.components.len();
                out.push_back(AdmitUnit {
                    tmpl: Template::Single(p.app),
                    release: b.release,
                    members: vec![MemberSpec {
                        id,
                        arrival: p.arrival,
                        deadline: p.deadline,
                        priority: p.priority,
                        comps: 0..ncomp,
                    }],
                });
            }
        }
    }
    Ok(())
}

/// Pick the queued unit to shed, if any has negative projected laxity:
/// every member carries a deadline and every absolute deadline instant
/// (`arrival + deadline`) lies before `now`. Among expired units the
/// plan's policy chooses the victim: [`ShedPolicy::LowestPriority`] sheds
/// the least-urgent unit first (tie: latest deadline);
/// [`ShedPolicy::LatestDeadline`] sheds the unit whose deadline passed
/// most recently — it had the most slack to begin with (tie: lowest
/// priority). Units with any deadline-free member are never shed: nothing
/// bounds their laxity.
fn shed_victim(q: &VecDeque<AdmitUnit>, now: f64, policy: ShedPolicy) -> Option<usize> {
    let mut best: Option<(usize, u32, f64)> = None; // (index, min priority, max deadline)
    for (i, u) in q.iter().enumerate() {
        let expired = !u.members.is_empty()
            && u.members
                .iter()
                .all(|m| m.deadline.map(|d| m.arrival + d < now).unwrap_or(false));
        if !expired {
            continue;
        }
        let prio = u.members.iter().map(|m| m.priority).min().unwrap_or(0);
        let dl = u
            .members
            .iter()
            .filter_map(|m| m.deadline.map(|d| m.arrival + d))
            .fold(f64::NEG_INFINITY, f64::max);
        let better = match best {
            None => true,
            Some((_, bp, bd)) => match policy {
                ShedPolicy::LowestPriority => prio < bp || (prio == bp && dl > bd),
                ShedPolicy::LatestDeadline => dl > bd || (dl == bd && prio < bp),
            },
        };
        if better {
            best = Some((i, prio, dl));
        }
    }
    best.map(|(i, ..)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::sched::LeastLoaded;
    use crate::serve::arrival::poisson_arrivals;
    use crate::serve::request::Workload;
    use crate::serve::streaming::serve_stream;
    use std::cell::Cell;
    use std::io;
    use std::rc::Rc;

    fn stream(n: usize, rate: f64) -> Vec<ServeRequest> {
        poisson_arrivals(7, n, rate)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, t)| ServeRequest::new(i, t, Workload::Head { beta: 64 }))
            .collect()
    }

    /// Writer that fails with a typed io error after `ok_writes` successful
    /// write calls — a disk filling up mid-stream.
    struct FailingWriter {
        ok_writes: usize,
    }

    impl Write for FailingWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.ok_writes == 0 {
                return Err(io::Error::new(io::ErrorKind::Other, "disk full"));
            }
            self.ok_writes -= 1;
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn failing_sink_writer_surfaces_a_typed_io_error_mid_stream() {
        let platform = Platform::scaled(2, 1, 3, 1);
        let mut pol = LeastLoaded;
        let cfg = StreamingConfig::default();
        let mut sink = JsonlSink::new(FailingWriter { ok_writes: 3 });
        let e = serve_stream(
            stream(24, 2000.0),
            &platform,
            &PaperCost,
            &mut pol,
            &cfg,
            &mut sink,
        )
        .unwrap_err();
        assert!(matches!(e, Error::Io(_)), "{e}");
        assert!(e.to_string().contains("disk full"), "{e}");
    }

    #[derive(Clone, Default)]
    struct FlushProbe {
        flushed: Rc<Cell<bool>>,
    }

    impl Write for FlushProbe {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            self.flushed.set(true);
            Ok(())
        }
    }

    #[test]
    fn jsonl_sink_flushes_on_drop() {
        let probe = FlushProbe::default();
        let flushed = probe.flushed.clone();
        drop(JsonlSink::new(probe));
        assert!(flushed.get(), "JsonlSink dropped without flushing");
    }
}
