//! The always-on streaming serving path.
//!
//! [`serve_sim_cached`](super::serve_sim_cached) is a **closed-world** run:
//! it admits the whole request vector up front, merges every batch into one
//! monolithic application, and simulates it in one shot — memory grows with
//! the stream length, which caps how long a "server" it can model. This
//! module is the open-world counterpart: [`serve_stream`] walks an arrival
//! *iterator* (never materialized), admits batches into a long-lived
//! [`StreamSim`] while earlier requests are still executing, and emits each
//! per-request outcome through an [`OutcomeSink`] the moment it completes.
//! Completed requests are fully retired inside the simulator — slots,
//! dispatch records, and scheduler entries are reclaimed and reused — so
//! live state is bounded by the admission window, not the stream length.
//!
//! # Equivalence contract
//!
//! With an unbounded window (`window == 0`), `serve_stream` reproduces
//! `serve_sim_cached` **bit for bit** on the same arrival-ordered stream:
//! identical batch membership ([`StreamBatcher`] vs
//! [`batch_requests`](super::batch_requests)), identical admission decisions
//! (same laxity memo), and identical simulated event sequence
//! ([`StreamSim`]'s contract). Retirement changes memory, never outcomes.
//! A *finite* window adds backpressure — admission of a closed batch waits
//! until live requests fit under the window — which legitimately changes
//! schedules under overload; that is the knob doing its job, not a
//! divergence bug.
//!
//! # Memory profile
//!
//! Held for the whole run: per-request `(priority, latency)` scalars for
//! the final percentile cuts (16 bytes/request), the template cache, and
//! the simulator arena (bounded by the window). Held transiently: pending
//! request records between admission and batch close, and queued
//! [`AdmitUnit`]s under backpressure (the inherent arrival backlog of an
//! open-loop system in overload).

use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::sync::Arc;

use super::admission::{check_laxity_estimate, OpenBatch, StreamBatcher};
use super::cache::TemplateCache;
use super::engine::{outcome_fields, percentile_sorted, Pacing, RequestOutcome};
use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::{Error, Result};
use crate::graph::{Dag, Partition};
use crate::json::Json;
use crate::platform::{DeviceId, Platform};
use crate::sched::{app_solo_estimate, Policy};
use crate::sim::{AdmitUnit, FinishedRequest, MemberSpec, PumpStop, SimConfig, StreamSim, Template};

/// Streaming-server knobs. The subset of [`super::ServeConfig`] that is
/// meaningful for an always-on run, plus the admission window.
#[derive(Debug, Clone)]
pub struct StreamingConfig {
    /// Admission window: max requests live in the simulator at once
    /// (`0` = unbounded, the equivalence-test setting). A closed batch
    /// larger than the window is admitted whole once the server drains
    /// idle, so oversized batches stall but never wedge.
    pub window: usize,
    /// Batching window (seconds from a batch opener), as in
    /// [`super::ServeConfig::batch_window`].
    pub batch_window: f64,
    /// Max task components resident per device (multi-tenancy).
    pub tenancy: usize,
    /// Laxity-based admission control (see [`super::admission::admit_slo`]).
    pub laxity_admission: bool,
    /// Underlying simulator knobs. `max_events` is the per-pump runaway
    /// guard here, not a whole-run cap.
    pub sim: SimConfig,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig {
            window: 512,
            batch_window: 2e-3,
            tenancy: 4,
            laxity_admission: true,
            sim: SimConfig::default(),
        }
    }
}

/// Where per-request outcomes go, one call per completion, in completion
/// order. The streaming server never accumulates an outcome vector — this
/// sink is the only place results exist.
pub trait OutcomeSink {
    /// `devices` is the device each of the request's components ran on,
    /// in component order (last device for preempted components).
    fn emit(&mut self, outcome: &RequestOutcome, devices: &[DeviceId]) -> Result<()>;

    /// Flush any buffered output; called once at end of stream.
    fn flush(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Discards outcomes (throughput benches: accounting without I/O).
#[derive(Debug, Default)]
pub struct NullSink;

impl OutcomeSink for NullSink {
    fn emit(&mut self, _outcome: &RequestOutcome, _devices: &[DeviceId]) -> Result<()> {
        Ok(())
    }
}

/// Collects outcomes in memory — for tests comparing the streaming path
/// against the build-once pipeline (which defeats bounded memory; don't use
/// it on unbounded streams).
#[derive(Debug, Default)]
pub struct CollectSink {
    pub outcomes: Vec<RequestOutcome>,
}

impl OutcomeSink for CollectSink {
    fn emit(&mut self, outcome: &RequestOutcome, _devices: &[DeviceId]) -> Result<()> {
        self.outcomes.push(outcome.clone());
        Ok(())
    }
}

/// Streams outcomes as JSON Lines: one object per request with fixed keys
/// `id`, `arrival`, `release`, `finish`, `latency_s`, `deadline_met`
/// (bool or null), `priority`, `devices` (array of device ids). Wrap the
/// writer in a `BufWriter` for file targets — emit is called per request.
#[derive(Debug)]
pub struct JsonlSink<W: Write> {
    w: W,
}

impl<W: Write> JsonlSink<W> {
    pub fn new(w: W) -> Self {
        JsonlSink { w }
    }
}

impl<W: Write> OutcomeSink for JsonlSink<W> {
    fn emit(&mut self, o: &RequestOutcome, devices: &[DeviceId]) -> Result<()> {
        let met = match o.deadline_met {
            Some(true) => "true",
            Some(false) => "false",
            None => "null",
        };
        write!(
            self.w,
            "{{\"id\":{},\"arrival\":{},\"release\":{},\"finish\":{},\"latency_s\":{},\"deadline_met\":{},\"priority\":{},\"devices\":[",
            o.id, o.arrival, o.release, o.finish, o.latency, met, o.priority
        )?;
        for (i, d) in devices.iter().enumerate() {
            if i > 0 {
                write!(self.w, ",")?;
            }
            write!(self.w, "{d}")?;
        }
        writeln!(self.w, "]}}")?;
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Aggregate statistics of one streaming run — the scalars a long-lived
/// server can afford to keep (no per-request vectors beyond the
/// percentile-cut pairs).
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub policy: String,
    /// Requests that completed (every admitted request completes — the
    /// stream is drained before returning).
    pub served: usize,
    /// Total admission rejections over the stream.
    pub rejected: usize,
    /// First few `(request id, admission error)` rejections, capped — the
    /// full list would grow with the stream.
    pub rejected_sample: Vec<(usize, String)>,
    /// ... of the rejections, how many were laxity-based.
    pub laxity_rejections: usize,
    /// Last completion instant (virtual seconds from the epoch).
    pub makespan: f64,
    pub throughput_rps: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub deadline_total: usize,
    pub deadline_misses: usize,
    pub deadline_miss_rate: f64,
    /// p99 latency per distinct priority, ascending priority.
    pub per_priority_p99: Vec<(u32, f64)>,
    pub preemptions: usize,
    /// Compute busy fraction per device over the makespan.
    pub device_util: Vec<f64>,
    /// The admission window the run used (0 = unbounded).
    pub window: usize,
    /// High-water mark of requests live in the simulator at once — the
    /// bounded-memory witness (≤ window when the window binds).
    pub peak_live_requests: usize,
    /// High-water mark of live components (slots) — what the soak bench
    /// gates in CI.
    pub peak_live_components: usize,
    /// Simulated events processed.
    pub events: u64,
    /// Merged-template cache hits/misses over this run.
    pub template_cache_hits: usize,
    pub template_cache_misses: usize,
}

impl StreamReport {
    /// The BENCH_serve_soak.json building block.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str("streaming")),
            ("policy", Json::str(self.policy.clone())),
            ("requests", Json::num(self.served as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("laxity_rejections", Json::num(self.laxity_rejections as f64)),
            ("makespan_s", Json::num(self.makespan)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("p50_latency_s", Json::num(self.p50_latency)),
            ("p99_latency_s", Json::num(self.p99_latency)),
            ("deadline_total", Json::num(self.deadline_total as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("deadline_miss_rate", Json::num(self.deadline_miss_rate)),
            (
                "per_priority_p99_s",
                Json::Arr(
                    self.per_priority_p99
                        .iter()
                        .map(|&(p, l)| {
                            Json::obj(vec![
                                ("priority", Json::num(p as f64)),
                                ("p99_latency_s", Json::num(l)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("preemptions", Json::num(self.preemptions as f64)),
            (
                "device_util",
                Json::Arr(self.device_util.iter().map(|&u| Json::num(u)).collect()),
            ),
            ("window", Json::num(self.window as f64)),
            (
                "peak_live_requests",
                Json::num(self.peak_live_requests as f64),
            ),
            (
                "peak_live_components",
                Json::num(self.peak_live_components as f64),
            ),
            ("events", Json::num(self.events as f64)),
            (
                "template_cache_hits",
                Json::num(self.template_cache_hits as f64),
            ),
            (
                "template_cache_misses",
                Json::num(self.template_cache_misses as f64),
            ),
        ])
    }
}

/// A request admitted but not yet batch-closed: the scalars the streaming
/// server keeps between admission and batch close (the `ServeRequest`
/// itself — workload payload included — is dropped at admission).
struct PendingReq {
    arrival: f64,
    deadline: Option<f64>,
    priority: u32,
    cacheable: bool,
    app: Arc<(Dag, Partition)>,
}

const REJECT_SAMPLE_CAP: usize = 32;

/// [`serve_stream_cached`] with a fresh per-run [`TemplateCache`].
pub fn serve_stream<I>(
    requests: I,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &StreamingConfig,
    sink: &mut dyn OutcomeSink,
) -> Result<StreamReport>
where
    I: IntoIterator<Item = ServeRequest>,
{
    let mut cache = TemplateCache::new();
    serve_stream_cached(requests, platform, cost, policy, cfg, &mut cache, sink)
}

/// Serve an arrival-ordered request stream through the long-lived
/// [`StreamSim`], with a caller-held [`TemplateCache`].
///
/// The loop interleaves four activities until the stream and the simulator
/// are both drained:
///
/// 1. **admit** queued closed batches while live requests fit the window;
/// 2. **pump** virtual time to the next admission boundary — the earliest
///    of the first open batch's opener and the next arrival instant (so
///    simulated time never overtakes a batch that is still coalescing);
/// 3. **drain** completed requests into the sink, retiring their state;
/// 4. **ingest** one arrival: admission checks (template cache + laxity
///    gate, both memoized per signature exactly as
///    [`admit_all`](super::engine) does), then offer it to the
///    [`StreamBatcher`]; batches it closes become [`AdmitUnit`]s.
///
/// Arrivals must be non-decreasing (an arrival stream, not a request bag);
/// an out-of-order arrival is a typed [`Error::Admission`] that aborts the
/// run — incremental batching is ill-defined on it.
#[allow(clippy::too_many_arguments)]
pub fn serve_stream_cached<I>(
    requests: I,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &StreamingConfig,
    cache: &mut TemplateCache,
    sink: &mut dyn OutcomeSink,
) -> Result<StreamReport>
where
    I: IntoIterator<Item = ServeRequest>,
{
    let policy_name = policy.name().to_string();
    let (hits0, misses0) = cache.stats();
    let empty_dag = Dag::default();
    let empty_part = Partition {
        components: Vec::new(),
        assignment: Vec::new(),
    };
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = cfg.tenancy.max(1);
    let mut sim = StreamSim::new(&empty_dag, &empty_part, platform, cost, policy, &sim_cfg)?;

    let mut it = requests.into_iter();
    let mut next_arr = it.next();
    let mut last_arrival = f64::NEG_INFINITY;
    let mut batcher = StreamBatcher::new(cfg.batch_window);
    let mut closed: Vec<OpenBatch> = Vec::new();
    let mut admit_q: VecDeque<AdmitUnit> = VecDeque::new();
    let mut pending: HashMap<usize, PendingReq> = HashMap::new();
    let mut solo_memo: HashMap<String, f64> = HashMap::new();
    let mut finished: Vec<FinishedRequest> = Vec::new();

    let mut served = 0usize;
    let mut rejected = 0usize;
    let mut rejected_sample: Vec<(usize, String)> = Vec::new();
    let mut laxity_rejections = 0usize;
    let mut deadline_total = 0usize;
    let mut deadline_misses = 0usize;
    // (priority, latency) per served request — the only per-request state
    // kept to the end, for the percentile cuts.
    let mut pairs: Vec<(u32, f64)> = Vec::new();

    let mut reject = |id: usize, e: Error, rejected: &mut usize| {
        *rejected += 1;
        if rejected_sample.len() < REJECT_SAMPLE_CAP {
            rejected_sample.push((id, e.to_string()));
        }
    };

    loop {
        // (1) Admit queued units while the window admits them. An idle
        // server takes any unit (oversized batches must not wedge).
        let mut admitted_any = false;
        while let Some(u) = admit_q.front() {
            let live = sim.live_members();
            if cfg.window == 0 || live == 0 || live + u.members.len() <= cfg.window {
                let u = admit_q.pop_front().expect("front() was Some");
                sim.admit(u)?;
                admitted_any = true;
            } else {
                break;
            }
        }

        // (2) Advance virtual time to the next admission boundary. While a
        // batch is open its *opener* is the bound: the batch may close with
        // a release at or after the opener, and admission must happen
        // before simulated time reaches it (the monolithic run has had the
        // release event queued since t = 0).
        let h_arr = next_arr
            .as_ref()
            .map(|r: &ServeRequest| r.arrival)
            .unwrap_or(f64::INFINITY);
        let stop = sim.pump(batcher.horizon().min(h_arr))?;

        // (3) Retire completions into the sink.
        sim.drain_finished_into(&mut finished);
        let emitted = finished.len();
        for f in finished.drain(..) {
            let o = outcome_fields(
                f.id, f.arrival, f.deadline, f.priority, f.release, f.finish, Pacing::Open,
            );
            if let Some(met) = o.deadline_met {
                deadline_total += 1;
                if !met {
                    deadline_misses += 1;
                }
            }
            pairs.push((o.priority, o.latency));
            served += 1;
            sink.emit(&o, &f.devices)?;
        }
        if admitted_any || emitted > 0 {
            // Progress was made — capacity may have freed or new units may
            // now fit; go admit/pump again before touching the arrival
            // stream.
            continue;
        }

        // (4) Ingest exactly one arrival, mirroring admit_all's per-request
        // admission pipeline.
        if let Some(req) = next_arr.take() {
            next_arr = it.next();
            match cache.admit_app(&req) {
                Ok(app) => {
                    if req.arrival < last_arrival {
                        return Err(Error::Admission(format!(
                            "streaming arrivals must be non-decreasing: request {} \
                             arrived at {} after {}",
                            req.id, req.arrival, last_arrival
                        )));
                    }
                    last_arrival = req.arrival;
                    if pending.contains_key(&req.id) {
                        reject(
                            req.id,
                            Error::Admission(format!(
                                "request {}: duplicate id in flight",
                                req.id
                            )),
                            &mut rejected,
                        );
                        continue;
                    }
                    if cfg.laxity_admission && req.deadline.is_some() {
                        let estimate = if req.workload.cacheable() {
                            *solo_memo
                                .entry(req.workload.signature())
                                .or_insert_with(|| {
                                    app_solo_estimate(&app.0, &app.1, platform, cost)
                                })
                        } else {
                            app_solo_estimate(&app.0, &app.1, platform, cost)
                        };
                        if let Err(e) = check_laxity_estimate(&req, estimate) {
                            laxity_rejections += 1;
                            reject(req.id, e, &mut rejected);
                            continue;
                        }
                    }
                    let sig = req.workload.signature();
                    batcher.offer(req.id, &sig, req.arrival, &mut closed);
                    pending.insert(
                        req.id,
                        PendingReq {
                            arrival: req.arrival,
                            deadline: req.deadline,
                            priority: req.priority,
                            cacheable: req.workload.cacheable(),
                            app,
                        },
                    );
                    units_from_closed(&mut closed, &mut pending, cache, &mut admit_q)?;
                }
                Err(e) => reject(req.id, e, &mut rejected),
            }
            continue;
        }

        // (5) End of stream: close the still-open batches, once.
        if batcher.open_len() > 0 {
            batcher.flush(&mut closed);
            units_from_closed(&mut closed, &mut pending, cache, &mut admit_q)?;
            continue;
        }

        // (6) Drained?
        if admit_q.is_empty() && sim.live_members() == 0 {
            break;
        }

        // (7) Work remains but nothing was admitted, nothing completed, and
        // the stream is exhausted. An idle simulator here is a wedge.
        if stop == PumpStop::Idle {
            return Err(Error::Sched(format!(
                "streaming stall: {} queued unit(s), {} live request(s), \
                 simulator idle",
                admit_q.len(),
                sim.live_members()
            )));
        }
    }
    sink.flush()?;

    debug_assert!(pending.is_empty(), "requests left pending at end of stream");

    // Final accounting: one latency sort for p50/p99, one (priority,
    // latency) sort for the per-priority tails (the deadline_stats shape,
    // over scalars instead of outcomes).
    let makespan = sim.makespan();
    let mut latencies: Vec<f64> = pairs.iter().map(|&(_, l)| l).collect();
    latencies.sort_by(|a, b| a.total_cmp(b));
    pairs.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.total_cmp(&b.1)));
    let mut per_priority_p99 = Vec::new();
    let mut start = 0usize;
    while start < pairs.len() {
        let p = pairs[start].0;
        let end = start + pairs[start..].partition_point(|&(q, _)| q == p);
        let group = &pairs[start..end];
        let idx = ((group.len() as f64 - 1.0) * 0.99).round() as usize;
        per_priority_p99.push((p, group[idx].1));
        start = end;
    }
    let device_util = sim
        .device_busy()
        .iter()
        .map(|&busy| if makespan > 0.0 { busy / makespan } else { 0.0 })
        .collect();
    let (hits1, misses1) = cache.stats();
    Ok(StreamReport {
        policy: policy_name,
        served,
        rejected,
        rejected_sample,
        laxity_rejections,
        makespan,
        throughput_rps: if makespan > 0.0 {
            served as f64 / makespan
        } else {
            0.0
        },
        p50_latency: percentile_sorted(&latencies, 0.50),
        p99_latency: percentile_sorted(&latencies, 0.99),
        deadline_total,
        deadline_misses,
        deadline_miss_rate: if deadline_total > 0 {
            deadline_misses as f64 / deadline_total as f64
        } else {
            0.0
        },
        per_priority_p99,
        preemptions: sim.preemptions(),
        device_util,
        window: cfg.window,
        peak_live_requests: sim.peak_live_members(),
        peak_live_components: sim.peak_live_components(),
        events: sim.events(),
        template_cache_hits: hits1 - hits0,
        template_cache_misses: misses1 - misses0,
    })
}

/// Turn closed batches into admission units, in close order. A fully
/// cacheable batch becomes **one** merged-block unit (all sizes go through
/// the template cache, size-1 included — counter parity with
/// [`serve_sim_cached`](super::serve_sim_cached)); a batch with any
/// uncacheable member becomes one single-app unit **per member**, in member
/// order — exactly the component layout the monolithic assembly would
/// append.
fn units_from_closed(
    closed: &mut Vec<OpenBatch>,
    pending: &mut HashMap<usize, PendingReq>,
    cache: &mut TemplateCache,
    out: &mut VecDeque<AdmitUnit>,
) -> Result<()> {
    for b in closed.drain(..) {
        let missing = || Error::Admission("internal: batch member not pending".into());
        let cacheable = b
            .members
            .iter()
            .all(|id| pending.get(id).map(|p| p.cacheable).unwrap_or(false));
        if cacheable {
            let first = pending.get(&b.members[0]).ok_or_else(missing)?;
            let block = cache.merged_block(&b.signature, b.members.len(), &first.app)?;
            let mut members = Vec::with_capacity(b.members.len());
            for (i, &id) in b.members.iter().enumerate() {
                let p = pending.remove(&id).ok_or_else(missing)?;
                members.push(MemberSpec {
                    id,
                    arrival: p.arrival,
                    deadline: p.deadline,
                    priority: p.priority,
                    comps: block.component_ranges[i].clone(),
                });
            }
            out.push_back(AdmitUnit {
                tmpl: Template::Merged(block),
                release: b.release,
                members,
            });
        } else {
            for &id in &b.members {
                let p = pending.remove(&id).ok_or_else(missing)?;
                let ncomp = p.app.1.components.len();
                out.push_back(AdmitUnit {
                    tmpl: Template::Single(p.app),
                    release: b.release,
                    members: vec![MemberSpec {
                        id,
                        arrival: p.arrival,
                        deadline: p.deadline,
                        priority: p.priority,
                        comps: 0..ncomp,
                    }],
                });
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::sched::LeastLoaded;
    use crate::serve::arrival::poisson_arrivals;
    use crate::serve::engine::{serve_sim_cached, ServeConfig};
    use crate::serve::request::Workload;

    fn stream(n: usize, rate: f64) -> Vec<ServeRequest> {
        let arrivals = poisson_arrivals(7, n, rate).unwrap();
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let beta = if i % 4 == 3 { 128 } else { 64 };
                let mut r = ServeRequest::new(i, t, Workload::Head { beta });
                if i % 5 == 0 {
                    r.deadline = Some(2.0);
                    r.priority = 1;
                }
                r
            })
            .collect()
    }

    #[test]
    fn streaming_matches_batch_pipeline_bit_for_bit() {
        let platform = Platform::scaled(2, 1, 3, 1);
        let cost = PaperCost;
        let reqs = stream(96, 1500.0);

        let mut pol = LeastLoaded;
        let mono_cfg = ServeConfig::default();
        let mut mono_cache = TemplateCache::new();
        let mono = serve_sim_cached(
            &reqs, &platform, &cost, &mut pol, &mono_cfg, &mut mono_cache,
        )
        .unwrap();

        let mut pol2 = LeastLoaded;
        let cfg = StreamingConfig {
            window: 0,
            ..StreamingConfig::default()
        };
        let mut sink = CollectSink::default();
        let report = serve_stream(
            reqs.clone(),
            &platform,
            &cost,
            &mut pol2,
            &cfg,
            &mut sink,
        )
        .unwrap();

        assert_eq!(report.served, mono.outcomes.len());
        let mut mono_by_id: HashMap<usize, &RequestOutcome> =
            mono.outcomes.iter().map(|o| (o.id, o)).collect();
        for o in &sink.outcomes {
            let m = mono_by_id.remove(&o.id).expect("request served twice");
            assert_eq!(o.release.to_bits(), m.release.to_bits(), "id {}", o.id);
            assert_eq!(o.finish.to_bits(), m.finish.to_bits(), "id {}", o.id);
            assert_eq!(o.latency.to_bits(), m.latency.to_bits(), "id {}", o.id);
            assert_eq!(o.deadline_met, m.deadline_met, "id {}", o.id);
        }
        assert!(mono_by_id.is_empty());
        assert_eq!(report.makespan.to_bits(), mono.makespan.to_bits());
        assert_eq!(report.preemptions, mono.preemptions);
        for (a, b) in report.device_util.iter().zip(&mono.device_util) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(
            (report.template_cache_hits, report.template_cache_misses),
            (mono.template_cache_hits, mono.template_cache_misses)
        );
    }

    #[test]
    fn finite_window_bounds_live_requests() {
        let platform = Platform::scaled(2, 1, 3, 1);
        let cost = PaperCost;
        let reqs = stream(64, 4000.0);
        let mut pol = LeastLoaded;
        // batch_window 0 keeps every unit a singleton, so the window bound
        // is airtight (a merged batch larger than the window is otherwise
        // admitted whole when the server idles — by design).
        let cfg = StreamingConfig {
            window: 4,
            batch_window: 0.0,
            ..StreamingConfig::default()
        };
        let mut sink = NullSink;
        let report =
            serve_stream(reqs, &platform, &cost, &mut pol, &cfg, &mut sink).unwrap();
        assert_eq!(report.served + report.rejected, 64);
        assert!(
            report.peak_live_requests <= 4,
            "window 4 exceeded: peak {}",
            report.peak_live_requests
        );
    }

    #[test]
    fn jsonl_sink_emits_one_valid_object_per_request() {
        let platform = Platform::scaled(2, 1, 3, 1);
        let cost = PaperCost;
        let reqs = stream(12, 1500.0);
        let mut pol = LeastLoaded;
        let cfg = StreamingConfig::default();
        let mut buf = Vec::new();
        let served = {
            let mut sink = JsonlSink::new(&mut buf);
            let report =
                serve_stream(reqs, &platform, &cost, &mut pol, &cfg, &mut sink).unwrap();
            assert_eq!(report.served + report.rejected, 12);
            report.served
        };
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), served);
        assert!(served > 0);
        for line in lines {
            let v = Json::parse(line).unwrap();
            let obj = v.as_obj().unwrap();
            for key in [
                "id",
                "arrival",
                "release",
                "finish",
                "latency_s",
                "deadline_met",
                "priority",
                "devices",
            ] {
                assert!(obj.contains_key(key), "missing key {key} in {line}");
            }
        }
    }

    #[test]
    fn out_of_order_arrivals_abort_the_stream() {
        let platform = Platform::scaled(2, 1, 3, 1);
        let cost = PaperCost;
        let reqs = vec![
            ServeRequest::new(0, 0.010, Workload::Head { beta: 64 }),
            ServeRequest::new(1, 0.002, Workload::Head { beta: 64 }),
        ];
        let mut pol = LeastLoaded;
        let cfg = StreamingConfig::default();
        let mut sink = NullSink;
        let e = serve_stream(reqs, &platform, &cost, &mut pol, &cfg, &mut sink).unwrap_err();
        assert!(matches!(e, Error::Admission(_)), "{e}");
    }
}
