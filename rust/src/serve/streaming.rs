//! The simulated always-on serving path: [`SimBackend`] plugs the
//! long-lived [`StreamSim`] into the unified serve core.
//!
//! [`serve_sim_cached`](super::serve_sim_cached) is a **closed-world** run:
//! the whole request vector is known up front. This module is the
//! open-world counterpart: [`serve_stream`] walks an arrival *iterator*
//! (never materialized) through [`serve_core`] — incremental batching,
//! windowed backpressure, per-completion [`OutcomeSink`] emission — with
//! the simulator as the execution backend. Completed requests are fully
//! retired inside the simulator — slots, dispatch records, and scheduler
//! entries are reclaimed and reused — so live state is bounded by the
//! admission window, not the stream length.
//!
//! # Equivalence contract
//!
//! With an unbounded window (`window == 0`), `serve_stream` reproduces
//! `serve_sim_cached` **bit for bit** on the same arrival-ordered stream
//! (which is itself a `window: 0` wrapper over the same core — the frozen
//! pre-refactor monolith lives in `serve::reference` and gates both):
//! identical batch membership, identical admission decisions, identical
//! simulated event sequence. Retirement changes memory, never outcomes.
//! A *finite* window adds backpressure — admission of a closed batch waits
//! until live requests fit under the window — which legitimately changes
//! schedules under overload; that is the knob doing its job, not a
//! divergence bug.

use super::cache::TemplateCache;
use super::core::{
    serve_core, BackendStats, OutcomeSink, ServeBackend, StreamReport, StreamingConfig,
    REJECT_SAMPLE_CAP,
};
use super::engine::Pacing;
use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::Result;
use crate::graph::{Dag, Partition};
use crate::platform::Platform;
use crate::sched::Policy;
use crate::sim::{AdmitUnit, FinishedRequest, PumpStop, StreamSim};

/// [`ServeBackend`] over the long-lived event-driven simulator: units admit
/// into [`StreamSim`], virtual time advances on [`pump`](ServeBackend::pump),
/// completions retire through the simulator's own drain. Virtual time is
/// inherently open-loop, so the backend reports [`Pacing::Open`] and the
/// final report keeps the `"virtual"` pacing label.
pub struct SimBackend<'a> {
    sim: StreamSim<'a>,
}

impl<'a> SimBackend<'a> {
    pub fn new(sim: StreamSim<'a>) -> Self {
        SimBackend { sim }
    }
}

impl ServeBackend for SimBackend<'_> {
    fn admit(&mut self, unit: AdmitUnit) -> Result<()> {
        self.sim.admit(unit)
    }

    fn pump(&mut self, horizon: f64) -> Result<PumpStop> {
        self.sim.pump(horizon)
    }

    fn drain_finished_into(&mut self, out: &mut Vec<FinishedRequest>) {
        self.sim.drain_finished_into(out);
    }

    fn live_requests(&self) -> usize {
        self.sim.live_members()
    }

    fn now(&self) -> f64 {
        self.sim.now()
    }

    fn pacing(&self) -> Pacing {
        Pacing::Open
    }

    fn stats(&self) -> BackendStats {
        BackendStats {
            makespan: self.sim.makespan(),
            preemptions: self.sim.preemptions(),
            device_busy: self.sim.device_busy().to_vec(),
            events: self.sim.events(),
            peak_live_requests: self.sim.peak_live_members(),
            peak_live_components: self.sim.peak_live_components(),
        }
    }
}

/// Run the serve core over a fresh [`SimBackend`] — the shared body of
/// [`serve_stream_cached`] and the batch-mode
/// [`serve_sim_cached`](super::serve_sim_cached) wrapper (which passes
/// `window: 0` and an uncapped rejection sample).
pub(crate) fn run_sim_core<I>(
    requests: I,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &StreamingConfig,
    cache: &mut TemplateCache,
    sink: &mut dyn OutcomeSink,
    reject_sample_cap: usize,
) -> Result<StreamReport>
where
    I: IntoIterator<Item = ServeRequest>,
{
    let policy_name = policy.name().to_string();
    let empty_dag = Dag::default();
    let empty_part = Partition {
        components: Vec::new(),
        assignment: Vec::new(),
    };
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = cfg.tenancy.max(1);
    let mut sim = StreamSim::new(&empty_dag, &empty_part, platform, cost, policy, &sim_cfg)?;
    if let Some(plan) = &cfg.faults {
        sim.install_faults(plan)?;
    }
    let mut backend = SimBackend::new(sim);
    serve_core(
        requests,
        platform,
        cost,
        &mut backend,
        cfg,
        cache,
        sink,
        &policy_name,
        reject_sample_cap,
    )
}

/// [`serve_stream_cached`] with a fresh per-run [`TemplateCache`].
pub fn serve_stream<I>(
    requests: I,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &StreamingConfig,
    sink: &mut dyn OutcomeSink,
) -> Result<StreamReport>
where
    I: IntoIterator<Item = ServeRequest>,
{
    let mut cache = TemplateCache::new();
    serve_stream_cached(requests, platform, cost, policy, cfg, &mut cache, sink)
}

/// Serve an arrival-ordered request stream through the long-lived
/// [`StreamSim`], with a caller-held [`TemplateCache`] — [`serve_core`]
/// over a [`SimBackend`]; see the core for the loop's contract.
#[allow(clippy::too_many_arguments)]
pub fn serve_stream_cached<I>(
    requests: I,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &StreamingConfig,
    cache: &mut TemplateCache,
    sink: &mut dyn OutcomeSink,
) -> Result<StreamReport>
where
    I: IntoIterator<Item = ServeRequest>,
{
    run_sim_core(
        requests,
        platform,
        cost,
        policy,
        cfg,
        cache,
        sink,
        REJECT_SAMPLE_CAP,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::error::Error;
    use crate::json::Json;
    use crate::sched::LeastLoaded;
    use crate::serve::arrival::poisson_arrivals;
    use crate::serve::core::{CollectSink, JsonlSink, NullSink};
    use crate::serve::engine::{serve_sim_cached, RequestOutcome, ServeConfig};
    use crate::serve::request::Workload;
    use std::collections::HashMap;

    fn stream(n: usize, rate: f64) -> Vec<ServeRequest> {
        let arrivals = poisson_arrivals(7, n, rate).unwrap();
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let beta = if i % 4 == 3 { 128 } else { 64 };
                let mut r = ServeRequest::new(i, t, Workload::Head { beta });
                if i % 5 == 0 {
                    r.deadline = Some(2.0);
                    r.priority = 1;
                }
                r
            })
            .collect()
    }

    #[test]
    fn streaming_matches_batch_pipeline_bit_for_bit() {
        let platform = Platform::scaled(2, 1, 3, 1);
        let cost = PaperCost;
        let reqs = stream(96, 1500.0);

        let mut pol = LeastLoaded;
        let mono_cfg = ServeConfig::default();
        let mut mono_cache = TemplateCache::new();
        let mono = serve_sim_cached(
            &reqs, &platform, &cost, &mut pol, &mono_cfg, &mut mono_cache,
        )
        .unwrap();

        let mut pol2 = LeastLoaded;
        let cfg = StreamingConfig {
            window: 0,
            ..StreamingConfig::default()
        };
        let mut sink = CollectSink::default();
        let report = serve_stream(
            reqs.clone(),
            &platform,
            &cost,
            &mut pol2,
            &cfg,
            &mut sink,
        )
        .unwrap();

        assert_eq!(report.served, mono.outcomes.len());
        let mut mono_by_id: HashMap<usize, &RequestOutcome> =
            mono.outcomes.iter().map(|o| (o.id, o)).collect();
        for o in &sink.outcomes {
            let m = mono_by_id.remove(&o.id).expect("request served twice");
            assert_eq!(o.release.to_bits(), m.release.to_bits(), "id {}", o.id);
            assert_eq!(o.finish.to_bits(), m.finish.to_bits(), "id {}", o.id);
            assert_eq!(o.latency.to_bits(), m.latency.to_bits(), "id {}", o.id);
            assert_eq!(o.deadline_met, m.deadline_met, "id {}", o.id);
        }
        assert!(mono_by_id.is_empty());
        assert_eq!(report.makespan.to_bits(), mono.makespan.to_bits());
        assert_eq!(report.preemptions, mono.preemptions);
        for (a, b) in report.device_util.iter().zip(&mono.device_util) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(
            (report.template_cache_hits, report.template_cache_misses),
            (mono.template_cache_hits, mono.template_cache_misses)
        );
    }

    #[test]
    fn finite_window_bounds_live_requests() {
        let platform = Platform::scaled(2, 1, 3, 1);
        let cost = PaperCost;
        let reqs = stream(64, 4000.0);
        let mut pol = LeastLoaded;
        // batch_window 0 keeps every unit a singleton, so the window bound
        // is airtight (a merged batch larger than the window is otherwise
        // admitted whole when the server idles — by design).
        let cfg = StreamingConfig {
            window: 4,
            batch_window: 0.0,
            ..StreamingConfig::default()
        };
        let mut sink = NullSink;
        let report =
            serve_stream(reqs, &platform, &cost, &mut pol, &cfg, &mut sink).unwrap();
        assert_eq!(report.served + report.rejected, 64);
        assert!(
            report.peak_live_requests <= 4,
            "window 4 exceeded: peak {}",
            report.peak_live_requests
        );
    }

    #[test]
    fn jsonl_sink_emits_one_valid_object_per_request() {
        let platform = Platform::scaled(2, 1, 3, 1);
        let cost = PaperCost;
        let reqs = stream(12, 1500.0);
        let mut pol = LeastLoaded;
        let cfg = StreamingConfig::default();
        let mut buf = Vec::new();
        let served = {
            let mut sink = JsonlSink::new(&mut buf);
            let report =
                serve_stream(reqs, &platform, &cost, &mut pol, &cfg, &mut sink).unwrap();
            assert_eq!(report.served + report.rejected, 12);
            report.served
        };
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), served);
        assert!(served > 0);
        for line in lines {
            let v = Json::parse(line).unwrap();
            let obj = v.as_obj().unwrap();
            for key in [
                "id",
                "arrival",
                "release",
                "finish",
                "latency_s",
                "deadline_met",
                "priority",
                "devices",
            ] {
                assert!(obj.contains_key(key), "missing key {key} in {line}");
            }
        }
    }

    #[test]
    fn out_of_order_arrivals_abort_the_stream() {
        let platform = Platform::scaled(2, 1, 3, 1);
        let cost = PaperCost;
        let reqs = vec![
            ServeRequest::new(0, 0.010, Workload::Head { beta: 64 }),
            ServeRequest::new(1, 0.002, Workload::Head { beta: 64 }),
        ];
        let mut pol = LeastLoaded;
        let cfg = StreamingConfig::default();
        let mut sink = NullSink;
        let e = serve_stream(reqs, &platform, &cost, &mut pol, &cfg, &mut sink).unwrap_err();
        assert!(matches!(e, Error::Admission(_)), "{e}");
    }
}
