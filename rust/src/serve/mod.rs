//! The multi-DAG serving layer.
//!
//! The paper schedules *one* application DAG at a time; a production system
//! serves a **stream** of DAG requests that must share the platform. This
//! subsystem turns the single-shot machinery into a runtime:
//!
//! * [`request`] — a [`ServeRequest`] (arrival, deadline, priority) wrapping
//!   a [`Workload`] (generator-based or a parsed spec);
//! * [`arrival`] — deterministic seeded Poisson and trace-file arrival
//!   processes;
//! * [`admission`] — request validation with typed [`crate::Error::Admission`]
//!   rejections, plus the batching front-end that coalesces compatible
//!   requests arriving within a window;
//! * [`merge`] — fuses many application DAG/partition pairs into one
//!   multi-tenant application with component↔request maps
//!   ([`MergedAssembly`] appends validated apps or whole pre-merged blocks
//!   incrementally);
//! * [`cache`] — the merged-template cache ([`TemplateCache`]): app
//!   templates per workload signature and pre-merged batch blocks per
//!   (signature, batch size), the sim-side analog of the real path's PJRT
//!   executable cache, with hit/miss counters surfaced in
//!   [`ServeReport::template_cache_hits`];
//! * [`engine`] — the simulated serving path ([`serve_sim`]) over
//!   [`crate::sim::simulate_served`] and the sequential-replay baseline
//!   ([`serve_sequential`]), with per-request makespan/latency accounting;
//! * [`streaming`] — the always-on serving path ([`serve_stream`]): a
//!   long-lived [`crate::sim::StreamSim`] admits batches while earlier
//!   requests execute, retires completed requests (bounded memory), and
//!   emits each outcome incrementally through an [`OutcomeSink`] (JSONL or
//!   custom) instead of accumulating report vectors;
//! * [`real`] — the real path over [`crate::exec::execute_dag_served`]'s
//!   thread-per-queue machinery (PJRT kernels), with open- or closed-loop
//!   arrival pacing ([`Pacing`]), per-component deadline metadata threaded
//!   into the executor's scheduler state, and a warm executable cache whose
//!   hit/miss counts and cold-vs-warm batch latency the report carries.
//!
//! Multi-tenancy itself lives one layer down: `SimConfig::max_tenants` /
//! `execute_dag_multi`'s `tenancy` let several components — from different
//! requests — reside on one device, and the shared
//! [`crate::sched::SchedState`] exposes the resulting cross-DAG device load
//! to every [`crate::sched::Policy`].
//!
//! Serving is **deadline-aware**: each request's deadline (made absolute)
//! and priority are threaded through the merge into per-component
//! [`crate::sim::CompMeta`], so policies like [`crate::sched::Edf`] order
//! the frontier by urgency and may preempt less urgent resident tenants
//! ([`crate::sched::Policy::preempt`]). Reports carry deadline-miss rate,
//! per-priority p99, and the preemption count. Admission is **SLO-aware**:
//! requests whose laxity is already negative at arrival (deadline budget
//! below the optimistic solo estimate) are rejected up front
//! ([`admission::admit_slo`]) and counted in
//! [`ServeReport::laxity_rejections`].

pub mod admission;
pub mod arrival;
pub mod cache;
pub mod engine;
pub mod merge;
pub mod real;
pub mod request;
pub mod streaming;

pub use admission::{admit, admit_slo, batch_requests, check_laxity, Batch, OpenBatch, StreamBatcher};
pub use arrival::{parse_rate, poisson_arrivals, trace_arrivals, PoissonStream};
pub use cache::TemplateCache;
pub use engine::{
    percentile_sorted, request_outcome, serve_sequential, serve_sim, serve_sim_cached, Pacing,
    RequestOutcome, ServeConfig, ServeReport,
};
pub use merge::{merge_apps, merge_apps_refs, MergedApp, MergedAssembly};
pub use real::serve_real;
pub use request::{ServeRequest, Workload};
pub use streaming::{
    serve_stream, serve_stream_cached, CollectSink, JsonlSink, NullSink, OutcomeSink,
    StreamReport, StreamingConfig,
};
