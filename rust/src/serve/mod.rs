//! The multi-DAG serving layer.
//!
//! The paper schedules *one* application DAG at a time; a production system
//! serves a **stream** of DAG requests that must share the platform. This
//! subsystem turns the single-shot machinery into a runtime:
//!
//! * [`request`] — a [`ServeRequest`] (arrival, deadline, priority) wrapping
//!   a [`Workload`] (generator-based or a parsed spec);
//! * [`arrival`] — deterministic seeded Poisson and trace-file arrival
//!   processes;
//! * [`admission`] — request validation with typed [`crate::Error::Admission`]
//!   rejections, the memoized laxity gate, and the batching front-end
//!   (batch-mode [`batch_requests`] and the incremental [`StreamBatcher`]);
//! * [`merge`] — fuses many application DAG/partition pairs into one
//!   multi-tenant application with component↔request maps
//!   ([`MergedAssembly`] appends validated apps or whole pre-merged blocks
//!   incrementally);
//! * [`cache`] — the merged-template cache ([`TemplateCache`]): app
//!   templates per workload signature and pre-merged batch blocks per
//!   (signature, batch size), the sim-side analog of the real path's PJRT
//!   executable cache, with hit/miss counters surfaced in
//!   [`ServeReport::template_cache_hits`];
//! * [`core`] — **the unified serve core** ([`serve_core`]): the one
//!   admission/backpressure loop every serving mode runs through —
//!   arrival-iterator ingestion, incremental batching, windowed
//!   backpressure, [`OutcomeSink`] emission, histogram-based percentile
//!   accounting — parameterized by a [`ServeBackend`] (execution only);
//! * [`histogram`] — fixed-bin log-scale latency histogram
//!   ([`LatencyHistogram`]): streaming p50/p99 within 1% relative error in
//!   O(1) memory per priority class;
//! * [`engine`] — batch-mode entry points ([`serve_sim`],
//!   [`serve_sim_cached`] — a `window: 0` wrapper over the core), the
//!   shared report/outcome vocabulary, and the sequential-replay baseline
//!   ([`serve_sequential`]);
//! * [`streaming`] — the sim execution backend ([`SimBackend`] over a
//!   long-lived [`crate::sim::StreamSim`]) and the always-on sim entry
//!   points ([`serve_stream`], [`serve_stream_cached`]);
//! * [`real`] — the real execution backend ([`RealBackend`] over
//!   [`crate::exec::execute_dag_served`]'s thread-per-queue machinery and
//!   PJRT kernels) with open/closed arrival pacing ([`Pacing`]), and the
//!   real entry points: batch [`serve_real`] and always-on
//!   [`serve_real_stream`];
//! * [`router`] — the signature-affinity request router ([`Router`]):
//!   deterministic signature→shard hashing with power-of-two-choices spill
//!   above a queue-depth threshold, global duplicate-id rejection, and the
//!   SLO-driven [`Router::rebalance`] hook;
//! * [`shard`] — sharded multi-replica serving
//!   ([`serve_sharded_stream`], [`serve_sharded_real_stream`]): N
//!   concurrent serve loops on disjoint sub-platforms behind the router,
//!   merged bin-wise into one [`ShardedReport`] (`--shards 1` is
//!   byte-identical to the unsharded path);
//! * [`autoscale`] — SLO-aware capacity search ([`autoscale_search`]):
//!   binary search over the GPU-scale axis with a per-scale report cache,
//!   replacing `--autoscale-target`'s linear scan;
//! * `reference` (doc-hidden) — the frozen pre-refactor pipeline, kept as
//!   the bit-equality oracle for the core refactor.
//!
//! Multi-tenancy itself lives one layer down: `SimConfig::max_tenants` /
//! `execute_dag_multi`'s `tenancy` let several components — from different
//! requests — reside on one device, and the shared
//! [`crate::sched::SchedState`] exposes the resulting cross-DAG device load
//! to every [`crate::sched::Policy`].
//!
//! Serving is **deadline-aware**: each request's deadline (made absolute)
//! and priority are threaded through the merge into per-component
//! [`crate::sim::CompMeta`], so policies like [`crate::sched::Edf`] order
//! the frontier by urgency and may preempt less urgent resident tenants
//! ([`crate::sched::Policy::preempt`]). Reports carry deadline-miss rate,
//! per-priority p99, and the preemption count. Admission is **SLO-aware**:
//! requests whose laxity is already negative at arrival (deadline budget
//! below the optimistic solo estimate) are rejected up front
//! ([`admission::admit_slo`]) and counted in
//! [`ServeReport::laxity_rejections`].

pub mod admission;
pub mod arrival;
pub mod autoscale;
pub mod cache;
pub mod core;
pub mod engine;
pub mod histogram;
pub mod merge;
pub mod real;
#[doc(hidden)]
pub mod reference;
pub mod request;
pub mod router;
pub mod shard;
pub mod streaming;

pub use admission::{admit, admit_slo, batch_requests, check_laxity, Batch, OpenBatch, StreamBatcher};
pub use arrival::{parse_rate, poisson_arrivals, trace_arrivals, PoissonStream};
pub use autoscale::{autoscale_search, Autoscale};
pub use cache::TemplateCache;
pub use engine::{
    percentile_sorted, request_outcome, serve_sequential, serve_sim, serve_sim_cached, Pacing,
    RequestOutcome, ServeConfig, ServeReport,
};
pub use histogram::LatencyHistogram;
pub use merge::{merge_apps, merge_apps_refs, MergedApp, MergedAssembly};
pub use real::{serve_real, serve_real_stream, RealBackend};
pub use request::{ServeRequest, Workload};
pub use router::{RouteDecision, Router, RouterStats};
pub use self::core::{
    serve_core, BackendStats, CollectSink, JsonlSink, NullSink, OutcomeSink, ServeBackend,
    StreamReport, StreamingConfig,
};
pub use shard::{
    merge_stream_reports, serve_sharded_real_stream, serve_sharded_stream, PlatformShape,
    ShardSpec, ShardSummary, ShardedReport,
};
pub use streaming::{serve_stream, serve_stream_cached, SimBackend};
