//! Fusing many application DAGs into one multi-tenant application.
//!
//! Requests stay independent (no cross-request edges), so the merged DAG is
//! a disjoint union with kernel/buffer/component ids offset per app. The
//! scheduler then sees one frontier spanning every admitted request — which
//! is exactly what lets the existing `Policy` trait arbitrate *between*
//! requests with no API change.
//!
//! §Perf (PR 4): [`MergedAssembly`] is the incremental builder behind both
//! [`merge_apps`] and the serving engine's batch-block assembly — a
//! pre-merged batch template ([`crate::serve::TemplateCache`]) is appended
//! as one contiguous block ([`MergedAssembly::append_merged`]) instead of
//! re-cloning and re-validating every constituent app per batch.

use crate::error::Result;
use crate::graph::{Dag, Partition};
use crate::platform::DeviceType;
use std::ops::Range;

/// The merged application plus the maps back to its constituent apps.
#[derive(Debug, Clone)]
pub struct MergedApp {
    pub dag: Dag,
    pub partition: Partition,
    /// Per input app: its component ids in the merged partition.
    pub component_ranges: Vec<Range<usize>>,
    /// Per input app: its first kernel id in the merged DAG.
    pub kernel_offsets: Vec<usize>,
    /// Per input app: its first buffer id in the merged DAG.
    pub buffer_offsets: Vec<usize>,
}

/// Incremental disjoint-union builder. Append validated apps (or whole
/// pre-merged blocks), then [`MergedAssembly::finish`]. The appended
/// content is **trusted to be individually validated** (admission validates
/// every app; cached blocks are validated once when built): a disjoint
/// union of valid DAGs is valid, so `finish` skips the O(V+E) revalidation
/// the one-shot [`merge_apps`] entry point still performs.
#[derive(Debug, Default)]
pub struct MergedAssembly {
    dag: Dag,
    groups: Vec<(Vec<usize>, DeviceType)>,
    component_ranges: Vec<Range<usize>>,
    kernel_offsets: Vec<usize>,
    buffer_offsets: Vec<usize>,
}

impl MergedAssembly {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of apps appended so far.
    pub fn num_apps(&self) -> usize {
        self.kernel_offsets.len()
    }

    /// Append one application; returns its component range in the merged
    /// partition.
    pub fn append_app(&mut self, app: &(Dag, Partition)) -> Range<usize> {
        let (app_dag, app_part) = app;
        let ko = self.dag.kernels.len();
        let bo = self.dag.buffers.len();
        self.kernel_offsets.push(ko);
        self.buffer_offsets.push(bo);
        for k in &app_dag.kernels {
            let mut k = k.clone();
            k.id += ko;
            for b in k.inputs.iter_mut().chain(k.outputs.iter_mut()) {
                *b += bo;
            }
            self.dag.kernels.push(k);
        }
        for b in &app_dag.buffers {
            let mut b = b.clone();
            b.id += bo;
            b.kernel += ko;
            self.dag.buffers.push(b);
        }
        for &(src, dst) in &app_dag.buffer_edges {
            self.dag.buffer_edges.push((src + bo, dst + bo));
        }
        let comp_base = self.groups.len();
        for c in &app_part.components {
            self.groups
                .push((c.kernels.iter().map(|&k| k + ko).collect(), c.dev));
        }
        let range = comp_base..self.groups.len();
        self.component_ranges.push(range.clone());
        range
    }

    /// Append a whole pre-merged block (e.g. a cached batch template) as
    /// one contiguous run of apps: ids are shifted by the current offsets
    /// in a single pass over the block, and the block's own per-app maps
    /// are rebased — no per-app loops, no revalidation. Returns the
    /// component range of each app *inside the block*, in block order.
    pub fn append_merged(&mut self, block: &MergedApp) -> Vec<Range<usize>> {
        let ko = self.dag.kernels.len();
        let bo = self.dag.buffers.len();
        let comp_base = self.groups.len();
        for k in &block.dag.kernels {
            let mut k = k.clone();
            k.id += ko;
            for b in k.inputs.iter_mut().chain(k.outputs.iter_mut()) {
                *b += bo;
            }
            self.dag.kernels.push(k);
        }
        for b in &block.dag.buffers {
            let mut b = b.clone();
            b.id += bo;
            b.kernel += ko;
            self.dag.buffers.push(b);
        }
        for &(src, dst) in &block.dag.buffer_edges {
            self.dag.buffer_edges.push((src + bo, dst + bo));
        }
        for c in &block.partition.components {
            self.groups
                .push((c.kernels.iter().map(|&k| k + ko).collect(), c.dev));
        }
        let mut ranges = Vec::with_capacity(block.component_ranges.len());
        for (i, r) in block.component_ranges.iter().enumerate() {
            self.kernel_offsets.push(ko + block.kernel_offsets[i]);
            self.buffer_offsets.push(bo + block.buffer_offsets[i]);
            let shifted = (comp_base + r.start)..(comp_base + r.end);
            self.component_ranges.push(shifted.clone());
            ranges.push(shifted);
        }
        ranges
    }

    /// Seal the assembly: rebuild the adjacency index and the partition.
    /// Structural *validation* of the union is intentionally skipped — see
    /// the type-level contract above; [`merge_apps`] revalidates for
    /// untrusted inputs.
    pub fn finish(self) -> Result<MergedApp> {
        let mut dag = self.dag;
        dag.reindex();
        let partition = Partition::new(&dag, self.groups)?;
        Ok(MergedApp {
            dag,
            partition,
            component_ranges: self.component_ranges,
            kernel_offsets: self.kernel_offsets,
            buffer_offsets: self.buffer_offsets,
        })
    }
}

/// Disjoint union of `apps` (each a validated dag + partition), by
/// reference — the allocation the serving layer avoids is the caller-side
/// deep clone into a contiguous `Vec<(Dag, Partition)>`.
pub fn merge_apps_refs(apps: &[&(Dag, Partition)]) -> Result<MergedApp> {
    let mut asm = MergedAssembly::new();
    for app in apps {
        asm.append_app(app);
    }
    let merged = asm.finish()?;
    merged.dag.validate()?;
    Ok(merged)
}

/// Disjoint union of `apps` (each a validated dag + partition).
pub fn merge_apps(apps: &[(Dag, Partition)]) -> Result<MergedApp> {
    let refs: Vec<&(Dag, Partition)> = apps.iter().collect();
    merge_apps_refs(&refs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::DeviceType;
    use crate::transformer::{cluster_by_head, head_dag, vadd_vsin_dag};

    fn head_app() -> (Dag, Partition) {
        let (dag, io) = head_dag(64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, std::slice::from_ref(&io), 0);
        (dag, part)
    }

    #[test]
    fn merge_is_a_disjoint_union() {
        let apps = vec![head_app(), head_app(), head_app()];
        let m = merge_apps(&apps).unwrap();
        assert_eq!(m.dag.num_kernels(), 3 * 8);
        assert_eq!(m.partition.components.len(), 3);
        assert_eq!(m.component_ranges, vec![0..1, 1..2, 2..3]);
        assert_eq!(m.kernel_offsets, vec![0, 8, 16]);
        // No cross-app edges: every edge stays within one app's id band.
        for (app, &bo) in m.buffer_offsets.iter().enumerate() {
            let hi = m
                .buffer_offsets
                .get(app + 1)
                .copied()
                .unwrap_or(m.dag.buffers.len());
            for &(s, d) in &m.dag.buffer_edges {
                let s_in = (bo..hi).contains(&s);
                let d_in = (bo..hi).contains(&d);
                assert_eq!(s_in, d_in, "edge ({s},{d}) crosses app boundary");
            }
        }
    }

    #[test]
    fn merge_of_one_app_is_identity_shaped() {
        let (dag, part) = head_app();
        let m = merge_apps(&[(dag.clone(), part.clone())]).unwrap();
        assert_eq!(m.dag.num_kernels(), dag.num_kernels());
        assert_eq!(m.dag.buffer_edges, dag.buffer_edges);
        assert_eq!(m.partition.components.len(), part.components.len());
        assert_eq!(m.partition.assignment, part.assignment);
    }

    #[test]
    fn merged_heterogeneous_apps_validate() {
        let (vdag, vks) = vadd_vsin_dag(4096);
        let vpart = Partition::singletons(&vdag);
        let apps = vec![head_app(), (vdag, vpart)];
        let m = merge_apps(&apps).unwrap();
        m.dag.validate().unwrap();
        assert_eq!(m.partition.components.len(), 1 + 2);
        // The vadd→vsin dependency survives the offset.
        let vadd_merged = vks[0] + m.kernel_offsets[1];
        let vsin_merged = vks[1] + m.kernel_offsets[1];
        assert_eq!(m.dag.kernel_succs(vadd_merged), vec![vsin_merged]);
    }

    /// Appending a pre-merged block must produce byte-for-byte the same
    /// merged application as appending its constituent apps one by one —
    /// the invariant the serving engine's template cache rests on.
    #[test]
    fn block_append_equals_per_app_append() {
        let a = head_app();
        let (vdag, _) = vadd_vsin_dag(4096);
        let vpart = Partition::singletons(&vdag);
        let b = (vdag, vpart);

        // Flat: [b, a, a, a] appended app by app.
        let flat = merge_apps(&[b.clone(), a.clone(), a.clone(), a.clone()]).unwrap();

        // Blocked: [b] appended, then a pre-merged [a, a, a] block.
        let block = merge_apps(&[a.clone(), a.clone(), a.clone()]).unwrap();
        let mut asm = MergedAssembly::new();
        let r0 = asm.append_app(&b);
        let rs = asm.append_merged(&block);
        let m = asm.finish().unwrap();
        m.dag.validate().unwrap();

        assert_eq!(m.dag.num_kernels(), flat.dag.num_kernels());
        assert_eq!(m.dag.buffer_edges, flat.dag.buffer_edges);
        assert_eq!(m.partition.assignment, flat.partition.assignment);
        assert_eq!(m.kernel_offsets, flat.kernel_offsets);
        assert_eq!(m.buffer_offsets, flat.buffer_offsets);
        assert_eq!(m.component_ranges, flat.component_ranges);
        assert_eq!(r0, flat.component_ranges[0]);
        assert_eq!(rs, flat.component_ranges[1..].to_vec());
        // Kernel/buffer contents line up (ids + wiring).
        for (k1, k2) in m.dag.kernels.iter().zip(&flat.dag.kernels) {
            assert_eq!(k1.id, k2.id);
            assert_eq!(k1.name, k2.name);
            assert_eq!(k1.inputs, k2.inputs);
            assert_eq!(k1.outputs, k2.outputs);
        }
        for (b1, b2) in m.dag.buffers.iter().zip(&flat.dag.buffers) {
            assert_eq!(b1.id, b2.id);
            assert_eq!(b1.kernel, b2.kernel);
            assert_eq!(b1.size_bytes, b2.size_bytes);
        }
    }
}
