//! Fusing many application DAGs into one multi-tenant application.
//!
//! Requests stay independent (no cross-request edges), so the merged DAG is
//! a disjoint union with kernel/buffer/component ids offset per app. The
//! scheduler then sees one frontier spanning every admitted request — which
//! is exactly what lets the existing `Policy` trait arbitrate *between*
//! requests with no API change.

use crate::error::Result;
use crate::graph::{Dag, Partition};
use std::ops::Range;

/// The merged application plus the maps back to its constituent apps.
#[derive(Debug, Clone)]
pub struct MergedApp {
    pub dag: Dag,
    pub partition: Partition,
    /// Per input app: its component ids in the merged partition.
    pub component_ranges: Vec<Range<usize>>,
    /// Per input app: its first kernel id in the merged DAG.
    pub kernel_offsets: Vec<usize>,
    /// Per input app: its first buffer id in the merged DAG.
    pub buffer_offsets: Vec<usize>,
}

/// Disjoint union of `apps` (each a validated dag + partition).
pub fn merge_apps(apps: &[(Dag, Partition)]) -> Result<MergedApp> {
    let mut dag = Dag::default();
    let mut groups: Vec<(Vec<usize>, crate::platform::DeviceType)> = Vec::new();
    let mut component_ranges = Vec::with_capacity(apps.len());
    let mut kernel_offsets = Vec::with_capacity(apps.len());
    let mut buffer_offsets = Vec::with_capacity(apps.len());

    for (app_dag, app_part) in apps {
        let ko = dag.kernels.len();
        let bo = dag.buffers.len();
        kernel_offsets.push(ko);
        buffer_offsets.push(bo);
        for k in &app_dag.kernels {
            let mut k = k.clone();
            k.id += ko;
            for b in k.inputs.iter_mut().chain(k.outputs.iter_mut()) {
                *b += bo;
            }
            dag.kernels.push(k);
        }
        for b in &app_dag.buffers {
            let mut b = b.clone();
            b.id += bo;
            b.kernel += ko;
            dag.buffers.push(b);
        }
        for &(src, dst) in &app_dag.buffer_edges {
            dag.buffer_edges.push((src + bo, dst + bo));
        }
        let comp_base = groups.len();
        for c in &app_part.components {
            groups.push((c.kernels.iter().map(|&k| k + ko).collect(), c.dev));
        }
        component_ranges.push(comp_base..groups.len());
    }

    dag.reindex();
    dag.validate()?;
    let partition = Partition::new(&dag, groups)?;
    Ok(MergedApp {
        dag,
        partition,
        component_ranges,
        kernel_offsets,
        buffer_offsets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::DeviceType;
    use crate::transformer::{cluster_by_head, head_dag, vadd_vsin_dag};

    fn head_app() -> (Dag, Partition) {
        let (dag, io) = head_dag(64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, std::slice::from_ref(&io), 0);
        (dag, part)
    }

    #[test]
    fn merge_is_a_disjoint_union() {
        let apps = vec![head_app(), head_app(), head_app()];
        let m = merge_apps(&apps).unwrap();
        assert_eq!(m.dag.num_kernels(), 3 * 8);
        assert_eq!(m.partition.components.len(), 3);
        assert_eq!(m.component_ranges, vec![0..1, 1..2, 2..3]);
        assert_eq!(m.kernel_offsets, vec![0, 8, 16]);
        // No cross-app edges: every edge stays within one app's id band.
        for (app, &bo) in m.buffer_offsets.iter().enumerate() {
            let hi = m
                .buffer_offsets
                .get(app + 1)
                .copied()
                .unwrap_or(m.dag.buffers.len());
            for &(s, d) in &m.dag.buffer_edges {
                let s_in = (bo..hi).contains(&s);
                let d_in = (bo..hi).contains(&d);
                assert_eq!(s_in, d_in, "edge ({s},{d}) crosses app boundary");
            }
        }
    }

    #[test]
    fn merge_of_one_app_is_identity_shaped() {
        let (dag, part) = head_app();
        let m = merge_apps(&[(dag.clone(), part.clone())]).unwrap();
        assert_eq!(m.dag.num_kernels(), dag.num_kernels());
        assert_eq!(m.dag.buffer_edges, dag.buffer_edges);
        assert_eq!(m.partition.components.len(), part.components.len());
        assert_eq!(m.partition.assignment, part.assignment);
    }

    #[test]
    fn merged_heterogeneous_apps_validate() {
        let (vdag, vks) = vadd_vsin_dag(4096);
        let vpart = Partition::singletons(&vdag);
        let apps = vec![head_app(), (vdag, vpart)];
        let m = merge_apps(&apps).unwrap();
        m.dag.validate().unwrap();
        assert_eq!(m.partition.components.len(), 1 + 2);
        // The vadd→vsin dependency survives the offset.
        let vadd_merged = vks[0] + m.kernel_offsets[1];
        let vsin_merged = vks[1] + m.kernel_offsets[1];
        assert_eq!(m.dag.kernel_succs(vadd_merged), vec![vsin_merged]);
    }
}
