//! The signature-affinity request router fronting the sharded server.
//!
//! A sharded deployment ([`super::shard`]) runs one serve loop per replica
//! shard, each with its own scheduler state, simulator/real backend, and
//! template/executable caches. The router decides which shard each arrival
//! goes to, balancing two forces:
//!
//! * **Cache affinity** — the per-shard [`super::TemplateCache`] (and on
//!   the real path the per-shard PJRT executable cache) is keyed by
//!   workload signature. Hashing the signature to an *affine* shard sends
//!   every `head_b64` to the same replica, so its template and executable
//!   stay hot instead of being recompiled on every shard.
//! * **Load balance** — pure affinity hotspots when a few signatures
//!   dominate. When the affine shard's queue depth exceeds the **spill
//!   threshold**, the router falls back to power-of-two-choices: a second
//!   hash-derived candidate is probed and the request goes to the less
//!   loaded of the two. Spills are counted; a hot signature pays one cold
//!   template build on its spill target and stays cache-resident there.
//!
//! Routing is **deterministic in the unloaded state**: the affine shard is
//! a pure FNV-1a hash of the signature ([`Router::shard_for_signature`]),
//! identical across runs, seeds, and processes — the property the router
//! tests pin. Depth-triggered spilling depends on instantaneous load, which
//! is the point.
//!
//! The router also owns two stream-global responsibilities the per-shard
//! loops cannot see:
//!
//! * **Duplicate-id rejection** — the core's in-flight duplicate check is
//!   per serve loop, so the same id arriving on two different shards would
//!   be admitted twice. The router keeps the global in-flight id set and
//!   rejects a duplicate exactly once, before it reaches any shard.
//! * **SLO-driven rebalancing** ([`Router::rebalance`]) — shard sinks feed
//!   observed deadline outcomes back; when the running miss rate crosses
//!   the configured target the router halves the effective spill threshold
//!   (spreading load sooner at the price of more cold caches) and restores
//!   it once the SLO recovers. Transitions are counted as `rebalances`.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::request::ServeRequest;

/// Minimum observed deadline outcomes before [`Router::rebalance`] acts —
/// a handful of early misses must not flap the spill threshold.
const REBALANCE_MIN_SAMPLES: usize = 32;

/// FNV-1a, 64-bit: tiny, allocation-free, and stable across platforms —
/// the mapping must not depend on `DefaultHasher`'s unspecified seed.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// What the router decided for one arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteDecision {
    /// Forward to this shard's sub-stream.
    Shard(usize),
    /// The id is already in flight on some shard: reject globally, exactly
    /// once, without forwarding.
    Duplicate,
}

/// Router counters, snapshotted into the sharded report.
#[derive(Debug, Clone)]
pub struct RouterStats {
    pub shards: usize,
    /// Requests forwarded per shard (duplicates excluded).
    pub routed: Vec<usize>,
    /// Requests diverted off their affine shard by power-of-two-choices.
    pub spills: usize,
    /// Requests rejected by the global duplicate-id check.
    pub duplicate_rejections: usize,
    /// Spill-threshold transitions driven by [`Router::rebalance`].
    pub rebalances: usize,
    /// The configured spill threshold.
    pub spill_threshold: usize,
    /// The threshold currently in force (≤ configured when the SLO is
    /// being missed).
    pub effective_spill_threshold: usize,
}

/// Signature-affinity router with power-of-two-choices spill. All state is
/// interior-mutable behind atomics (plus one mutex for the id set): the
/// feed thread routes while shard threads report completions concurrently.
pub struct Router {
    shards: usize,
    spill_threshold: usize,
    effective_spill: AtomicUsize,
    slo_target: Option<f64>,
    /// Global in-flight id set. Only maintained with more than one shard:
    /// at `--shards 1` the core's own per-loop duplicate check is already
    /// global, and its window (admission → batch close) is narrower than
    /// the router's (route → completion) — tracking here would *change*
    /// single-shard semantics, breaking the byte-identity contract.
    in_flight: Option<Mutex<HashSet<usize>>>,
    routed: Vec<AtomicUsize>,
    finished: Vec<AtomicUsize>,
    spills: AtomicUsize,
    duplicates: AtomicUsize,
    rebalances: AtomicUsize,
    deadline_total: AtomicUsize,
    deadline_misses: AtomicUsize,
}

impl Router {
    /// `spill_threshold` is the queue depth (routed minus finished) above
    /// which the affine shard spills; `slo_target` arms
    /// [`rebalance`](Self::rebalance) with a deadline-miss-rate goal.
    pub fn new(shards: usize, spill_threshold: usize, slo_target: Option<f64>) -> Router {
        let shards = shards.max(1);
        Router {
            shards,
            spill_threshold,
            effective_spill: AtomicUsize::new(spill_threshold),
            slo_target,
            in_flight: (shards > 1).then(|| Mutex::new(HashSet::new())),
            routed: (0..shards).map(|_| AtomicUsize::new(0)).collect(),
            finished: (0..shards).map(|_| AtomicUsize::new(0)).collect(),
            spills: AtomicUsize::new(0),
            duplicates: AtomicUsize::new(0),
            rebalances: AtomicUsize::new(0),
            deadline_total: AtomicUsize::new(0),
            deadline_misses: AtomicUsize::new(0),
        }
    }

    /// The pure affinity mapping: which shard owns this signature when no
    /// load forces a spill. Deterministic across runs, seeds, processes.
    pub fn shard_for_signature(&self, signature: &str) -> usize {
        (fnv1a(signature.as_bytes()) % self.shards as u64) as usize
    }

    /// In-flight depth of a shard: routed minus finished. Saturating — the
    /// two counters are bumped from different threads and a transient
    /// finished-ahead-of-routed read must not wrap.
    fn depth(&self, shard: usize) -> usize {
        let routed = self.routed[shard].load(Ordering::Relaxed);
        let finished = self.finished[shard].load(Ordering::Relaxed);
        routed.saturating_sub(finished)
    }

    /// Route one arrival: global duplicate check, then affinity with
    /// power-of-two-choices spill. On `Shard(s)` the request counts as in
    /// flight on `s` until [`on_finished`](Self::on_finished) /
    /// [`on_rejected`](Self::on_rejected) releases it.
    pub fn route(&self, req: &ServeRequest) -> RouteDecision {
        if let Some(in_flight) = &self.in_flight {
            let mut seen = in_flight.lock().unwrap_or_else(|e| e.into_inner());
            if !seen.insert(req.id) {
                self.duplicates.fetch_add(1, Ordering::Relaxed);
                return RouteDecision::Duplicate;
            }
        }
        let h = fnv1a(req.workload.signature().as_bytes());
        let affine = (h % self.shards as u64) as usize;
        let shard = if self.shards == 1 {
            affine
        } else {
            let depth = self.depth(affine);
            if depth <= self.effective_spill.load(Ordering::Relaxed) {
                affine
            } else {
                // Power of two choices: a second hash-derived candidate
                // (upper bits, nudged off the affine shard), taken only
                // when actually less loaded.
                let mut alt = ((h >> 32) % self.shards as u64) as usize;
                if alt == affine {
                    alt = (affine + 1) % self.shards;
                }
                if self.depth(alt) < depth {
                    self.spills.fetch_add(1, Ordering::Relaxed);
                    alt
                } else {
                    affine
                }
            }
        };
        self.routed[shard].fetch_add(1, Ordering::Relaxed);
        RouteDecision::Shard(shard)
    }

    /// A routed request left its shard (served or shed). `deadline_met`
    /// feeds the SLO observer; pass `None` when the request carried no
    /// deadline or was shed.
    pub fn on_finished(&self, id: usize, shard: usize, deadline_met: Option<bool>) {
        self.finished[shard].fetch_add(1, Ordering::Relaxed);
        if let Some(in_flight) = &self.in_flight {
            in_flight
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .remove(&id);
        }
        if let Some(met) = deadline_met {
            self.deadline_total.fetch_add(1, Ordering::Relaxed);
            if !met {
                self.deadline_misses.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// A routed request was rejected at its shard's admission (laxity,
    /// malformed workload): release the id so a resubmission can route.
    pub fn on_rejected(&self, id: usize, shard: usize) {
        self.finished[shard].fetch_add(1, Ordering::Relaxed);
        if let Some(in_flight) = &self.in_flight {
            in_flight
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .remove(&id);
        }
    }

    /// The SLO-driven mid-stream scale decision: when the observed
    /// deadline-miss rate crosses the target, halve the effective spill
    /// threshold so load spreads off hot shards sooner; restore the
    /// configured threshold once the SLO recovers. No-op without a target
    /// or before [`REBALANCE_MIN_SAMPLES`] deadline outcomes. Called by the
    /// feed loop after every route — cheap (three relaxed loads).
    pub fn rebalance(&self) {
        let Some(target) = self.slo_target else {
            return;
        };
        let total = self.deadline_total.load(Ordering::Relaxed);
        if total < REBALANCE_MIN_SAMPLES {
            return;
        }
        let miss = self.deadline_misses.load(Ordering::Relaxed) as f64 / total as f64;
        let want = if miss > target {
            (self.spill_threshold / 2).max(1)
        } else {
            self.spill_threshold
        };
        let prev = self.effective_spill.swap(want, Ordering::Relaxed);
        if prev != want {
            self.rebalances.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot the counters for the sharded report.
    pub fn stats(&self) -> RouterStats {
        RouterStats {
            shards: self.shards,
            routed: self
                .routed
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            spills: self.spills.load(Ordering::Relaxed),
            duplicate_rejections: self.duplicates.load(Ordering::Relaxed),
            rebalances: self.rebalances.load(Ordering::Relaxed),
            spill_threshold: self.spill_threshold,
            effective_spill_threshold: self.effective_spill.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::Workload;

    fn req(id: usize, beta: u64) -> ServeRequest {
        ServeRequest::new(id, id as f64 * 1e-3, Workload::Head { beta })
    }

    #[test]
    fn affinity_is_deterministic_across_router_instances() {
        let a = Router::new(4, 64, None);
        let b = Router::new(4, 64, None);
        let sigs: Vec<String> = (0..64).map(|i| format!("head_b{}", 64 + 8 * i)).collect();
        let mut seen = HashSet::new();
        for s in &sigs {
            let sa = a.shard_for_signature(s);
            assert_eq!(sa, b.shard_for_signature(s), "sig {s}");
            assert_eq!(sa, a.shard_for_signature(s), "sig {s} unstable");
            assert!(sa < 4);
            seen.insert(sa);
        }
        // Non-degenerate: 64 signatures spread over more than one shard.
        assert!(seen.len() > 1, "all signatures hashed to one shard");
    }

    #[test]
    fn unloaded_route_follows_the_affine_shard() {
        let r = Router::new(4, 64, None);
        for id in 0..32 {
            let q = req(id, 64 + 8 * (id as u64 % 16));
            let affine = r.shard_for_signature(&q.workload.signature());
            match r.route(&q) {
                RouteDecision::Shard(s) => {
                    assert_eq!(s, affine);
                    r.on_finished(id, s, None);
                }
                RouteDecision::Duplicate => panic!("unexpected duplicate"),
            }
        }
        assert_eq!(r.stats().spills, 0);
    }

    #[test]
    fn overloaded_affine_shard_spills_to_the_second_choice() {
        // Threshold 0: the second same-signature arrival (depth 1 on the
        // affine shard, nothing finished) must divert.
        let r = Router::new(4, 0, None);
        let affine = r.shard_for_signature(&req(0, 64).workload.signature());
        let RouteDecision::Shard(first) = r.route(&req(0, 64)) else {
            panic!("duplicate")
        };
        assert_eq!(first, affine);
        let RouteDecision::Shard(second) = r.route(&req(1, 64)) else {
            panic!("duplicate")
        };
        assert_ne!(second, affine, "depth above threshold must spill");
        assert_eq!(r.stats().spills, 1);
        // Both choices equally deep: stay affine (spill only when strictly
        // less loaded).
        let RouteDecision::Shard(third) = r.route(&req(2, 64)) else {
            panic!("duplicate")
        };
        assert_eq!(third, affine);
        assert_eq!(r.stats().spills, 1);
    }

    #[test]
    fn duplicate_ids_reject_exactly_once_and_release_on_finish() {
        let r = Router::new(2, 64, None);
        let RouteDecision::Shard(s) = r.route(&req(7, 64)) else {
            panic!("duplicate")
        };
        assert_eq!(r.route(&req(7, 128)), RouteDecision::Duplicate);
        assert_eq!(r.route(&req(7, 64)), RouteDecision::Duplicate);
        assert_eq!(r.stats().duplicate_rejections, 2);
        r.on_finished(7, s, None);
        assert!(matches!(r.route(&req(7, 64)), RouteDecision::Shard(_)));
    }

    #[test]
    fn single_shard_router_never_tracks_duplicates() {
        // The core's own in-flight check owns duplicate semantics at one
        // shard — the router must stay out of the way (byte-identity).
        let r = Router::new(1, 64, None);
        assert!(matches!(r.route(&req(3, 64)), RouteDecision::Shard(0)));
        assert!(matches!(r.route(&req(3, 64)), RouteDecision::Shard(0)));
        assert_eq!(r.stats().duplicate_rejections, 0);
    }

    #[test]
    fn rebalance_halves_and_restores_the_spill_threshold() {
        let r = Router::new(2, 64, Some(0.1));
        // Below the sample floor: no action.
        for i in 0..REBALANCE_MIN_SAMPLES - 1 {
            r.on_finished(i, 0, Some(false));
        }
        r.rebalance();
        assert_eq!(r.stats().effective_spill_threshold, 64);
        // Cross the floor with a 100% miss rate: threshold halves.
        r.on_finished(REBALANCE_MIN_SAMPLES, 0, Some(false));
        r.rebalance();
        let s = r.stats();
        assert_eq!(s.effective_spill_threshold, 32);
        assert_eq!(s.rebalances, 1);
        // Recover the SLO (flood of met deadlines): threshold restores.
        for i in 0..1000 {
            r.on_finished(10_000 + i, 1, Some(true));
        }
        r.rebalance();
        let s = r.stats();
        assert_eq!(s.effective_spill_threshold, 64);
        assert_eq!(s.rebalances, 2);
    }
}
