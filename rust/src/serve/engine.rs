//! The serving engine: concurrent multi-DAG scheduling over the simulator,
//! plus the sequential-replay baseline every serving run is judged against.

use super::admission::{admit, batch_requests, check_laxity};
use super::merge::merge_apps;
use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::Result;
use crate::graph::{Dag, Partition};
use crate::json::Json;
use crate::platform::Platform;
use crate::sched::Policy;
use crate::sim::{simulate, simulate_served, CompMeta, SimConfig};
use crate::trace::Lane;

/// Arrival pacing of the real serving loop.
///
/// * `Closed` — replay: the loop dispatches each batch as soon as the
///   previous one completes, so wall-clock dispatch can outrun the nominal
///   arrival process and latency degenerates to service latency
///   ([`request_outcome`] documents the clamp).
/// * `Open` — open-loop: the loop **sleeps until each batch's nominal
///   release instant** before dispatching, so measured latencies are
///   genuinely end-to-end against the arrival process — the only numbers a
///   deadline/SLO evaluation can trust (Clipper/Clockwork-style serving
///   methodology).
///
/// The simulated paths are inherently open-loop (virtual time honours
/// release instants by construction), so this knob only changes
/// [`super::serve_real`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Pacing {
    /// Closed-loop replay (dispatch as fast as batches complete).
    #[default]
    Closed,
    /// Open-loop (sleep until each batch's nominal release instant).
    Open,
}

impl Pacing {
    /// Report/CLI spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Pacing::Closed => "closed",
            Pacing::Open => "open",
        }
    }
}

/// Serving-layer knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Batching window: compatible requests arriving within this many
    /// seconds of a batch opener coalesce into one dispatch group.
    pub batch_window: f64,
    /// Max task components resident per device at once (multi-tenancy).
    pub tenancy: usize,
    /// Arrival pacing of the real serving loop (sim paths ignore this —
    /// virtual time is always open-loop).
    pub pacing: Pacing,
    /// Laxity-based admission control: reject deadline-carrying requests
    /// whose laxity is already negative at arrival
    /// ([`super::admission::admit_slo`]). On by default; turn off to let
    /// unmeetable requests through and count their misses instead.
    pub laxity_admission: bool,
    /// Real path only: eagerly compile every AOT artifact before the epoch
    /// (Clockwork-style), moving executable lowering off the request path.
    /// Leave off to measure cold-vs-warm batch latency.
    pub prewarm: bool,
    /// Underlying simulator knobs.
    pub sim: SimConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch_window: 2e-3,
            tenancy: 4,
            pacing: Pacing::Closed,
            laxity_admission: true,
            prewarm: false,
            sim: SimConfig::default(),
        }
    }
}

/// Per-request accounting.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: usize,
    pub arrival: f64,
    /// Instant the request's components became dispatchable (batch release
    /// in concurrent mode; service start in sequential replay).
    pub release: f64,
    /// Instant the last of its components finished.
    pub finish: f64,
    /// End-to-end latency (see [`request_outcome`] for the exact
    /// semantics, shared by every serving path).
    pub latency: f64,
    /// Whether the deadline was met (requests without deadlines: `None`).
    pub deadline_met: Option<bool>,
    /// The request's priority (carried through for per-priority tails).
    pub priority: u32,
}

/// The single place where latency and deadline semantics are defined, used
/// by the sim, sequential, and real serving paths alike.
///
/// Latency is **end-to-end**: `finish - arrival`, and a deadline of `d`
/// seconds is met iff `finish - arrival <= d`. Under [`Pacing::Open`] that
/// is the whole story: the serving loop slept until each batch's nominal
/// release instant, so `release >= arrival` holds by construction and every
/// latency is measured against the arrival process. The sim and sequential
/// paths guarantee the same invariant in virtual time and also pass
/// `Open`.
///
/// One caveat remains, now confined to [`Pacing::Closed`]: a closed-loop
/// replay never sleeps waiting for an arrival, so wall-clock dispatch can
/// outrun the nominal arrival process. When a batch starts before a
/// member's arrival instant (`release < arrival`), `finish - arrival` would
/// under-state the work done; the latency therefore degenerates to service
/// latency (`finish - release`) exactly in that case, via `max`.
pub fn request_outcome(
    req: &ServeRequest,
    release: f64,
    finish: f64,
    pacing: Pacing,
) -> RequestOutcome {
    let latency = match pacing {
        Pacing::Open => finish - req.arrival,
        Pacing::Closed => (finish - req.arrival).max(finish - release),
    };
    RequestOutcome {
        id: req.id,
        arrival: req.arrival,
        release,
        finish,
        latency,
        deadline_met: req.deadline.map(|d| latency <= d),
        priority: req.priority,
    }
}

/// Aggregate serving statistics for one run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub policy: String,
    /// `"concurrent"` (multi-tenant serving) or `"sequential"` (replay).
    pub mode: &'static str,
    pub outcomes: Vec<RequestOutcome>,
    /// `(request id, admission error)` per rejected request.
    pub rejected: Vec<(usize, String)>,
    /// Time from epoch to the last completion.
    pub makespan: f64,
    pub throughput_rps: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    /// Requests that carried a deadline.
    pub deadline_total: usize,
    /// ... of which this many missed it.
    pub deadline_misses: usize,
    /// `deadline_misses / deadline_total` (0 when no request has one).
    pub deadline_miss_rate: f64,
    /// p99 latency per distinct request priority, ascending priority.
    pub per_priority_p99: Vec<(u32, f64)>,
    /// Resident components displaced mid-flight (EDF preemption; 0 for
    /// deadline-blind policies and the sequential/real paths).
    pub preemptions: usize,
    /// Compute busy fraction per device over the makespan.
    pub device_util: Vec<f64>,
    /// Arrival pacing the run used: `"open"`, `"closed"`, or `"virtual"`
    /// (simulated paths — virtual time is always open-loop).
    pub pacing: &'static str,
    /// ... of the rejections, how many were laxity-based admission-control
    /// rejections (deadline budget below the solo estimate at arrival).
    pub laxity_rejections: usize,
    /// Real path: PJRT executable-cache hits over the run (0 in sim),
    /// counted per kernel execution — kernels sharing an artifact hit
    /// within a single batch too, so treat this as a sanity floor. The
    /// cross-batch-reuse guarantee is the *miss* count staying at one per
    /// distinct artifact for the whole run.
    pub exec_cache_hits: usize,
    /// Real path: executables actually lowered + compiled (one per
    /// distinct artifact when the cache works; growth per batch means
    /// recompilation regressed).
    pub exec_cache_misses: usize,
    /// Real path: mean service latency of *cold* batches — batches that
    /// actually lowered at least one executable (nonzero per-batch
    /// cache-miss delta); typically the first batch of each signature on a
    /// fresh runtime. 0 when the run had none (prewarmed runtime, sim).
    pub cold_batch_latency: f64,
    /// Real path: mean service latency of *warm* batches — served entirely
    /// from the executable cache (0 when none).
    pub warm_batch_latency: f64,
}

impl ServeReport {
    /// The BENCH_serve.json building block.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str(self.mode)),
            ("policy", Json::str(self.policy.clone())),
            ("requests", Json::num(self.outcomes.len() as f64)),
            ("rejected", Json::num(self.rejected.len() as f64)),
            ("makespan_s", Json::num(self.makespan)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("p50_latency_s", Json::num(self.p50_latency)),
            ("p99_latency_s", Json::num(self.p99_latency)),
            ("deadline_total", Json::num(self.deadline_total as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("deadline_miss_rate", Json::num(self.deadline_miss_rate)),
            (
                "per_priority_p99_s",
                Json::Arr(
                    self.per_priority_p99
                        .iter()
                        .map(|&(p, l)| {
                            Json::obj(vec![
                                ("priority", Json::num(p as f64)),
                                ("p99_latency_s", Json::num(l)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("preemptions", Json::num(self.preemptions as f64)),
            (
                "device_util",
                Json::Arr(self.device_util.iter().map(|&u| Json::num(u)).collect()),
            ),
            ("pacing", Json::str(self.pacing)),
            ("laxity_rejections", Json::num(self.laxity_rejections as f64)),
            ("exec_cache_hits", Json::num(self.exec_cache_hits as f64)),
            ("exec_cache_misses", Json::num(self.exec_cache_misses as f64)),
            ("cold_batch_latency_s", Json::num(self.cold_batch_latency)),
            ("warm_batch_latency_s", Json::num(self.warm_batch_latency)),
        ])
    }
}

/// Nearest-rank percentile over unsorted latencies; 0 when empty.
pub fn percentile(values: &[f64], q: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let idx = ((sorted.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
    sorted[idx]
}

/// Sort by arrival, admit each request; returns (admitted requests, their
/// instantiated apps, typed rejections, laxity-rejection count).
pub(crate) type Admitted = (
    Vec<ServeRequest>,
    Vec<(Dag, Partition)>,
    Vec<(usize, String)>,
    usize,
);

/// Shared admission front-end for the sim and real serving paths: arrival
/// order, priority-descending tie-break, then id. With
/// `ServeConfig::laxity_admission` on, deadline-carrying requests whose
/// laxity is already negative at arrival are rejected up front
/// ([`check_laxity`]) and counted in the returned tally (typed, not
/// inferred from rejection messages).
pub(crate) fn admit_all(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    laxity_admission: bool,
) -> Admitted {
    let mut sorted: Vec<ServeRequest> = requests.to_vec();
    sorted.sort_by(|a, b| {
        a.arrival
            .total_cmp(&b.arrival)
            .then_with(|| b.priority.cmp(&a.priority))
            .then_with(|| a.id.cmp(&b.id))
    });
    let mut admitted = Vec::new();
    let mut apps = Vec::new();
    let mut rejected = Vec::new();
    let mut laxity_rejections = 0usize;
    for req in sorted {
        match admit(&req) {
            Ok(app) => {
                if laxity_admission {
                    if let Err(e) = check_laxity(&req, &app, platform, cost) {
                        laxity_rejections += 1;
                        rejected.push((req.id, e.to_string()));
                        continue;
                    }
                }
                admitted.push(req);
                apps.push(app);
            }
            Err(e) => rejected.push((req.id, e.to_string())),
        }
    }
    (admitted, apps, rejected, laxity_rejections)
}

/// Deadline-miss and per-priority tail statistics over a set of outcomes.
pub(crate) fn deadline_stats(outcomes: &[RequestOutcome]) -> (usize, usize, f64, Vec<(u32, f64)>) {
    let deadline_total = outcomes.iter().filter(|o| o.deadline_met.is_some()).count();
    let deadline_misses = outcomes
        .iter()
        .filter(|o| o.deadline_met == Some(false))
        .count();
    let deadline_miss_rate = if deadline_total > 0 {
        deadline_misses as f64 / deadline_total as f64
    } else {
        0.0
    };
    let mut prios: Vec<u32> = outcomes.iter().map(|o| o.priority).collect();
    prios.sort_unstable();
    prios.dedup();
    let per_priority_p99 = prios
        .into_iter()
        .map(|p| {
            let lats: Vec<f64> = outcomes
                .iter()
                .filter(|o| o.priority == p)
                .map(|o| o.latency)
                .collect();
            (p, percentile(&lats, 0.99))
        })
        .collect();
    (deadline_total, deadline_misses, deadline_miss_rate, per_priority_p99)
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn build_report(
    mode: &'static str,
    policy: &str,
    outcomes: Vec<RequestOutcome>,
    rejected: Vec<(usize, String)>,
    laxity_rejections: usize,
    makespan: f64,
    device_util: Vec<f64>,
    preemptions: usize,
) -> ServeReport {
    let latencies: Vec<f64> = outcomes.iter().map(|o| o.latency).collect();
    let throughput_rps = if makespan > 0.0 {
        outcomes.len() as f64 / makespan
    } else {
        0.0
    };
    let (deadline_total, deadline_misses, deadline_miss_rate, per_priority_p99) =
        deadline_stats(&outcomes);
    ServeReport {
        policy: policy.to_string(),
        mode,
        outcomes,
        rejected,
        makespan,
        throughput_rps,
        p50_latency: percentile(&latencies, 0.50),
        p99_latency: percentile(&latencies, 0.99),
        deadline_total,
        deadline_misses,
        deadline_miss_rate,
        per_priority_p99,
        preemptions,
        device_util,
        pacing: "virtual",
        laxity_rejections,
        exec_cache_hits: 0,
        exec_cache_misses: 0,
        cold_batch_latency: 0.0,
        warm_batch_latency: 0.0,
    }
}

/// Serve the request stream **concurrently**: admit, batch, merge every
/// admitted app into one multi-tenant application, and run it through
/// [`simulate_served`] — per-component release times plus absolute
/// deadlines and priorities ([`CompMeta`]), so deadline-aware policies
/// (`edf`) can order and preempt across requests. Requests share devices
/// (up to `cfg.tenancy` residents each) under `policy`.
pub fn serve_sim(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let (admitted, apps, rejected, laxity_rejections) =
        admit_all(requests, platform, cost, cfg.laxity_admission);
    if admitted.is_empty() {
        return Ok(build_report(
            "concurrent",
            policy.name(),
            Vec::new(),
            rejected,
            laxity_rejections,
            0.0,
            vec![0.0; platform.devices.len()],
            0,
        ));
    }
    let batches = batch_requests(&admitted, cfg.batch_window);
    let merged = merge_apps(&apps)?;
    let mut meta = vec![CompMeta::default(); merged.partition.components.len()];
    for b in &batches {
        for &m in &b.members {
            for c in merged.component_ranges[m].clone() {
                meta[c].release = b.release;
            }
        }
    }
    // Deadlines are absolute (arrival + budget) so EDF compares requests on
    // one clock; priorities ride along per component.
    for (i, req) in admitted.iter().enumerate() {
        for c in merged.component_ranges[i].clone() {
            meta[c].deadline = req.deadline.map(|d| req.arrival + d).unwrap_or(f64::INFINITY);
            meta[c].priority = req.priority;
        }
    }
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = cfg.tenancy.max(1);
    let sim = simulate_served(
        &merged.dag,
        &merged.partition,
        platform,
        cost,
        policy,
        &sim_cfg,
        &meta,
    )?;

    let outcomes = admitted
        .iter()
        .enumerate()
        .map(|(i, req)| {
            let range = merged.component_ranges[i].clone();
            let release = meta[range.start].release;
            let finish = range
                .map(|c| sim.component_finish[c])
                .fold(0.0f64, f64::max);
            request_outcome(req, release, finish, Pacing::Open)
        })
        .collect();

    let makespan = sim.makespan;
    let device_util = (0..platform.devices.len())
        .map(|d| {
            let busy = sim
                .trace
                .busy_time(|l| matches!(l, Lane::Device { dev, .. } if *dev == d));
            if makespan > 0.0 {
                busy / makespan
            } else {
                0.0
            }
        })
        .collect();
    Ok(build_report(
        "concurrent",
        &sim.policy,
        outcomes,
        rejected,
        laxity_rejections,
        makespan,
        device_util,
        sim.preemptions,
    ))
}

/// The baseline: replay the same stream **sequentially** — each admitted
/// request runs through the single-shot [`simulate`] in arrival order, one
/// at a time, exactly as the paper's single-application flow would serve a
/// queue of users.
pub fn serve_sequential(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let (admitted, apps, rejected, laxity_rejections) =
        admit_all(requests, platform, cost, cfg.laxity_admission);
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = 1;
    let mut clock = 0.0f64;
    let mut busy = vec![0.0f64; platform.devices.len()];
    let mut outcomes = Vec::with_capacity(admitted.len());
    for (req, (dag, part)) in admitted.iter().zip(&apps) {
        let r = simulate(dag, part, platform, cost, policy, &sim_cfg)?;
        let start = clock.max(req.arrival);
        let finish = start + r.makespan;
        clock = finish;
        for (d, b) in busy.iter_mut().enumerate() {
            *b += r
                .trace
                .busy_time(|l| matches!(l, Lane::Device { dev, .. } if *dev == d));
        }
        outcomes.push(request_outcome(req, start, finish, Pacing::Open));
    }
    let device_util = busy
        .into_iter()
        .map(|b| if clock > 0.0 { b / clock } else { 0.0 })
        .collect();
    Ok(build_report(
        "sequential",
        policy.name(),
        outcomes,
        rejected,
        laxity_rejections,
        clock,
        device_util,
        0,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::sched::Clustering;
    use crate::serve::request::Workload;

    #[test]
    fn empty_stream_serves_trivially() {
        let platform = Platform::paper_testbed(3, 1);
        let r = serve_sim(
            &[],
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
        )
        .unwrap();
        assert_eq!(r.outcomes.len(), 0);
        assert_eq!(r.makespan, 0.0);
        assert_eq!(r.throughput_rps, 0.0);
    }

    #[test]
    fn rejections_are_reported_not_fatal() {
        let platform = Platform::paper_testbed(3, 1);
        let mut bad = ServeRequest::new(7, 0.0, Workload::Head { beta: 64 });
        bad.deadline = Some(-1.0);
        let good = ServeRequest::new(8, 0.0, Workload::Head { beta: 64 });
        let r = serve_sim(
            &[bad, good],
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
        )
        .unwrap();
        assert_eq!(r.outcomes.len(), 1);
        assert_eq!(r.rejected.len(), 1);
        assert_eq!(r.rejected[0].0, 7);
        assert!(r.rejected[0].1.contains("admission"), "{}", r.rejected[0].1);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&v, 0.5), 3.0); // round(1.5) = 2 → 3.0
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn request_outcome_is_end_to_end_with_closed_loop_clamp() {
        let mut req = ServeRequest::new(1, 0.010, Workload::Head { beta: 64 });
        req.deadline = Some(0.050);
        // Normal case (release after arrival): end-to-end latency, same
        // under either pacing.
        for pacing in [Pacing::Open, Pacing::Closed] {
            let o = request_outcome(&req, 0.012, 0.040, pacing);
            assert!((o.latency - 0.030).abs() < 1e-12);
            assert_eq!(o.deadline_met, Some(true));
        }
        // Closed-loop replay outran the arrival (release < arrival): the
        // latency degenerates to service latency, never negative.
        let o = request_outcome(&req, 0.000, 0.008, Pacing::Closed);
        assert!((o.latency - 0.008).abs() < 1e-12);
        assert_eq!(o.deadline_met, Some(true));
        // Open pacing has no clamp: release >= arrival holds by
        // construction (the loop slept), so latency is always measured
        // against the nominal arrival instant.
        let o = request_outcome(&req, 0.015, 0.040, Pacing::Open);
        assert!((o.latency - 0.030).abs() < 1e-12);
        // No deadline → None.
        req.deadline = None;
        assert_eq!(
            request_outcome(&req, 0.012, 0.040, Pacing::Open).deadline_met,
            None
        );
    }

    #[test]
    fn negative_laxity_arrivals_are_rejected_and_counted() {
        let platform = Platform::paper_testbed(3, 1);
        let mut tight = ServeRequest::new(0, 0.0, Workload::Head { beta: 64 });
        tight.deadline = Some(1e-9); // below any solo estimate
        let ok = ServeRequest::new(1, 0.0, Workload::Head { beta: 64 });
        let cfg = ServeConfig::default();
        let r = serve_sim(
            &[tight.clone(), ok.clone()],
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
        )
        .unwrap();
        assert_eq!(r.outcomes.len(), 1);
        assert_eq!(r.rejected.len(), 1);
        assert_eq!(r.rejected[0].0, 0);
        assert!(r.rejected[0].1.contains("negative laxity"), "{}", r.rejected[0].1);
        assert_eq!(r.laxity_rejections, 1);
        // With admission control off the request is admitted and its miss
        // is counted instead.
        let off = ServeConfig {
            laxity_admission: false,
            ..ServeConfig::default()
        };
        let r = serve_sim(&[tight, ok], &platform, &PaperCost, &mut Clustering, &off).unwrap();
        assert_eq!(r.outcomes.len(), 2);
        assert_eq!(r.laxity_rejections, 0);
        assert_eq!(r.deadline_misses, 1);
    }

    #[test]
    fn deadline_stats_aggregate_misses_and_priorities() {
        let mk = |met: Option<bool>, priority: u32, latency: f64| RequestOutcome {
            id: 0,
            arrival: 0.0,
            release: 0.0,
            finish: latency,
            latency,
            deadline_met: met,
            priority,
        };
        let outcomes = vec![
            mk(Some(true), 0, 0.010),
            mk(Some(false), 0, 0.030),
            mk(None, 1, 0.005),
            mk(Some(false), 1, 0.040),
        ];
        let (total, misses, rate, per_prio) = deadline_stats(&outcomes);
        assert_eq!(total, 3);
        assert_eq!(misses, 2);
        assert!((rate - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(per_prio.len(), 2);
        assert_eq!(per_prio[0].0, 0);
        assert!((per_prio[0].1 - 0.030).abs() < 1e-12);
        assert_eq!(per_prio[1].0, 1);
        assert!((per_prio[1].1 - 0.040).abs() < 1e-12);
        assert_eq!(deadline_stats(&[]).2, 0.0);
    }
}
