//! The serving engine: concurrent multi-DAG scheduling over the simulator,
//! plus the sequential-replay baseline every serving run is judged against.

use super::admission::{admit, batch_requests};
use super::merge::merge_apps;
use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::Result;
use crate::graph::{Dag, Partition};
use crate::json::Json;
use crate::platform::Platform;
use crate::sched::Policy;
use crate::sim::{simulate, simulate_released, SimConfig};
use crate::trace::Lane;

/// Serving-layer knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Batching window: compatible requests arriving within this many
    /// seconds of a batch opener coalesce into one dispatch group.
    pub batch_window: f64,
    /// Max task components resident per device at once (multi-tenancy).
    pub tenancy: usize,
    /// Underlying simulator knobs.
    pub sim: SimConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch_window: 2e-3,
            tenancy: 4,
            sim: SimConfig::default(),
        }
    }
}

/// Per-request accounting.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: usize,
    pub arrival: f64,
    /// Instant the request's components became dispatchable (batch release
    /// in concurrent mode; service start in sequential replay).
    pub release: f64,
    /// Instant the last of its components finished.
    pub finish: f64,
    /// End-to-end latency: `finish - arrival`.
    pub latency: f64,
    /// Whether the deadline was met (requests without deadlines: `None`).
    pub deadline_met: Option<bool>,
}

/// Aggregate serving statistics for one run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub policy: String,
    /// `"concurrent"` (multi-tenant serving) or `"sequential"` (replay).
    pub mode: &'static str,
    pub outcomes: Vec<RequestOutcome>,
    /// `(request id, admission error)` per rejected request.
    pub rejected: Vec<(usize, String)>,
    /// Time from epoch to the last completion.
    pub makespan: f64,
    pub throughput_rps: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    /// Compute busy fraction per device over the makespan.
    pub device_util: Vec<f64>,
}

impl ServeReport {
    /// The BENCH_serve.json building block.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str(self.mode)),
            ("policy", Json::str(self.policy.clone())),
            ("requests", Json::num(self.outcomes.len() as f64)),
            ("rejected", Json::num(self.rejected.len() as f64)),
            ("makespan_s", Json::num(self.makespan)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("p50_latency_s", Json::num(self.p50_latency)),
            ("p99_latency_s", Json::num(self.p99_latency)),
            (
                "device_util",
                Json::Arr(self.device_util.iter().map(|&u| Json::num(u)).collect()),
            ),
        ])
    }
}

/// Nearest-rank percentile over unsorted latencies; 0 when empty.
pub fn percentile(values: &[f64], q: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let idx = ((sorted.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
    sorted[idx]
}

/// Sort by arrival, admit each request; returns (admitted requests,
/// their instantiated apps, typed rejections).
pub(crate) type Admitted = (Vec<ServeRequest>, Vec<(Dag, Partition)>, Vec<(usize, String)>);

/// Shared admission front-end for the sim and real serving paths: arrival
/// order, priority-descending tie-break, then id.
pub(crate) fn admit_all(requests: &[ServeRequest]) -> Admitted {
    let mut sorted: Vec<ServeRequest> = requests.to_vec();
    sorted.sort_by(|a, b| {
        a.arrival
            .total_cmp(&b.arrival)
            .then_with(|| b.priority.cmp(&a.priority))
            .then_with(|| a.id.cmp(&b.id))
    });
    let mut admitted = Vec::new();
    let mut apps = Vec::new();
    let mut rejected = Vec::new();
    for req in sorted {
        match admit(&req) {
            Ok(app) => {
                admitted.push(req);
                apps.push(app);
            }
            Err(e) => rejected.push((req.id, e.to_string())),
        }
    }
    (admitted, apps, rejected)
}

fn build_report(
    mode: &'static str,
    policy: &str,
    outcomes: Vec<RequestOutcome>,
    rejected: Vec<(usize, String)>,
    makespan: f64,
    device_util: Vec<f64>,
) -> ServeReport {
    let latencies: Vec<f64> = outcomes.iter().map(|o| o.latency).collect();
    let throughput_rps = if makespan > 0.0 {
        outcomes.len() as f64 / makespan
    } else {
        0.0
    };
    ServeReport {
        policy: policy.to_string(),
        mode,
        outcomes,
        rejected,
        makespan,
        throughput_rps,
        p50_latency: percentile(&latencies, 0.50),
        p99_latency: percentile(&latencies, 0.99),
        device_util,
    }
}

/// Serve the request stream **concurrently**: admit, batch, merge every
/// admitted app into one multi-tenant application, and run it through
/// [`simulate_released`] with per-component release times — requests share
/// devices (up to `cfg.tenancy` residents each) under `policy`.
pub fn serve_sim(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let (admitted, apps, rejected) = admit_all(requests);
    if admitted.is_empty() {
        return Ok(build_report(
            "concurrent",
            policy.name(),
            Vec::new(),
            rejected,
            0.0,
            vec![0.0; platform.devices.len()],
        ));
    }
    let batches = batch_requests(&admitted, cfg.batch_window);
    let merged = merge_apps(&apps)?;
    let mut releases = vec![0.0; merged.partition.components.len()];
    for b in &batches {
        for &m in &b.members {
            for c in merged.component_ranges[m].clone() {
                releases[c] = b.release;
            }
        }
    }
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = cfg.tenancy.max(1);
    let sim = simulate_released(
        &merged.dag,
        &merged.partition,
        platform,
        cost,
        policy,
        &sim_cfg,
        &releases,
    )?;

    let outcomes = admitted
        .iter()
        .enumerate()
        .map(|(i, req)| {
            let range = merged.component_ranges[i].clone();
            let release = releases[range.start];
            let finish = range
                .map(|c| sim.component_finish[c])
                .fold(0.0f64, f64::max);
            let latency = finish - req.arrival;
            RequestOutcome {
                id: req.id,
                arrival: req.arrival,
                release,
                finish,
                latency,
                deadline_met: req.deadline.map(|d| latency <= d),
            }
        })
        .collect();

    let makespan = sim.makespan;
    let device_util = (0..platform.devices.len())
        .map(|d| {
            let busy = sim
                .trace
                .busy_time(|l| matches!(l, Lane::Device { dev, .. } if *dev == d));
            if makespan > 0.0 {
                busy / makespan
            } else {
                0.0
            }
        })
        .collect();
    Ok(build_report(
        "concurrent",
        &sim.policy,
        outcomes,
        rejected,
        makespan,
        device_util,
    ))
}

/// The baseline: replay the same stream **sequentially** — each admitted
/// request runs through the single-shot [`simulate`] in arrival order, one
/// at a time, exactly as the paper's single-application flow would serve a
/// queue of users.
pub fn serve_sequential(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let (admitted, apps, rejected) = admit_all(requests);
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = 1;
    let mut clock = 0.0f64;
    let mut busy = vec![0.0f64; platform.devices.len()];
    let mut outcomes = Vec::with_capacity(admitted.len());
    for (req, (dag, part)) in admitted.iter().zip(&apps) {
        let r = simulate(dag, part, platform, cost, policy, &sim_cfg)?;
        let start = clock.max(req.arrival);
        let finish = start + r.makespan;
        clock = finish;
        for (d, b) in busy.iter_mut().enumerate() {
            *b += r
                .trace
                .busy_time(|l| matches!(l, Lane::Device { dev, .. } if *dev == d));
        }
        let latency = finish - req.arrival;
        outcomes.push(RequestOutcome {
            id: req.id,
            arrival: req.arrival,
            release: start,
            finish,
            latency,
            deadline_met: req.deadline.map(|d| latency <= d),
        });
    }
    let device_util = busy
        .into_iter()
        .map(|b| if clock > 0.0 { b / clock } else { 0.0 })
        .collect();
    Ok(build_report(
        "sequential",
        policy.name(),
        outcomes,
        rejected,
        clock,
        device_util,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::sched::Clustering;
    use crate::serve::request::Workload;

    #[test]
    fn empty_stream_serves_trivially() {
        let platform = Platform::paper_testbed(3, 1);
        let r = serve_sim(
            &[],
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
        )
        .unwrap();
        assert_eq!(r.outcomes.len(), 0);
        assert_eq!(r.makespan, 0.0);
        assert_eq!(r.throughput_rps, 0.0);
    }

    #[test]
    fn rejections_are_reported_not_fatal() {
        let platform = Platform::paper_testbed(3, 1);
        let mut bad = ServeRequest::new(7, 0.0, Workload::Head { beta: 64 });
        bad.deadline = Some(-1.0);
        let good = ServeRequest::new(8, 0.0, Workload::Head { beta: 64 });
        let r = serve_sim(
            &[bad, good],
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
        )
        .unwrap();
        assert_eq!(r.outcomes.len(), 1);
        assert_eq!(r.rejected.len(), 1);
        assert_eq!(r.rejected[0].0, 7);
        assert!(r.rejected[0].1.contains("admission"), "{}", r.rejected[0].1);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&v, 0.5), 3.0); // round(1.5) = 2 → 3.0
        assert_eq!(percentile(&[], 0.5), 0.0);
    }
}
