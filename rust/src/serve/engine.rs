//! The serving engine: batch-mode entry points, shared report/outcome
//! vocabulary, and the sequential-replay baseline every serving run is
//! judged against.
//!
//! §Perf (PR 4): applications come from the [`TemplateCache`] (one
//! instantiate + validate per cacheable signature) and admission sorts an
//! index permutation instead of cloning the request vector. Report
//! percentiles sort each latency vector once and take nearest-rank cuts
//! from the shared sorted buffer.
//!
//! §Refactor (PR 7): [`serve_sim_cached`] is no longer a monolith — it is
//! a thin wrapper that sorts the request vector into admission order and
//! drives the unified serve core ([`super::core::serve_core`]) at
//! `window: 0` over the simulator backend. The frozen pre-refactor
//! pipeline lives in `serve::reference`, which enforces bit-equality
//! against this wrapper.

use super::admission::AdmissionGate;
use super::cache::TemplateCache;
use super::core::{CollectSink, StreamReport, StreamingConfig};
use super::request::ServeRequest;
use super::streaming::run_sim_core;
use crate::cost::CostModel;
use crate::error::Result;
use crate::graph::{Dag, Partition};
use crate::json::Json;
use crate::platform::Platform;
use crate::sched::Policy;
use crate::sim::{simulate, SimConfig};
use crate::trace::Lane;
use std::sync::Arc;

/// Arrival pacing of the real serving loop.
///
/// * `Closed` — replay: the loop dispatches each batch as soon as the
///   previous one completes, so wall-clock dispatch can outrun the nominal
///   arrival process and latency degenerates to service latency
///   ([`request_outcome`] documents the clamp).
/// * `Open` — open-loop: the loop **sleeps until each batch's nominal
///   release instant** before dispatching, so measured latencies are
///   genuinely end-to-end against the arrival process — the only numbers a
///   deadline/SLO evaluation can trust (Clipper/Clockwork-style serving
///   methodology).
///
/// The simulated paths are inherently open-loop (virtual time honours
/// release instants by construction), so this knob only changes
/// [`super::serve_real`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Pacing {
    /// Closed-loop replay (dispatch as fast as batches complete).
    #[default]
    Closed,
    /// Open-loop (sleep until each batch's nominal release instant).
    Open,
}

impl Pacing {
    /// Report/CLI spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Pacing::Closed => "closed",
            Pacing::Open => "open",
        }
    }
}

/// Serving-layer knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Batching window: compatible requests arriving within this many
    /// seconds of a batch opener coalesce into one dispatch group.
    pub batch_window: f64,
    /// Max task components resident per device at once (multi-tenancy).
    pub tenancy: usize,
    /// Arrival pacing of the real serving loop (sim paths ignore this —
    /// virtual time is always open-loop).
    pub pacing: Pacing,
    /// Laxity-based admission control: reject deadline-carrying requests
    /// whose laxity is already negative at arrival
    /// ([`super::admission::admit_slo`]). On by default; turn off to let
    /// unmeetable requests through and count their misses instead.
    pub laxity_admission: bool,
    /// Real path only: eagerly compile every AOT artifact before the epoch
    /// (Clockwork-style), moving executable lowering off the request path.
    /// Leave off to measure cold-vs-warm batch latency.
    pub prewarm: bool,
    /// Underlying simulator knobs.
    pub sim: SimConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch_window: 2e-3,
            tenancy: 4,
            pacing: Pacing::Closed,
            laxity_admission: true,
            prewarm: false,
            sim: SimConfig::default(),
        }
    }
}

/// Per-request accounting.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: usize,
    pub arrival: f64,
    /// Instant the request's components became dispatchable (batch release
    /// in concurrent mode; service start in sequential replay).
    pub release: f64,
    /// Instant the last of its components finished.
    pub finish: f64,
    /// End-to-end latency (see [`request_outcome`] for the exact
    /// semantics, shared by every serving path).
    pub latency: f64,
    /// Whether the deadline was met (requests without deadlines: `None`).
    pub deadline_met: Option<bool>,
    /// The request's priority (carried through for per-priority tails).
    pub priority: u32,
}

/// The single place where latency and deadline semantics are defined, used
/// by the sim, sequential, and real serving paths alike.
///
/// Latency is **end-to-end**: `finish - arrival`, and a deadline of `d`
/// seconds is met iff `finish - arrival <= d`. Under [`Pacing::Open`] that
/// is the whole story: the serving loop slept until each batch's nominal
/// release instant, so `release >= arrival` holds by construction and every
/// latency is measured against the arrival process. The sim and sequential
/// paths guarantee the same invariant in virtual time and also pass
/// `Open`.
///
/// One caveat remains, now confined to [`Pacing::Closed`]: a closed-loop
/// replay never sleeps waiting for an arrival, so wall-clock dispatch can
/// outrun the nominal arrival process. When a batch starts before a
/// member's arrival instant (`release < arrival`), `finish - arrival` would
/// under-state the work done; the latency therefore degenerates to service
/// latency (`finish - release`) exactly in that case, via `max`.
pub fn request_outcome(
    req: &ServeRequest,
    release: f64,
    finish: f64,
    pacing: Pacing,
) -> RequestOutcome {
    outcome_fields(
        req.id,
        req.arrival,
        req.deadline,
        req.priority,
        release,
        finish,
        pacing,
    )
}

/// [`request_outcome`] from bare fields, for paths that no longer hold the
/// `ServeRequest` when a request finishes (the streaming server retires
/// request records at batch close and carries only these scalars).
#[allow(clippy::too_many_arguments)]
pub(crate) fn outcome_fields(
    id: usize,
    arrival: f64,
    deadline: Option<f64>,
    priority: u32,
    release: f64,
    finish: f64,
    pacing: Pacing,
) -> RequestOutcome {
    let latency = match pacing {
        Pacing::Open => finish - arrival,
        Pacing::Closed => (finish - arrival).max(finish - release),
    };
    RequestOutcome {
        id,
        arrival,
        release,
        finish,
        latency,
        deadline_met: deadline.map(|d| latency <= d),
        priority,
    }
}

/// Aggregate serving statistics for one run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub policy: String,
    /// `"concurrent"` (multi-tenant serving) or `"sequential"` (replay).
    pub mode: &'static str,
    pub outcomes: Vec<RequestOutcome>,
    /// `(request id, admission error)` per rejected request.
    pub rejected: Vec<(usize, String)>,
    /// Time from epoch to the last completion.
    pub makespan: f64,
    pub throughput_rps: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    /// Requests that carried a deadline.
    pub deadline_total: usize,
    /// ... of which this many missed it.
    pub deadline_misses: usize,
    /// `deadline_misses / deadline_total` (0 when no request has one).
    pub deadline_miss_rate: f64,
    /// p99 latency per distinct request priority, ascending priority.
    pub per_priority_p99: Vec<(u32, f64)>,
    /// Resident components displaced mid-flight (EDF preemption; 0 for
    /// deadline-blind policies and the sequential/real paths).
    pub preemptions: usize,
    /// Compute busy fraction per device over the makespan.
    pub device_util: Vec<f64>,
    /// Arrival pacing the run used: `"open"`, `"closed"`, or `"virtual"`
    /// (simulated paths — virtual time is always open-loop).
    pub pacing: &'static str,
    /// ... of the rejections, how many were laxity-based admission-control
    /// rejections (deadline budget below the solo estimate at arrival).
    pub laxity_rejections: usize,
    /// Real path: PJRT executable-cache hits over the run (0 in sim),
    /// counted per kernel execution — kernels sharing an artifact hit
    /// within a single batch too, so treat this as a sanity floor. The
    /// cross-batch-reuse guarantee is the *miss* count staying at one per
    /// distinct artifact for the whole run.
    pub exec_cache_hits: usize,
    /// Real path: executables actually lowered + compiled (one per
    /// distinct artifact when the cache works; growth per batch means
    /// recompilation regressed).
    pub exec_cache_misses: usize,
    /// Real path: mean service latency of *cold* batches — batches that
    /// actually lowered at least one executable (nonzero per-batch
    /// cache-miss delta); typically the first batch of each signature on a
    /// fresh runtime. 0 when the run had none (prewarmed runtime, sim).
    pub cold_batch_latency: f64,
    /// Real path: mean service latency of *warm* batches — served entirely
    /// from the executable cache (0 when none).
    pub warm_batch_latency: f64,
    /// Merged-template cache hits over the run: batches instantiated from
    /// a pre-merged (signature, batch-size) block instead of deep-cloning
    /// every member app through `merge_apps` ([`TemplateCache`] — the
    /// sim-side analog of the executable cache).
    pub template_cache_hits: usize,
    /// Merged-template blocks actually built (one per distinct
    /// (signature, batch-size) shape when the cache works).
    pub template_cache_misses: usize,
}

impl ServeReport {
    /// The BENCH_serve.json building block.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str(self.mode)),
            ("policy", Json::str(self.policy.clone())),
            ("requests", Json::num(self.outcomes.len() as f64)),
            ("rejected", Json::num(self.rejected.len() as f64)),
            ("makespan_s", Json::num(self.makespan)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("p50_latency_s", Json::num(self.p50_latency)),
            ("p99_latency_s", Json::num(self.p99_latency)),
            ("deadline_total", Json::num(self.deadline_total as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("deadline_miss_rate", Json::num(self.deadline_miss_rate)),
            (
                "per_priority_p99_s",
                Json::Arr(
                    self.per_priority_p99
                        .iter()
                        .map(|&(p, l)| {
                            Json::obj(vec![
                                ("priority", Json::num(p as f64)),
                                ("p99_latency_s", Json::num(l)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("preemptions", Json::num(self.preemptions as f64)),
            (
                "device_util",
                Json::Arr(self.device_util.iter().map(|&u| Json::num(u)).collect()),
            ),
            ("pacing", Json::str(self.pacing)),
            ("laxity_rejections", Json::num(self.laxity_rejections as f64)),
            ("exec_cache_hits", Json::num(self.exec_cache_hits as f64)),
            ("exec_cache_misses", Json::num(self.exec_cache_misses as f64)),
            ("cold_batch_latency_s", Json::num(self.cold_batch_latency)),
            ("warm_batch_latency_s", Json::num(self.warm_batch_latency)),
            (
                "template_cache_hits",
                Json::num(self.template_cache_hits as f64),
            ),
            (
                "template_cache_misses",
                Json::num(self.template_cache_misses as f64),
            ),
        ])
    }
}

/// Nearest-rank percentile over unsorted values; 0 when empty. Clones and
/// sorts per call — when cutting several ranks from one vector (every
/// report does), sort once and use [`percentile_sorted`].
pub fn percentile(values: &[f64], q: f64) -> f64 {
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&sorted, q)
}

/// Nearest-rank percentile over an **ascending-sorted** slice; 0 when
/// empty. The shared-sorted-buffer fast path behind [`percentile`].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
    sorted[idx]
}

/// Sort by arrival, admit each request; returns (admitted requests, their
/// shared application templates, typed rejections, laxity-rejection count).
pub(crate) type Admitted = (
    Vec<ServeRequest>,
    Vec<Arc<(Dag, Partition)>>,
    Vec<(usize, String)>,
    usize,
);

/// The admission sort as an **index permutation**: arrival order,
/// priority-descending tie-break, then id. This is the order every batch
/// entry point feeds the serve core (the former `requests.to_vec()`
/// deep-cloned every request, workload payload included, just to sort).
pub(crate) fn admission_order(requests: &[ServeRequest]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..requests.len()).collect();
    order.sort_by(|&a, &b| {
        requests[a]
            .arrival
            .total_cmp(&requests[b].arrival)
            .then_with(|| requests[b].priority.cmp(&requests[a].priority))
            .then_with(|| requests[a].id.cmp(&requests[b].id))
    });
    order
}

/// Shared admission front-end for the batch serving paths: sort via
/// [`admission_order`], admit each request's application through the
/// template cache, laxity-gate deadline-carrying requests through the
/// memoized [`AdmissionGate`] — the same per-request pipeline the serve
/// core applies incrementally.
pub(crate) fn admit_all(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    laxity_admission: bool,
    cache: &mut TemplateCache,
) -> Admitted {
    let mut admitted = Vec::new();
    let mut apps = Vec::new();
    let mut rejected = Vec::new();
    let mut laxity_rejections = 0usize;
    let mut gate = AdmissionGate::new(laxity_admission);
    for &ri in &admission_order(requests) {
        let req = &requests[ri];
        match cache.admit_app(req) {
            Ok(app) => {
                if let Err(e) = gate.check(req, app.as_ref(), platform, cost) {
                    laxity_rejections += 1;
                    rejected.push((req.id, e.to_string()));
                    continue;
                }
                admitted.push(req.clone());
                apps.push(app);
            }
            Err(e) => rejected.push((req.id, e.to_string())),
        }
    }
    (admitted, apps, rejected, laxity_rejections)
}

/// Deadline-miss and per-priority tail statistics over a set of outcomes.
/// One sort of (priority, latency) pairs: each priority class becomes a
/// contiguous latency-ascending slice, and every p99 is a nearest-rank cut
/// from that shared sorted buffer (the former shape re-collected and
/// re-sorted per class via [`percentile`]).
pub(crate) fn deadline_stats(outcomes: &[RequestOutcome]) -> (usize, usize, f64, Vec<(u32, f64)>) {
    let deadline_total = outcomes.iter().filter(|o| o.deadline_met.is_some()).count();
    let deadline_misses = outcomes
        .iter()
        .filter(|o| o.deadline_met == Some(false))
        .count();
    let deadline_miss_rate = if deadline_total > 0 {
        deadline_misses as f64 / deadline_total as f64
    } else {
        0.0
    };
    let mut pairs: Vec<(u32, f64)> = outcomes.iter().map(|o| (o.priority, o.latency)).collect();
    pairs.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.total_cmp(&b.1)));
    let mut per_priority_p99 = Vec::new();
    let mut start = 0usize;
    while start < pairs.len() {
        let p = pairs[start].0;
        let end = start + pairs[start..].partition_point(|&(q, _)| q == p);
        let group = &pairs[start..end];
        let idx = ((group.len() as f64 - 1.0) * 0.99).round() as usize;
        per_priority_p99.push((p, group[idx].1));
        start = end;
    }
    (deadline_total, deadline_misses, deadline_miss_rate, per_priority_p99)
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn build_report(
    mode: &'static str,
    policy: &str,
    outcomes: Vec<RequestOutcome>,
    rejected: Vec<(usize, String)>,
    laxity_rejections: usize,
    makespan: f64,
    device_util: Vec<f64>,
    preemptions: usize,
) -> ServeReport {
    // One sort; p50 and p99 are nearest-rank cuts from the same buffer.
    let mut latencies: Vec<f64> = outcomes.iter().map(|o| o.latency).collect();
    latencies.sort_by(|a, b| a.total_cmp(b));
    let throughput_rps = if makespan > 0.0 {
        outcomes.len() as f64 / makespan
    } else {
        0.0
    };
    let (deadline_total, deadline_misses, deadline_miss_rate, per_priority_p99) =
        deadline_stats(&outcomes);
    ServeReport {
        policy: policy.to_string(),
        mode,
        outcomes,
        rejected,
        makespan,
        throughput_rps,
        p50_latency: percentile_sorted(&latencies, 0.50),
        p99_latency: percentile_sorted(&latencies, 0.99),
        deadline_total,
        deadline_misses,
        deadline_miss_rate,
        per_priority_p99,
        preemptions,
        device_util,
        pacing: "virtual",
        laxity_rejections,
        exec_cache_hits: 0,
        exec_cache_misses: 0,
        cold_batch_latency: 0.0,
        warm_batch_latency: 0.0,
        template_cache_hits: 0,
        template_cache_misses: 0,
    }
}

/// Serve the request stream **concurrently**: admit, batch, merge every
/// admitted app into one multi-tenant application, and run it through
/// [`simulate_served`] — per-component release times plus absolute
/// deadlines and priorities ([`CompMeta`]), so deadline-aware policies
/// (`edf`) can order and preempt across requests. Requests share devices
/// (up to `cfg.tenancy` residents each) under `policy`.
///
/// Uses a fresh per-run [`TemplateCache`]; hold one across runs via
/// [`serve_sim_cached`] for cross-stream template reuse.
pub fn serve_sim(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let mut cache = TemplateCache::new();
    serve_sim_cached(requests, platform, cost, policy, cfg, &mut cache)
}

/// [`serve_sim`] with a caller-held [`TemplateCache`] — since PR 7 a thin
/// wrapper over the unified serve core: sort the request vector into
/// admission order, run [`super::core::serve_core`] at `window: 0`
/// (everything admitted up front, as the monolith did) over the simulator
/// backend, and re-sort the completion-ordered outcomes back into
/// admission order for the classic batch report. Bit-equality with the
/// frozen pre-refactor pipeline is enforced by `serve::reference`.
pub fn serve_sim_cached(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
    cache: &mut TemplateCache,
) -> Result<ServeReport> {
    let policy_name = policy.name().to_string();
    let order = admission_order(requests);
    let scfg = StreamingConfig {
        window: 0,
        batch_window: cfg.batch_window,
        tenancy: cfg.tenancy,
        laxity_admission: cfg.laxity_admission,
        sim: cfg.sim.clone(),
        faults: None,
    };
    let mut sink = CollectSink::default();
    // Uncapped rejection sample: the batch report carries the full list.
    let sreport = run_sim_core(
        order.iter().map(|&i| requests[i].clone()),
        platform,
        cost,
        policy,
        &scfg,
        cache,
        &mut sink,
        usize::MAX,
    )?;
    // The sink emits in completion order; the batch report has always been
    // in admission order. The admission key is unique per request (id
    // breaks every tie), so this re-sort reproduces it exactly.
    let mut outcomes = sink.outcomes;
    outcomes.sort_by(|a, b| {
        a.arrival
            .total_cmp(&b.arrival)
            .then_with(|| b.priority.cmp(&a.priority))
            .then_with(|| a.id.cmp(&b.id))
    });
    let StreamReport {
        rejected_sample,
        laxity_rejections,
        makespan,
        device_util,
        preemptions,
        template_cache_hits,
        template_cache_misses,
        ..
    } = sreport;
    let mut report = build_report(
        "concurrent",
        &policy_name,
        outcomes,
        rejected_sample,
        laxity_rejections,
        makespan,
        device_util,
        preemptions,
    );
    report.template_cache_hits = template_cache_hits;
    report.template_cache_misses = template_cache_misses;
    Ok(report)
}

/// The baseline: replay the same stream **sequentially** — each admitted
/// request runs through the single-shot [`simulate`] in arrival order, one
/// at a time, exactly as the paper's single-application flow would serve a
/// queue of users.
pub fn serve_sequential(
    requests: &[ServeRequest],
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let mut cache = TemplateCache::new();
    let (admitted, apps, rejected, laxity_rejections) =
        admit_all(requests, platform, cost, cfg.laxity_admission, &mut cache);
    let mut sim_cfg = cfg.sim.clone();
    sim_cfg.max_tenants = 1;
    let mut clock = 0.0f64;
    let mut busy = vec![0.0f64; platform.devices.len()];
    let mut outcomes = Vec::with_capacity(admitted.len());
    for (req, app) in admitted.iter().zip(&apps) {
        let (dag, part) = app.as_ref();
        let r = simulate(dag, part, platform, cost, policy, &sim_cfg)?;
        let start = clock.max(req.arrival);
        let finish = start + r.makespan;
        clock = finish;
        for (d, b) in busy.iter_mut().enumerate() {
            *b += r
                .trace
                .busy_time(|l| matches!(l, Lane::Device { dev, .. } if *dev == d));
        }
        outcomes.push(request_outcome(req, start, finish, Pacing::Open));
    }
    let device_util = busy
        .into_iter()
        .map(|b| if clock > 0.0 { b / clock } else { 0.0 })
        .collect();
    Ok(build_report(
        "sequential",
        policy.name(),
        outcomes,
        rejected,
        laxity_rejections,
        clock,
        device_util,
        0,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::sched::Clustering;
    use crate::serve::request::Workload;

    #[test]
    fn empty_stream_serves_trivially() {
        let platform = Platform::paper_testbed(3, 1);
        let r = serve_sim(
            &[],
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
        )
        .unwrap();
        assert_eq!(r.outcomes.len(), 0);
        assert_eq!(r.makespan, 0.0);
        assert_eq!(r.throughput_rps, 0.0);
        assert_eq!(r.template_cache_hits, 0);
        assert_eq!(r.template_cache_misses, 0);
    }

    #[test]
    fn rejections_are_reported_not_fatal() {
        let platform = Platform::paper_testbed(3, 1);
        let mut bad = ServeRequest::new(7, 0.0, Workload::Head { beta: 64 });
        bad.deadline = Some(-1.0);
        let good = ServeRequest::new(8, 0.0, Workload::Head { beta: 64 });
        let r = serve_sim(
            &[bad, good],
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
        )
        .unwrap();
        assert_eq!(r.outcomes.len(), 1);
        assert_eq!(r.rejected.len(), 1);
        assert_eq!(r.rejected[0].0, 7);
        assert!(r.rejected[0].1.contains("admission"), "{}", r.rejected[0].1);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&v, 0.5), 3.0); // round(1.5) = 2 → 3.0
        assert_eq!(percentile(&[], 0.5), 0.0);
        // The shared-sorted-buffer fast path agrees with the sorting form.
        let sorted = [1.0, 2.0, 3.0, 4.0];
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(percentile_sorted(&sorted, q), percentile(&v, q));
        }
        assert_eq!(percentile_sorted(&[], 0.5), 0.0);
    }

    #[test]
    fn request_outcome_is_end_to_end_with_closed_loop_clamp() {
        let mut req = ServeRequest::new(1, 0.010, Workload::Head { beta: 64 });
        req.deadline = Some(0.050);
        // Normal case (release after arrival): end-to-end latency, same
        // under either pacing.
        for pacing in [Pacing::Open, Pacing::Closed] {
            let o = request_outcome(&req, 0.012, 0.040, pacing);
            assert!((o.latency - 0.030).abs() < 1e-12);
            assert_eq!(o.deadline_met, Some(true));
        }
        // Closed-loop replay outran the arrival (release < arrival): the
        // latency degenerates to service latency, never negative.
        let o = request_outcome(&req, 0.000, 0.008, Pacing::Closed);
        assert!((o.latency - 0.008).abs() < 1e-12);
        assert_eq!(o.deadline_met, Some(true));
        // Open pacing has no clamp: release >= arrival holds by
        // construction (the loop slept), so latency is always measured
        // against the nominal arrival instant.
        let o = request_outcome(&req, 0.015, 0.040, Pacing::Open);
        assert!((o.latency - 0.030).abs() < 1e-12);
        // No deadline → None.
        req.deadline = None;
        assert_eq!(
            request_outcome(&req, 0.012, 0.040, Pacing::Open).deadline_met,
            None
        );
    }

    #[test]
    fn negative_laxity_arrivals_are_rejected_and_counted() {
        let platform = Platform::paper_testbed(3, 1);
        let mut tight = ServeRequest::new(0, 0.0, Workload::Head { beta: 64 });
        tight.deadline = Some(1e-9); // below any solo estimate
        let ok = ServeRequest::new(1, 0.0, Workload::Head { beta: 64 });
        let cfg = ServeConfig::default();
        let r = serve_sim(
            &[tight.clone(), ok.clone()],
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
        )
        .unwrap();
        assert_eq!(r.outcomes.len(), 1);
        assert_eq!(r.rejected.len(), 1);
        assert_eq!(r.rejected[0].0, 0);
        assert!(r.rejected[0].1.contains("negative laxity"), "{}", r.rejected[0].1);
        assert_eq!(r.laxity_rejections, 1);
        // With admission control off the request is admitted and its miss
        // is counted instead.
        let off = ServeConfig {
            laxity_admission: false,
            ..ServeConfig::default()
        };
        let r = serve_sim(&[tight, ok], &platform, &PaperCost, &mut Clustering, &off).unwrap();
        assert_eq!(r.outcomes.len(), 2);
        assert_eq!(r.laxity_rejections, 0);
        assert_eq!(r.deadline_misses, 1);
    }

    #[test]
    fn deadline_stats_aggregate_misses_and_priorities() {
        let mk = |met: Option<bool>, priority: u32, latency: f64| RequestOutcome {
            id: 0,
            arrival: 0.0,
            release: 0.0,
            finish: latency,
            latency,
            deadline_met: met,
            priority,
        };
        let outcomes = vec![
            mk(Some(true), 0, 0.010),
            mk(Some(false), 0, 0.030),
            mk(None, 1, 0.005),
            mk(Some(false), 1, 0.040),
        ];
        let (total, misses, rate, per_prio) = deadline_stats(&outcomes);
        assert_eq!(total, 3);
        assert_eq!(misses, 2);
        assert!((rate - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(per_prio.len(), 2);
        assert_eq!(per_prio[0].0, 0);
        assert!((per_prio[0].1 - 0.030).abs() < 1e-12);
        assert_eq!(per_prio[1].0, 1);
        assert!((per_prio[1].1 - 0.040).abs() < 1e-12);
        assert_eq!(deadline_stats(&[]).2, 0.0);
    }

    /// A stream whose batch shapes repeat must hit the merged-template
    /// cache, and the warm-cache run must be **bit-identical** to the cold
    /// one — memoizing a deterministic construction may never change the
    /// simulation.
    #[test]
    fn warm_template_cache_is_bit_identical_to_cold() {
        use crate::serve::arrival::poisson_arrivals;
        let platform = Platform::paper_testbed(3, 1);
        let cfg = ServeConfig::default();
        let requests: Vec<ServeRequest> = poisson_arrivals(17, 24, 3000.0)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, t)| ServeRequest::new(i, t, Workload::Head { beta: 64 }))
            .collect();
        let mut cache = TemplateCache::new();
        let cold = serve_sim_cached(
            &requests,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            &mut cache,
        )
        .unwrap();
        assert_eq!(cold.outcomes.len(), 24);
        assert!(
            cold.template_cache_misses > 0,
            "first run must build at least one block"
        );
        let warm = serve_sim_cached(
            &requests,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            &mut cache,
        )
        .unwrap();
        // Every block shape was cached by the cold run.
        assert_eq!(warm.template_cache_misses, 0, "warm run rebuilt a block");
        assert!(warm.template_cache_hits > 0);
        assert_eq!(warm.makespan.to_bits(), cold.makespan.to_bits());
        for (a, b) in warm.outcomes.iter().zip(&cold.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.latency.to_bits(), b.latency.to_bits());
            assert_eq!(a.finish.to_bits(), b.finish.to_bits());
        }
    }

    /// Repeated batch shapes within a single run surface as hits in the
    /// report (zero-window: every request is its own size-1 batch, so the
    /// first builds the block and the rest hit).
    #[test]
    fn template_cache_hits_surface_in_report() {
        let platform = Platform::paper_testbed(3, 1);
        let cfg = ServeConfig {
            batch_window: 0.0,
            ..ServeConfig::default()
        };
        let requests: Vec<ServeRequest> = (0..6)
            .map(|i| ServeRequest::new(i, i as f64 * 1e-3, Workload::Head { beta: 64 }))
            .collect();
        let r = serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
        assert_eq!(r.outcomes.len(), 6);
        assert_eq!(r.template_cache_misses, 1, "one (head_b64, 1) block");
        assert_eq!(r.template_cache_hits, 5, "five repeats of that shape");
    }

    /// Spec workloads bypass the cache (their signature is not injective)
    /// yet serve identically through the per-app append path.
    #[test]
    fn spec_workloads_serve_uncached() {
        let platform = Platform::paper_testbed(3, 1);
        let (dag, partition) = Workload::Head { beta: 64 }.instantiate().unwrap();
        let requests: Vec<ServeRequest> = (0..3)
            .map(|i| {
                ServeRequest::new(
                    i,
                    i as f64 * 1e-4,
                    Workload::Spec {
                        dag: dag.clone(),
                        partition: partition.clone(),
                    },
                )
            })
            .collect();
        let r = serve_sim(
            &requests,
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
        )
        .unwrap();
        assert_eq!(r.outcomes.len(), 3);
        assert_eq!(r.template_cache_hits, 0);
        assert_eq!(r.template_cache_misses, 0);
        assert!(r.outcomes.iter().all(|o| o.finish.is_finite()));
    }
}
