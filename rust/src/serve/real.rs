//! The real serving path: batched requests over the threaded executor.
//!
//! Each batch's apps are merged into one multi-tenant application and run
//! through [`execute_dag_multi`] — the same thread-per-queue Algorithm-1
//! machinery as single-DAG execution, with up to `cfg.tenancy` components
//! resident per device, so requests genuinely share the PJRT worker pool.
//!
//! Arrival times order and coalesce the stream (closed-loop replay): the
//! serving loop does not sleep between batches, so per-request latency here
//! is *service* latency (batch start → request completion) and the report's
//! makespan/throughput are wall-clock. Deadlines are judged on service
//! latency for the same reason.

use super::admission::batch_requests;
use super::engine::{admit_all, percentile, RequestOutcome, ServeConfig, ServeReport};
use super::merge::merge_apps;
use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::Result;
use crate::exec::execute_dag_multi;
use crate::graph::{Dag, Partition};
use crate::platform::Platform;
use crate::runtime::Runtime;
use crate::sched::Policy;
use crate::trace::Lane;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Deterministic request input data (xorshift64*), keyed by seed.
fn seeded_input(seed: u64, len: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
    (0..len)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect()
}

/// Seed every isolated input buffer of `dag` (per-request deterministic).
fn seed_isolated_inputs(dag: &Dag, seed: u64) -> HashMap<usize, Vec<f32>> {
    let mut inputs = HashMap::new();
    for b in &dag.buffers {
        let is_input = dag.kernels[b.kernel].inputs.contains(&b.id);
        if is_input && dag.buffer_pred(b.id).is_none() {
            inputs.insert(
                b.id,
                seeded_input(seed ^ (b.id as u64 + 1), (b.size_bytes / 4) as usize),
            );
        }
    }
    inputs
}

/// Serve the stream for real. Requires every kernel of every admitted
/// workload to carry an AOT artifact (generator workloads do at the AOT β
/// sizes); missing artifacts reject the batch with a typed executor error.
pub fn serve_real(
    requests: &[ServeRequest],
    runtime: &Arc<Runtime>,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
    seed: u64,
) -> Result<ServeReport> {
    // Admission: same rules and ordering as the sim path.
    let (admitted, apps, rejected): (Vec<ServeRequest>, Vec<(Dag, Partition)>, _) =
        admit_all(requests);

    let batches = batch_requests(&admitted, cfg.batch_window);
    let epoch = Instant::now();
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(admitted.len());
    let mut busy = vec![0.0f64; platform.devices.len()];
    for batch in &batches {
        let members: Vec<(Dag, Partition)> =
            batch.members.iter().map(|&m| apps[m].clone()).collect();
        let merged = merge_apps(&members)?;
        let inputs = seed_isolated_inputs(&merged.dag, seed);
        let start = epoch.elapsed().as_secs_f64();
        let report = execute_dag_multi(
            &merged.dag,
            &merged.partition,
            platform,
            cost,
            policy,
            runtime,
            &inputs,
            cfg.tenancy.max(1),
        )?;
        let finish = epoch.elapsed().as_secs_f64();
        for (d, b) in busy.iter_mut().enumerate() {
            *b += report
                .trace
                .busy_time(|l| matches!(l, Lane::Device { dev, .. } if *dev == d));
        }
        for &m in &batch.members {
            let req = &admitted[m];
            let latency = finish - start;
            outcomes.push(RequestOutcome {
                id: req.id,
                arrival: req.arrival,
                release: start,
                finish,
                latency,
                deadline_met: req.deadline.map(|d| latency <= d),
            });
        }
    }

    let makespan = epoch.elapsed().as_secs_f64();
    let latencies: Vec<f64> = outcomes.iter().map(|o| o.latency).collect();
    let throughput_rps = if makespan > 0.0 {
        outcomes.len() as f64 / makespan
    } else {
        0.0
    };
    Ok(ServeReport {
        policy: policy.name().to_string(),
        mode: "real",
        outcomes,
        rejected,
        makespan,
        throughput_rps,
        p50_latency: percentile(&latencies, 0.50),
        p99_latency: percentile(&latencies, 0.99),
        device_util: busy
            .into_iter()
            .map(|b| if makespan > 0.0 { b / makespan } else { 0.0 })
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::sched::Clustering;
    use crate::serve::request::Workload;
    use std::path::Path;

    #[test]
    fn serves_for_real_when_artifacts_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let Ok(rt) = Runtime::new(&dir) else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        let rt = Arc::new(rt);
        let platform = Platform::paper_testbed(3, 1);
        let requests: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest::new(i, 0.0, Workload::Head { beta: 32 }))
            .collect();
        let report = serve_real(
            &requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
            7,
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 4);
        assert!(report.makespan > 0.0);
        assert!(report.throughput_rps > 0.0);
    }

    #[test]
    fn seeded_inputs_are_deterministic() {
        let (dag, _) = Workload::Head { beta: 64 }.instantiate().unwrap();
        let a = seed_isolated_inputs(&dag, 7);
        let b = seed_isolated_inputs(&dag, 7);
        assert_eq!(a.len(), b.len());
        for (k, v) in &a {
            assert_eq!(Some(v), b.get(k));
        }
        // X and the four weights per head: 7 isolated inputs.
        assert_eq!(a.len(), 7);
    }
}
