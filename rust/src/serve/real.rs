//! The real serving path: batched requests over the threaded executor.
//!
//! Each batch's apps are merged into one multi-tenant application and run
//! through [`execute_dag_served`] — the same thread-per-queue Algorithm-1
//! machinery as single-DAG execution, with up to `cfg.tenancy` components
//! resident per device, so requests genuinely share the PJRT worker pool.
//!
//! **Pacing** ([`Pacing`]): under `--pacing open` the serving loop sleeps
//! until each batch's nominal release instant before dispatching, so
//! wall-clock latencies reflect the arrival process (open-loop serving
//! methodology); under `closed` it replays as fast as batches complete and
//! latency degenerates to service latency when the loop outruns arrivals
//! ([`super::engine::request_outcome`] defines both semantics in one
//! place). **Deadline metadata** is threaded per component into the
//! executor's scheduler state (re-based to each batch's clock), so `edf` orders
//! real dispatch by urgency too; preemption stays sim-only — OS threads
//! cannot be displaced mid-kernel. **Executable cache**: one
//! [`Runtime`] serves every batch, so artifacts compile once per process —
//! the report carries hit/miss counts and cold-vs-warm batch latency (a
//! batch is cold iff it actually lowered an executable; repeats and
//! prewarmed runs are served warm).

use super::admission::batch_requests;
use super::cache::TemplateCache;
use super::engine::{
    admit_all, build_report, request_outcome, Pacing, RequestOutcome, ServeConfig, ServeReport,
};
use super::merge::{merge_apps_refs, MergedApp};
use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::Result;
use crate::exec::execute_dag_served;
use crate::graph::{Dag, Partition};
use crate::platform::Platform;
use crate::runtime::Runtime;
use crate::sched::Policy;
use crate::sim::CompMeta;
use crate::trace::Lane;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deterministic request input data (xorshift64*), keyed by seed.
fn seeded_input(seed: u64, len: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
    (0..len)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect()
}

/// Seed every isolated input buffer of a **merged** batch, keyed by
/// `(request id, request-local buffer index)` rather than the merged buffer
/// id: a request's data must not depend on where the merge placed it, i.e.
/// on batch composition — the per-request deterministic contract. `members`
/// are the request ids of the batch's apps, in merge order (the merge's
/// per-app buffer ranges recover each buffer's owner and local index).
fn seed_isolated_inputs(
    merged: &MergedApp,
    members: &[usize],
    seed: u64,
) -> HashMap<usize, Vec<f32>> {
    let mut inputs = HashMap::new();
    for (i, &req_id) in members.iter().enumerate() {
        let lo = merged.buffer_offsets[i];
        let hi = merged
            .buffer_offsets
            .get(i + 1)
            .copied()
            .unwrap_or(merged.dag.buffers.len());
        for b in &merged.dag.buffers[lo..hi] {
            let is_input = merged.dag.kernels[b.kernel].inputs.contains(&b.id);
            if is_input && merged.dag.buffer_pred(b.id).is_none() {
                let local = (b.id - lo) as u64;
                let key = seed
                    ^ (req_id as u64).wrapping_mul(0x9E3779B97F4A7C15)
                    ^ (local + 1).wrapping_mul(0xD1B54A32D192ED03);
                inputs.insert(b.id, seeded_input(key, (b.size_bytes / 4) as usize));
            }
        }
    }
    inputs
}

/// Ceiling on one paced sleep *chunk*: `Duration::from_secs_f64` panics
/// near 1.8e19 s, so distant releases sleep in bounded chunks — the caller
/// loops until the release is actually due (never dispatching early, which
/// would make open-loop latencies negative).
const MAX_PACE_WAIT_S: f64 = 3600.0;

/// Open-loop pacing: the next sleep chunk so the batch is dispatched no
/// earlier than its nominal `release` instant (`now` = seconds since the
/// serving epoch). `None` when the release is already due. Non-finite
/// releases yield `None` as pure defense — admission and the arrival
/// parsers already reject non-finite instants, and `Batch::release` is a
/// max over admitted arrivals.
fn pace_wait(release: f64, now: f64) -> Option<Duration> {
    let wait = release - now;
    (wait.is_finite() && wait > 0.0)
        .then(|| Duration::from_secs_f64(wait.min(MAX_PACE_WAIT_S)))
}

/// Serve the stream for real. Requires every kernel of every admitted
/// workload to carry an AOT artifact (generator workloads do at the AOT β
/// sizes); missing artifacts reject the batch with a typed executor error.
pub fn serve_real(
    requests: &[ServeRequest],
    runtime: &Arc<Runtime>,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
    seed: u64,
) -> Result<ServeReport> {
    // Admission: same rules and ordering as the sim path (including
    // laxity-based rejection of requests that cannot meet their deadline).
    // The template cache also serves the per-batch merges below, so a
    // repeated (signature, batch-size) shape merges once per run.
    let mut cache = TemplateCache::new();
    let (admitted, apps, rejected, laxity_rejections): (
        Vec<ServeRequest>,
        Vec<Arc<(Dag, Partition)>>,
        _,
        usize,
    ) = admit_all(requests, platform, cost, cfg.laxity_admission, &mut cache);

    let batches = batch_requests(&admitted, cfg.batch_window);
    if cfg.prewarm {
        // Clockwork-style: compile every artifact before the epoch so no
        // request pays lowering (cold ≈ warm afterwards).
        runtime.warmup()?;
    }
    let (hits0, misses0) = runtime.cache_stats();
    let epoch = Instant::now();
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(admitted.len());
    let mut busy = vec![0.0f64; platform.devices.len()];
    // Cold vs warm batch service latency — the observable cost of the
    // executable cache. A batch is *cold* iff it actually lowered at least
    // one executable (per-batch cache-miss delta), so a run on an
    // already-warm runtime (prewarm, or a second stream in one process)
    // correctly reports every batch warm.
    let mut cold: Vec<f64> = Vec::new();
    let mut warm: Vec<f64> = Vec::new();
    for batch in &batches {
        let member_ids: Vec<usize> = batch.members.iter().map(|&m| admitted[m].id).collect();
        // Cacheable batches (the common case) reuse the pre-merged
        // (signature, batch-size) block; Spec workloads merge fresh.
        let cacheable = batch.members.iter().all(|&m| admitted[m].workload.cacheable());
        let merged: Arc<MergedApp> = if cacheable {
            let sig = admitted[batch.members[0]].workload.signature();
            cache.merged_block(&sig, batch.members.len(), &apps[batch.members[0]])?
        } else {
            let refs: Vec<&(Dag, Partition)> =
                batch.members.iter().map(|&m| apps[m].as_ref()).collect();
            Arc::new(merge_apps_refs(&refs)?)
        };
        let inputs = seed_isolated_inputs(&merged, &member_ids, seed);
        if cfg.pacing == Pacing::Open {
            // Dispatch no earlier than the nominal release instant: the
            // open-loop clock that makes latency-vs-arrival measurements
            // meaningful. Chunked so a distant release neither overflows
            // the Duration conversion nor dispatches early (a runaway
            // trace is bounded by the CI job timeout, not by pacing).
            while let Some(wait) = pace_wait(batch.release, epoch.elapsed().as_secs_f64()) {
                std::thread::sleep(wait);
            }
        }
        let (_, batch_misses0) = runtime.cache_stats();
        let start = epoch.elapsed().as_secs_f64();
        // Deadline/priority metadata for the executor's SchedState, re-based
        // to the batch's clock (the executor's `now` starts at 0 per call):
        // absolute deadline on the serving epoch minus the batch start.
        let mut meta = vec![CompMeta::default(); merged.partition.components.len()];
        for (i, &m) in batch.members.iter().enumerate() {
            let req = &admitted[m];
            for c in merged.component_ranges[i].clone() {
                meta[c].deadline = req
                    .deadline
                    .map(|d| req.arrival + d - start)
                    .unwrap_or(f64::INFINITY);
                meta[c].priority = req.priority;
            }
        }
        let report = execute_dag_served(
            &merged.dag,
            &merged.partition,
            platform,
            cost,
            policy,
            runtime,
            &inputs,
            cfg.tenancy.max(1),
            &meta,
        )?;
        let finish = epoch.elapsed().as_secs_f64();
        let (_, batch_misses1) = runtime.cache_stats();
        if batch_misses1 > batch_misses0 {
            cold.push(finish - start);
        } else {
            warm.push(finish - start);
        }
        for (d, b) in busy.iter_mut().enumerate() {
            *b += report
                .trace
                .busy_time(|l| matches!(l, Lane::Device { dev, .. } if *dev == d));
        }
        // Per-request finish from the executor trace (the batch-level
        // `finish` would charge every member the slowest member's tail —
        // erasing exactly the reordering a deadline-aware policy buys).
        // Span ends are on the executor's clock, which starts ≈ `start` on
        // the serving epoch (sub-batch skew only).
        let mut comp_finish = vec![0.0f64; merged.partition.components.len()];
        for span in &report.trace.spans {
            if let Some(k) = span.kernel {
                let c = merged.partition.assignment[k];
                comp_finish[c] = comp_finish[c].max(span.end);
            }
        }
        for (i, &m) in batch.members.iter().enumerate() {
            let fin = merged.component_ranges[i]
                .clone()
                .map(|c| start + comp_finish[c])
                .fold(start, f64::max);
            outcomes.push(request_outcome(&admitted[m], start, fin, cfg.pacing));
        }
    }

    let makespan = epoch.elapsed().as_secs_f64();
    let device_util = busy
        .into_iter()
        .map(|b| if makespan > 0.0 { b / makespan } else { 0.0 })
        .collect();
    let (hits1, misses1) = runtime.cache_stats();
    let mean = |v: &[f64]| {
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    let mut report = build_report(
        "real",
        policy.name(),
        outcomes,
        rejected,
        laxity_rejections,
        makespan,
        device_util,
        0,
    );
    report.pacing = cfg.pacing.as_str();
    report.exec_cache_hits = hits1 - hits0;
    report.exec_cache_misses = misses1 - misses0;
    report.cold_batch_latency = mean(&cold);
    report.warm_batch_latency = mean(&warm);
    let (t_hits, t_misses) = cache.stats();
    report.template_cache_hits = t_hits;
    report.template_cache_misses = t_misses;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::sched::Clustering;
    use crate::serve::merge::merge_apps;
    use crate::serve::request::Workload;
    use std::path::Path;

    fn artifact_runtime() -> Option<Arc<Runtime>> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match Runtime::new(&dir) {
            Ok(rt) => Some(Arc::new(rt)),
            Err(_) => {
                eprintln!("skipping: artifacts not built (python -m compile.aot)");
                None
            }
        }
    }

    #[test]
    fn serves_for_real_when_artifacts_built() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let requests: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest::new(i, 0.0, Workload::Head { beta: 32 }))
            .collect();
        let report = serve_real(
            &requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
            7,
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 4);
        assert!(report.makespan > 0.0);
        assert!(report.throughput_rps > 0.0);
        assert_eq!(report.pacing, "closed");
    }

    #[test]
    fn pace_wait_sleeps_only_until_future_releases() {
        assert_eq!(pace_wait(0.0, 1.0), None); // already due
        assert_eq!(pace_wait(2.0, 2.0), None); // exactly due
        let w = pace_wait(2.5, 2.0).unwrap();
        assert!((w.as_secs_f64() - 0.5).abs() < 1e-9);
        // Non-finite releases are skipped (defense in depth); distant
        // finite ones sleep in bounded chunks the caller loops over, so
        // the Duration conversion can never overflow/panic.
        assert_eq!(pace_wait(f64::INFINITY, 0.0), None);
        assert_eq!(pace_wait(f64::NAN, 0.0), None);
        let w = pace_wait(1e20, 0.0).unwrap();
        assert!((w.as_secs_f64() - MAX_PACE_WAIT_S).abs() < 1e-6);
        // The chunk sequence converges on the true release instant.
        let w = pace_wait(4000.0, 3600.0).unwrap();
        assert!((w.as_secs_f64() - 400.0).abs() < 1e-9);
    }

    #[test]
    fn repeated_signature_batches_hit_the_executable_cache_warm() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        // batch_window 0 → one batch per request: the first batch of the
        // head_b32 signature is cold (compiles), batches 2..8 are warm.
        let cfg = ServeConfig {
            batch_window: 0.0,
            ..ServeConfig::default()
        };
        let requests: Vec<ServeRequest> = (0..8)
            .map(|i| ServeRequest::new(i, 0.0, Workload::Head { beta: 32 }))
            .collect();
        let report = serve_real(
            &requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            7,
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 8);
        // Hits are a sanity floor only (kernels sharing an artifact hit
        // within one batch); the cross-batch-reuse guarantee is the miss
        // equality below: every distinct artifact is lowered exactly once
        // for the whole 8-batch run.
        assert!(report.exec_cache_hits > 0, "no cache hits");
        let distinct_artifacts = {
            let (dag, _) = Workload::Head { beta: 32 }.instantiate().unwrap();
            let names: std::collections::HashSet<_> =
                dag.kernels.iter().filter_map(|k| k.artifact.clone()).collect();
            names.len()
        };
        assert_eq!(
            report.exec_cache_misses, distinct_artifacts,
            "each artifact must be lowered exactly once across batches"
        );
        // The report separates cold (lowered something) from warm batches;
        // warm service skips lowering so it must not exceed cold — with a
        // 2x margin because cold is a single wall-clock sample on shared CI
        // runners (the hard recompile guarantee is the miss equality above).
        assert!(report.cold_batch_latency > 0.0);
        assert!(report.warm_batch_latency > 0.0);
        assert!(
            report.warm_batch_latency <= report.cold_batch_latency * 2.0,
            "warm {} > cold {} beyond jitter",
            report.warm_batch_latency,
            report.cold_batch_latency
        );
    }

    #[test]
    fn different_signatures_get_their_own_cold_batches() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let cfg = ServeConfig {
            batch_window: 0.0,
            ..ServeConfig::default()
        };
        // Two signatures (β=32 and β=64) interleaved: each gets exactly one
        // cold batch; caches must not alias across sizes.
        let requests: Vec<ServeRequest> = (0..4)
            .map(|i| {
                let beta = if i % 2 == 0 { 32 } else { 64 };
                ServeRequest::new(i, 0.0, Workload::Head { beta })
            })
            .collect();
        let (h0, m0) = rt.cache_stats();
        assert_eq!((h0, m0), (0, 0));
        let report = serve_real(
            &requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            7,
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 4);
        // β=32 and β=64 use distinct artifacts: misses for both signatures,
        // warm batches for the repeats.
        let one_sig_misses = {
            let (dag, _) = Workload::Head { beta: 32 }.instantiate().unwrap();
            let names: std::collections::HashSet<_> = dag
                .kernels
                .iter()
                .filter_map(|k| k.artifact.clone())
                .collect();
            names.len()
        };
        assert!(
            report.exec_cache_misses > one_sig_misses,
            "misses {} suggest β=64 aliased onto β=32's executables",
            report.exec_cache_misses
        );
        assert!(report.exec_cache_hits > 0);
    }

    #[test]
    fn open_pacing_dispatches_no_earlier_than_release() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let cfg = ServeConfig {
            batch_window: 0.0,
            pacing: Pacing::Open,
            ..ServeConfig::default()
        };
        // Arrivals spread over 60 ms: the paced loop must not outrun them.
        let requests: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest::new(i, i as f64 * 0.020, Workload::Head { beta: 32 }))
            .collect();
        let report = serve_real(
            &requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            7,
        )
        .unwrap();
        assert_eq!(report.pacing, "open");
        for o in &report.outcomes {
            assert!(
                o.release >= o.arrival - 1e-9,
                "request {} dispatched at {} before its arrival {}",
                o.id,
                o.release,
                o.arrival
            );
            // Latency is measured against the nominal arrival instant.
            assert!((o.latency - (o.finish - o.arrival)).abs() < 1e-12);
        }
        // The run cannot finish before the last nominal arrival.
        assert!(report.makespan >= 0.060);
    }

    /// Per-request numerics cross-check (ROADMAP open item): a request
    /// served *inside a multi-tenant batch* must produce bit-identical
    /// outputs to a solo [`crate::exec::execute_dag`] run of the same
    /// seeded request — batching, merging, and concurrent dispatch may
    /// never change what a request computes, only when.
    #[test]
    fn served_request_outputs_match_solo_execution() {
        use crate::exec::{execute_dag, execute_dag_multi};
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let app = Workload::Head { beta: 32 }.instantiate().unwrap();
        // Request id 5 served mid-batch between two neighbours.
        let batch = merge_apps(&[app.clone(), app.clone(), app.clone()]).unwrap();
        let inputs = seed_isolated_inputs(&batch, &[9, 5, 7], 11);
        let served = execute_dag_multi(
            &batch.dag,
            &batch.partition,
            &platform,
            &PaperCost,
            &mut Clustering,
            &rt,
            &inputs,
            4,
        )
        .unwrap();
        // Solo run of request 5 with the same per-request seeded inputs
        // (seeding is keyed by request id + request-local buffer index, so
        // the data is batch-composition independent).
        let solo = merge_apps(std::slice::from_ref(&app)).unwrap();
        let solo_inputs = seed_isolated_inputs(&solo, &[5], 11);
        let solo_report = execute_dag(
            &solo.dag,
            &solo.partition,
            &platform,
            &PaperCost,
            &mut Clustering,
            &rt,
            &solo_inputs,
        )
        .unwrap();
        // Request 5 is batch member 1: its buffers live at that offset.
        let off = batch.buffer_offsets[1];
        let mut compared = 0usize;
        for k in solo.dag.sink_kernels() {
            for &b in &solo.dag.kernels[k].outputs {
                let solo_out = solo_report.store.host(b).expect("solo output read back");
                let served_out = served
                    .store
                    .host(b + off)
                    .expect("served output read back");
                assert_eq!(
                    solo_out, served_out,
                    "output buffer {b} diverged between solo and served"
                );
                compared += 1;
            }
        }
        assert!(compared > 0, "no sink outputs compared");
    }

    #[test]
    fn seeded_inputs_are_deterministic() {
        let app = Workload::Head { beta: 64 }.instantiate().unwrap();
        let merged = merge_apps(std::slice::from_ref(&app)).unwrap();
        let a = seed_isolated_inputs(&merged, &[5], 7);
        let b = seed_isolated_inputs(&merged, &[5], 7);
        assert_eq!(a.len(), b.len());
        for (k, v) in &a {
            assert_eq!(Some(v), b.get(k));
        }
        // X and the four weights per head: 7 isolated inputs.
        assert_eq!(a.len(), 7);
        // A different request id yields different data for the same slots.
        let c = seed_isolated_inputs(&merged, &[6], 7);
        assert!(a.iter().any(|(k, v)| c.get(k) != Some(v)));
    }

    #[test]
    fn seeded_inputs_independent_of_batch_composition() {
        // The same request (id 5) must see identical input data whether it
        // is merged alone or behind another request — data is keyed by
        // (request id, request-local buffer index), not merged buffer id.
        let app = Workload::Head { beta: 64 }.instantiate().unwrap();
        let solo = merge_apps(std::slice::from_ref(&app)).unwrap();
        let solo_inputs = seed_isolated_inputs(&solo, &[5], 7);

        let pair = merge_apps(&[app.clone(), app.clone()]).unwrap();
        let pair_inputs = seed_isolated_inputs(&pair, &[9, 5], 7);
        let off = pair.buffer_offsets[1];
        for (&b, data) in &solo_inputs {
            assert_eq!(
                Some(data),
                pair_inputs.get(&(b + off)),
                "buffer {b} data depends on batch composition"
            );
        }
    }
}
