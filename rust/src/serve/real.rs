//! The real serving path: [`RealBackend`] plugs the threaded executor and
//! the PJRT stand-in [`Runtime`] into the unified serve core.
//!
//! Each admission unit's apps are merged into one multi-tenant application
//! and run through [`execute_dag_served`] — the same thread-per-queue
//! Algorithm-1 machinery as single-DAG execution, with up to `tenancy`
//! components resident per device, so requests genuinely share the PJRT
//! worker pool. Completed requests retire incrementally through the core's
//! drain, so a paced open-loop run with a finite `--window` holds bounded
//! state (`live_requests ≤ window`) exactly like the sim backend — the
//! always-on real server the ROADMAP asked for.
//!
//! Two entry points:
//!
//! * [`serve_real`] — the batch-mode wrapper: sorts the request vector into
//!   admission order and runs the core at `window: 0` (whole stream
//!   admitted, classic [`ServeReport`] out).
//! * [`serve_real_stream`] — the always-on path behind
//!   `serve --streaming --mode real`: arrival iterator in, windowed
//!   backpressure, per-completion [`OutcomeSink`] emission,
//!   [`StreamReport`] out.
//!
//! **Pacing** ([`Pacing`]): under `open` the backend sleeps until each
//! unit's nominal release instant before dispatching, so wall-clock
//! latencies reflect the arrival process (open-loop serving methodology);
//! under `closed` it replays as fast as units complete and latency
//! degenerates to service latency when the loop outruns arrivals
//! ([`super::engine::request_outcome`] defines both semantics in one
//! place). **Deadline metadata** is threaded per component into the
//! executor's scheduler state (re-based to each unit's dispatch clock), so
//! `edf` orders real dispatch by urgency too; preemption stays sim-only —
//! OS threads cannot be displaced mid-kernel. **Executable cache**: one
//! [`Runtime`] serves every unit, so artifacts compile once per process —
//! the report carries hit/miss counts and cold-vs-warm batch latency (a
//! unit is cold iff it actually lowered an executable; repeats and
//! prewarmed runs are served warm).
//!
//! One documented divergence from the pre-core batch loop: a batch with an
//! *uncacheable* (Spec) member used to execute as one whole-batch merge;
//! the core splits such batches into one single-app unit per member
//! (executed in member order). Outcome order and request data are
//! unchanged — inputs are keyed by request id and request-local buffer
//! index, independent of batch composition — only wall-clock overlap
//! within those rare batches differs.

use super::cache::TemplateCache;
use super::core::{
    serve_core, BackendStats, CollectSink, OutcomeSink, ServeBackend, StreamReport,
    StreamingConfig, REJECT_SAMPLE_CAP,
};
use super::engine::{admission_order, build_report, Pacing, ServeConfig, ServeReport};
use super::merge::{merge_apps_refs, MergedApp};
use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::Result;
use crate::exec::{execute_dag_served, execute_dag_served_faulted, is_fault_error, ExecFaults};
use crate::fault::FaultPlan;
use crate::platform::Platform;
use crate::runtime::Runtime;
use crate::sched::Policy;
use crate::sim::{AdmitUnit, CompMeta, FinishedRequest, PumpStop, Template};
use crate::trace::Lane;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deterministic request input data (xorshift64*), keyed by seed.
fn seeded_input(seed: u64, len: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
    (0..len)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect()
}

/// Seed every isolated input buffer of a **merged** batch, keyed by
/// `(request id, request-local buffer index)` rather than the merged buffer
/// id: a request's data must not depend on where the merge placed it, i.e.
/// on batch composition — the per-request deterministic contract. `members`
/// are the request ids of the batch's apps, in merge order (the merge's
/// per-app buffer ranges recover each buffer's owner and local index).
fn seed_isolated_inputs(
    merged: &MergedApp,
    members: &[usize],
    seed: u64,
) -> HashMap<usize, Vec<f32>> {
    let mut inputs = HashMap::new();
    for (i, &req_id) in members.iter().enumerate() {
        let lo = merged.buffer_offsets[i];
        let hi = merged
            .buffer_offsets
            .get(i + 1)
            .copied()
            .unwrap_or(merged.dag.buffers.len());
        for b in &merged.dag.buffers[lo..hi] {
            let is_input = merged.dag.kernels[b.kernel].inputs.contains(&b.id);
            if is_input && merged.dag.buffer_pred(b.id).is_none() {
                let local = (b.id - lo) as u64;
                let key = seed
                    ^ (req_id as u64).wrapping_mul(0x9E3779B97F4A7C15)
                    ^ (local + 1).wrapping_mul(0xD1B54A32D192ED03);
                inputs.insert(b.id, seeded_input(key, (b.size_bytes / 4) as usize));
            }
        }
    }
    inputs
}

/// Ceiling on one paced sleep *chunk*: `Duration::from_secs_f64` panics
/// near 1.8e19 s, so distant releases sleep in bounded chunks — the caller
/// loops until the release is actually due (never dispatching early, which
/// would make open-loop latencies negative).
const MAX_PACE_WAIT_S: f64 = 3600.0;

/// Watchdog budget per kernel command: cost estimate × slack + floor.
/// Generous on purpose — the estimate models a GTX-970-class device while
/// the stand-in runs on whatever CPU CI provides, and the watchdog exists
/// to catch *wedges* (commands that stopped progressing), not jitter.
const WATCHDOG_SLACK: f64 = 64.0;
const WATCHDOG_FLOOR_S: f64 = 0.25;

/// Open-loop pacing: the next sleep chunk so the unit is dispatched no
/// earlier than its nominal `release` instant (`now` = seconds since the
/// serving epoch). `None` when the release is already due. Non-finite
/// releases yield `None` as pure defense — admission and the arrival
/// parsers already reject non-finite instants, and a unit's release is a
/// max over admitted arrivals.
fn pace_wait(release: f64, now: f64) -> Option<Duration> {
    let wait = release - now;
    (wait.is_finite() && wait > 0.0)
        .then(|| Duration::from_secs_f64(wait.min(MAX_PACE_WAIT_S)))
}

/// [`ServeBackend`] over the threaded executor: admitted units queue in
/// release order and execute one per [`pump`](ServeBackend::pump) on the
/// wall clock (seconds since the backend's construction epoch). A unit
/// whose release lies beyond the pump horizon is deferred — the core
/// ingests more arrivals first and pumps to `INFINITY` once the stream
/// ends, so deferral never wedges.
pub struct RealBackend<'a> {
    runtime: &'a Arc<Runtime>,
    platform: &'a Platform,
    cost: &'a dyn CostModel,
    policy: &'a mut dyn Policy,
    tenancy: usize,
    pacing: Pacing,
    seed: u64,
    epoch: Instant,
    queue: std::collections::VecDeque<AdmitUnit>,
    finished: Vec<FinishedRequest>,
    live: usize,
    live_components: usize,
    peak_live: usize,
    peak_live_components: usize,
    busy: Vec<f64>,
    /// Executed kernel spans (the real-path analog of simulated events).
    events: u64,
    makespan: f64,
    cold: Vec<f64>,
    warm: Vec<f64>,
    hits0: usize,
    misses0: usize,
    /// Fault-injection plan on the serving epoch's wall clock (`None` keeps
    /// the path byte-identical to the fault-free build).
    faults: Option<FaultPlan>,
    retry_budget: u32,
    backoff_base: f64,
}

impl<'a> RealBackend<'a> {
    /// The epoch (t = 0 for releases and outcomes) and the executable-cache
    /// baseline are captured here — construct after any prewarm so warmup
    /// compiles don't count as this run's misses.
    pub fn new(
        runtime: &'a Arc<Runtime>,
        platform: &'a Platform,
        cost: &'a dyn CostModel,
        policy: &'a mut dyn Policy,
        tenancy: usize,
        pacing: Pacing,
        seed: u64,
    ) -> Self {
        let (hits0, misses0) = runtime.cache_stats();
        RealBackend {
            runtime,
            platform,
            cost,
            policy,
            tenancy,
            pacing,
            seed,
            epoch: Instant::now(),
            queue: std::collections::VecDeque::new(),
            finished: Vec::new(),
            live: 0,
            live_components: 0,
            peak_live: 0,
            peak_live_components: 0,
            busy: vec![0.0; platform.devices.len()],
            events: 0,
            makespan: 0.0,
            cold: Vec::new(),
            warm: Vec::new(),
            hits0,
            misses0,
            faults: None,
            retry_budget: 0,
            backoff_base: 0.0,
        }
    }

    /// Arm fault injection: validated against this backend's platform, the
    /// plan's instants interpreted as wall seconds on the serving epoch.
    pub fn install_faults(&mut self, plan: &FaultPlan) -> Result<()> {
        plan.validate()?;
        plan.validate_devices(self.platform.devices.len())?;
        self.retry_budget = plan.retry_budget;
        self.backoff_base = plan.backoff_base;
        self.faults = Some(plan.clone().normalized()?);
        Ok(())
    }

    /// Execute one unit end-to-end: pace to its release (open pacing),
    /// merge-or-reuse the template, seed per-request inputs, run the
    /// threaded executor with per-component deadline metadata, and retire
    /// every member with its own trace-derived finish instant.
    fn execute_unit(&mut self, unit: AdmitUnit) -> Result<()> {
        if self.pacing == Pacing::Open {
            // Dispatch no earlier than the nominal release instant: the
            // open-loop clock that makes latency-vs-arrival measurements
            // meaningful. Chunked so a distant release neither overflows
            // the Duration conversion nor dispatches early.
            while let Some(wait) = pace_wait(unit.release, self.epoch.elapsed().as_secs_f64()) {
                std::thread::sleep(wait);
            }
        }
        let member_ids: Vec<usize> = unit.members.iter().map(|m| m.id).collect();
        let merged: Arc<MergedApp> = match &unit.tmpl {
            Template::Merged(block) => block.clone(),
            // Single-app units go through the identity merge: same
            // component/buffer layout as the app itself, so member `comps`
            // ranges stay valid.
            Template::Single(app) => Arc::new(merge_apps_refs(&[app.as_ref()])?),
        };
        let inputs = seed_isolated_inputs(&merged, &member_ids, self.seed);
        // Fault recovery, whole-unit re-stage semantics: a `fault:`-typed
        // failure (crashed device, wedge/watchdog timeout) rolls the unit
        // back and re-runs it from scratch — inputs re-stage, every kernel
        // re-executes on whatever devices survive — after an exponential
        // backoff, up to the plan's retry budget. Budget exhausted, the
        // unit's members are retired as typed shed outcomes instead of
        // failing the stream. Non-fault errors abort as before.
        let mut attempt: u32 = 0;
        let (report, start) = loop {
            let (_, batch_misses0) = self.runtime.cache_stats();
            let start = self.epoch.elapsed().as_secs_f64();
            // Deadline/priority metadata for the executor's SchedState,
            // re-based to this attempt's clock (the executor's `now` starts
            // at 0 per call): absolute deadline on the serving epoch minus
            // the dispatch start.
            let mut meta = vec![CompMeta::default(); merged.partition.components.len()];
            for m in &unit.members {
                for c in m.comps.clone() {
                    meta[c].deadline = m
                        .deadline
                        .map(|d| m.arrival + d - start)
                        .unwrap_or(f64::INFINITY);
                    meta[c].priority = m.priority;
                }
            }
            let res = execute_dag_served_faulted(
                &merged.dag,
                &merged.partition,
                self.platform,
                self.cost,
                &mut *self.policy,
                self.runtime,
                &inputs,
                self.tenancy.max(1),
                &meta,
                self.faults.as_ref().map(|plan| ExecFaults {
                    plan,
                    epoch_offset: start,
                    slack: WATCHDOG_SLACK,
                    floor: WATCHDOG_FLOOR_S,
                }),
            );
            match res {
                Ok(report) => {
                    let finish = self.epoch.elapsed().as_secs_f64();
                    let (_, batch_misses1) = self.runtime.cache_stats();
                    // Cold vs warm unit service latency — the observable
                    // cost of the executable cache. A unit is *cold* iff it
                    // actually lowered at least one executable (per-unit
                    // cache-miss delta), so a run on an already-warm
                    // runtime (prewarm, or a second stream in one process)
                    // correctly reports every unit warm.
                    if batch_misses1 > batch_misses0 {
                        self.cold.push(finish - start);
                    } else {
                        self.warm.push(finish - start);
                    }
                    break (report, start);
                }
                Err(e) if self.faults.is_some() && is_fault_error(&e) => {
                    attempt += 1;
                    if attempt > self.retry_budget {
                        let now = self.epoch.elapsed().as_secs_f64();
                        for m in &unit.members {
                            self.finished.push(FinishedRequest {
                                id: m.id,
                                arrival: m.arrival,
                                deadline: m.deadline,
                                priority: m.priority,
                                release: unit.release,
                                finish: now.max(unit.release),
                                devices: Vec::new(),
                                shed: true,
                                retries: self.retry_budget,
                            });
                        }
                        self.live -= unit.members.len();
                        self.live_components -= merged.partition.components.len();
                        self.makespan = self.epoch.elapsed().as_secs_f64();
                        return Ok(());
                    }
                    let wait = self.backoff_base * (1u64 << (attempt - 1).min(62)) as f64;
                    if wait > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(wait.min(MAX_PACE_WAIT_S)));
                    }
                }
                Err(e) => return Err(e),
            }
        };
        for (d, b) in self.busy.iter_mut().enumerate() {
            *b += report
                .trace
                .busy_time(|l| matches!(l, Lane::Device { dev, .. } if *dev == d));
        }
        self.events += report.trace.spans.len() as u64;
        // Per-request finish from the executor trace (the unit-level
        // `finish` would charge every member the slowest member's tail —
        // erasing exactly the reordering a deadline-aware policy buys).
        // Span ends are on the executor's clock, which starts ≈ `start` on
        // the serving epoch (sub-batch skew only).
        let mut comp_finish = vec![0.0f64; merged.partition.components.len()];
        for span in &report.trace.spans {
            if let Some(k) = span.kernel {
                let c = merged.partition.assignment[k];
                comp_finish[c] = comp_finish[c].max(span.end);
            }
        }
        for m in &unit.members {
            let fin = m
                .comps
                .clone()
                .map(|c| start + comp_finish[c])
                .fold(start, f64::max);
            let devices = m.comps.clone().map(|c| report.component_device[c]).collect();
            self.finished.push(FinishedRequest {
                id: m.id,
                arrival: m.arrival,
                deadline: m.deadline,
                priority: m.priority,
                release: start,
                finish: fin,
                devices,
                shed: false,
                retries: attempt,
            });
        }
        self.live -= unit.members.len();
        self.live_components -= merged.partition.components.len();
        self.makespan = self.epoch.elapsed().as_secs_f64();
        Ok(())
    }
}

impl ServeBackend for RealBackend<'_> {
    fn admit(&mut self, unit: AdmitUnit) -> Result<()> {
        self.live += unit.members.len();
        self.live_components += unit.tmpl.partition().components.len();
        self.peak_live = self.peak_live.max(self.live);
        self.peak_live_components = self.peak_live_components.max(self.live_components);
        self.queue.push_back(unit);
        Ok(())
    }

    fn pump(&mut self, horizon: f64) -> Result<PumpStop> {
        let Some(front) = self.queue.front() else {
            return Ok(PumpStop::Idle);
        };
        if horizon.is_finite() && front.release > horizon {
            // The unit is not due within the core's admission boundary:
            // defer so arrivals that belong before it can still batch. The
            // core pumps to INFINITY after the stream ends, so deferred
            // units always execute eventually.
            return Ok(PumpStop::Horizon);
        }
        let unit = self.queue.pop_front().expect("front() was Some");
        self.execute_unit(unit)?;
        Ok(PumpStop::Horizon)
    }

    fn drain_finished_into(&mut self, out: &mut Vec<FinishedRequest>) {
        out.append(&mut self.finished);
    }

    fn live_requests(&self) -> usize {
        self.live
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn abort(&mut self) {
        // Typed mid-stream abort: retire everything still resident. Unit
        // execution is synchronous — execute_dag_served joins its worker
        // threads before returning — so once the queue is dropped no
        // executor thread can outlive the serve call; this drains the
        // admitted-but-unexecuted units and the undrained completions so
        // the backend ends the call empty.
        for u in self.queue.drain(..) {
            self.live -= u.members.len();
            self.live_components -= u.tmpl.partition().components.len();
        }
        self.finished.clear();
    }

    fn pacing(&self) -> Pacing {
        self.pacing
    }

    fn stats(&self) -> BackendStats {
        BackendStats {
            makespan: self.makespan,
            // OS threads cannot be displaced mid-kernel: preemption is
            // sim-only.
            preemptions: 0,
            device_busy: self.busy.clone(),
            events: self.events,
            peak_live_requests: self.peak_live,
            peak_live_components: self.peak_live_components,
        }
    }

    fn finalize_report(&self, report: &mut StreamReport) {
        report.pacing = self.pacing.as_str();
        let (hits1, misses1) = self.runtime.cache_stats();
        report.exec_cache_hits = hits1 - self.hits0;
        report.exec_cache_misses = misses1 - self.misses0;
        let mean = |v: &[f64]| {
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<f64>() / v.len() as f64
            }
        };
        report.cold_batch_latency = mean(&self.cold);
        report.warm_batch_latency = mean(&self.warm);
    }
}

/// The always-on real serving path (`serve --streaming --mode real`):
/// [`serve_core`] over a [`RealBackend`] — arrival-iterator ingestion,
/// incremental batching, `cfg.window` backpressure, per-completion sink
/// emission, bounded live state. Requires every kernel of every admitted
/// workload to carry an AOT artifact (generator workloads do at the AOT β
/// sizes); missing artifacts reject the unit with a typed executor error.
#[allow(clippy::too_many_arguments)]
pub fn serve_real_stream<I>(
    requests: I,
    runtime: &Arc<Runtime>,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &StreamingConfig,
    pacing: Pacing,
    prewarm: bool,
    seed: u64,
    sink: &mut dyn OutcomeSink,
) -> Result<StreamReport>
where
    I: IntoIterator<Item = ServeRequest>,
{
    let policy_name = policy.name().to_string();
    if prewarm {
        // Clockwork-style: compile every artifact before the epoch so no
        // request pays lowering (the backend's cache baseline is captured
        // after, so warmup compiles don't count as this run's misses).
        runtime.warmup()?;
    }
    let mut cache = TemplateCache::new();
    let mut backend =
        RealBackend::new(runtime, platform, cost, policy, cfg.tenancy, pacing, seed);
    if let Some(plan) = &cfg.faults {
        backend.install_faults(plan)?;
    }
    serve_core(
        requests,
        platform,
        cost,
        &mut backend,
        cfg,
        &mut cache,
        sink,
        &policy_name,
        REJECT_SAMPLE_CAP,
    )
}

/// Serve the stream for real, batch mode: sort into admission order and
/// run the core at `window: 0` (whole stream admitted up front — the
/// pre-core behavior, now a thin wrapper). Requires every kernel of every
/// admitted workload to carry an AOT artifact.
pub fn serve_real(
    requests: &[ServeRequest],
    runtime: &Arc<Runtime>,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
    seed: u64,
) -> Result<ServeReport> {
    let policy_name = policy.name().to_string();
    if cfg.prewarm {
        runtime.warmup()?;
    }
    // The core ingests arrivals in order; feed it the same admission order
    // the sim path uses (arrival, priority desc, id) as an index
    // permutation.
    let order = admission_order(requests);
    let scfg = StreamingConfig {
        window: 0,
        batch_window: cfg.batch_window,
        tenancy: cfg.tenancy,
        laxity_admission: cfg.laxity_admission,
        sim: cfg.sim.clone(),
        faults: None,
    };
    let mut cache = TemplateCache::new();
    let mut backend =
        RealBackend::new(runtime, platform, cost, policy, cfg.tenancy, cfg.pacing, seed);
    let mut sink = CollectSink::default();
    // Uncapped rejection sample: the batch report has always carried the
    // full rejection list.
    let sreport = serve_core(
        order.iter().map(|&i| requests[i].clone()),
        platform,
        cost,
        &mut backend,
        &scfg,
        &mut cache,
        &mut sink,
        &policy_name,
        usize::MAX,
    )?;
    // Units execute in batch-close order and members in member order, so
    // the sink's emission order *is* the old batch loop's outcome order —
    // no re-sort needed.
    let StreamReport {
        rejected_sample,
        laxity_rejections,
        makespan,
        device_util,
        pacing,
        exec_cache_hits,
        exec_cache_misses,
        cold_batch_latency,
        warm_batch_latency,
        template_cache_hits,
        template_cache_misses,
        ..
    } = sreport;
    let mut report = build_report(
        "real",
        &policy_name,
        sink.outcomes,
        rejected_sample,
        laxity_rejections,
        makespan,
        device_util,
        0,
    );
    report.pacing = pacing;
    report.exec_cache_hits = exec_cache_hits;
    report.exec_cache_misses = exec_cache_misses;
    report.cold_batch_latency = cold_batch_latency;
    report.warm_batch_latency = warm_batch_latency;
    report.template_cache_hits = template_cache_hits;
    report.template_cache_misses = template_cache_misses;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::error::Error;
    use crate::fault::{FaultEvent, FaultKind};
    use crate::sched::Clustering;
    use crate::serve::core::{JsonlSink, NullSink};
    use crate::serve::engine::RequestOutcome;
    use crate::serve::merge::merge_apps;
    use crate::serve::request::Workload;
    use std::collections::HashSet;
    use std::io;
    use std::path::Path;

    fn artifact_runtime() -> Option<Arc<Runtime>> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match Runtime::new(&dir) {
            Ok(rt) => Some(Arc::new(rt)),
            Err(_) => {
                eprintln!("skipping: artifacts not built (python -m compile.aot)");
                None
            }
        }
    }

    #[test]
    fn serves_for_real_when_artifacts_built() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let requests: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest::new(i, 0.0, Workload::Head { beta: 32 }))
            .collect();
        let report = serve_real(
            &requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
            7,
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 4);
        assert!(report.makespan > 0.0);
        assert!(report.throughput_rps > 0.0);
        assert_eq!(report.pacing, "closed");
    }

    #[test]
    fn pace_wait_sleeps_only_until_future_releases() {
        assert_eq!(pace_wait(0.0, 1.0), None); // already due
        assert_eq!(pace_wait(2.0, 2.0), None); // exactly due
        let w = pace_wait(2.5, 2.0).unwrap();
        assert!((w.as_secs_f64() - 0.5).abs() < 1e-9);
        // Non-finite releases are skipped (defense in depth); distant
        // finite ones sleep in bounded chunks the caller loops over, so
        // the Duration conversion can never overflow/panic.
        assert_eq!(pace_wait(f64::INFINITY, 0.0), None);
        assert_eq!(pace_wait(f64::NAN, 0.0), None);
        let w = pace_wait(1e20, 0.0).unwrap();
        assert!((w.as_secs_f64() - MAX_PACE_WAIT_S).abs() < 1e-6);
        // The chunk sequence converges on the true release instant.
        let w = pace_wait(4000.0, 3600.0).unwrap();
        assert!((w.as_secs_f64() - 400.0).abs() < 1e-9);
    }

    #[test]
    fn repeated_signature_batches_hit_the_executable_cache_warm() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        // batch_window 0 → one batch per request: the first batch of the
        // head_b32 signature is cold (compiles), batches 2..8 are warm.
        let cfg = ServeConfig {
            batch_window: 0.0,
            ..ServeConfig::default()
        };
        let requests: Vec<ServeRequest> = (0..8)
            .map(|i| ServeRequest::new(i, 0.0, Workload::Head { beta: 32 }))
            .collect();
        let report = serve_real(
            &requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            7,
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 8);
        // Hits are a sanity floor only (kernels sharing an artifact hit
        // within one batch); the cross-batch-reuse guarantee is the miss
        // equality below: every distinct artifact is lowered exactly once
        // for the whole 8-batch run.
        assert!(report.exec_cache_hits > 0, "no cache hits");
        let distinct_artifacts = {
            let (dag, _) = Workload::Head { beta: 32 }.instantiate().unwrap();
            let names: std::collections::HashSet<_> =
                dag.kernels.iter().filter_map(|k| k.artifact.clone()).collect();
            names.len()
        };
        assert_eq!(
            report.exec_cache_misses, distinct_artifacts,
            "each artifact must be lowered exactly once across batches"
        );
        // The report separates cold (lowered something) from warm batches;
        // warm service skips lowering so it must not exceed cold — with a
        // 2x margin because cold is a single wall-clock sample on shared CI
        // runners (the hard recompile guarantee is the miss equality above).
        assert!(report.cold_batch_latency > 0.0);
        assert!(report.warm_batch_latency > 0.0);
        assert!(
            report.warm_batch_latency <= report.cold_batch_latency * 2.0,
            "warm {} > cold {} beyond jitter",
            report.warm_batch_latency,
            report.cold_batch_latency
        );
    }

    #[test]
    fn different_signatures_get_their_own_cold_batches() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let cfg = ServeConfig {
            batch_window: 0.0,
            ..ServeConfig::default()
        };
        // Two signatures (β=32 and β=64) interleaved: each gets exactly one
        // cold batch; caches must not alias across sizes.
        let requests: Vec<ServeRequest> = (0..4)
            .map(|i| {
                let beta = if i % 2 == 0 { 32 } else { 64 };
                ServeRequest::new(i, 0.0, Workload::Head { beta })
            })
            .collect();
        let (h0, m0) = rt.cache_stats();
        assert_eq!((h0, m0), (0, 0));
        let report = serve_real(
            &requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            7,
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 4);
        // β=32 and β=64 use distinct artifacts: misses for both signatures,
        // warm batches for the repeats.
        let one_sig_misses = {
            let (dag, _) = Workload::Head { beta: 32 }.instantiate().unwrap();
            let names: std::collections::HashSet<_> = dag
                .kernels
                .iter()
                .filter_map(|k| k.artifact.clone())
                .collect();
            names.len()
        };
        assert!(
            report.exec_cache_misses > one_sig_misses,
            "misses {} suggest β=64 aliased onto β=32's executables",
            report.exec_cache_misses
        );
        assert!(report.exec_cache_hits > 0);
    }

    #[test]
    fn open_pacing_dispatches_no_earlier_than_release() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let cfg = ServeConfig {
            batch_window: 0.0,
            pacing: Pacing::Open,
            ..ServeConfig::default()
        };
        // Arrivals spread over 60 ms: the paced loop must not outrun them.
        let requests: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest::new(i, i as f64 * 0.020, Workload::Head { beta: 32 }))
            .collect();
        let report = serve_real(
            &requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            7,
        )
        .unwrap();
        assert_eq!(report.pacing, "open");
        for o in &report.outcomes {
            assert!(
                o.release >= o.arrival - 1e-9,
                "request {} dispatched at {} before its arrival {}",
                o.id,
                o.release,
                o.arrival
            );
            // Latency is measured against the nominal arrival instant.
            assert!((o.latency - (o.finish - o.arrival)).abs() < 1e-12);
        }
        // The run cannot finish before the last nominal arrival.
        assert!(report.makespan >= 0.060);
    }

    /// Per-request numerics cross-check (ROADMAP open item): a request
    /// served *inside a multi-tenant batch* must produce bit-identical
    /// outputs to a solo [`crate::exec::execute_dag`] run of the same
    /// seeded request — batching, merging, and concurrent dispatch may
    /// never change what a request computes, only when.
    #[test]
    fn served_request_outputs_match_solo_execution() {
        use crate::exec::{execute_dag, execute_dag_multi};
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let app = Workload::Head { beta: 32 }.instantiate().unwrap();
        // Request id 5 served mid-batch between two neighbours.
        let batch = merge_apps(&[app.clone(), app.clone(), app.clone()]).unwrap();
        let inputs = seed_isolated_inputs(&batch, &[9, 5, 7], 11);
        let served = execute_dag_multi(
            &batch.dag,
            &batch.partition,
            &platform,
            &PaperCost,
            &mut Clustering,
            &rt,
            &inputs,
            4,
        )
        .unwrap();
        // Solo run of request 5 with the same per-request seeded inputs
        // (seeding is keyed by request id + request-local buffer index, so
        // the data is batch-composition independent).
        let solo = merge_apps(std::slice::from_ref(&app)).unwrap();
        let solo_inputs = seed_isolated_inputs(&solo, &[5], 11);
        let solo_report = execute_dag(
            &solo.dag,
            &solo.partition,
            &platform,
            &PaperCost,
            &mut Clustering,
            &rt,
            &solo_inputs,
        )
        .unwrap();
        // Request 5 is batch member 1: its buffers live at that offset.
        let off = batch.buffer_offsets[1];
        let mut compared = 0usize;
        for k in solo.dag.sink_kernels() {
            for &b in &solo.dag.kernels[k].outputs {
                let solo_out = solo_report.store.host(b).expect("solo output read back");
                let served_out = served
                    .store
                    .host(b + off)
                    .expect("served output read back");
                assert_eq!(
                    solo_out, served_out,
                    "output buffer {b} diverged between solo and served"
                );
                compared += 1;
            }
        }
        assert!(compared > 0, "no sink outputs compared");
    }

    #[test]
    fn seeded_inputs_are_deterministic() {
        let app = Workload::Head { beta: 64 }.instantiate().unwrap();
        let merged = merge_apps(std::slice::from_ref(&app)).unwrap();
        let a = seed_isolated_inputs(&merged, &[5], 7);
        let b = seed_isolated_inputs(&merged, &[5], 7);
        assert_eq!(a.len(), b.len());
        for (k, v) in &a {
            assert_eq!(Some(v), b.get(k));
        }
        // X and the four weights per head: 7 isolated inputs.
        assert_eq!(a.len(), 7);
        // A different request id yields different data for the same slots.
        let c = seed_isolated_inputs(&merged, &[6], 7);
        assert!(a.iter().any(|(k, v)| c.get(k) != Some(v)));
    }

    #[test]
    fn seeded_inputs_independent_of_batch_composition() {
        // The same request (id 5) must see identical input data whether it
        // is merged alone or behind another request — data is keyed by
        // (request id, request-local buffer index), not merged buffer id.
        let app = Workload::Head { beta: 64 }.instantiate().unwrap();
        let solo = merge_apps(std::slice::from_ref(&app)).unwrap();
        let solo_inputs = seed_isolated_inputs(&solo, &[5], 7);

        let pair = merge_apps(&[app.clone(), app.clone()]).unwrap();
        let pair_inputs = seed_isolated_inputs(&pair, &[9, 5], 7);
        let off = pair.buffer_offsets[1];
        for (&b, data) in &solo_inputs {
            assert_eq!(
                Some(data),
                pair_inputs.get(&(b + off)),
                "buffer {b} data depends on batch composition"
            );
        }
    }

    /// Tentpole equivalence on the real path: `--streaming --mode real` at
    /// `window: 0` must match batch `serve_real` per-request outcomes —
    /// same served-id set, same rejections, same deadline verdicts (under
    /// budgets generous enough that wall-clock jitter cannot flip them),
    /// and identical lowering work on fresh runtimes.
    #[test]
    fn streaming_real_window0_matches_batch_serve_real() {
        let Some(rt_batch) = artifact_runtime() else {
            return;
        };
        let Some(rt_stream) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let requests: Vec<ServeRequest> = (0..6)
            .map(|i| {
                let mut r = ServeRequest::new(i, i as f64 * 0.002, Workload::Head { beta: 32 });
                if i % 2 == 0 {
                    r.deadline = Some(5.0);
                    r.priority = 1;
                }
                r
            })
            .collect();
        let cfg = ServeConfig::default();
        let batch = serve_real(
            &requests,
            &rt_batch,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            7,
        )
        .unwrap();

        let scfg = StreamingConfig {
            window: 0,
            batch_window: cfg.batch_window,
            tenancy: cfg.tenancy,
            laxity_admission: cfg.laxity_admission,
            sim: cfg.sim.clone(),
            faults: None,
        };
        let mut sink = CollectSink::default();
        let streamed = serve_real_stream(
            requests.clone(),
            &rt_stream,
            &platform,
            &PaperCost,
            &mut Clustering,
            &scfg,
            Pacing::Closed,
            false,
            7,
            &mut sink,
        )
        .unwrap();

        assert_eq!(streamed.served, batch.outcomes.len());
        assert_eq!(streamed.rejected, batch.rejected.len());
        assert_eq!(streamed.rejected, 0);
        let batch_ids: HashSet<usize> = batch.outcomes.iter().map(|o| o.id).collect();
        let stream_ids: HashSet<usize> = sink.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(batch_ids, stream_ids);
        let by_id: HashMap<usize, &RequestOutcome> =
            batch.outcomes.iter().map(|o| (o.id, o)).collect();
        for o in &sink.outcomes {
            assert_eq!(o.deadline_met, by_id[&o.id].deadline_met, "id {}", o.id);
            assert_eq!(o.priority, by_id[&o.id].priority, "id {}", o.id);
        }
        // Fresh runtimes on both sides → identical lowering and merge work.
        assert_eq!(streamed.exec_cache_misses, batch.exec_cache_misses);
        assert_eq!(
            (streamed.template_cache_hits, streamed.template_cache_misses),
            (batch.template_cache_hits, batch.template_cache_misses)
        );
        assert_eq!(streamed.pacing, "closed");
        assert_eq!(streamed.window, 0);
    }

    /// Writer that fails with a typed io error after `ok_writes` successful
    /// write calls — a disk filling up mid-stream.
    struct FailingWriter {
        ok_writes: usize,
    }

    impl io::Write for FailingWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.ok_writes == 0 {
                return Err(io::Error::new(io::ErrorKind::Other, "disk full"));
            }
            self.ok_writes -= 1;
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    /// A typed mid-stream sink failure must abort the real path cleanly:
    /// the error surfaces as `Error::Io`, the call returns (unit execution
    /// is synchronous, so no executor thread outlives it), and the
    /// backend's abort hook retires every queued unit and undrained
    /// completion instead of leaking them.
    #[test]
    fn failing_sink_mid_stream_aborts_and_drains_the_real_backend() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let requests: Vec<ServeRequest> = (0..6)
            .map(|i| ServeRequest::new(i, i as f64 * 1e-4, Workload::Head { beta: 32 }))
            .collect();
        let scfg = StreamingConfig {
            window: 1,
            batch_window: 0.0,
            ..StreamingConfig::default()
        };
        let mut sink = JsonlSink::new(FailingWriter { ok_writes: 3 });
        let e = serve_real_stream(
            requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &scfg,
            Pacing::Closed,
            false,
            7,
            &mut sink,
        )
        .unwrap_err();
        assert!(matches!(e, Error::Io(_)), "{e}");
        assert!(e.to_string().contains("disk full"), "{e}");
    }

    /// A crashed device is masked from dispatch: with the GPU down from
    /// t = 0, every request still serves on the surviving CPU device, and
    /// the run needs neither retries nor shedding.
    #[test]
    fn crashed_device_is_masked_and_the_stream_survives() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let plan = FaultPlan {
            events: vec![FaultEvent {
                device: 0,
                at: 0.0,
                kind: FaultKind::Crash,
            }],
            retry_budget: 2,
            backoff_base: 0.0,
            ..FaultPlan::default()
        };
        let n = 4;
        let requests: Vec<ServeRequest> = (0..n)
            .map(|i| ServeRequest::new(i, 0.0, Workload::Head { beta: 32 }))
            .collect();
        let scfg = StreamingConfig {
            window: 0,
            batch_window: 0.0,
            faults: Some(plan),
            ..StreamingConfig::default()
        };
        let report = serve_real_stream(
            requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &scfg,
            Pacing::Closed,
            false,
            7,
            &mut NullSink,
        )
        .unwrap();
        assert_eq!(report.offered, n);
        assert_eq!(report.served, n, "shed {} rejected {}", report.shed, report.rejected);
        assert_eq!(report.shed, 0);
        assert_eq!(report.served + report.rejected + report.shed, report.offered);
    }

    /// With every device crashed from t = 0, recovery has nowhere to go:
    /// each unit burns its retry budget and is shed, typed — and the
    /// conservation law still balances the books exactly.
    #[test]
    fn all_devices_crashed_sheds_every_request_with_conservation() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        let plan = FaultPlan {
            events: vec![
                FaultEvent {
                    device: 0,
                    at: 0.0,
                    kind: FaultKind::Crash,
                },
                FaultEvent {
                    device: 1,
                    at: 0.0,
                    kind: FaultKind::Crash,
                },
            ],
            retry_budget: 1,
            backoff_base: 0.0,
            ..FaultPlan::default()
        };
        let n = 3;
        let requests: Vec<ServeRequest> = (0..n)
            .map(|i| ServeRequest::new(i, 0.0, Workload::Head { beta: 32 }))
            .collect();
        let scfg = StreamingConfig {
            window: 0,
            batch_window: 0.0,
            faults: Some(plan),
            ..StreamingConfig::default()
        };
        let report = serve_real_stream(
            requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &scfg,
            Pacing::Closed,
            false,
            7,
            &mut NullSink,
        )
        .unwrap();
        assert_eq!(report.offered, n);
        assert_eq!(report.served, 0);
        assert_eq!(report.shed, n);
        assert!(report.max_retries <= 1, "retries {}", report.max_retries);
        assert_eq!(report.served + report.rejected + report.shed, report.offered);
    }

    /// Property: the real backend honours the admission window — across
    /// window sizes, live requests never exceed it, and every request is
    /// accounted for. `batch_window: 0` keeps units singleton so the bound
    /// is airtight.
    #[test]
    fn real_backend_live_requests_bounded_by_window() {
        let Some(rt) = artifact_runtime() else {
            return;
        };
        let platform = Platform::paper_testbed(3, 1);
        for &window in &[1usize, 2, 4] {
            let n = 8;
            let requests: Vec<ServeRequest> = (0..n)
                .map(|i| ServeRequest::new(i, i as f64 * 1e-4, Workload::Head { beta: 32 }))
                .collect();
            let scfg = StreamingConfig {
                window,
                batch_window: 0.0,
                ..StreamingConfig::default()
            };
            let report = serve_real_stream(
                requests,
                &rt,
                &platform,
                &PaperCost,
                &mut Clustering,
                &scfg,
                Pacing::Closed,
                false,
                7,
                &mut NullSink,
            )
            .unwrap();
            assert_eq!(report.served + report.rejected, n, "window {window}");
            assert_eq!(report.served, n, "window {window}: unexpected rejections");
            assert!(
                report.peak_live_requests <= window,
                "window {window}: peak {} live requests",
                report.peak_live_requests
            );
            assert_eq!(report.window, window);
        }
    }
}
