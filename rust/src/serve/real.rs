//! The real serving path: batched requests over the threaded executor.
//!
//! Each batch's apps are merged into one multi-tenant application and run
//! through [`execute_dag_multi`] — the same thread-per-queue Algorithm-1
//! machinery as single-DAG execution, with up to `cfg.tenancy` components
//! resident per device, so requests genuinely share the PJRT worker pool.
//!
//! Arrival times order and coalesce the stream (closed-loop replay): the
//! serving loop does not sleep between batches, so wall-clock dispatch can
//! outrun the nominal arrival process. Latency and deadline semantics are
//! **end-to-end and shared with the sim path** — defined in one place,
//! [`super::engine::request_outcome`], which also documents the closed-loop
//! degeneration to service latency. The real path is **deadline-blind at
//! scheduling time**: `execute_dag_multi` feeds neutral metadata to
//! `SchedView`, so `edf` degenerates to rank order here (threading
//! `CompMeta` into the executor is a ROADMAP item), and there is no
//! preemption (OS threads cannot be displaced mid-kernel). Deadlines are
//! still *judged* and reported per request.

use super::admission::batch_requests;
use super::engine::{
    admit_all, build_report, request_outcome, RequestOutcome, ServeConfig, ServeReport,
};
use super::merge::{merge_apps, MergedApp};
use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::Result;
use crate::exec::execute_dag_multi;
use crate::graph::{Dag, Partition};
use crate::platform::Platform;
use crate::runtime::Runtime;
use crate::sched::Policy;
use crate::trace::Lane;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Deterministic request input data (xorshift64*), keyed by seed.
fn seeded_input(seed: u64, len: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
    (0..len)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect()
}

/// Seed every isolated input buffer of a **merged** batch, keyed by
/// `(request id, request-local buffer index)` rather than the merged buffer
/// id: a request's data must not depend on where the merge placed it, i.e.
/// on batch composition — the per-request deterministic contract. `members`
/// are the request ids of the batch's apps, in merge order (the merge's
/// per-app buffer ranges recover each buffer's owner and local index).
fn seed_isolated_inputs(
    merged: &MergedApp,
    members: &[usize],
    seed: u64,
) -> HashMap<usize, Vec<f32>> {
    let mut inputs = HashMap::new();
    for (i, &req_id) in members.iter().enumerate() {
        let lo = merged.buffer_offsets[i];
        let hi = merged
            .buffer_offsets
            .get(i + 1)
            .copied()
            .unwrap_or(merged.dag.buffers.len());
        for b in &merged.dag.buffers[lo..hi] {
            let is_input = merged.dag.kernels[b.kernel].inputs.contains(&b.id);
            if is_input && merged.dag.buffer_pred(b.id).is_none() {
                let local = (b.id - lo) as u64;
                let key = seed
                    ^ (req_id as u64).wrapping_mul(0x9E3779B97F4A7C15)
                    ^ (local + 1).wrapping_mul(0xD1B54A32D192ED03);
                inputs.insert(b.id, seeded_input(key, (b.size_bytes / 4) as usize));
            }
        }
    }
    inputs
}

/// Serve the stream for real. Requires every kernel of every admitted
/// workload to carry an AOT artifact (generator workloads do at the AOT β
/// sizes); missing artifacts reject the batch with a typed executor error.
pub fn serve_real(
    requests: &[ServeRequest],
    runtime: &Arc<Runtime>,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &ServeConfig,
    seed: u64,
) -> Result<ServeReport> {
    // Admission: same rules and ordering as the sim path.
    let (admitted, apps, rejected): (Vec<ServeRequest>, Vec<(Dag, Partition)>, _) =
        admit_all(requests);

    let batches = batch_requests(&admitted, cfg.batch_window);
    let epoch = Instant::now();
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(admitted.len());
    let mut busy = vec![0.0f64; platform.devices.len()];
    for batch in &batches {
        let members: Vec<(Dag, Partition)> =
            batch.members.iter().map(|&m| apps[m].clone()).collect();
        let member_ids: Vec<usize> = batch.members.iter().map(|&m| admitted[m].id).collect();
        let merged = merge_apps(&members)?;
        let inputs = seed_isolated_inputs(&merged, &member_ids, seed);
        let start = epoch.elapsed().as_secs_f64();
        let report = execute_dag_multi(
            &merged.dag,
            &merged.partition,
            platform,
            cost,
            policy,
            runtime,
            &inputs,
            cfg.tenancy.max(1),
        )?;
        let finish = epoch.elapsed().as_secs_f64();
        for (d, b) in busy.iter_mut().enumerate() {
            *b += report
                .trace
                .busy_time(|l| matches!(l, Lane::Device { dev, .. } if *dev == d));
        }
        for &m in &batch.members {
            outcomes.push(request_outcome(&admitted[m], start, finish));
        }
    }

    let makespan = epoch.elapsed().as_secs_f64();
    let device_util = busy
        .into_iter()
        .map(|b| if makespan > 0.0 { b / makespan } else { 0.0 })
        .collect();
    Ok(build_report(
        "real",
        policy.name(),
        outcomes,
        rejected,
        makespan,
        device_util,
        0,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::sched::Clustering;
    use crate::serve::request::Workload;
    use std::path::Path;

    #[test]
    fn serves_for_real_when_artifacts_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let Ok(rt) = Runtime::new(&dir) else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        let rt = Arc::new(rt);
        let platform = Platform::paper_testbed(3, 1);
        let requests: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest::new(i, 0.0, Workload::Head { beta: 32 }))
            .collect();
        let report = serve_real(
            &requests,
            &rt,
            &platform,
            &PaperCost,
            &mut Clustering,
            &ServeConfig::default(),
            7,
        )
        .unwrap();
        assert_eq!(report.outcomes.len(), 4);
        assert!(report.makespan > 0.0);
        assert!(report.throughput_rps > 0.0);
    }

    #[test]
    fn seeded_inputs_are_deterministic() {
        let app = Workload::Head { beta: 64 }.instantiate().unwrap();
        let merged = merge_apps(std::slice::from_ref(&app)).unwrap();
        let a = seed_isolated_inputs(&merged, &[5], 7);
        let b = seed_isolated_inputs(&merged, &[5], 7);
        assert_eq!(a.len(), b.len());
        for (k, v) in &a {
            assert_eq!(Some(v), b.get(k));
        }
        // X and the four weights per head: 7 isolated inputs.
        assert_eq!(a.len(), 7);
        // A different request id yields different data for the same slots.
        let c = seed_isolated_inputs(&merged, &[6], 7);
        assert!(a.iter().any(|(k, v)| c.get(k) != Some(v)));
    }

    #[test]
    fn seeded_inputs_independent_of_batch_composition() {
        // The same request (id 5) must see identical input data whether it
        // is merged alone or behind another request — data is keyed by
        // (request id, request-local buffer index), not merged buffer id.
        let app = Workload::Head { beta: 64 }.instantiate().unwrap();
        let solo = merge_apps(std::slice::from_ref(&app)).unwrap();
        let solo_inputs = seed_isolated_inputs(&solo, &[5], 7);

        let pair = merge_apps(&[app.clone(), app.clone()]).unwrap();
        let pair_inputs = seed_isolated_inputs(&pair, &[9, 5], 7);
        let off = pair.buffer_offsets[1];
        for (&b, data) in &solo_inputs {
            assert_eq!(
                Some(data),
                pair_inputs.get(&(b + off)),
                "buffer {b} data depends on batch composition"
            );
        }
    }
}
