//! Admission control and the batching front-end.

use super::request::ServeRequest;
use crate::cost::CostModel;
use crate::error::{Error, Result};
use crate::graph::{Dag, Partition};
use crate::platform::Platform;
use crate::sched::app_solo_estimate;
use std::collections::HashMap;

/// Request-level validation (arrival, deadline budget) — the per-request
/// half of [`admit`], split out so the template cache can skip re-running
/// the *application*-level half for an already-validated cached template.
pub(crate) fn validate_request(req: &ServeRequest) -> Result<()> {
    let reject = |msg: String| Error::Admission(format!("request {}: {msg}", req.id));
    if !req.arrival.is_finite() || req.arrival < 0.0 {
        return Err(reject(format!("invalid arrival time {}", req.arrival)));
    }
    if let Some(d) = req.deadline {
        if !d.is_finite() || d <= 0.0 {
            return Err(reject(format!("non-positive deadline {d}")));
        }
    }
    Ok(())
}

/// Application-level validation — structural checks over an instantiated
/// workload, rejections typed and naming the request id. Run once per
/// *template* under the cache (the result is workload-determined), once
/// per request for uncacheable workloads.
pub(crate) fn validate_app(req: &ServeRequest, dag: &Dag, partition: &Partition) -> Result<()> {
    let reject = |msg: String| Error::Admission(format!("request {}: {msg}", req.id));
    if dag.num_kernels() == 0 {
        return Err(reject("empty DAG".into()));
    }
    dag.validate().map_err(|e| reject(e.to_string()))?;
    if partition.assignment.len() != dag.num_kernels() {
        return Err(reject(format!(
            "partition covers {} kernels, DAG has {}",
            partition.assignment.len(),
            dag.num_kernels()
        )));
    }
    if partition.components.is_empty() {
        return Err(reject("partition has no components".into()));
    }
    Ok(())
}

/// Validate one request and materialize its application. Every rejection is
/// a typed [`Error::Admission`] naming the request id.
pub fn admit(req: &ServeRequest) -> Result<(Dag, Partition)> {
    validate_request(req)?;
    let (dag, partition) = req
        .workload
        .instantiate()
        .map_err(|e| Error::Admission(format!("request {}: {e}", req.id)))?;
    validate_app(req, &dag, &partition)?;
    Ok((dag, partition))
}

/// Laxity-based admission control over an already-admitted application: a
/// deadline-carrying request whose laxity is already negative *at arrival*
/// — its budget is smaller than the optimistic solo estimate of its own
/// work ([`app_solo_estimate`]) — cannot be served on time by any policy,
/// so it is rejected up front instead of occupying devices only to miss.
/// Laxity at arrival needs no clock: `deadline_absolute - arrival -
/// estimate` is exactly `budget - estimate`. Deadline-free requests are
/// never laxity-rejected.
pub fn check_laxity(
    req: &ServeRequest,
    app: &(Dag, Partition),
    platform: &Platform,
    cost: &dyn CostModel,
) -> Result<()> {
    if req.deadline.is_some() {
        let estimate = app_solo_estimate(&app.0, &app.1, platform, cost);
        return check_laxity_estimate(req, estimate);
    }
    Ok(())
}

/// [`check_laxity`] against a precomputed solo estimate — the admission
/// loop memoizes the estimate per workload signature (it is a pure
/// function of the app/platform/cost model), so a 10k-request stream of
/// one signature prices its laxity gate once instead of 10k times.
pub(crate) fn check_laxity_estimate(req: &ServeRequest, estimate: f64) -> Result<()> {
    if let Some(budget) = req.deadline {
        let laxity = budget - estimate;
        if laxity < 0.0 {
            return Err(Error::Admission(format!(
                "request {}: negative laxity at arrival ({:.3} ms): deadline budget \
                 {:.3} ms < solo estimate {:.3} ms",
                req.id,
                laxity * 1e3,
                budget * 1e3,
                estimate * 1e3
            )));
        }
    }
    Ok(())
}

/// The memoized laxity gate shared by every serving path: one instance per
/// run holds the per-signature solo-estimate memo, so a 10k-request stream
/// of one signature prices its laxity check once. With `laxity_admission`
/// off (or for deadline-free requests) [`check`](Self::check) is a no-op —
/// the same short-circuit the former `admit_all` loop applied inline.
#[derive(Debug, Default)]
pub(crate) struct AdmissionGate {
    laxity_admission: bool,
    solo_memo: HashMap<String, f64>,
}

impl AdmissionGate {
    pub(crate) fn new(laxity_admission: bool) -> Self {
        AdmissionGate {
            laxity_admission,
            solo_memo: HashMap::new(),
        }
    }

    /// Laxity-check one admitted request against its application template.
    /// Uncacheable workloads bypass the memo (their signature is not
    /// injective, so a cached estimate could belong to a different app).
    pub(crate) fn check(
        &mut self,
        req: &ServeRequest,
        app: &(Dag, Partition),
        platform: &Platform,
        cost: &dyn CostModel,
    ) -> Result<()> {
        if !self.laxity_admission || req.deadline.is_none() {
            return Ok(());
        }
        let estimate = if req.workload.cacheable() {
            *self
                .solo_memo
                .entry(req.workload.signature())
                .or_insert_with(|| app_solo_estimate(&app.0, &app.1, platform, cost))
        } else {
            app_solo_estimate(&app.0, &app.1, platform, cost)
        };
        check_laxity_estimate(req, estimate)
    }
}

/// [`admit`] plus [`check_laxity`] in one call — the SLO-aware admission
/// front door, rejecting with a typed [`Error::Admission`] either way.
pub fn admit_slo(
    req: &ServeRequest,
    platform: &Platform,
    cost: &dyn CostModel,
) -> Result<(Dag, Partition)> {
    let app = admit(req)?;
    check_laxity(req, &app, platform, cost)?;
    Ok(app)
}

/// A coalesced dispatch group: compatible requests arriving within the
/// batching window of the group opener share one release instant.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    /// Coalesced dispatch instant: the latest member arrival (the batch
    /// waits for its slowest member, never reorders time backwards).
    pub release: f64,
    /// Indices into the admitted-request list, arrival order.
    pub members: Vec<usize>,
}

/// Group `requests` (must be sorted by arrival) into batches: a request
/// joins the open batch **of its own workload signature** iff it arrives
/// within `window` seconds of that batch's opener; otherwise it opens a
/// fresh batch (replacing any stale open batch of the same signature).
/// Keeping one open batch *per signature* means interleaved arrivals of
/// different classes cannot fragment each other's coalescing — A@0,
/// B@0.5 ms, A@1 ms with a 2 ms window yields two batches, not three.
/// `window <= 0` disables coalescing (one batch per request). Batches are
/// returned in opener-arrival order.
pub fn batch_requests(requests: &[ServeRequest], window: f64) -> Vec<Batch> {
    let mut batches: Vec<Batch> = Vec::new();
    // Open batch per signature: (signature, batch index, opener arrival).
    let mut open: Vec<(String, usize, f64)> = Vec::new();
    for (i, req) in requests.iter().enumerate() {
        let sig = req.workload.signature();
        let joins = if window > 0.0 {
            open.iter()
                .find(|(s, _, oarr)| *s == sig && req.arrival <= oarr + window)
                .map(|&(_, bi, _)| bi)
        } else {
            None
        };
        match joins {
            Some(bi) => {
                batches[bi].members.push(i);
                batches[bi].release = batches[bi].release.max(req.arrival);
            }
            None => {
                let bi = batches.len();
                batches.push(Batch {
                    release: req.arrival,
                    members: vec![i],
                });
                match open.iter().position(|(s, _, _)| *s == sig) {
                    Some(slot) => open[slot] = (sig, bi, req.arrival),
                    None => open.push((sig, bi, req.arrival)),
                }
            }
        }
    }
    batches
}

/// An open (still-growing) batch inside [`StreamBatcher`]: the streaming
/// analog of [`Batch`], carrying its opener arrival and workload signature
/// so later arrivals can join or expire it.
#[derive(Debug, Clone, PartialEq)]
pub struct OpenBatch {
    /// Workload signature shared by every member.
    pub signature: String,
    /// Arrival of the request that opened the batch (the coalescing
    /// window is measured from here).
    pub opener: f64,
    /// Coalesced dispatch instant so far: the latest member arrival.
    pub release: f64,
    /// Request ids (caller-chosen), arrival order.
    pub members: Vec<usize>,
}

/// Incremental [`batch_requests`]: arrivals are offered one at a time (in
/// nondecreasing arrival order) and batches are emitted as soon as they
/// provably cannot grow — when some later arrival falls outside their
/// opener's window. Openers ascend, so expired batches always form a
/// prefix of the open list and batches close in opener order: the closed
/// sequence is **exactly** the [`batch_requests`] output for the same
/// stream (proven by `stream_batcher_matches_batch_requests`).
///
/// [`StreamBatcher::horizon`] is the earliest open opener — the streaming
/// driver must not simulate past it, because a batch releases no earlier
/// than its opener and must be admitted before the simulator reaches its
/// release.
#[derive(Debug, Default)]
pub struct StreamBatcher {
    window: f64,
    /// Open batches, opener-ascending. At most one per signature: a stale
    /// same-signature batch is necessarily expired (that is *why* the new
    /// opener did not join it) and was closed by the prefix rule.
    open: Vec<OpenBatch>,
}

impl StreamBatcher {
    /// `window <= 0` disables coalescing (one batch per request).
    pub fn new(window: f64) -> Self {
        StreamBatcher {
            window,
            open: Vec::new(),
        }
    }

    /// Number of batches still open.
    pub fn open_len(&self) -> usize {
        self.open.len()
    }

    /// Earliest instant the simulator may not advance past while batches
    /// are open ([`f64::INFINITY`] when none are).
    pub fn horizon(&self) -> f64 {
        self.open.first().map(|b| b.opener).unwrap_or(f64::INFINITY)
    }

    /// Offer the next arrival (nondecreasing `arrival` across calls);
    /// batches this arrival expires are appended to `closed` in opener
    /// order, then the request joins its signature's open batch or opens a
    /// fresh one.
    pub fn offer(&mut self, id: usize, signature: &str, arrival: f64, closed: &mut Vec<OpenBatch>) {
        // Prefix-close every batch this arrival can no longer join. Any
        // future arrival is >= this one, so expiry is permanent.
        let expired = self
            .open
            .iter()
            .take_while(|b| !(self.window > 0.0 && arrival <= b.opener + self.window))
            .count();
        closed.extend(self.open.drain(..expired));
        if self.window > 0.0 {
            if let Some(b) = self.open.iter_mut().find(|b| b.signature == signature) {
                debug_assert!(arrival <= b.opener + self.window);
                b.members.push(id);
                b.release = b.release.max(arrival);
                return;
            }
        }
        self.open.push(OpenBatch {
            signature: signature.to_string(),
            opener: arrival,
            release: arrival,
            members: vec![id],
        });
    }

    /// End of stream: close every remaining open batch, in opener order.
    pub fn flush(&mut self, closed: &mut Vec<OpenBatch>) {
        closed.append(&mut self.open);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::Workload;

    fn head_req(id: usize, arrival: f64) -> ServeRequest {
        ServeRequest::new(id, arrival, Workload::Head { beta: 64 })
    }

    #[test]
    fn admit_accepts_well_formed_requests() {
        let (dag, part) = admit(&head_req(0, 0.0)).unwrap();
        assert_eq!(dag.num_kernels(), 8);
        assert_eq!(part.components.len(), 1);
    }

    #[test]
    fn admit_rejects_bad_arrival_and_deadline() {
        let mut r = head_req(3, -1.0);
        assert!(matches!(admit(&r), Err(Error::Admission(_))));
        r.arrival = 0.0;
        r.deadline = Some(0.0);
        let e = admit(&r).unwrap_err();
        assert!(matches!(e, Error::Admission(_)));
        assert!(e.to_string().contains("request 3"), "{e}");
    }

    #[test]
    fn admit_rejects_malformed_spec_workloads() {
        // A cyclic DAG assembled from raw parts (DagBuilder would refuse it).
        let cyclic = {
            let mut b = crate::graph::DagBuilder::new();
            let k0 = b.kernel("a", crate::platform::DeviceType::Gpu, 1, 1);
            let k1 = b.kernel("b", crate::platform::DeviceType::Gpu, 1, 1);
            let o0 = b.out_buf(k0, 4);
            let i0 = b.in_buf(k0, 4);
            let o1 = b.out_buf(k1, 4);
            let i1 = b.in_buf(k1, 4);
            b.edge(o0, i1);
            b.edge(o1, i0);
            let mut dag = b.dag().clone();
            dag.reindex();
            dag
        };
        let partition = Partition {
            components: vec![],
            assignment: vec![],
        };
        let r = ServeRequest::new(
            9,
            0.0,
            Workload::Spec {
                dag: cyclic,
                partition,
            },
        );
        let e = admit(&r).unwrap_err();
        assert!(matches!(e, Error::Admission(_)), "{e}");
    }

    #[test]
    fn admit_slo_rejects_negative_laxity_at_arrival() {
        use crate::cost::PaperCost;
        let platform = Platform::paper_testbed(3, 1);
        // A budget no schedule can meet: far below the solo estimate.
        let mut r = head_req(4, 0.0);
        r.deadline = Some(1e-9);
        let e = admit_slo(&r, &platform, &PaperCost).unwrap_err();
        assert!(matches!(e, Error::Admission(_)), "{e}");
        assert!(e.to_string().contains("negative laxity"), "{e}");
        assert!(e.to_string().contains("request 4"), "{e}");
        // A generous budget admits.
        r.deadline = Some(10.0);
        admit_slo(&r, &platform, &PaperCost).unwrap();
        // Deadline-free requests are never laxity-rejected.
        r.deadline = None;
        admit_slo(&r, &platform, &PaperCost).unwrap();
    }

    #[test]
    fn batching_coalesces_compatible_close_arrivals() {
        let reqs = vec![
            head_req(0, 0.000),
            head_req(1, 0.001),                                        // joins
            ServeRequest::new(2, 0.0015, Workload::Mm2 { beta: 64 }), // wrong class
            head_req(3, 0.010),                                        // outside window
        ];
        let batches = batch_requests(&reqs, 0.002);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].members, vec![0, 1]);
        assert!((batches[0].release - 0.001).abs() < 1e-12);
        assert_eq!(batches[1].members, vec![2]);
        assert_eq!(batches[2].members, vec![3]);
    }

    #[test]
    fn zero_window_disables_coalescing() {
        let reqs = vec![head_req(0, 0.0), head_req(1, 0.0)];
        assert_eq!(batch_requests(&reqs, 0.0).len(), 2);
    }

    #[test]
    fn interleaved_signatures_do_not_fragment_batches() {
        // A@0, B@0.0005, A@0.001 with a 2 ms window: the B arrival must not
        // close A's open batch — 2 batches, not 3.
        let reqs = vec![
            head_req(0, 0.0),
            ServeRequest::new(1, 0.0005, Workload::Mm2 { beta: 64 }),
            head_req(2, 0.001),
        ];
        let batches = batch_requests(&reqs, 0.002);
        assert_eq!(batches.len(), 2, "{batches:?}");
        assert_eq!(batches[0].members, vec![0, 2]);
        assert!((batches[0].release - 0.001).abs() < 1e-12);
        assert_eq!(batches[1].members, vec![1]);
        assert!((batches[1].release - 0.0005).abs() < 1e-12);
    }

    #[test]
    fn stale_open_batch_is_replaced_per_signature() {
        // The second A batch opens after the window; a third A arrival
        // within the *new* opener's window joins the new batch, not the old.
        let reqs = vec![head_req(0, 0.0), head_req(1, 0.010), head_req(2, 0.011)];
        let batches = batch_requests(&reqs, 0.002);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].members, vec![0]);
        assert_eq!(batches[1].members, vec![1, 2]);
    }

    /// Run the same stream through [`batch_requests`] and the incremental
    /// [`StreamBatcher`], asserting identical batches in identical order.
    fn assert_stream_batcher_matches(reqs: &[ServeRequest], window: f64) {
        let want = batch_requests(reqs, window);
        let mut batcher = StreamBatcher::new(window);
        let mut got: Vec<OpenBatch> = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            batcher.offer(i, &r.workload.signature(), r.arrival, &mut got);
        }
        batcher.flush(&mut got);
        assert_eq!(got.len(), want.len(), "window {window}: batch count");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.members, w.members, "window {window}");
            assert_eq!(g.release.to_bits(), w.release.to_bits(), "window {window}");
        }
    }

    #[test]
    fn stream_batcher_matches_batch_requests() {
        // Interleaved signatures, joins, window expiries, duplicates.
        let reqs = vec![
            head_req(0, 0.000),
            head_req(1, 0.001),
            ServeRequest::new(2, 0.0015, Workload::Mm2 { beta: 64 }),
            ServeRequest::new(3, 0.0016, Workload::Mm2 { beta: 64 }),
            head_req(4, 0.0019),
            head_req(5, 0.010),
            ServeRequest::new(6, 0.0105, Workload::Mm2 { beta: 64 }),
            head_req(7, 0.011),
            head_req(8, 0.030),
        ];
        for window in [0.0, 0.001, 0.002, 0.005, 1.0] {
            assert_stream_batcher_matches(&reqs, window);
        }
    }

    #[test]
    fn stream_batcher_closes_expired_batches_incrementally() {
        let mut b = StreamBatcher::new(0.002);
        let mut closed = Vec::new();
        b.offer(0, "A", 0.0, &mut closed);
        b.offer(1, "A", 0.001, &mut closed);
        assert!(closed.is_empty());
        assert_eq!(b.horizon(), 0.0);
        // An arrival past the opener's window closes the batch even though
        // it belongs to a different signature.
        b.offer(2, "B", 0.005, &mut closed);
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].members, vec![0, 1]);
        assert!((closed[0].release - 0.001).abs() < 1e-12);
        assert_eq!(b.horizon(), 0.005);
        assert_eq!(b.open_len(), 1);
        b.flush(&mut closed);
        assert_eq!(closed.len(), 2);
        assert_eq!(b.horizon(), f64::INFINITY);
    }

    #[test]
    fn stream_batcher_zero_window_yields_singletons() {
        let mut b = StreamBatcher::new(0.0);
        let mut closed = Vec::new();
        b.offer(0, "A", 0.0, &mut closed);
        b.offer(1, "A", 0.0, &mut closed);
        b.flush(&mut closed);
        assert_eq!(closed.len(), 2);
        assert!(closed.iter().all(|c| c.members.len() == 1));
    }
}
