//! Arrival processes for the serving stream.
//!
//! Both are deterministic given their inputs — a hard requirement for the
//! CI bench smoke and the seeded serving tests.

use crate::error::{Error, Result};
use crate::json::Json;

/// xorshift64* uniform in (0, 1].
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15).max(1))
    }

    fn next_unit(&mut self) -> f64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        let v = self.0.wrapping_mul(0x2545F4914F6CDD1D);
        ((v >> 11) as f64 + 1.0) / (1u64 << 53) as f64
    }
}

/// The one rate-domain rule: arrival rates must be finite and positive.
fn validate_rate(rate: f64) -> Result<f64> {
    if !rate.is_finite() || rate <= 0.0 {
        return Err(Error::Admission(format!(
            "arrival rate must be finite and positive, got {rate}"
        )));
    }
    Ok(rate)
}

/// `n` Poisson arrivals at `rate` requests/second: exponential
/// inter-arrival gaps via inverse-CDF sampling, seeded and reproducible.
/// A non-finite or non-positive rate is a typed [`Error::Admission`] — it
/// used to be silently clamped to 1.0, which made `--rate 0` look like a
/// valid (and surprisingly slow) arrival process.
pub fn poisson_arrivals(seed: u64, n: usize, rate: f64) -> Result<Vec<f64>> {
    let rate = validate_rate(rate)?;
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    Ok((0..n)
        .map(|_| {
            t += -rng.next_unit().ln() / rate;
            t
        })
        .collect())
}

/// Lazy Poisson arrival stream: the iterator form of [`poisson_arrivals`],
/// with **identical** RNG math — the first `n` items equal
/// `poisson_arrivals(seed, n, rate)` element for element. The streaming
/// soak bench walks millions of arrivals through this without ever
/// materializing the arrival vector (bounded memory starts at the arrival
/// process).
pub struct PoissonStream {
    rng: Rng,
    rate: f64,
    t: f64,
}

impl PoissonStream {
    pub fn new(seed: u64, rate: f64) -> Result<Self> {
        let rate = validate_rate(rate)?;
        Ok(PoissonStream {
            rng: Rng::new(seed),
            rate,
            t: 0.0,
        })
    }
}

impl Iterator for PoissonStream {
    type Item = f64;

    fn next(&mut self) -> Option<f64> {
        self.t += -self.rng.next_unit().ln() / self.rate;
        Some(self.t)
    }
}

/// Parse a CLI `--rate` value: a finite, positive requests/second figure
/// (same domain rule as [`poisson_arrivals`]). Unparseable text and
/// out-of-domain values are typed [`Error::Admission`]s, not silent
/// fallbacks to a default rate.
pub fn parse_rate(text: &str) -> Result<f64> {
    let rate: f64 = text
        .trim()
        .parse()
        .map_err(|_| Error::Admission(format!("invalid arrival rate '{text}'")))?;
    validate_rate(rate)
}

/// Parse a trace file: a JSON array of non-negative arrival instants
/// (seconds), e.g. `[0.0, 0.0021, 0.0058]`. Returned sorted ascending.
pub fn trace_arrivals(text: &str) -> Result<Vec<f64>> {
    let root = Json::parse(text)?;
    let arr = root
        .as_arr()
        .ok_or_else(|| Error::Admission("arrival trace must be a JSON array".into()))?;
    let mut out = Vec::with_capacity(arr.len());
    for v in arr {
        let t = v
            .as_f64()
            .ok_or_else(|| Error::Admission("arrival trace entries must be numbers".into()))?;
        if !t.is_finite() || t < 0.0 {
            return Err(Error::Admission(format!("invalid arrival instant {t}")));
        }
        out.push(t);
    }
    out.sort_by(|a, b| a.total_cmp(b));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_deterministic_and_monotone() {
        let a = poisson_arrivals(42, 64, 500.0).unwrap();
        let b = poisson_arrivals(42, 64, 500.0).unwrap();
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[1] >= w[0]));
        assert!(a.iter().all(|&t| t > 0.0 && t.is_finite()));
        // Different seed, different stream.
        assert_ne!(a, poisson_arrivals(43, 64, 500.0).unwrap());
    }

    #[test]
    fn poisson_stream_matches_materialized_arrivals() {
        let want = poisson_arrivals(42, 256, 1500.0).unwrap();
        let got: Vec<f64> = PoissonStream::new(42, 1500.0).unwrap().take(256).collect();
        assert_eq!(got, want);
        assert!(matches!(
            PoissonStream::new(1, 0.0),
            Err(Error::Admission(_))
        ));
    }

    #[test]
    fn poisson_mean_gap_tracks_rate() {
        let a = poisson_arrivals(7, 4000, 100.0).unwrap();
        let mean_gap = a.last().unwrap() / a.len() as f64;
        assert!((mean_gap - 0.01).abs() < 0.002, "mean gap {mean_gap}");
    }

    #[test]
    fn poisson_rejects_degenerate_rates_with_typed_error() {
        for rate in [0.0, -5.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let e = poisson_arrivals(7, 4, rate).unwrap_err();
            assert!(matches!(e, Error::Admission(_)), "rate {rate}: {e}");
        }
    }

    #[test]
    fn parse_rate_covers_the_cli_rate_path() {
        assert_eq!(parse_rate("2000").unwrap(), 2000.0);
        assert_eq!(parse_rate(" 12.5 ").unwrap(), 12.5);
        for bad in ["soon", "", "0", "-3", "inf", "nan"] {
            let e = parse_rate(bad).unwrap_err();
            assert!(matches!(e, Error::Admission(_)), "'{bad}': {e}");
        }
    }

    #[test]
    fn trace_parses_and_sorts() {
        let t = trace_arrivals("[0.003, 0.001, 0.002]").unwrap();
        assert_eq!(t, vec![0.001, 0.002, 0.003]);
    }

    #[test]
    fn trace_rejects_garbage() {
        assert!(matches!(
            trace_arrivals("{\"a\": 1}"),
            Err(Error::Admission(_))
        ));
        assert!(matches!(trace_arrivals("[-1.0]"), Err(Error::Admission(_))));
        assert!(matches!(
            trace_arrivals("[\"soon\"]"),
            Err(Error::Admission(_))
        ));
    }
}
