//! Sharded multi-replica serving: N concurrent serve loops behind the
//! signature-affinity [`Router`].
//!
//! The platform is partitioned into `N` equal replica shards. Each shard
//! owns a **full serving stack** — its own scheduler state, its own
//! [`StreamSim`](crate::sim::StreamSim) or
//! [`RealBackend`](super::RealBackend) (with its own PJRT runtime and
//! executable cache on the real path), and its own [`TemplateCache`] — and
//! runs the unmodified [`serve_core`](super::serve_core) loop over a
//! per-shard arrival sub-stream. Nothing in the core changes: sharding is
//! a layer *above* it.
//!
//! # Concurrency shape
//!
//! [`std::thread::scope`] spawns one worker per shard; each receives its
//! sub-stream over a bounded [`mpsc::sync_channel`] whose blocking `send`
//! is the feed thread's backpressure (a slow shard stalls the feeder, not
//! memory). The feed thread walks the global arrival iterator in order,
//! asks the [`Router`] for a shard (global duplicate rejection, affinity,
//! power-of-two-choices spill), and forwards. Outcome emission funnels
//! through one shared [`OutcomeSink`] behind a mutex, each emission tagged
//! back to the router so queue depths and SLO observations stay current.
//!
//! # Single-shard identity
//!
//! At `shards == 1` the runner is a pass-through: one channel, one serve
//! loop over the whole platform, a router whose only decision is
//! `Shard(0)`, duplicate tracking disabled (the core's own check governs,
//! with its narrower admission→batch-close window), and the merge returns
//! the single report unchanged. The integration test pins this
//! **byte-for-byte** against the unsharded [`super::serve_stream`] path.
//!
//! # Report merging
//!
//! Per-shard [`StreamReport`]s merge into one global report: counters sum,
//! makespan is the max, latency histograms merge **bin-wise**
//! ([`LatencyHistogram::merge`]) so global p50/p99 keep the histogram's
//! ≤1% error bound, and per-shard device utilizations are re-based onto
//! the global device table (busy seconds over the *global* makespan).
//! Conservation holds globally: `served + rejected + shed == offered`,
//! where router-level duplicate rejections count as offered-and-rejected.

use std::path::Path;
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::cache::TemplateCache;
use super::core::{serve_core, OutcomeSink, StreamReport, StreamingConfig, REJECT_SAMPLE_CAP};
use super::engine::{Pacing, RequestOutcome};
use super::real::RealBackend;
use super::request::ServeRequest;
use super::router::{RouteDecision, Router, RouterStats};
use super::streaming::run_sim_core;
use crate::cost::CostModel;
use crate::error::{Error, Result};
use crate::platform::{DeviceId, Platform};
use crate::runtime::Runtime;
use crate::sched::Policy;
use crate::serve::histogram::LatencyHistogram;

/// The scaled-platform shape the CLI serves on, kept symbolic so the
/// sharded runner can cut it into per-shard sub-platforms.
#[derive(Debug, Clone, Copy)]
pub struct PlatformShape {
    pub gpus: usize,
    pub cpus: usize,
    pub queues_gpu: usize,
    pub queues_cpu: usize,
}

impl PlatformShape {
    /// The whole platform, as `Platform::scaled` builds it.
    pub fn full(&self) -> Platform {
        Platform::scaled(self.gpus, self.cpus, self.queues_gpu, self.queues_cpu)
    }

    /// Typed validation that the shape cuts evenly into `shards` replicas.
    pub fn validate_shards(&self, shards: usize) -> Result<()> {
        if shards == 0 {
            return Err(Error::Admission("--shards must be at least 1".into()));
        }
        if self.gpus < shards || self.gpus % shards != 0 {
            return Err(Error::Admission(format!(
                "{} GPU(s) cannot split into {shards} equal shard(s) \
                 (need a positive multiple of the shard count)",
                self.gpus
            )));
        }
        if self.cpus % shards != 0 {
            return Err(Error::Admission(format!(
                "{} CPU(s) cannot split into {shards} equal shard(s)",
                self.cpus
            )));
        }
        Ok(())
    }

    /// One shard's sub-platform: `1/shards` of the devices, same queue
    /// depths. Callers validate first.
    pub fn shard(&self, shards: usize) -> Platform {
        Platform::scaled(
            self.gpus / shards,
            self.cpus / shards,
            self.queues_gpu,
            self.queues_cpu,
        )
    }
}

/// Sharding knobs.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// Number of replica shards (1 = the unsharded path, bit-identical).
    pub shards: usize,
    /// Queue depth above which the affine shard spills
    /// ([`Router`] power-of-two-choices).
    pub spill_threshold: usize,
    /// Deadline-miss-rate target arming [`Router::rebalance`].
    pub slo_target: Option<f64>,
    /// Bound of each shard's arrival channel — the feed thread blocks when
    /// a shard falls this far behind (backpressure, not growth).
    pub channel_capacity: usize,
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec {
            shards: 1,
            spill_threshold: 64,
            slo_target: None,
            channel_capacity: 1024,
        }
    }
}

/// One shard's slice of the sharded report.
#[derive(Debug, Clone)]
pub struct ShardSummary {
    pub shard: usize,
    /// Requests the router forwarded to this shard.
    pub routed: usize,
    pub served: usize,
    pub rejected: usize,
    pub shed: usize,
    pub offered: usize,
    pub makespan: f64,
    pub throughput_rps: f64,
    pub peak_live_requests: usize,
    pub template_cache_misses: usize,
}

/// The merged outcome of a sharded run.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    /// The global view: counters summed, histograms merged bin-wise,
    /// device utilization on the full device table. Satisfies the same
    /// conservation invariant as any single-loop report.
    pub merged: StreamReport,
    pub shards: Vec<ShardSummary>,
    pub router: RouterStats,
    /// Wall seconds the feed thread spent inside the router — the
    /// numerator of the bench's router-overhead fraction.
    pub route_seconds: f64,
}

/// Per-shard sink: forwards every emission to the shared global sink (in
/// shard-completion order, interleaved across shards) and reports each
/// retired id back to the [`Router`] so depths and SLO observations track.
struct ShardSink<'a> {
    shard: usize,
    router: &'a Router,
    shared: &'a Mutex<&'a mut (dyn OutcomeSink + Send)>,
}

impl OutcomeSink for ShardSink<'_> {
    fn emit(&mut self, outcome: &RequestOutcome, devices: &[DeviceId]) -> Result<()> {
        let r = {
            let mut g = self.shared.lock().unwrap_or_else(|e| e.into_inner());
            (*g).emit(outcome, devices)
        };
        self.router
            .on_finished(outcome.id, self.shard, outcome.deadline_met);
        r
    }

    fn emit_shed(&mut self, outcome: &RequestOutcome, devices: &[DeviceId]) -> Result<()> {
        let r = {
            let mut g = self.shared.lock().unwrap_or_else(|e| e.into_inner());
            (*g).emit_shed(outcome, devices)
        };
        // Shed requests carry no served-deadline observation.
        self.router.on_finished(outcome.id, self.shard, None);
        r
    }

    fn emit_rejected(&mut self, id: usize, err: &Error) -> Result<()> {
        let r = {
            let mut g = self.shared.lock().unwrap_or_else(|e| e.into_inner());
            (*g).emit_rejected(id, err)
        };
        self.router.on_rejected(id, self.shard);
        r
    }

    fn flush(&mut self) -> Result<()> {
        let mut g = self.shared.lock().unwrap_or_else(|e| e.into_inner());
        (*g).flush()
    }
}

/// What the generic runner hands back before report assembly.
struct ShardRun {
    reports: Vec<StreamReport>,
    router: RouterStats,
    route_seconds: f64,
    duplicate_sample: Vec<(usize, String)>,
}

/// The generic sharded runner: spawn one `run_shard` worker per shard
/// under a thread scope, feed the arrival stream through the router, join,
/// and surface the first error (feed, worker, or panic) typed.
fn serve_sharded_with<I, F>(
    requests: I,
    spec: &ShardSpec,
    policies: Vec<Box<dyn Policy>>,
    sink: &mut (dyn OutcomeSink + Send),
    run_shard: F,
) -> Result<ShardRun>
where
    I: IntoIterator<Item = ServeRequest>,
    F: Fn(usize, Box<dyn Policy>, Receiver<ServeRequest>, &mut dyn OutcomeSink) -> Result<StreamReport>
        + Sync,
{
    let n = spec.shards.max(1);
    debug_assert_eq!(policies.len(), n, "one policy instance per shard");
    let router = Router::new(n, spec.spill_threshold, spec.slo_target);
    let shared: Mutex<&mut (dyn OutcomeSink + Send)> = Mutex::new(sink);
    let router_ref = &router;
    let shared_ref = &shared;
    let run_ref = &run_shard;
    let mut route_seconds = 0.0f64;
    let mut duplicate_sample: Vec<(usize, String)> = Vec::new();

    let reports: Result<Vec<StreamReport>> = std::thread::scope(|s| {
        let mut txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (i, policy) in policies.into_iter().enumerate() {
            let (tx, rx) = mpsc::sync_channel::<ServeRequest>(spec.channel_capacity.max(1));
            txs.push(tx);
            handles.push(s.spawn(move || {
                let mut shard_sink = ShardSink {
                    shard: i,
                    router: router_ref,
                    shared: shared_ref,
                };
                run_ref(i, policy, rx, &mut shard_sink)
            }));
        }

        // Feed: route each arrival, in global arrival order. A send error
        // means the shard's loop already exited — on error; remember a
        // typed feed error but still join every worker so the real cause
        // (the worker's own error) wins.
        let mut first_err: Option<Error> = None;
        for req in requests {
            let t0 = Instant::now();
            let decision = router_ref.route(&req);
            router_ref.rebalance();
            route_seconds += t0.elapsed().as_secs_f64();
            match decision {
                RouteDecision::Shard(shard) => {
                    if txs[shard].send(req).is_err() {
                        first_err = Some(Error::Sched(format!(
                            "shard {shard} stopped accepting requests mid-stream"
                        )));
                        break;
                    }
                }
                RouteDecision::Duplicate => {
                    if duplicate_sample.len() < REJECT_SAMPLE_CAP {
                        duplicate_sample.push((
                            req.id,
                            format!("request {}: duplicate id in flight (router)", req.id),
                        ));
                    }
                }
            }
        }
        // Close every channel: each shard's arrival iterator ends, its
        // serve loop drains and returns.
        drop(txs);

        let mut reports = Vec::with_capacity(n);
        for (i, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(report)) => reports.push(report),
                Ok(Err(e)) => {
                    // Prefer a worker's typed error over the feeder's
                    // derived send-failure.
                    first_err = Some(e);
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(Error::Sched(format!("shard {i} worker panicked")));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(reports),
        }
    });
    let reports = reports?;

    Ok(ShardRun {
        reports,
        router: router.stats(),
        route_seconds,
        duplicate_sample,
    })
}

/// Merge per-shard reports into one global [`StreamReport`].
///
/// Identity at one shard: the single report is returned **unchanged** (the
/// `--shards 1` byte-identity contract). Otherwise counters sum, makespan
/// is the max (shards run concurrently on disjoint devices), histograms
/// merge bin-wise, and shard-local device utilizations are re-based: shard
/// `s`'s local GPU `d` is global GPU `s·(gpus/shards)+d`, its local CPU
/// `j` is global CPU `s·(cpus/shards)+j` (after all GPUs), each converted
/// through busy seconds to a fraction of the **global** makespan.
pub fn merge_stream_reports(
    mut reports: Vec<StreamReport>,
    shape: &PlatformShape,
    shards: usize,
) -> StreamReport {
    assert!(!reports.is_empty(), "merge of zero shard reports");
    if reports.len() == 1 {
        return reports.pop().expect("len checked");
    }
    let makespan = reports.iter().fold(0.0f64, |m, r| m.max(r.makespan));
    let gpus_per_shard = shape.gpus / shards;
    let cpus_per_shard = shape.cpus / shards;
    let mut device_util = vec![0.0f64; shape.gpus + shape.cpus];

    let mut served = 0usize;
    let mut rejected = 0usize;
    let mut shed = 0usize;
    let mut offered = 0usize;
    let mut max_retries = 0u32;
    let mut rejected_sample: Vec<(usize, String)> = Vec::new();
    let mut laxity_rejections = 0usize;
    let mut deadline_total = 0usize;
    let mut deadline_misses = 0usize;
    let mut preemptions = 0usize;
    let mut peak_live_requests = 0usize;
    let mut peak_live_components = 0usize;
    let mut events = 0u64;
    let mut exec_cache_hits = 0usize;
    let mut exec_cache_misses = 0usize;
    let mut template_cache_hits = 0usize;
    let mut template_cache_misses = 0usize;
    let mut cold: Vec<f64> = Vec::new();
    let mut warm: Vec<f64> = Vec::new();
    let mut hist = LatencyHistogram::new();

    for (s, r) in reports.iter().enumerate() {
        served += r.served;
        rejected += r.rejected;
        shed += r.shed;
        offered += r.offered;
        max_retries = max_retries.max(r.max_retries);
        laxity_rejections += r.laxity_rejections;
        deadline_total += r.deadline_total;
        deadline_misses += r.deadline_misses;
        preemptions += r.preemptions;
        // Peaks sum: the shards are live at the same time, so the global
        // high-water mark is bounded by (and conservatively reported as)
        // the sum of per-shard peaks.
        peak_live_requests += r.peak_live_requests;
        peak_live_components += r.peak_live_components;
        events += r.events;
        exec_cache_hits += r.exec_cache_hits;
        exec_cache_misses += r.exec_cache_misses;
        template_cache_hits += r.template_cache_hits;
        template_cache_misses += r.template_cache_misses;
        if r.cold_batch_latency > 0.0 {
            cold.push(r.cold_batch_latency);
        }
        if r.warm_batch_latency > 0.0 {
            warm.push(r.warm_batch_latency);
        }
        hist.merge(&r.latency_hist);
        for (id, why) in &r.rejected_sample {
            if rejected_sample.len() < REJECT_SAMPLE_CAP {
                rejected_sample.push((*id, why.clone()));
            }
        }
        for (d, &util) in r.device_util.iter().enumerate() {
            let busy = util * r.makespan;
            let global = if d < gpus_per_shard {
                s * gpus_per_shard + d
            } else {
                shape.gpus + s * cpus_per_shard + (d - gpus_per_shard)
            };
            if let Some(slot) = device_util.get_mut(global) {
                *slot = if makespan > 0.0 { busy / makespan } else { 0.0 };
            }
        }
    }

    let mean = |xs: &[f64]| {
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    };
    StreamReport {
        policy: reports[0].policy.clone(),
        served,
        rejected,
        shed,
        offered,
        max_retries,
        rejected_sample,
        laxity_rejections,
        makespan,
        throughput_rps: if makespan > 0.0 {
            served as f64 / makespan
        } else {
            0.0
        },
        p50_latency: hist.quantile(0.50),
        p99_latency: hist.quantile(0.99),
        deadline_total,
        deadline_misses,
        deadline_miss_rate: if deadline_total > 0 {
            deadline_misses as f64 / deadline_total as f64
        } else {
            0.0
        },
        per_priority_p99: hist.per_priority_quantile(0.99),
        preemptions,
        device_util,
        window: reports[0].window,
        peak_live_requests,
        peak_live_components,
        events,
        pacing: reports[0].pacing,
        exec_cache_hits,
        exec_cache_misses,
        cold_batch_latency: mean(&cold),
        warm_batch_latency: mean(&warm),
        template_cache_hits,
        template_cache_misses,
        latency_hist: hist,
    }
}

/// Assemble the public report: per-shard summaries, router counters, and
/// the merged global view with router-level duplicate rejections folded
/// into the books (`offered` and `rejected` both grow by the duplicate
/// count, so global conservation covers requests no shard ever saw).
fn assemble_sharded_report(
    run: ShardRun,
    shape: &PlatformShape,
    spec: &ShardSpec,
) -> ShardedReport {
    let ShardRun {
        reports,
        router,
        route_seconds,
        duplicate_sample,
    } = run;
    let shards: Vec<ShardSummary> = reports
        .iter()
        .enumerate()
        .map(|(i, r)| ShardSummary {
            shard: i,
            routed: router.routed.get(i).copied().unwrap_or(0),
            served: r.served,
            rejected: r.rejected,
            shed: r.shed,
            offered: r.offered,
            makespan: r.makespan,
            throughput_rps: r.throughput_rps,
            peak_live_requests: r.peak_live_requests,
            template_cache_misses: r.template_cache_misses,
        })
        .collect();
    let mut merged = merge_stream_reports(reports, shape, spec.shards.max(1));
    merged.offered += router.duplicate_rejections;
    merged.rejected += router.duplicate_rejections;
    for s in duplicate_sample {
        if merged.rejected_sample.len() < REJECT_SAMPLE_CAP {
            merged.rejected_sample.push(s);
        }
    }
    ShardedReport {
        merged,
        shards,
        router,
        route_seconds,
    }
}

/// Sharded **simulated** streaming: N concurrent [`run_sim_core`] loops,
/// each over its own per-shard sub-platform and fresh [`TemplateCache`].
/// `policy_factory` is called once per shard (each loop owns a policy).
pub fn serve_sharded_stream<I>(
    requests: I,
    shape: PlatformShape,
    cost: &dyn CostModel,
    mut policy_factory: impl FnMut() -> Result<Box<dyn Policy>>,
    cfg: &StreamingConfig,
    spec: &ShardSpec,
    sink: &mut (dyn OutcomeSink + Send),
) -> Result<ShardedReport>
where
    I: IntoIterator<Item = ServeRequest>,
{
    shape.validate_shards(spec.shards)?;
    let policies: Vec<Box<dyn Policy>> = (0..spec.shards)
        .map(|_| policy_factory())
        .collect::<Result<_>>()?;
    let sub = shape.shard(spec.shards);
    let run = |_shard: usize,
               mut policy: Box<dyn Policy>,
               rx: Receiver<ServeRequest>,
               sink: &mut dyn OutcomeSink|
     -> Result<StreamReport> {
        let mut cache = TemplateCache::new();
        run_sim_core(
            rx,
            &sub,
            cost,
            policy.as_mut(),
            cfg,
            &mut cache,
            sink,
            REJECT_SAMPLE_CAP,
        )
    };
    let out = serve_sharded_with(requests, spec, policies, sink, run)?;
    Ok(assemble_sharded_report(out, &shape, spec))
}

/// Sharded **real** streaming: one [`RealBackend`] per shard, each with
/// its own [`Runtime`] (own PJRT clients and executable cache) over its
/// sub-platform. Per-shard fault plans address shard-local device ids.
/// Each shard's wall-clock epoch starts when its worker constructs the
/// backend — a few hundred microseconds of skew across shards, far below
/// the latencies the report cuts.
#[allow(clippy::too_many_arguments)]
pub fn serve_sharded_real_stream<I>(
    requests: I,
    artifact_dir: &Path,
    shape: PlatformShape,
    cost: &dyn CostModel,
    mut policy_factory: impl FnMut() -> Result<Box<dyn Policy>>,
    cfg: &StreamingConfig,
    pacing: Pacing,
    prewarm: bool,
    seed: u64,
    spec: &ShardSpec,
    sink: &mut (dyn OutcomeSink + Send),
) -> Result<ShardedReport>
where
    I: IntoIterator<Item = ServeRequest>,
{
    shape.validate_shards(spec.shards)?;
    let policies: Vec<Box<dyn Policy>> = (0..spec.shards)
        .map(|_| policy_factory())
        .collect::<Result<_>>()?;
    let sub = shape.shard(spec.shards);
    let run = |_shard: usize,
               mut policy: Box<dyn Policy>,
               rx: Receiver<ServeRequest>,
               sink: &mut dyn OutcomeSink|
     -> Result<StreamReport> {
        // Per-shard runtime: its own PJRT clients and executable cache —
        // the cache affinity the router preserves.
        let runtime = Arc::new(Runtime::new(artifact_dir)?);
        if prewarm {
            runtime.warmup()?;
        }
        let policy_name = policy.name().to_string();
        let mut cache = TemplateCache::new();
        let mut backend = RealBackend::new(
            &runtime,
            &sub,
            cost,
            policy.as_mut(),
            cfg.tenancy,
            pacing,
            seed,
        );
        if let Some(plan) = &cfg.faults {
            backend.install_faults(plan)?;
        }
        serve_core(
            rx,
            &sub,
            cost,
            &mut backend,
            cfg,
            &mut cache,
            sink,
            &policy_name,
            REJECT_SAMPLE_CAP,
        )
    };
    let out = serve_sharded_with(requests, spec, policies, sink, run)?;
    Ok(assemble_sharded_report(out, &shape, spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::serve::arrival::poisson_arrivals;
    use crate::serve::core::{CollectSink, NullSink};
    use crate::serve::request::Workload;
    use crate::sched::LeastLoaded;

    fn stream(n: usize, rate: f64) -> Vec<ServeRequest> {
        poisson_arrivals(13, n, rate)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let beta = 64 + 8 * (i as u64 % 16);
                let mut r = ServeRequest::new(i, t, Workload::Head { beta });
                if i % 6 == 0 {
                    r.deadline = Some(2.0);
                    r.priority = 1;
                }
                r
            })
            .collect()
    }

    fn factory() -> Result<Box<dyn Policy>> {
        Ok(Box::new(LeastLoaded))
    }

    #[test]
    fn shape_validation_rejects_uneven_cuts() {
        let shape = PlatformShape {
            gpus: 4,
            cpus: 2,
            queues_gpu: 3,
            queues_cpu: 1,
        };
        assert!(shape.validate_shards(1).is_ok());
        assert!(shape.validate_shards(2).is_ok());
        assert!(shape.validate_shards(0).is_err());
        assert!(shape.validate_shards(3).is_err());
        assert!(shape.validate_shards(8).is_err());
        let e = shape.validate_shards(3).unwrap_err();
        assert!(matches!(e, Error::Admission(_)), "{e}");
    }

    #[test]
    fn two_shards_conserve_and_sum_to_the_merged_report() {
        let shape = PlatformShape {
            gpus: 4,
            cpus: 2,
            queues_gpu: 3,
            queues_cpu: 1,
        };
        let reqs = stream(160, 2000.0);
        let n = reqs.len();
        let mut sink = CollectSink::default();
        let spec = ShardSpec {
            shards: 2,
            ..ShardSpec::default()
        };
        let r = serve_sharded_stream(
            reqs,
            shape,
            &PaperCost,
            factory,
            &StreamingConfig::default(),
            &spec,
            &mut sink,
        )
        .unwrap();
        let m = &r.merged;
        assert_eq!(m.offered, n);
        assert_eq!(m.served + m.rejected + m.shed, m.offered, "conservation");
        assert_eq!(m.served, sink.outcomes.len());
        assert_eq!(r.shards.len(), 2);
        let shard_served: usize = r.shards.iter().map(|s| s.served).sum();
        assert_eq!(shard_served, m.served);
        let routed: usize = r.router.routed.iter().sum();
        assert_eq!(routed, n, "every non-duplicate request routed");
        assert_eq!(m.device_util.len(), shape.gpus + shape.cpus);
        // Both shards saw work (16 signatures over 2 shards).
        assert!(r.shards.iter().all(|s| s.routed > 0));
        // Merged histogram backs the quantiles: count equals served.
        assert_eq!(m.latency_hist.count(), m.served);
    }

    #[test]
    fn merged_quantiles_equal_a_bin_wise_histogram_merge() {
        let shape = PlatformShape {
            gpus: 4,
            cpus: 2,
            queues_gpu: 3,
            queues_cpu: 1,
        };
        let spec = ShardSpec {
            shards: 2,
            ..ShardSpec::default()
        };
        let mut sink = NullSink;
        let r = serve_sharded_stream(
            stream(200, 2500.0),
            shape,
            &PaperCost,
            factory,
            &StreamingConfig::default(),
            &spec,
            &mut sink,
        )
        .unwrap();
        assert_eq!(
            r.merged.p99_latency.to_bits(),
            r.merged.latency_hist.quantile(0.99).to_bits()
        );
        assert_eq!(
            r.merged.p50_latency.to_bits(),
            r.merged.latency_hist.quantile(0.50).to_bits()
        );
    }
}
