//! Core DAG data structures: `G = ⟨(K, B), (E_I, E_O, E)⟩` (paper §3).
//!
//! Each buffer node belongs to exactly one kernel (its argument), so the
//! kernel↔buffer edge sets `E_I`/`E_O` are stored implicitly as buffer
//! ownership + kind; the cross-kernel buffer-to-buffer set `E` is explicit.

use crate::error::{Error, Result};
use crate::platform::DeviceType;
use std::collections::HashSet;

/// Index of a kernel node in the DAG.
pub type KernelId = usize;
/// Index of a buffer node in the DAG.
pub type BufferId = usize;

/// Whether a buffer is a kernel input, output, or both (paper Fig. 8
/// `inputBuffers` / `outputBuffers` / `ioBuffers`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufferKind {
    Input,
    Output,
    /// Read-modify-write buffer (e.g. vsin's in-place vector).
    Io,
}

/// Paper §3 copy classification for kernel-buffer dependencies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyClass {
    /// No buffer-to-buffer edge touches this buffer: the host must supply
    /// (write) or retrieve (read) it unconditionally.
    Isolated,
    /// Connected through `E` to another kernel's buffer: the copy is only
    /// materialized across task-component boundaries.
    Dependent,
}

/// A computational kernel node (circular node in the paper's figures).
#[derive(Debug, Clone)]
pub struct KernelNode {
    pub id: KernelId,
    /// Kernel function name, e.g. `"gemm"`.
    pub name: String,
    /// Key into the artifact manifest for real execution, e.g. `"gemm_b256"`.
    /// `None` for simulation-only kernels.
    pub artifact: Option<String>,
    /// Device preference from the spec's `dev` field.
    pub dev_pref: DeviceType,
    /// NDRange geometry (spec `globalWorkSize`), kept for cost modeling.
    pub global_work_size: [u64; 3],
    pub work_dim: u8,
    /// Useful-work estimate for the cost model.
    pub flops: u64,
    /// Total bytes moved by H2D+D2H for this kernel's isolated traffic.
    pub bytes: u64,
    /// Input buffers in argument order.
    pub inputs: Vec<BufferId>,
    /// Output buffers in argument order.
    pub outputs: Vec<BufferId>,
}

/// A buffer node (rectangular node in the paper's figures).
#[derive(Debug, Clone)]
pub struct Buffer {
    pub id: BufferId,
    /// Owning kernel (the kernel for which this is an argument).
    pub kernel: KernelId,
    pub kind: BufferKind,
    /// Size in bytes (spec `size` × sizeof(type)).
    pub size_bytes: u64,
    /// Argument position in the kernel invocation (spec `pos`).
    pub pos: usize,
}

/// The application DAG `G`.
#[derive(Debug, Clone, Default)]
pub struct Dag {
    pub kernels: Vec<KernelNode>,
    pub buffers: Vec<Buffer>,
    /// `E ⊆ B_O × B_I`: producer output buffer → consumer input buffer.
    pub buffer_edges: Vec<(BufferId, BufferId)>,
    /// Adjacency index over `buffer_edges`, built by [`Dag::reindex`]
    /// (§Perf: `buffer_pred`/`buffer_succs` are the hottest graph queries in
    /// both `setup_cq` and the simulator). Empty ⇒ fall back to scanning.
    pred_cache: Vec<Option<BufferId>>,
    succ_cache: Vec<Vec<BufferId>>,
}

impl Dag {
    pub fn num_kernels(&self) -> usize {
        self.kernels.len()
    }

    /// (Re)build the adjacency index. Called by `DagBuilder::build`; call
    /// again after mutating `buffer_edges` directly.
    pub fn reindex(&mut self) {
        self.pred_cache = vec![None; self.buffers.len()];
        self.succ_cache = vec![Vec::new(); self.buffers.len()];
        for &(src, dst) in &self.buffer_edges {
            if src < self.buffers.len() && dst < self.buffers.len() {
                self.pred_cache[dst] = Some(src);
                self.succ_cache[src].push(dst);
            }
        }
    }

    fn indexed(&self) -> bool {
        self.pred_cache.len() == self.buffers.len()
    }

    /// Immediate predecessor buffer of `b` under `E`, if any.
    /// (Each input buffer has at most one producer.)
    pub fn buffer_pred(&self, b: BufferId) -> Option<BufferId> {
        if self.indexed() {
            return self.pred_cache[b];
        }
        self.buffer_edges
            .iter()
            .find(|&&(_, dst)| dst == b)
            .map(|&(src, _)| src)
    }

    /// Immediate successor buffers of `b` under `E`.
    pub fn buffer_succs(&self, b: BufferId) -> Vec<BufferId> {
        if self.indexed() {
            return self.succ_cache[b].clone();
        }
        self.buffer_edges
            .iter()
            .filter(|&&(src, _)| src == b)
            .map(|&(_, dst)| dst)
            .collect()
    }

    /// Paper §3: an input buffer is an *isolated write* iff no `E` edge ends
    /// at it; otherwise it is a *dependent write*.
    pub fn write_class(&self, b: BufferId) -> CopyClass {
        if self.buffer_pred(b).is_some() {
            CopyClass::Dependent
        } else {
            CopyClass::Isolated
        }
    }

    /// Paper §3: an output buffer is an *isolated read* iff no `E` edge
    /// starts at it; otherwise it is a *dependent read*.
    pub fn read_class(&self, b: BufferId) -> CopyClass {
        if self.buffer_succs(b).is_empty() {
            CopyClass::Isolated
        } else {
            CopyClass::Dependent
        }
    }

    /// Kernel-level predecessors of `k`: producers of buffers feeding `k`'s
    /// input buffers through `E`.
    pub fn kernel_preds(&self, k: KernelId) -> Vec<KernelId> {
        let mut out = Vec::new();
        for &bi in &self.kernels[k].inputs {
            if let Some(bp) = self.buffer_pred(bi) {
                let p = self.buffers[bp].kernel;
                if !out.contains(&p) {
                    out.push(p);
                }
            }
        }
        out
    }

    /// Kernel-level successors of `k`.
    pub fn kernel_succs(&self, k: KernelId) -> Vec<KernelId> {
        let mut out = Vec::new();
        for &bo in &self.kernels[k].outputs {
            for bs in self.buffer_succs(bo) {
                let s = self.buffers[bs].kernel;
                if !out.contains(&s) {
                    out.push(s);
                }
            }
        }
        out
    }

    /// Structural validation: ownership consistency, `E` endpoints are
    /// (output, input) pairs of *different* kernels, and acyclicity.
    pub fn validate(&self) -> Result<()> {
        for k in &self.kernels {
            for &b in k.inputs.iter().chain(&k.outputs) {
                if b >= self.buffers.len() {
                    return Err(Error::Graph(format!(
                        "kernel {} references unknown buffer {b}",
                        k.id
                    )));
                }
                if self.buffers[b].kernel != k.id {
                    return Err(Error::Graph(format!(
                        "buffer {b} owned by kernel {} but referenced by {}",
                        self.buffers[b].kernel, k.id
                    )));
                }
            }
        }
        for &(src, dst) in &self.buffer_edges {
            if src >= self.buffers.len() || dst >= self.buffers.len() {
                return Err(Error::Graph(format!("dangling edge ({src},{dst})")));
            }
            let (bs, bd) = (&self.buffers[src], &self.buffers[dst]);
            if bs.kind == BufferKind::Input {
                return Err(Error::Graph(format!(
                    "edge source buffer {src} is an input buffer"
                )));
            }
            if bd.kind == BufferKind::Output {
                return Err(Error::Graph(format!(
                    "edge target buffer {dst} is an output buffer"
                )));
            }
            if bs.kernel == bd.kernel {
                return Err(Error::Graph(format!(
                    "self edge within kernel {} ({src}->{dst})",
                    bs.kernel
                )));
            }
        }
        // An input buffer must have at most one producer.
        let mut seen: HashSet<BufferId> = HashSet::new();
        for &(_, dst) in &self.buffer_edges {
            if !seen.insert(dst) {
                return Err(Error::Graph(format!(
                    "input buffer {dst} has multiple producers"
                )));
            }
        }
        // Acyclicity via Kahn's algorithm on kernels.
        if crate::graph::rank::topo_order(self).len() != self.kernels.len() {
            return Err(Error::Graph("kernel dependency cycle".into()));
        }
        Ok(())
    }

    /// Kernels with no predecessors (DAG sources).
    pub fn source_kernels(&self) -> Vec<KernelId> {
        (0..self.kernels.len())
            .filter(|&k| self.kernel_preds(k).is_empty())
            .collect()
    }

    /// Kernels with no successors (DAG sinks).
    pub fn sink_kernels(&self) -> Vec<KernelId> {
        (0..self.kernels.len())
            .filter(|&k| self.kernel_succs(k).is_empty())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagBuilder;

    /// The paper's Fig. 6 DAG: five kernels k0..k4 in one component.
    /// k0(b2,b3)->b4; k1(b5,b6)->b9; k2(b7,b8)->b10; k3(b11,..)->b13;
    /// k4(b12,..)->b14; edges (b4,b6),(b4,b7),(b9,b11),(b10,b12) intra-ish.
    pub fn fig6_dag() -> (Dag, Vec<KernelId>) {
        let mut b = DagBuilder::new();
        let k0 = b.kernel("k0", DeviceType::Gpu, 1024, 1024);
        let k1 = b.kernel("k1", DeviceType::Gpu, 1024, 1024);
        let k2 = b.kernel("k2", DeviceType::Gpu, 1024, 1024);
        let k3 = b.kernel("k3", DeviceType::Gpu, 1024, 1024);
        let k4 = b.kernel("k4", DeviceType::Gpu, 1024, 1024);
        // External producer kernels feeding k0 (the "different component"
        // predecessors in Fig. 6 are outside T; we model them as kp).
        let kp = b.kernel("kp", DeviceType::Cpu, 16, 16);
        let b0 = b.out_buf(kp, 64);
        let b1 = b.out_buf(kp, 64);
        let b2 = b.in_buf(k0, 64);
        let b3 = b.in_buf(k0, 64);
        let b4 = b.out_buf(k0, 64);
        let b5 = b.in_buf(k1, 64); // isolated write
        let b6 = b.in_buf(k1, 64);
        let b7 = b.in_buf(k2, 64);
        let b8 = b.in_buf(k2, 64); // isolated write
        let b9 = b.out_buf(k1, 64);
        let b10 = b.out_buf(k2, 64);
        let b11 = b.in_buf(k3, 64);
        let b12 = b.in_buf(k4, 64);
        let b13 = b.out_buf(k3, 64);
        let b14 = b.out_buf(k4, 64);
        // Downstream consumers (other component).
        let kn = b.kernel("kn", DeviceType::Cpu, 16, 16);
        let b15 = b.in_buf(kn, 64);
        let b16 = b.in_buf(kn, 64);
        b.edge(b0, b2);
        b.edge(b1, b3);
        b.edge(b4, b6);
        b.edge(b4, b7);
        b.edge(b9, b11);
        b.edge(b10, b12);
        b.edge(b13, b15);
        b.edge(b14, b16);
        let _ = (b5, b8);
        (b.build().unwrap(), vec![k0, k1, k2, k3, k4, kp, kn])
    }

    #[test]
    fn fig6_structure() {
        let (dag, ks) = fig6_dag();
        let (k0, k1, k2, k3, k4, kp, _kn) =
            (ks[0], ks[1], ks[2], ks[3], ks[4], ks[5], ks[6]);
        assert_eq!(dag.kernel_preds(k0), vec![kp]);
        assert_eq!(dag.kernel_preds(k1), vec![k0]);
        assert_eq!(dag.kernel_preds(k2), vec![k0]);
        assert_eq!(dag.kernel_preds(k3), vec![k1]);
        assert_eq!(dag.kernel_preds(k4), vec![k2]);
        let mut succ = dag.kernel_succs(k0);
        succ.sort();
        assert_eq!(succ, vec![k1, k2]);
        dag.validate().unwrap();
    }

    #[test]
    fn copy_classification_matches_paper() {
        let (dag, ks) = fig6_dag();
        let (k1, k2) = (ks[1], ks[2]);
        // (b5,k1) and (b8,k2) are isolated writes; everything else dependent.
        let b5 = dag.kernels[k1].inputs[0];
        let b6 = dag.kernels[k1].inputs[1];
        let b8 = dag.kernels[k2].inputs[1];
        assert_eq!(dag.write_class(b5), CopyClass::Isolated);
        assert_eq!(dag.write_class(b8), CopyClass::Isolated);
        assert_eq!(dag.write_class(b6), CopyClass::Dependent);
        // k3/k4 outputs feed kn => dependent reads.
        let b13 = dag.kernels[ks[3]].outputs[0];
        assert_eq!(dag.read_class(b13), CopyClass::Dependent);
    }

    #[test]
    fn validate_rejects_cycle() {
        let mut b = DagBuilder::new();
        let k0 = b.kernel("a", DeviceType::Gpu, 1, 1);
        let k1 = b.kernel("b", DeviceType::Gpu, 1, 1);
        let i0 = b.in_buf(k0, 4);
        let o0 = b.out_buf(k0, 4);
        let i1 = b.in_buf(k1, 4);
        let o1 = b.out_buf(k1, 4);
        b.edge(o0, i1);
        b.edge(o1, i0);
        assert!(b.build().is_err());
    }

    #[test]
    fn validate_rejects_multi_producer() {
        let mut b = DagBuilder::new();
        let k0 = b.kernel("a", DeviceType::Gpu, 1, 1);
        let k1 = b.kernel("b", DeviceType::Gpu, 1, 1);
        let k2 = b.kernel("c", DeviceType::Gpu, 1, 1);
        let o0 = b.out_buf(k0, 4);
        let o1 = b.out_buf(k1, 4);
        let i2 = b.in_buf(k2, 4);
        b.edge(o0, i2);
        b.edge(o1, i2);
        assert!(b.build().is_err());
    }

    #[test]
    fn sources_and_sinks() {
        let (dag, ks) = fig6_dag();
        assert_eq!(dag.source_kernels(), vec![ks[5]]);
        assert_eq!(dag.sink_kernels(), vec![ks[6]]);
    }
}
