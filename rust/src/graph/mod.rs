//! The OpenCL-style application DAG model from paper §3:
//! `G = ⟨(K, B), (E_I, E_O, E)⟩`.
//!
//! * [`dag`] — kernels, buffers, the three edge sets, structural queries and
//!   the isolated/dependent copy classification.
//! * [`component`] — task components `T ⊆ K`, the `FRONT/END/IN` kernel
//!   classification (Defs 1–3) and intra/inter edge classification.
//! * [`rank`] — topological order and bottom-level ranks (HEFT upward rank).
//! * [`builder`] — ergonomic construction API used by the spec frontend and
//!   the generators in [`crate::transformer`].

pub mod builder;
pub mod component;
pub mod dag;
pub mod rank;

pub use builder::DagBuilder;
pub use component::{EdgeClass, Partition, TaskComponent};
pub use dag::{Buffer, BufferId, BufferKind, CopyClass, Dag, KernelId, KernelNode};
pub use rank::{bottom_level_ranks, topo_order};
