//! Topological ordering and bottom-level ranks.
//!
//! The bottom-level rank of a kernel is "the maximum time left to finish all
//! kernels in the path starting from k to the last kernel in the DAG"
//! (paper §5, citing HEFT [16]). It orders the priority frontier `F` in both
//! the clustering scheme and the dynamic baselines.

use super::dag::{Dag, KernelId};

/// Kahn topological order over kernels. Returns fewer than `num_kernels`
/// entries iff the graph has a cycle (used by `Dag::validate`).
pub fn topo_order(dag: &Dag) -> Vec<KernelId> {
    let n = dag.num_kernels();
    let mut indeg = vec![0usize; n];
    let mut succs: Vec<Vec<KernelId>> = vec![Vec::new(); n];
    for k in 0..n {
        for s in dag.kernel_succs(k) {
            succs[k].push(s);
            indeg[s] += 1;
        }
    }
    let mut queue: Vec<KernelId> = (0..n).filter(|&k| indeg[k] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(k) = queue.pop() {
        order.push(k);
        for &s in &succs[k] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                queue.push(s);
            }
        }
    }
    order
}

/// Bottom-level rank per kernel: `rank(k) = w(k) + max_succ rank(succ)`,
/// where `w(k)` is the kernel's execution-time estimate (caller supplies,
/// typically the cross-device mean as in HEFT).
pub fn bottom_level_ranks(dag: &Dag, weights: &[f64]) -> Vec<f64> {
    let n = dag.num_kernels();
    assert_eq!(weights.len(), n, "one weight per kernel");
    let order = topo_order(dag);
    let mut rank = vec![0.0f64; n];
    for &k in order.iter().rev() {
        let succ_max = dag
            .kernel_succs(k)
            .into_iter()
            .map(|s| rank[s])
            .fold(0.0f64, f64::max);
        rank[k] = weights[k] + succ_max;
    }
    rank
}

/// Critical-path length of the DAG under `weights` (a lower bound on any
/// schedule's makespan — used by the simulator's property tests).
pub fn critical_path(dag: &Dag, weights: &[f64]) -> f64 {
    bottom_level_ranks(dag, weights)
        .into_iter()
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagBuilder;
    use crate::platform::DeviceType;

    /// Chain a -> b -> c plus isolated d.
    fn chain() -> (Dag, [KernelId; 4]) {
        let mut bld = DagBuilder::new();
        let a = bld.kernel("a", DeviceType::Gpu, 1, 1);
        let b = bld.kernel("b", DeviceType::Gpu, 1, 1);
        let c = bld.kernel("c", DeviceType::Gpu, 1, 1);
        let d = bld.kernel("d", DeviceType::Gpu, 1, 1);
        let oa = bld.out_buf(a, 4);
        let ib = bld.in_buf(b, 4);
        let ob = bld.out_buf(b, 4);
        let ic = bld.in_buf(c, 4);
        bld.out_buf(c, 4);
        bld.in_buf(d, 4);
        bld.edge(oa, ib);
        bld.edge(ob, ic);
        (bld.build().unwrap(), [a, b, c, d])
    }

    #[test]
    fn topo_respects_edges() {
        let (dag, [a, b, c, _]) = chain();
        let order = topo_order(&dag);
        assert_eq!(order.len(), 4);
        let pos = |k| order.iter().position(|&x| x == k).unwrap();
        assert!(pos(a) < pos(b));
        assert!(pos(b) < pos(c));
    }

    #[test]
    fn ranks_decrease_along_chain() {
        let (dag, [a, b, c, d]) = chain();
        let r = bottom_level_ranks(&dag, &[2.0, 3.0, 5.0, 1.0]);
        assert_eq!(r[c], 5.0);
        assert_eq!(r[b], 8.0);
        assert_eq!(r[a], 10.0);
        assert_eq!(r[d], 1.0);
    }

    #[test]
    fn critical_path_is_max_rank() {
        let (dag, _) = chain();
        assert_eq!(critical_path(&dag, &[2.0, 3.0, 5.0, 1.0]), 10.0);
    }
}
