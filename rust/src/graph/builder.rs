//! Ergonomic DAG construction used by the spec frontend, the transformer
//! generators, and tests.

use super::dag::{Buffer, BufferId, BufferKind, Dag, KernelId, KernelNode};
use crate::error::Result;
use crate::platform::DeviceType;

/// Incremental builder for [`Dag`]. `build()` runs full validation.
#[derive(Debug, Default)]
pub struct DagBuilder {
    dag: Dag,
}

impl DagBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a kernel with a flops/bytes cost annotation.
    pub fn kernel(
        &mut self,
        name: &str,
        dev_pref: DeviceType,
        flops: u64,
        bytes: u64,
    ) -> KernelId {
        let id = self.dag.kernels.len();
        self.dag.kernels.push(KernelNode {
            id,
            name: name.to_string(),
            artifact: None,
            dev_pref,
            global_work_size: [1, 1, 1],
            work_dim: 1,
            flops,
            bytes,
            inputs: Vec::new(),
            outputs: Vec::new(),
        });
        id
    }

    /// Attach the runtime artifact key (manifest name) to a kernel.
    pub fn artifact(&mut self, k: KernelId, key: &str) -> &mut Self {
        self.dag.kernels[k].artifact = Some(key.to_string());
        self
    }

    /// Set NDRange geometry.
    pub fn ndrange(&mut self, k: KernelId, dim: u8, gws: [u64; 3]) -> &mut Self {
        self.dag.kernels[k].work_dim = dim;
        self.dag.kernels[k].global_work_size = gws;
        self
    }

    fn buf(&mut self, k: KernelId, kind: BufferKind, size_bytes: u64) -> BufferId {
        let id = self.dag.buffers.len();
        let pos = self.dag.kernels[k].inputs.len() + self.dag.kernels[k].outputs.len();
        self.dag.buffers.push(Buffer {
            id,
            kernel: k,
            kind,
            size_bytes,
            pos,
        });
        match kind {
            BufferKind::Input => self.dag.kernels[k].inputs.push(id),
            BufferKind::Output => self.dag.kernels[k].outputs.push(id),
            BufferKind::Io => {
                self.dag.kernels[k].inputs.push(id);
                self.dag.kernels[k].outputs.push(id);
            }
        }
        id
    }

    /// Add an input buffer to kernel `k`.
    pub fn in_buf(&mut self, k: KernelId, size_bytes: u64) -> BufferId {
        self.buf(k, BufferKind::Input, size_bytes)
    }

    /// Add an output buffer to kernel `k`.
    pub fn out_buf(&mut self, k: KernelId, size_bytes: u64) -> BufferId {
        self.buf(k, BufferKind::Output, size_bytes)
    }

    /// Add an in/out (read-modify-write) buffer to kernel `k`.
    pub fn io_buf(&mut self, k: KernelId, size_bytes: u64) -> BufferId {
        self.buf(k, BufferKind::Io, size_bytes)
    }

    /// Add a buffer-to-buffer dependency `(src_output, dst_input) ∈ E`.
    pub fn edge(&mut self, src: BufferId, dst: BufferId) -> &mut Self {
        self.dag.buffer_edges.push((src, dst));
        self
    }

    /// Finalize, validating the structure and building the adjacency index.
    pub fn build(mut self) -> Result<Dag> {
        self.dag.validate()?;
        self.dag.reindex();
        Ok(self.dag)
    }

    /// Peek at the DAG under construction (for generators).
    pub fn dag(&self) -> &Dag {
        &self.dag
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_buffer_is_both_input_and_output() {
        let mut b = DagBuilder::new();
        let k = b.kernel("vsin", DeviceType::Gpu, 10, 10);
        let io = b.io_buf(k, 16);
        let dag = b.build().unwrap();
        assert!(dag.kernels[k].inputs.contains(&io));
        assert!(dag.kernels[k].outputs.contains(&io));
    }

    #[test]
    fn positions_follow_insertion_order() {
        let mut b = DagBuilder::new();
        let k = b.kernel("gemm", DeviceType::Gpu, 10, 10);
        let a = b.in_buf(k, 16);
        let bb = b.in_buf(k, 16);
        let c = b.out_buf(k, 16);
        let dag = b.build().unwrap();
        assert_eq!(dag.buffers[a].pos, 0);
        assert_eq!(dag.buffers[bb].pos, 1);
        assert_eq!(dag.buffers[c].pos, 2);
    }
}
