//! Task components and the paper's Defs 1–3 (`FRONT`, `END`, `IN`) plus the
//! intra/inter classification of buffer-to-buffer edges.

use super::dag::{BufferId, Dag, KernelId};
use crate::error::{Error, Result};
use crate::platform::DeviceType;
use std::collections::HashSet;

/// A task component `T`: a set of kernels all mapped to one device *type*
/// (paper §3). Dispatch binds it to a concrete device at runtime.
#[derive(Debug, Clone)]
pub struct TaskComponent {
    pub id: usize,
    pub kernels: Vec<KernelId>,
    pub dev: DeviceType,
}

/// Classification of a buffer-to-buffer edge w.r.t. a partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeClass {
    /// Producer and consumer kernels in the same task component: the data
    /// stays resident on the device, no host round-trip.
    Intra,
    /// Crosses components: the producer's read and the consumer's write are
    /// both materialized.
    Inter,
}

/// A full task-component partition `T = {T_1..T_M}` with `⋃ T_i = K`.
#[derive(Debug, Clone)]
pub struct Partition {
    pub components: Vec<TaskComponent>,
    /// kernel id → component id.
    pub assignment: Vec<usize>,
}

impl Partition {
    /// Build and validate a partition: components must cover every kernel
    /// exactly once, and kernels in one component must share a device pref.
    pub fn new(dag: &Dag, groups: Vec<(Vec<KernelId>, DeviceType)>) -> Result<Self> {
        let mut assignment = vec![usize::MAX; dag.num_kernels()];
        let mut components = Vec::with_capacity(groups.len());
        for (cid, (kernels, dev)) in groups.into_iter().enumerate() {
            if kernels.is_empty() {
                return Err(Error::Partition(format!("component {cid} is empty")));
            }
            for &k in &kernels {
                if k >= dag.num_kernels() {
                    return Err(Error::Partition(format!("unknown kernel {k}")));
                }
                if assignment[k] != usize::MAX {
                    return Err(Error::Partition(format!(
                        "kernel {k} in components {} and {cid}",
                        assignment[k]
                    )));
                }
                assignment[k] = cid;
            }
            components.push(TaskComponent {
                id: cid,
                kernels,
                dev,
            });
        }
        if let Some(k) = assignment.iter().position(|&c| c == usize::MAX) {
            return Err(Error::Partition(format!("kernel {k} unassigned")));
        }
        Ok(Partition {
            components,
            assignment,
        })
    }

    /// One component per kernel (the paper's eager/HEFT setup), device pref
    /// taken from each kernel.
    pub fn singletons(dag: &Dag) -> Self {
        let groups = dag
            .kernels
            .iter()
            .map(|k| (vec![k.id], k.dev_pref))
            .collect();
        Self::new(dag, groups).expect("singleton partition is always valid")
    }

    pub fn component_of(&self, k: KernelId) -> usize {
        self.assignment[k]
    }

    /// Paper §3 edge classification.
    pub fn edge_class(&self, dag: &Dag, src: BufferId, dst: BufferId) -> EdgeClass {
        let pk = dag.buffers[src].kernel;
        let ck = dag.buffers[dst].kernel;
        if self.assignment[pk] == self.assignment[ck] {
            EdgeClass::Intra
        } else {
            EdgeClass::Inter
        }
    }

    /// Def 1: `FRONT(T)` — kernels with an input buffer whose immediate
    /// predecessor under `E` is produced by a kernel in a *different*
    /// component.
    pub fn front(&self, dag: &Dag, cid: usize) -> Vec<KernelId> {
        self.components[cid]
            .kernels
            .iter()
            .copied()
            .filter(|&k| {
                dag.kernels[k].inputs.iter().any(|&bi| {
                    dag.buffer_pred(bi)
                        .map(|bp| self.assignment[dag.buffers[bp].kernel] != cid)
                        .unwrap_or(false)
                })
            })
            .collect()
    }

    /// Def 2: `END(T)` — kernels with an output buffer whose immediate
    /// successor under `E` belongs to a kernel in a *different* component.
    pub fn end(&self, dag: &Dag, cid: usize) -> Vec<KernelId> {
        self.components[cid]
            .kernels
            .iter()
            .copied()
            .filter(|&k| {
                dag.kernels[k].outputs.iter().any(|&bo| {
                    dag.buffer_succs(bo)
                        .iter()
                        .any(|&bs| self.assignment[dag.buffers[bs].kernel] != cid)
                })
            })
            .collect()
    }

    /// Def 3: `IN(T)` — kernels in neither `FRONT(T)` nor `END(T)`.
    pub fn inner(&self, dag: &Dag, cid: usize) -> Vec<KernelId> {
        let front: HashSet<_> = self.front(dag, cid).into_iter().collect();
        let end: HashSet<_> = self.end(dag, cid).into_iter().collect();
        self.components[cid]
            .kernels
            .iter()
            .copied()
            .filter(|k| !front.contains(k) && !end.contains(k))
            .collect()
    }

    /// Kernels of `T` whose outputs never leave the component *and* are not
    /// DAG sinks — completion bookkeeping sinks: `END(T) ∪ terminal sinks`.
    /// Callback registration targets (paper §4B "Callback Assignment" plus
    /// the Fig. 2 final-read callback).
    pub fn callback_kernels(&self, dag: &Dag, cid: usize) -> Vec<KernelId> {
        let end: HashSet<_> = self.end(dag, cid).into_iter().collect();
        self.components[cid]
            .kernels
            .iter()
            .copied()
            .filter(|&k| {
                end.contains(&k)
                    || dag.kernels[k]
                        .outputs
                        .iter()
                        .all(|&bo| dag.buffer_succs(bo).is_empty())
            })
            .collect()
    }

    /// Callback kernels that genuinely need the *asynchronous* callback
    /// path: members of `END(T)` (inter-edge outputs must notify dependent
    /// components through `clSetEventCallback`). Terminal sinks whose reads
    /// are isolated use a cheap blocking wait instead — the clustering
    /// advantage the paper's §5 comparative evaluation dissects.
    pub fn async_callback_kernels(&self, dag: &Dag, cid: usize) -> Vec<KernelId> {
        self.end(dag, cid)
    }

    /// Inter-component kernel dependencies: `cid_from → cid_to` pairs.
    pub fn component_deps(&self, dag: &Dag) -> Vec<(usize, usize)> {
        let mut deps = Vec::new();
        for &(src, dst) in &dag.buffer_edges {
            let a = self.assignment[dag.buffers[src].kernel];
            let b = self.assignment[dag.buffers[dst].kernel];
            if a != b && !deps.contains(&(a, b)) {
                deps.push((a, b));
            }
        }
        deps
    }

    /// Components with no inter-component predecessors (initially ready).
    pub fn ready_components(&self, dag: &Dag) -> Vec<usize> {
        let deps = self.component_deps(dag);
        (0..self.components.len())
            .filter(|&c| !deps.iter().any(|&(_, b)| b == c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagBuilder;

    /// Fig. 6 with the explicit 3-component split: {kp}, {k0..k4}, {kn}.
    fn fig6() -> (Dag, Partition, Vec<KernelId>) {
        let mut b = DagBuilder::new();
        let kp = b.kernel("kp", DeviceType::Cpu, 1, 1);
        let k0 = b.kernel("k0", DeviceType::Gpu, 1, 1);
        let k1 = b.kernel("k1", DeviceType::Gpu, 1, 1);
        let k2 = b.kernel("k2", DeviceType::Gpu, 1, 1);
        let k3 = b.kernel("k3", DeviceType::Gpu, 1, 1);
        let k4 = b.kernel("k4", DeviceType::Gpu, 1, 1);
        let kn = b.kernel("kn", DeviceType::Cpu, 1, 1);
        let b0 = b.out_buf(kp, 4);
        let b1 = b.out_buf(kp, 4);
        let b2 = b.in_buf(k0, 4);
        let b3 = b.in_buf(k0, 4);
        let b4 = b.out_buf(k0, 4);
        let b5 = b.in_buf(k1, 4);
        let b6 = b.in_buf(k1, 4);
        let b7 = b.in_buf(k2, 4);
        let b8 = b.in_buf(k2, 4);
        let b9 = b.out_buf(k1, 4);
        let b10 = b.out_buf(k2, 4);
        let b11 = b.in_buf(k3, 4);
        let b12 = b.in_buf(k4, 4);
        let b13 = b.out_buf(k3, 4);
        let b14 = b.out_buf(k4, 4);
        let b15 = b.in_buf(kn, 4);
        let b16 = b.in_buf(kn, 4);
        b.edge(b0, b2);
        b.edge(b1, b3);
        b.edge(b4, b6);
        b.edge(b4, b7);
        b.edge(b9, b11);
        b.edge(b10, b12);
        b.edge(b13, b15);
        b.edge(b14, b16);
        let _ = (b5, b8);
        let dag = b.build().unwrap();
        let part = Partition::new(
            &dag,
            vec![
                (vec![kp], DeviceType::Cpu),
                (vec![k0, k1, k2, k3, k4], DeviceType::Gpu),
                (vec![kn], DeviceType::Cpu),
            ],
        )
        .unwrap();
        (dag, part, vec![kp, k0, k1, k2, k3, k4, kn])
    }

    #[test]
    fn front_end_in_match_paper_fig6() {
        let (dag, part, ks) = fig6();
        // Paper: FRONT(T) = {k0}, END(T) = {k3, k4}, IN(T) = {k1, k2}.
        assert_eq!(part.front(&dag, 1), vec![ks[1]]);
        let mut end = part.end(&dag, 1);
        end.sort();
        assert_eq!(end, vec![ks[4], ks[5]]);
        let mut inner = part.inner(&dag, 1);
        inner.sort();
        assert_eq!(inner, vec![ks[2], ks[3]]);
    }

    #[test]
    fn edge_classes_match_paper_fig6() {
        let (dag, part, _) = fig6();
        // (b4,b6),(b4,b7),(b9,b11),(b10,b12) intra; the rest inter.
        let mut intra = 0;
        let mut inter = 0;
        for &(s, d) in &dag.buffer_edges {
            match part.edge_class(&dag, s, d) {
                EdgeClass::Intra => intra += 1,
                EdgeClass::Inter => inter += 1,
            }
        }
        assert_eq!(intra, 4);
        assert_eq!(inter, 4);
    }

    #[test]
    fn component_readiness() {
        let (dag, part, _) = fig6();
        assert_eq!(part.ready_components(&dag), vec![0]); // only {kp}
        let deps = part.component_deps(&dag);
        assert!(deps.contains(&(0, 1)));
        assert!(deps.contains(&(1, 2)));
    }

    #[test]
    fn singleton_partition_covers_all() {
        let (dag, _, _) = fig6();
        let p = Partition::singletons(&dag);
        assert_eq!(p.components.len(), dag.num_kernels());
        for (k, &c) in p.assignment.iter().enumerate() {
            assert_eq!(p.components[c].kernels, vec![k]);
        }
    }

    #[test]
    fn rejects_overlapping_components() {
        let (dag, _, ks) = fig6();
        let bad = Partition::new(
            &dag,
            vec![
                (vec![ks[0], ks[1]], DeviceType::Cpu),
                (vec![ks[1]], DeviceType::Gpu),
            ],
        );
        assert!(bad.is_err());
    }

    #[test]
    fn callback_kernels_include_terminal_sinks() {
        let (dag, part, ks) = fig6();
        // kn is a terminal sink of the DAG -> callback kernel of component 2.
        assert_eq!(part.callback_kernels(&dag, 2), vec![ks[6]]);
        // Component 1's callback kernels are its END set.
        let mut cb = part.callback_kernels(&dag, 1);
        cb.sort();
        assert_eq!(cb, vec![ks[4], ks[5]]);
    }
}
