//! Minimal JSON substrate (parser + emitter).
//!
//! The build environment is fully offline (no serde/serde_json), and the
//! paper's design frontend is a JSON specification file (§4A Fig. 8) — so
//! JSON handling is implemented from scratch. Supports the full JSON value
//! grammar with `\uXXXX` escapes; numbers are f64 (adequate for spec sizes
//! and cost tables).

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Ordered map (BTreeMap keeps emission deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with a path context — spec parsing helper.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Spec(format!("missing field '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------------ builders

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------------ parse

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Spec(format!(
                "trailing characters at byte {} of JSON input",
                p.i
            )));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ emit

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, Some(2), 0);
        s
    }

    fn emit(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(n) = indent {
                out.push('\n');
                for _ in 0..n * d {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => emit_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    v.emit(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    emit_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.emit(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Spec("unexpected end of JSON input".into()))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Spec(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            )))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Spec(format!("invalid literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(Error::Spec(format!(
                "unexpected character '{}' at byte {}",
                c as char, self.i
            ))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(Error::Spec(format!(
                        "expected ',' or '}}' at byte {}, found '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => {
                    return Err(Error::Spec(format!(
                        "expected ',' or ']' at byte {}, found '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Spec("truncated \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::Spec("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Spec("bad \\u escape".into()))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => {
                            return Err(Error::Spec(format!(
                                "bad escape '\\{}'",
                                other as char
                            )))
                        }
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let start = self.i - 1;
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| Error::Spec("invalid UTF-8 in string".into()))?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Spec(format!("invalid number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nope").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn pretty_print_reparses() {
        let v = Json::parse(r#"{"x":[1,2],"y":{"z":true}}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integer_emission_is_exact() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }
}
