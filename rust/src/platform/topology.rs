//! Platform topology: the device set plus the PCIe/DMA interconnect.

use super::device::{Device, DeviceId, DeviceType};

/// The heterogeneous platform `P = {d_1..d_p}` plus interconnect parameters.
#[derive(Debug, Clone)]
pub struct Platform {
    pub devices: Vec<Device>,
    /// Effective PCIe bandwidth, bytes/second (paper platform: PCIe 3.0 x16,
    /// ~12 GB/s effective).
    pub pcie_bytes_per_sec: f64,
    /// Fixed DMA transfer setup latency, seconds.
    pub dma_latency: f64,
    /// Number of DMA copy engines (the paper models one).
    pub copy_engines: usize,
    /// Host-side cost of enqueueing one command during `setup_cq` (the
    /// paper notes clustering kernels "start executing much later" because
    /// all queues are populated before dispatch).
    pub enqueue_overhead: f64,
    /// Latency between an event completing and its callback having updated
    /// the frontier/device set (the paper's analysis of eager/HEFT gaps:
    /// callbacks run on host threads and are delayed under load).
    pub callback_latency: f64,
    /// Completion-notification latency for the *blocking-wait* path: task
    /// components with no inter-edge reads need no callbacks (paper §5
    /// comparative evaluation — the clustering advantage); the dispatch
    /// child thread wakes directly from clFinish.
    pub wait_latency: f64,
}

impl Platform {
    /// The paper's single-CPU + single-GPU testbed, with `q_gpu`/`q_cpu`
    /// command queues (a *mapping configuration* `mc` from Expt. 1).
    pub fn paper_testbed(q_gpu: usize, q_cpu: usize) -> Self {
        Platform {
            devices: vec![Device::gtx970(0, q_gpu), Device::i5_4690k(1, q_cpu)],
            pcie_bytes_per_sec: 12.0e9,
            dma_latency: 12e-6,
            copy_engines: 1,
            enqueue_overhead: 20e-6,
            callback_latency: 1.2e-3,
            wait_latency: 50e-6,
        }
    }

    /// A serving-scale platform: `n_gpu` GTX-970-shaped devices (each with
    /// its own DMA engine) plus `n_cpu` i5-shaped devices, with `q_gpu` /
    /// `q_cpu` command queues each. `scaled(1, 1, q, q')` has the same
    /// devices as [`Platform::paper_testbed`].
    pub fn scaled(n_gpu: usize, n_cpu: usize, q_gpu: usize, q_cpu: usize) -> Self {
        let mut devices = Vec::with_capacity(n_gpu + n_cpu);
        for _ in 0..n_gpu {
            devices.push(Device::gtx970(devices.len(), q_gpu));
        }
        for _ in 0..n_cpu {
            devices.push(Device::i5_4690k(devices.len(), q_cpu));
        }
        Platform {
            devices,
            copy_engines: n_gpu.max(1),
            ..Platform::paper_testbed(0, 0)
        }
    }

    pub fn device(&self, id: DeviceId) -> &Device {
        &self.devices[id]
    }

    /// Devices of a given type with at least one command queue.
    pub fn devices_of(&self, t: DeviceType) -> Vec<DeviceId> {
        self.devices
            .iter()
            .filter(|d| d.dtype == t && d.num_queues > 0)
            .map(|d| d.id)
            .collect()
    }

    /// Transfer time for `bytes` to/from a device. Devices sharing host
    /// memory (CPU) pay only a token mapping cost.
    pub fn transfer_time(&self, dev: DeviceId, bytes: u64) -> f64 {
        let d = self.device(dev);
        if d.shares_host_memory {
            1e-6 // clEnqueueMapBuffer-style zero-copy
        } else {
            self.dma_latency + bytes as f64 / self.pcie_bytes_per_sec
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testbed_matches_paper() {
        let p = Platform::paper_testbed(3, 1);
        assert_eq!(p.devices.len(), 2);
        assert_eq!(p.devices_of(DeviceType::Gpu), vec![0]);
        assert_eq!(p.devices_of(DeviceType::Cpu), vec![1]);
        assert_eq!(p.device(0).num_queues, 3);
    }

    #[test]
    fn zero_queue_devices_are_excluded() {
        // mc = (3, 0, _): CPU gets zero queues => not schedulable.
        let p = Platform::paper_testbed(3, 0);
        assert!(p.devices_of(DeviceType::Cpu).is_empty());
    }

    #[test]
    fn scaled_platform_shapes() {
        let p = Platform::scaled(2, 2, 3, 1);
        assert_eq!(p.devices.len(), 4);
        assert_eq!(p.devices_of(DeviceType::Gpu), vec![0, 1]);
        assert_eq!(p.devices_of(DeviceType::Cpu), vec![2, 3]);
        assert_eq!(p.copy_engines, 2);
        // Ids are dense and positional (device() indexes by id).
        for (i, d) in p.devices.iter().enumerate() {
            assert_eq!(d.id, i);
        }
    }

    #[test]
    fn cpu_transfers_near_free_gpu_pays_pcie() {
        let p = Platform::paper_testbed(1, 1);
        let mb = 1 << 20;
        let gpu = p.transfer_time(0, mb);
        let cpu = p.transfer_time(1, mb);
        assert!(gpu > 50.0 * cpu, "gpu={gpu} cpu={cpu}");
        // 1 MiB over ~12 GB/s ≈ 87 µs + latency.
        assert!(gpu > 80e-6 && gpu < 200e-6);
    }
}
