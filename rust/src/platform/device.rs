//! Device descriptors.

/// Index of a device within a [`super::Platform`].
pub type DeviceId = usize;

/// Device type, matching the spec file's `dev` field.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceType {
    Cpu,
    Gpu,
}

impl std::fmt::Display for DeviceType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeviceType::Cpu => write!(f, "cpu"),
            DeviceType::Gpu => write!(f, "gpu"),
        }
    }
}

impl std::str::FromStr for DeviceType {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "cpu" => Ok(DeviceType::Cpu),
            "gpu" => Ok(DeviceType::Gpu),
            other => Err(crate::error::Error::Spec(format!(
                "unknown device type '{other}' (expected cpu|gpu)"
            ))),
        }
    }
}

/// A compute device of the platform.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: DeviceId,
    pub name: String,
    pub dtype: DeviceType,
    /// Number of OpenCL-style command queues configured for this device
    /// (the spec's `cq` list; paper sweeps 0..=5).
    pub num_queues: usize,
    /// Hardware concurrency limit: Hyper-Q work queues on the GPU (32 on
    /// Kepler+), fissioned sub-devices on the CPU.
    pub hw_queues: usize,
    /// Peak compute throughput in GFLOP/s (for the analytic cost model).
    pub gflops: f64,
    /// Fraction of the device a *single* β=256 GEMM occupies; the
    /// contention model scales kernel occupancy from this anchor.
    pub base_occupancy: f64,
    /// Per-kernel fixed launch overhead, seconds.
    pub launch_overhead: f64,
    /// Whether the device shares the host address space (CPU): H2D/D2H
    /// transfers are elided / near-free, and completion callbacks attach to
    /// the ndrange event instead of reads (paper §4B).
    pub shares_host_memory: bool,
}

impl Device {
    /// The paper's GPU: NVIDIA GTX-970-shaped descriptor.
    pub fn gtx970(id: DeviceId, num_queues: usize) -> Self {
        Device {
            id,
            name: "sim-gtx970".into(),
            dtype: DeviceType::Gpu,
            num_queues,
            hw_queues: 32,
            gflops: 3494.0,
            // Calibrated so three concurrent β=256 GEMMs reproduce the
            // Fig. 5 / Fig. 11 ≈8–15% fine-grained win (cost::contention).
            base_occupancy: 0.7,
            launch_overhead: 25e-6,
            shares_host_memory: false,
        }
    }

    /// The paper's CPU: quad-core Intel i5-4690K-shaped descriptor.
    pub fn i5_4690k(id: DeviceId, num_queues: usize) -> Self {
        Device {
            id,
            name: "sim-i5-4690k".into(),
            dtype: DeviceType::Cpu,
            num_queues,
            hw_queues: 4,
            gflops: 220.0,
            // The work-greedy OpenCL CPU driver nearly saturates all four
            // cores with one kernel: little concurrency headroom (this is
            // what caps useful h_cpu at 1 in Fig. 11).
            base_occupancy: 0.85,
            launch_overhead: 8e-6,
            shares_host_memory: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_type_parse() {
        assert_eq!("gpu".parse::<DeviceType>().unwrap(), DeviceType::Gpu);
        assert_eq!("CPU".parse::<DeviceType>().unwrap(), DeviceType::Cpu);
        assert!("fpga".parse::<DeviceType>().is_err());
    }

    #[test]
    fn paper_devices_shape() {
        let g = Device::gtx970(0, 3);
        let c = Device::i5_4690k(1, 1);
        // The paper's observation: GPU has an order of magnitude more
        // processing capability than the CPU under consideration.
        assert!(g.gflops / c.gflops > 10.0);
        assert!(!g.shares_host_memory);
        assert!(c.shares_host_memory);
    }
}
