//! The heterogeneous platform model `P` (paper §3 Fig. 6): CPU + GPU devices
//! connected by a PCI-Express bus with a DMA copy engine.
//!
//! The paper's testbed — an NVIDIA GTX-970 (Hyper-Q, 13 SMs, 3.5 TFLOPS
//! peak, PCIe 3.0 x16) and a quad-core Intel i5-4690K — is unavailable here
//! (repro band 0), so the same descriptors parameterize (a) the
//! discrete-event simulator in [`crate::sim`] and (b) the PJRT-backed real
//! executor in [`crate::exec`], where "GPU" is a worker pool with GPU-shaped
//! concurrency limits (see DESIGN.md §Substitutions).

pub mod device;
pub mod topology;

pub use device::{Device, DeviceId, DeviceType};
pub use topology::Platform;
