//! Application-DAG generators for the paper's workloads:
//!
//! * [`head_dag`] — one transformer attention head: the 8-kernel DAG of
//!   Figs. 3/10 (3 projection GEMMs → transpose → score GEMM → softmax →
//!   context GEMM → output GEMM).
//! * [`transformer_dag`] — a full H-head layer (Expts 1–3), heads as
//!   independent branches.
//! * [`fork_join_dag`] — the Fig. 1 motivating fork-join graph.
//! * [`vadd_vsin_dag`] — the Fig. 2 background example.
//!
//! Every kernel is annotated with flops/bytes for the cost model and, when
//! `beta` matches an AOT artifact size, the artifact key for real execution.

pub mod polybench;

use crate::graph::{Dag, DagBuilder, KernelId, Partition};
use crate::platform::DeviceType;

/// Which β values have AOT artifacts (mirrors python/compile/aot.py BETAS).
pub const ARTIFACT_BETAS: [u64; 5] = [32, 64, 128, 256, 512];

fn artifact_for(op: &str, beta: u64) -> Option<String> {
    ARTIFACT_BETAS
        .contains(&beta)
        .then(|| format!("{op}_b{beta}"))
}

/// Buffer ids of the head's external interface.
#[derive(Debug, Clone)]
pub struct HeadIo {
    /// Input-feature buffer of each projection GEMM (X appears 3×).
    pub x_inputs: Vec<usize>,
    /// Weight buffers Wq, Wk, Wv, Wo.
    pub weights: Vec<usize>,
    /// Final output buffer Z.
    pub z_output: usize,
    /// Kernels in creation order: [gq, gk, gv, tr, ga, sm, gc, gz].
    pub kernels: Vec<KernelId>,
}

/// Append one attention-head sub-DAG to `b`; `beta` sizes all matrices.
pub fn add_head(b: &mut DagBuilder, beta: u64, dev: DeviceType) -> HeadIo {
    let el = 4 * beta * beta; // bytes of a β×β f32 matrix
    let gemm_flops = 2 * beta * beta * beta;
    let mk_gemm = |b: &mut DagBuilder, tag: &str| {
        let k = b.kernel("gemm", dev, gemm_flops, 3 * el);
        b.ndrange(k, 2, [beta, beta, 1]);
        if let Some(a) = artifact_for("gemm", beta) {
            b.artifact(k, &a);
        }
        let _ = tag;
        k
    };

    let gq = mk_gemm(b, "q");
    let gk = mk_gemm(b, "k");
    let gv = mk_gemm(b, "v");
    let tr = b.kernel("transpose", dev, beta * beta, 2 * el);
    b.ndrange(tr, 2, [beta, beta, 1]);
    if let Some(a) = artifact_for("transpose", beta) {
        b.artifact(tr, &a);
    }
    let ga = mk_gemm(b, "a");
    let sm = b.kernel("softmax", dev, 5 * beta * beta, 2 * el);
    b.ndrange(sm, 2, [beta, beta, 1]);
    if let Some(a) = artifact_for("softmax", beta) {
        b.artifact(sm, &a);
    }
    let gc = mk_gemm(b, "c");
    let gz = mk_gemm(b, "z");

    // Buffers. X and the four weights are external (isolated writes).
    let xq = b.in_buf(gq, el);
    let wq = b.in_buf(gq, el);
    let q = b.out_buf(gq, el);
    let xk = b.in_buf(gk, el);
    let wk = b.in_buf(gk, el);
    let kk = b.out_buf(gk, el);
    let xv = b.in_buf(gv, el);
    let wv = b.in_buf(gv, el);
    let v = b.out_buf(gv, el);
    let t_in = b.in_buf(tr, el);
    let kt = b.out_buf(tr, el);
    let a_q = b.in_buf(ga, el);
    let a_kt = b.in_buf(ga, el);
    let a = b.out_buf(ga, el);
    let s_in = b.in_buf(sm, el);
    let s_out = b.out_buf(sm, el);
    let c_b = b.in_buf(gc, el);
    let c_v = b.in_buf(gc, el);
    let c = b.out_buf(gc, el);
    let z_c = b.in_buf(gz, el);
    let wo = b.in_buf(gz, el);
    let z = b.out_buf(gz, el);

    // Intra-head dataflow (Fig. 10).
    b.edge(kk, t_in); // K -> transpose
    b.edge(q, a_q); // Q -> score GEMM
    b.edge(kt, a_kt); // K^T -> score GEMM
    b.edge(a, s_in); // A -> softmax
    b.edge(s_out, c_b); // B -> context GEMM
    b.edge(v, c_v); // V -> context GEMM
    b.edge(c, z_c); // C -> output GEMM

    HeadIo {
        x_inputs: vec![xq, xk, xv],
        weights: vec![wq, wk, wv, wo],
        z_output: z,
        kernels: vec![gq, gk, gv, tr, ga, sm, gc, gz],
    }
}

/// One attention head as a standalone DAG (the Figs. 4/5 motivation DAG).
pub fn head_dag(beta: u64, dev: DeviceType) -> (Dag, HeadIo) {
    let mut b = DagBuilder::new();
    let io = add_head(&mut b, beta, dev);
    (b.build().expect("head DAG valid"), io)
}

/// A full H-head transformer layer: H independent head branches (the paper
/// treats the final concat as the read of each head's Z output).
pub fn transformer_dag(heads: usize, beta: u64, dev: DeviceType) -> (Dag, Vec<HeadIo>) {
    let mut b = DagBuilder::new();
    let ios: Vec<HeadIo> = (0..heads).map(|_| add_head(&mut b, beta, dev)).collect();
    (b.build().expect("transformer DAG valid"), ios)
}

/// Clustering partition for a transformer layer: each head is one task
/// component; the first `h_cpu` heads go to the CPU (Expt 1's `h_cpu` knob).
pub fn cluster_by_head(dag: &Dag, ios: &[HeadIo], h_cpu: usize) -> Partition {
    let groups = ios
        .iter()
        .enumerate()
        .map(|(i, io)| {
            let dev = if i < h_cpu {
                DeviceType::Cpu
            } else {
                DeviceType::Gpu
            };
            (io.kernels.clone(), dev)
        })
        .collect();
    Partition::new(dag, groups).expect("head clustering is valid")
}

/// The Fig. 1 motivating fork-join DAG: k0 → {k1, k2} → k3.
pub fn fork_join_dag(beta: u64) -> (Dag, Vec<KernelId>) {
    let mut b = DagBuilder::new();
    let el = 4 * beta * beta;
    let flops = 2 * beta * beta * beta;
    let mut mk = |dev| {
        let k = b.kernel("gemm", dev, flops, 3 * el);
        if let Some(a) = artifact_for("gemm", beta) {
            b.artifact(k, &a);
        }
        k
    };
    let k0 = mk(DeviceType::Cpu);
    let k1 = mk(DeviceType::Gpu);
    let k2 = mk(DeviceType::Gpu);
    let k3 = mk(DeviceType::Cpu);
    let _i0 = b.in_buf(k0, el);
    let _i1 = b.in_buf(k0, el);
    let o0 = b.out_buf(k0, el);
    let i2 = b.in_buf(k1, el);
    let _i3 = b.in_buf(k1, el);
    let o1 = b.out_buf(k1, el);
    let i4 = b.in_buf(k2, el);
    let _i5 = b.in_buf(k2, el);
    let o2 = b.out_buf(k2, el);
    let i6 = b.in_buf(k3, el);
    let i7 = b.in_buf(k3, el);
    let _o3 = b.out_buf(k3, el);
    b.edge(o0, i2);
    b.edge(o0, i4);
    b.edge(o1, i6);
    b.edge(o2, i7);
    (b.build().expect("fork-join valid"), vec![k0, k1, k2, k3])
}

/// The Fig. 2 example: vadd → vsin over `n`-element vectors.
pub fn vadd_vsin_dag(n: u64) -> (Dag, Vec<KernelId>) {
    let mut b = DagBuilder::new();
    let bytes = 4 * n;
    let k0 = b.kernel("vadd", DeviceType::Gpu, n, 3 * bytes);
    let k1 = b.kernel("vsin", DeviceType::Gpu, 4 * n, 2 * bytes);
    if [4096, 1 << 20].contains(&n) {
        b.artifact(k0, &format!("vadd_n{n}"));
        b.artifact(k1, &format!("vsin_n{n}"));
    }
    let _b0 = b.in_buf(k0, bytes);
    let _b1 = b.in_buf(k0, bytes);
    let b2 = b.out_buf(k0, bytes);
    let b3 = b.io_buf(k1, bytes);
    b.edge(b2, b3);
    (b.build().expect("vadd-vsin valid"), vec![k0, k1])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeClass;

    #[test]
    fn head_has_paper_kernel_census() {
        let (dag, io) = head_dag(256, DeviceType::Gpu);
        assert_eq!(dag.num_kernels(), 8);
        let names: Vec<&str> = io
            .kernels
            .iter()
            .map(|&k| dag.kernels[k].name.as_str())
            .collect();
        assert_eq!(
            names.iter().filter(|n| **n == "gemm").count(),
            6,
            "6 GEMM-family kernels per head"
        );
        assert_eq!(names.iter().filter(|n| **n == "transpose").count(), 1);
        assert_eq!(names.iter().filter(|n| **n == "softmax").count(), 1);
    }

    #[test]
    fn head_level_structure() {
        let (dag, io) = head_dag(64, DeviceType::Gpu);
        let [gq, gk, gv, tr, ga, sm, gc, gz] = io.kernels[..] else {
            panic!()
        };
        // Level 1 kernels have no kernel preds.
        for k in [gq, gk, gv] {
            assert!(dag.kernel_preds(k).is_empty());
        }
        assert_eq!(dag.kernel_preds(tr), vec![gk]);
        let mut p = dag.kernel_preds(ga);
        p.sort();
        let mut expect = vec![gq, tr];
        expect.sort();
        assert_eq!(p, expect);
        assert_eq!(dag.kernel_preds(sm), vec![ga]);
        let mut pc = dag.kernel_preds(gc);
        pc.sort();
        let mut expect_c = vec![gv, sm];
        expect_c.sort();
        assert_eq!(pc, expect_c);
        assert_eq!(dag.kernel_preds(gz), vec![gc]);
        assert_eq!(dag.kernel_succs(gz), Vec::<usize>::new());
    }

    #[test]
    fn heads_are_independent_components() {
        let (dag, ios) = transformer_dag(4, 64, DeviceType::Gpu);
        assert_eq!(dag.num_kernels(), 32);
        let part = cluster_by_head(&dag, &ios, 1);
        // No inter edges: heads share nothing.
        for &(s, d) in &dag.buffer_edges {
            assert_eq!(part.edge_class(&dag, s, d), EdgeClass::Intra);
        }
        assert_eq!(part.components[0].dev, DeviceType::Cpu);
        assert_eq!(part.components[1].dev, DeviceType::Gpu);
        // All components immediately ready (paper: heads are independent).
        assert_eq!(part.ready_components(&dag).len(), 4);
    }

    #[test]
    fn artifacts_attached_at_paper_sizes() {
        let (dag, io) = head_dag(256, DeviceType::Gpu);
        assert_eq!(
            dag.kernels[io.kernels[0]].artifact.as_deref(),
            Some("gemm_b256")
        );
        let (dag31, io31) = head_dag(31, DeviceType::Gpu);
        assert!(dag31.kernels[io31.kernels[0]].artifact.is_none());
    }

    #[test]
    fn fork_join_shape() {
        let (dag, ks) = fork_join_dag(64);
        assert_eq!(dag.kernel_succs(ks[0]).len(), 2);
        assert_eq!(dag.kernel_preds(ks[3]).len(), 2);
    }

    #[test]
    fn vadd_vsin_chain() {
        let (dag, ks) = vadd_vsin_dag(4096);
        assert_eq!(dag.kernel_succs(ks[0]), vec![ks[1]]);
        assert_eq!(dag.kernels[ks[1]].artifact.as_deref(), Some("vsin_n4096"));
    }
}
