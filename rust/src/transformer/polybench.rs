//! Polybench-style application DAGs (the paper sources its OpenCL kernels
//! from the Polybench and NVIDIA SDK suites; these generators provide the
//! classic linear-algebra pipelines as additional scheduling workloads).
//!
//! All kernels map onto the same artifact inventory (gemm/transpose at the
//! AOT β sizes), so each DAG is both simulatable and really executable.

use crate::graph::{Dag, DagBuilder, KernelId};
use crate::platform::DeviceType;

fn gemm_kernel(b: &mut DagBuilder, beta: u64, dev: DeviceType) -> KernelId {
    let el = 4 * beta * beta;
    let k = b.kernel("gemm", dev, 2 * beta * beta * beta, 3 * el);
    b.ndrange(k, 2, [beta, beta, 1]);
    if super::ARTIFACT_BETAS.contains(&beta) {
        b.artifact(k, &format!("gemm_b{beta}"));
    }
    k
}

/// 2mm: D = A·B; E = D·C  (two chained GEMMs).
pub fn mm2_dag(beta: u64, dev: DeviceType) -> (Dag, Vec<KernelId>) {
    let mut b = DagBuilder::new();
    let el = 4 * beta * beta;
    let k0 = gemm_kernel(&mut b, beta, dev);
    let k1 = gemm_kernel(&mut b, beta, dev);
    let _a = b.in_buf(k0, el);
    let _bb = b.in_buf(k0, el);
    let d = b.out_buf(k0, el);
    let d_in = b.in_buf(k1, el);
    let _c = b.in_buf(k1, el);
    let _e = b.out_buf(k1, el);
    b.edge(d, d_in);
    (b.build().expect("2mm valid"), vec![k0, k1])
}

/// 3mm: E = A·B; F = C·D; G = E·F  (a fork-join of three GEMMs).
pub fn mm3_dag(beta: u64, dev: DeviceType) -> (Dag, Vec<KernelId>) {
    let mut b = DagBuilder::new();
    let el = 4 * beta * beta;
    let k0 = gemm_kernel(&mut b, beta, dev);
    let k1 = gemm_kernel(&mut b, beta, dev);
    let k2 = gemm_kernel(&mut b, beta, dev);
    for k in [k0, k1] {
        b.in_buf(k, el);
        b.in_buf(k, el);
    }
    let e = b.out_buf(k0, el);
    let f = b.out_buf(k1, el);
    let e_in = b.in_buf(k2, el);
    let f_in = b.in_buf(k2, el);
    let _g = b.out_buf(k2, el);
    b.edge(e, e_in);
    b.edge(f, f_in);
    (b.build().expect("3mm valid"), vec![k0, k1, k2])
}

/// atax: y = Aᵀ(Ax) — transpose + two GEMMs (matrix-matrix in our shape
/// inventory; the dataflow is the point).
pub fn atax_dag(beta: u64, dev: DeviceType) -> (Dag, Vec<KernelId>) {
    let mut b = DagBuilder::new();
    let el = 4 * beta * beta;
    let k0 = gemm_kernel(&mut b, beta, dev); // t0 = A·X
    let tr = b.kernel("transpose", dev, beta * beta, 2 * el);
    b.ndrange(tr, 2, [beta, beta, 1]);
    if super::ARTIFACT_BETAS.contains(&beta) {
        b.artifact(tr, &format!("transpose_b{beta}"));
    }
    let k1 = gemm_kernel(&mut b, beta, dev); // y = Aᵀ·t0
    let a0 = b.in_buf(k0, el);
    let _x = b.in_buf(k0, el);
    let t0 = b.out_buf(k0, el);
    let tr_in = b.in_buf(tr, el);
    let at = b.out_buf(tr, el);
    let at_in = b.in_buf(k1, el);
    let t0_in = b.in_buf(k1, el);
    let _y = b.out_buf(k1, el);
    // A feeds both the first GEMM and the transpose: model the transpose
    // input as an isolated copy of A (separate host writes), keeping the
    // single-producer invariant. Dataflow edges:
    b.edge(t0, t0_in);
    b.edge(at, at_in);
    let _ = (a0, tr_in);
    (b.build().expect("atax valid"), vec![k0, tr, k1])
}

/// bicg: q = A·p ; s = Aᵀ·r — two independent GEMM branches sharing A's
/// structure (independent => good clustering fodder).
pub fn bicg_dag(beta: u64, dev: DeviceType) -> (Dag, Vec<KernelId>) {
    let mut b = DagBuilder::new();
    let el = 4 * beta * beta;
    let k0 = gemm_kernel(&mut b, beta, dev);
    let tr = b.kernel("transpose", dev, beta * beta, 2 * el);
    b.ndrange(tr, 2, [beta, beta, 1]);
    if super::ARTIFACT_BETAS.contains(&beta) {
        b.artifact(tr, &format!("transpose_b{beta}"));
    }
    let k1 = gemm_kernel(&mut b, beta, dev);
    b.in_buf(k0, el);
    b.in_buf(k0, el);
    let _q = b.out_buf(k0, el);
    let _tr_in = b.in_buf(tr, el);
    let at = b.out_buf(tr, el);
    let at_in = b.in_buf(k1, el);
    b.in_buf(k1, el);
    let _s = b.out_buf(k1, el);
    b.edge(at, at_in);
    (b.build().expect("bicg valid"), vec![k0, tr, k1])
}

/// mvt: x1 += A·y1 ; x2 += Aᵀ·y2 — two fully independent branches.
pub fn mvt_dag(beta: u64, dev: DeviceType) -> (Dag, Vec<KernelId>) {
    let mut b = DagBuilder::new();
    let el = 4 * beta * beta;
    let k0 = gemm_kernel(&mut b, beta, dev);
    let k1 = gemm_kernel(&mut b, beta, dev);
    for k in [k0, k1] {
        b.in_buf(k, el);
        b.in_buf(k, el);
        b.out_buf(k, el);
    }
    (b.build().expect("mvt valid"), vec![k0, k1])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::graph::Partition;
    use crate::platform::Platform;
    use crate::sched::Clustering;
    use crate::sim::{simulate, SimConfig};

    fn simulate_ok(dag: &Dag) -> f64 {
        let part = Partition::singletons(dag);
        let platform = Platform::paper_testbed(2, 1);
        simulate(
            dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
        )
        .unwrap()
        .makespan
    }

    #[test]
    fn mm2_chains() {
        let (dag, ks) = mm2_dag(128, DeviceType::Gpu);
        assert_eq!(dag.kernel_succs(ks[0]), vec![ks[1]]);
        assert!(simulate_ok(&dag) > 0.0);
    }

    #[test]
    fn mm3_is_fork_join() {
        let (dag, ks) = mm3_dag(128, DeviceType::Gpu);
        assert_eq!(dag.kernel_preds(ks[2]).len(), 2);
        assert!(dag.kernel_preds(ks[0]).is_empty());
        assert!(simulate_ok(&dag) > 0.0);
    }

    #[test]
    fn atax_transpose_feeds_second_gemm() {
        let (dag, ks) = atax_dag(64, DeviceType::Gpu);
        assert!(dag.kernel_succs(ks[1]).contains(&ks[2]));
        assert!(simulate_ok(&dag) > 0.0);
    }

    #[test]
    fn mvt_branches_independent() {
        let (dag, ks) = mvt_dag(64, DeviceType::Gpu);
        assert!(dag.kernel_preds(ks[0]).is_empty());
        assert!(dag.kernel_preds(ks[1]).is_empty());
        assert!(dag.buffer_edges.is_empty());
    }

    #[test]
    fn bicg_partial_dependency() {
        let (dag, ks) = bicg_dag(64, DeviceType::Gpu);
        assert!(dag.kernel_preds(ks[0]).is_empty());
        assert_eq!(dag.kernel_preds(ks[2]), vec![ks[1]]);
    }

    #[test]
    fn all_polybench_dags_have_artifacts_at_aot_sizes() {
        for (dag, _) in [
            mm2_dag(64, DeviceType::Gpu),
            mm3_dag(64, DeviceType::Gpu),
            atax_dag(64, DeviceType::Gpu),
            bicg_dag(64, DeviceType::Gpu),
            mvt_dag(64, DeviceType::Gpu),
        ] {
            for k in &dag.kernels {
                assert!(k.artifact.is_some(), "kernel {} lacks artifact", k.id);
            }
        }
    }
}
