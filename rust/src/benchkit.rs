//! Minimal benchmarking harness (offline environment: no criterion).
//!
//! Used by the `rust/benches/*` targets (`harness = false`). Reports
//! mean/min/max over warmup + measured iterations, in criterion-like lines.

use std::time::Instant;

/// Timing statistics over the measured iterations, seconds.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub iters: usize,
}

impl Stats {
    pub fn line(&self, name: &str) -> String {
        format!(
            "{name:<44} time: [{} {} {}]  ({} iters)",
            fmt_time(self.min),
            fmt_time(self.mean),
            fmt_time(self.max),
            self.iters
        )
    }
}

/// Humanize a duration in seconds.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` for `warmup` + `iters` iterations and report stats.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let stats = Stats {
        mean: times.iter().sum::<f64>() / times.len() as f64,
        min: times.iter().copied().fold(f64::INFINITY, f64::min),
        max: times.iter().copied().fold(0.0, f64::max),
        iters,
    };
    println!("{}", stats.line(name));
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.mean && s.mean <= s.max);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
