//! Execution-time models.
//!
//! `AnalyticCost` estimates from kernel flops + device throughput with an
//! op-class efficiency factor (GEMM near peak, softmax/transpose/elementwise
//! bandwidth-bound). `CalibratedCost` wraps a measured table (built by
//! `pyschedcl calibrate` from real PJRT-CPU timings, with the GPU column
//! scaled by the paper's device ratio) and falls back to the analytic model.

use crate::graph::KernelNode;
use crate::json::Json;
use crate::platform::Device;
use std::collections::HashMap;

/// Per-(kernel, device) execution-time oracle, seconds.
pub trait CostModel: Send + Sync {
    /// Estimated solo (contention-free) execution time of `k` on `dev`.
    fn exec_time(&self, k: &KernelNode, dev: &Device) -> f64;

    /// Cross-device mean — the weight HEFT uses for upward ranks.
    fn mean_time(&self, k: &KernelNode, devs: &[&Device]) -> f64 {
        let s: f64 = devs.iter().map(|d| self.exec_time(k, d)).sum();
        s / devs.len().max(1) as f64
    }
}

/// Efficiency of an op class relative to device peak FLOPs.
/// CPU efficiencies are lower for GPU-optimized kernels — the paper notes
/// "the kernels selected are optimized for GPUs rather than CPUs".
fn efficiency(op: &str, dev: &Device) -> f64 {
    let gpu = dev.dtype == crate::platform::DeviceType::Gpu;
    match op {
        "gemm" => {
            if gpu {
                0.55
            } else {
                0.20
            }
        }
        "softmax" | "transpose" => {
            if gpu {
                0.08
            } else {
                0.05
            }
        }
        "vadd" | "vsin" => {
            if gpu {
                0.06
            } else {
                0.08
            }
        }
        _ => {
            if gpu {
                0.30
            } else {
                0.15
            }
        }
    }
}

/// FLOPs-over-throughput analytic model.
#[derive(Debug, Clone, Default)]
pub struct AnalyticCost;

impl CostModel for AnalyticCost {
    fn exec_time(&self, k: &KernelNode, dev: &Device) -> f64 {
        let eff = efficiency(&k.name, dev);
        let flops = k.flops.max(1) as f64;
        // Memory-bound ops are dominated by bytes/bandwidth; approximate
        // device-internal bandwidth as gflops-proportional (GB/s ≈ gflops/15).
        let mem_bw = dev.gflops * 1e9 / 15.0;
        let compute = flops / (dev.gflops * 1e9 * eff);
        let memory = k.bytes as f64 / mem_bw;
        dev.launch_overhead + compute.max(memory)
    }
}

/// Cost model calibrated to the paper's published measurements.
///
/// Anchors (β=256, GTX-970 + i5-4690K, Polybench/NVIDIA-SDK kernels):
/// * the Fig. 4 coarse-grained head DAG takes 105 ms — 6 GEMMs at ≈15 ms
///   plus softmax ≈6 ms, transpose ≈4 ms and ≈1 ms of transfers;
/// * moving >1 head to the CPU stops paying off above H=10 (Fig. 11),
///   which pins the CPU:GPU GEMM time ratio at ≈9×;
/// * non-GEMM kernels are less GPU-favoured (≈2–3× CPU:GPU).
///
/// Times scale from the β=256 anchor by the flops ratio (β³ for GEMM,
/// β² for the element-wise/bandwidth kernels).
#[derive(Debug, Clone, Default)]
pub struct PaperCost;

impl PaperCost {
    /// (anchor_seconds_gpu, anchor_seconds_cpu, anchor_flops) per op.
    fn anchor(op: &str) -> (f64, f64, f64) {
        const B: f64 = 256.0;
        match op {
            n if n.contains("gemm") || n.contains("matmul") => {
                (15.0e-3, 135.0e-3, 2.0 * B * B * B)
            }
            n if n.contains("softmax") => (6.0e-3, 18.0e-3, 5.0 * B * B),
            n if n.contains("transpose") => (4.0e-3, 8.0e-3, B * B),
            n if n.contains("sin") => (1.0e-3, 2.0e-3, 4.0 * B * B),
            n if n.contains("add") => (0.8e-3, 1.2e-3, B * B),
            _ => (5.0e-3, 25.0e-3, B * B),
        }
    }
}

impl CostModel for PaperCost {
    fn exec_time(&self, k: &KernelNode, dev: &Device) -> f64 {
        let (gpu_t, cpu_t, anchor_flops) = Self::anchor(&k.name);
        let base = match dev.dtype {
            crate::platform::DeviceType::Gpu => gpu_t,
            crate::platform::DeviceType::Cpu => cpu_t,
        };
        dev.launch_overhead + base * (k.flops.max(1) as f64 / anchor_flops)
    }
}

/// Measured table keyed by `(kernel_name, flops_bucket, device_type)`.
#[derive(Debug, Clone, Default)]
pub struct CalibratedCost {
    /// key: `"{name}:{flops}:{dtype}"` → seconds.
    pub table: HashMap<String, f64>,
}

impl CalibratedCost {
    pub fn key(k: &KernelNode, dev: &Device) -> String {
        format!("{}:{}:{}", k.name, k.flops, dev.dtype)
    }

    pub fn insert(&mut self, k: &KernelNode, dev: &Device, secs: f64) {
        self.table.insert(Self::key(k, dev), secs);
    }

    pub fn load(path: &std::path::Path) -> crate::error::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text)?;
        let mut table = HashMap::new();
        if let Some(obj) = json.as_obj() {
            for (k, v) in obj {
                if let Some(n) = v.as_f64() {
                    table.insert(k.clone(), n);
                }
            }
        }
        Ok(CalibratedCost { table })
    }

    pub fn save(&self, path: &std::path::Path) -> crate::error::Result<()> {
        let obj = Json::Obj(
            self.table
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        std::fs::write(path, obj.to_string_pretty())?;
        Ok(())
    }
}

impl CostModel for CalibratedCost {
    fn exec_time(&self, k: &KernelNode, dev: &Device) -> f64 {
        self.table
            .get(&Self::key(k, dev))
            .copied()
            .unwrap_or_else(|| AnalyticCost.exec_time(k, dev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagBuilder;
    use crate::platform::{Device, DeviceType};

    fn gemm_node(beta: u64) -> KernelNode {
        let mut b = DagBuilder::new();
        let k = b.kernel("gemm", DeviceType::Gpu, 2 * beta * beta * beta, 12 * beta * beta);
        b.dag().kernels[k].clone()
    }

    #[test]
    fn gemm_gpu_order_of_magnitude_faster() {
        let gpu = Device::gtx970(0, 1);
        let cpu = Device::i5_4690k(1, 1);
        let k = gemm_node(256);
        let tg = AnalyticCost.exec_time(&k, &gpu);
        let tc = AnalyticCost.exec_time(&k, &cpu);
        assert!(tc / tg > 10.0, "cpu {tc} vs gpu {tg}");
    }

    #[test]
    fn exec_time_scales_with_beta() {
        let gpu = Device::gtx970(0, 1);
        let t256 = AnalyticCost.exec_time(&gemm_node(256), &gpu);
        let t512 = AnalyticCost.exec_time(&gemm_node(512), &gpu);
        // Cubic flop growth, diluted by the fixed launch overhead.
        assert!(t512 > 3.0 * t256, "superlinear scaling expected: {t256} {t512}");
    }

    #[test]
    fn calibrated_falls_back_to_analytic() {
        let gpu = Device::gtx970(0, 1);
        let k = gemm_node(128);
        let mut c = CalibratedCost::default();
        assert_eq!(c.exec_time(&k, &gpu), AnalyticCost.exec_time(&k, &gpu));
        c.insert(&k, &gpu, 42.0);
        assert_eq!(c.exec_time(&k, &gpu), 42.0);
    }

    #[test]
    fn mean_time_is_cross_device_mean() {
        let gpu = Device::gtx970(0, 1);
        let cpu = Device::i5_4690k(1, 1);
        let k = gemm_node(64);
        let m = AnalyticCost.mean_time(&k, &[&gpu, &cpu]);
        let expect =
            (AnalyticCost.exec_time(&k, &gpu) + AnalyticCost.exec_time(&k, &cpu)) / 2.0;
        assert!((m - expect).abs() < 1e-12);
    }
}
