//! Kernel/device cost models: the execution-time estimates behind HEFT's
//! EFT computation, the frontier's bottom-level ranks, and the
//! discrete-event simulator.

pub mod contention;
pub mod model;

pub use contention::occupancy;
pub use model::{AnalyticCost, CalibratedCost, CostModel, PaperCost};
