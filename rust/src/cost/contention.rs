//! Concurrent-kernel contention model.
//!
//! The paper (§2.1, citing cCUDA [9]) observes that when multiple kernels
//! are dispatched concurrently, their work groups are round-robined onto the
//! device's compute units: *individual* kernel times increase, but *total*
//! time drops whenever one kernel alone cannot saturate the device.
//!
//! Model: each kernel `k` has an *occupancy* `u_k ∈ (0, 1]` — the fraction
//! of the device it can use alone. With running set `R`:
//!
//! * `Σ u ≤ 1`: no contention; every kernel proceeds at its solo speed.
//! * `Σ u > 1`: the device is oversubscribed; kernel k proceeds at speed
//!   `(u_k / Σu) · η` where `η < 1` is the round-robin interference penalty.
//!
//! This produces exactly the paper's Gantt behaviour: concurrent e1..e3
//! stretch individually yet finish earlier collectively (Fig. 5).

use crate::graph::KernelNode;
use crate::platform::Device;

/// Round-robin interference efficiency once the device is oversubscribed.
pub const CONTENTION_EFFICIENCY: f64 = 0.92;

/// Occupancy of one kernel on a device, anchored at `base_occupancy` for a
/// β=256-sized GEMM (2·256³ flops) and growing with the work's parallel
/// width. Memory-bound ops (few flops) still occupy bandwidth: floor at 0.15.
pub fn occupancy(k: &KernelNode, dev: &Device) -> f64 {
    const ANCHOR_FLOPS: f64 = 2.0 * 256.0 * 256.0 * 256.0;
    let scale = (k.flops.max(1) as f64 / ANCHOR_FLOPS).powf(1.0 / 3.0);
    (dev.base_occupancy * scale).clamp(0.15, 1.0)
}

/// Speed multiplier (0, 1] for each kernel in a running set with occupancies
/// `us`; returns one multiplier per kernel.
pub fn shared_speeds(us: &[f64]) -> Vec<f64> {
    shared_speeds_with(us, CONTENTION_EFFICIENCY)
}

/// [`shared_speeds`] with an explicit interference efficiency `eta`
/// (ablation knob — see `rust/benches/ablations.rs`).
pub fn shared_speeds_with(us: &[f64], eta: f64) -> Vec<f64> {
    let mut out = Vec::new();
    shared_speeds_into(us, eta, &mut out);
    out
}

/// Allocation-free [`shared_speeds_with`]: writes the multipliers into a
/// caller-owned buffer (cleared first). The simulator calls this once per
/// device per event — the reusable buffer is what keeps the hot loop
/// allocation-free. Identical floating-point expressions to the allocating
/// form, so results are bit-equal.
pub fn shared_speeds_into(us: &[f64], eta: f64, out: &mut Vec<f64>) {
    out.clear();
    let total: f64 = us.iter().sum();
    if total <= 1.0 {
        out.extend_from_slice(us);
    } else {
        out.extend(us.iter().map(|u| u / total * eta));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DagBuilder;
    use crate::platform::{Device, DeviceType};

    fn gemm(beta: u64) -> KernelNode {
        let mut b = DagBuilder::new();
        let k = b.kernel("gemm", DeviceType::Gpu, 2 * beta * beta * beta, 1);
        b.dag().kernels[k].clone()
    }

    #[test]
    fn occupancy_anchored_at_beta256() {
        let dev = Device::gtx970(0, 1);
        let u = occupancy(&gemm(256), &dev);
        assert!((u - dev.base_occupancy).abs() < 1e-9);
        assert!(occupancy(&gemm(64), &dev) < u);
        assert!(occupancy(&gemm(512), &dev) > u);
    }

    #[test]
    fn undersubscribed_runs_at_solo_speed() {
        let speeds = shared_speeds(&[0.4, 0.4]);
        assert_eq!(speeds, vec![0.4, 0.4]);
    }

    #[test]
    fn oversubscribed_shares_with_penalty() {
        let speeds = shared_speeds(&[0.8, 0.8]);
        // Each gets 0.5 of the device scaled by η.
        assert!((speeds[0] - 0.5 * CONTENTION_EFFICIENCY).abs() < 1e-9);
        // Individual slower than solo...
        assert!(speeds[0] < 0.8);
        // ...but aggregate throughput beats serial execution of the pair.
        assert!(speeds[0] + speeds[1] > 0.8);
    }

    #[test]
    fn concurrency_helps_when_unsaturated() {
        // Two kernels of work W with u = 0.42 (β=256 GEMM on the GTX-970):
        // serial time = 2·(W/0.42); concurrent = W/0.42 since both fit.
        let speeds = shared_speeds(&[0.42, 0.42]);
        let concurrent = 1.0 / speeds[0];
        let serial = 2.0 / 0.42;
        assert!(concurrent < serial * 0.6);
    }
}
