//! Experiment harness: regenerates every table/figure of the paper's §5.
//!
//! * [`motivation`] — Figs. 4/5 (coarse vs fine Gantt, one head, β=256).
//! * [`expt1`] — Fig. 11 (clustering best-config speedups over the default
//!   coarse configuration, H ∈ [1,16], β=256).
//! * [`expt2`] — Fig. 12(a) (clustering vs eager, H=16, β ∈ {64..512}).
//! * [`expt3`] — Fig. 12(b) (clustering vs HEFT, same sweep).
//! * [`gantt`] — Fig. 13 (per-policy Gantt charts at H=16, β=512).
//!
//! Each function both returns structured rows (consumed by benches and
//! integration tests) and renders the paper-style table via `Display`.
//!
//! [`serving`] adds the multi-DAG serving comparison (sequential replay vs
//! concurrent multi-tenant serving) and the CI bench artifact; [`benchgate`]
//! the bench-regression gate that compares those artifacts against the
//! committed baselines (`pyschedcl bench-check`).

pub mod benchgate;
pub mod experiments;
pub mod serving;

pub use benchgate::{
    check_bench, format_gate, format_gate_markdown, load_baseline, lookup_metric,
    parse_baseline, update_baseline, Baseline, CheckSpec, GateResult,
};
pub use experiments::{
    expt1, expt2, expt3, gantt, motivation, BaselineRow, Expt1Row, MappingConfig,
    MotivationResult,
};
pub use serving::{
    format_real_summary, format_serve_comparison, format_sharded_summary, format_stream_summary,
    peak_rss_mb, serve_bench_json, serve_chaos_json, serve_real_stream_json, serve_shard_json,
    serve_soak_json,
};
