//! Experiment implementations (see module docs in [`super`]).

use crate::cost::{CostModel, PaperCost};
use crate::error::Result;
use crate::graph::Partition;
use crate::platform::{DeviceType, Platform};
use crate::sched::{Clustering, Eager, Heft, Policy};
use crate::sim::{simulate, SimConfig, SimResult};
use crate::transformer::{cluster_by_head, transformer_dag};
use std::fmt;

/// An architecture mapping configuration `mc = ⟨q_gpu, q_cpu, h_cpu⟩`
/// (Expt 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MappingConfig {
    pub q_gpu: usize,
    pub q_cpu: usize,
    pub h_cpu: usize,
}

impl fmt::Display for MappingConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({},{},{})", self.q_gpu, self.q_cpu, self.h_cpu)
    }
}

/// Simulate the clustering scheme for a transformer layer under `mc`.
pub fn run_clustering(
    heads: usize,
    beta: u64,
    mc: MappingConfig,
    cost: &dyn CostModel,
) -> Result<SimResult> {
    let (dag, ios) = transformer_dag(heads, beta, DeviceType::Gpu);
    let part = cluster_by_head(&dag, &ios, mc.h_cpu);
    let platform = Platform::paper_testbed(mc.q_gpu, mc.q_cpu);
    simulate(
        &dag,
        &part,
        &platform,
        cost,
        &mut Clustering,
        &SimConfig::default(),
    )
}

/// Simulate a dynamic baseline (`eager` / `heft`) on singleton components
/// with one queue per device (paper §5 Expts 2–3).
pub fn run_baseline(
    heads: usize,
    beta: u64,
    policy: &mut dyn Policy,
    cost: &dyn CostModel,
) -> Result<SimResult> {
    let (dag, _) = transformer_dag(heads, beta, DeviceType::Gpu);
    let part = Partition::singletons(&dag);
    let platform = Platform::paper_testbed(1, 1);
    simulate(&dag, &part, &platform, cost, policy, &SimConfig::default())
}

/// The default coarse-grained configuration: whole DAG on the GPU through a
/// single command queue, `mc = (1, 0, 0)`.
pub const DEFAULT_MC: MappingConfig = MappingConfig {
    q_gpu: 1,
    q_cpu: 0,
    h_cpu: 0,
};

// ---------------------------------------------------------------- motivation

/// Figs. 4/5 output.
pub struct MotivationResult {
    pub coarse_ms: f64,
    pub fine_ms: f64,
    pub speedup: f64,
    pub coarse: SimResult,
    pub fine: SimResult,
}

/// Figs. 4/5: one transformer head at β=256, single queue vs 3 queues.
pub fn motivation(beta: u64) -> Result<MotivationResult> {
    let cost = PaperCost;
    let coarse = run_clustering(1, beta, DEFAULT_MC, &cost)?;
    let fine = run_clustering(
        1,
        beta,
        MappingConfig {
            q_gpu: 3,
            q_cpu: 0,
            h_cpu: 0,
        },
        &cost,
    )?;
    Ok(MotivationResult {
        coarse_ms: coarse.makespan * 1e3,
        fine_ms: fine.makespan * 1e3,
        speedup: coarse.makespan / fine.makespan,
        coarse,
        fine,
    })
}

// -------------------------------------------------------------------- expt 1

/// One row of Fig. 11.
#[derive(Debug, Clone, Copy)]
pub struct Expt1Row {
    pub heads: usize,
    pub best: MappingConfig,
    pub best_ms: f64,
    pub default_ms: f64,
    pub speedup: f64,
}

/// Expt 1: for each H ∈ [1, h_max], sweep `q_gpu ∈ [1,5]`, `q_cpu ∈ [0,5]`,
/// `h_cpu ∈ [0, min(H, h_cpu_max)]`; report best speedup over the default.
pub fn expt1(h_max: usize, beta: u64, h_cpu_max: usize) -> Result<Vec<Expt1Row>> {
    let cost = PaperCost;
    let mut rows = Vec::new();
    for heads in 1..=h_max {
        let default_t = run_clustering(heads, beta, DEFAULT_MC, &cost)?.makespan;
        let mut best = (DEFAULT_MC, default_t);
        for q_gpu in 1..=5usize {
            for q_cpu in 0..=5usize {
                for h_cpu in 0..=heads.min(h_cpu_max) {
                    if h_cpu > 0 && q_cpu == 0 {
                        continue; // CPU heads need a CPU queue
                    }
                    let mc = MappingConfig {
                        q_gpu,
                        q_cpu,
                        h_cpu,
                    };
                    let t = run_clustering(heads, beta, mc, &cost)?.makespan;
                    if t < best.1 {
                        best = (mc, t);
                    }
                }
            }
        }
        rows.push(Expt1Row {
            heads,
            best: best.0,
            best_ms: best.1 * 1e3,
            default_ms: default_t * 1e3,
            speedup: default_t / best.1,
        });
    }
    Ok(rows)
}

/// Render Fig. 11 as the paper's table: H, best (q_gpu,q_cpu), h_cpu, speedup.
pub fn format_expt1(rows: &[Expt1Row]) -> String {
    let mut s = String::from(
        "Expt 1 (Fig. 11): clustering best config vs default mc=(1,0,0), β=256\n\
         H  | best mc    | default ms | best ms | speedup\n\
         ---+------------+------------+---------+--------\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:>2} | {:<10} | {:>10.1} | {:>7.1} | {:.3}x\n",
            r.heads,
            r.best.to_string(),
            r.default_ms,
            r.best_ms,
            r.speedup
        ));
    }
    s
}

// ---------------------------------------------------------------- expts 2/3

/// One row of Fig. 12(a)/(b).
#[derive(Debug, Clone, Copy)]
pub struct BaselineRow {
    pub beta: u64,
    pub best: MappingConfig,
    pub clustering_ms: f64,
    pub baseline_ms: f64,
    pub speedup: f64,
}

fn best_clustering(
    heads: usize,
    beta: u64,
    cost: &dyn CostModel,
) -> Result<(MappingConfig, f64)> {
    let mut best: Option<(MappingConfig, f64)> = None;
    for q_gpu in 1..=5usize {
        for q_cpu in 0..=2usize {
            for h_cpu in 0..=1usize {
                if h_cpu > 0 && q_cpu == 0 {
                    continue;
                }
                let mc = MappingConfig {
                    q_gpu,
                    q_cpu,
                    h_cpu,
                };
                let t = run_clustering(heads, beta, mc, cost)?.makespan;
                if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                    best = Some((mc, t));
                }
            }
        }
    }
    Ok(best.expect("non-empty sweep"))
}

/// Expt 2 (Fig. 12a): clustering best config vs eager, H=16, β sweep.
pub fn expt2(heads: usize, betas: &[u64]) -> Result<Vec<BaselineRow>> {
    baseline_sweep(heads, betas, &mut Eager)
}

/// Expt 3 (Fig. 12b): clustering best config vs HEFT, H=16, β sweep.
pub fn expt3(heads: usize, betas: &[u64]) -> Result<Vec<BaselineRow>> {
    baseline_sweep(heads, betas, &mut Heft)
}

fn baseline_sweep(
    heads: usize,
    betas: &[u64],
    policy: &mut dyn Policy,
) -> Result<Vec<BaselineRow>> {
    let cost = PaperCost;
    let mut rows = Vec::new();
    for &beta in betas {
        let (mc, cl) = best_clustering(heads, beta, &cost)?;
        let bl = run_baseline(heads, beta, policy, &cost)?.makespan;
        rows.push(BaselineRow {
            beta,
            best: mc,
            clustering_ms: cl * 1e3,
            baseline_ms: bl * 1e3,
            speedup: bl / cl,
        });
    }
    Ok(rows)
}

/// Render Fig. 12-style table.
pub fn format_baseline(rows: &[BaselineRow], name: &str) -> String {
    let mut s = format!(
        "clustering (best mc) vs {name}, H=16\n\
         β    | best mc    | {name} ms | clustering ms | speedup\n\
         -----+------------+-----------+---------------+--------\n"
    );
    for r in rows {
        s.push_str(&format!(
            "{:>4} | {:<10} | {:>9.1} | {:>13.1} | {:.2}x\n",
            r.beta,
            r.best.to_string(),
            r.baseline_ms,
            r.clustering_ms,
            r.speedup
        ));
    }
    s
}

// ------------------------------------------------------------------- fig 13

/// Fig. 13: simulate one policy at (heads, beta) and return its trace
/// rendering plus gap statistics.
pub fn gantt(policy_name: &str, heads: usize, beta: u64) -> Result<(SimResult, String)> {
    let cost = PaperCost;
    let r = match policy_name {
        "clustering" => {
            let (mc, _) = best_clustering(heads, beta, &cost)?;
            run_clustering(heads, beta, mc, &cost)?
        }
        "eager" => run_baseline(heads, beta, &mut Eager, &cost)?,
        "heft" => run_baseline(heads, beta, &mut Heft, &cost)?,
        other => {
            return Err(crate::error::Error::Sched(format!(
                "unknown policy '{other}'"
            )))
        }
    };
    let mut s = format!(
        "policy={} makespan={:.1} ms  gpu_gap_max={:.1} ms  gpu_overlap={:.1} ms\n",
        r.policy,
        r.makespan * 1e3,
        r.trace.max_gap(0) * 1e3,
        r.trace.device_overlap(0) * 1e3,
    );
    s.push_str(&r.trace.ascii(100));
    Ok((r, s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn motivation_reproduces_fig4_5_shape() {
        let m = motivation(256).unwrap();
        // Paper: 105 ms -> 95 ms (≈8%). Accept the ballpark.
        assert!(m.coarse_ms > 85.0 && m.coarse_ms < 125.0, "{}", m.coarse_ms);
        assert!(m.speedup > 1.04 && m.speedup < 1.30, "{}", m.speedup);
    }

    #[test]
    fn expt1_small_sweep_shape() {
        // Reduced sweep for test speed: H ∈ {1, 12}.
        let rows = expt1(1, 256, 1).unwrap();
        assert!(rows[0].speedup >= 1.0);
        // All-GPU best for H=1.
        assert_eq!(rows[0].best.h_cpu, 0);
    }

    #[test]
    fn expt2_speedups_in_paper_band() {
        let rows = expt2(16, &[256]).unwrap();
        let s = rows[0].speedup;
        assert!(s > 1.3 && s < 4.5, "speedup {s}");
    }

    #[test]
    fn expt3_heft_closer_than_eager() {
        let e2 = expt2(16, &[256]).unwrap()[0].speedup;
        let e3 = expt3(16, &[256]).unwrap()[0].speedup;
        assert!(e3 < e2, "heft ({e3}) should be closer to clustering than eager ({e2})");
        assert!(e3 > 1.0, "clustering should still beat heft ({e3})");
    }

    #[test]
    fn gantt_diagnostics_match_fig13() {
        let (cl, _) = gantt("clustering", 8, 256).unwrap();
        let (hf, _) = gantt("heft", 8, 256).unwrap();
        // HEFT's per-kernel callbacks create bigger GPU gaps than clustering
        // (paper: "successive gaps introduced between each kernel").
        assert!(hf.trace.max_gap(0) > cl.trace.max_gap(0));
    }
}
