//! Serving-layer reporting: the sequential-vs-concurrent comparison table,
//! the `BENCH_serve.json` artifact the CI bench smoke uploads, the
//! streaming-soak artifact (`BENCH_serve_soak.json`) with its bounded-state
//! witnesses (peak live components, peak RSS), the real-path streaming
//! artifact (`BENCH_serve_real_stream.json`) gating
//! `serve --streaming --mode real` in CI, and the fault-injection artifact
//! (`BENCH_serve_chaos.json`) whose baseline pins `lost` — offered
//! requests unaccounted for by `served + rejected + shed` — to exactly
//! zero under a seeded crash/wedge/slowdown plan.

use crate::json::Json;
use crate::serve::{ServeReport, ShardedReport, StreamReport};

fn row(label: &str, r: &ServeReport) -> String {
    let util: Vec<String> = r
        .device_util
        .iter()
        .map(|u| format!("{:.0}%", u * 100.0))
        .collect();
    let miss = if r.deadline_total > 0 {
        format!("{:.0}%", r.deadline_miss_rate * 100.0)
    } else {
        "-".to_string()
    };
    format!(
        "{label:<11} | {:>4} | {:>9.1} | {:>10.1} | {:>8.2} | {:>8.2} | {:>5} | {}\n",
        r.outcomes.len(),
        r.makespan * 1e3,
        r.throughput_rps,
        r.p50_latency * 1e3,
        r.p99_latency * 1e3,
        miss,
        util.join(" ")
    )
}

/// Render the comparison table (latencies in ms, throughput in req/s).
pub fn format_serve_comparison(concurrent: &ServeReport, sequential: &ServeReport) -> String {
    let mut s = String::from(
        "mode        | reqs | span (ms) | thru (r/s) | p50 (ms) | p99 (ms) | miss  | device util\n\
         ------------+------+-----------+------------+----------+----------+-------+------------\n",
    );
    s.push_str(&row("sequential", sequential));
    s.push_str(&row("concurrent", concurrent));
    if concurrent.makespan > 0.0 {
        s.push_str(&format!(
            "concurrent serving speedup over sequential replay: {:.2}x\n",
            sequential.makespan / concurrent.makespan
        ));
    }
    if concurrent.deadline_total > 0 {
        s.push_str(&format!(
            "deadlines: {}/{} missed ({:.1}%), {} preemption(s)\n",
            concurrent.deadline_misses,
            concurrent.deadline_total,
            concurrent.deadline_miss_rate * 100.0,
            concurrent.preemptions
        ));
        for (p, l) in &concurrent.per_priority_p99 {
            s.push_str(&format!("  priority {p}: p99 {:.2} ms\n", l * 1e3));
        }
    }
    push_template_cache(&mut s, concurrent);
    push_rejections(&mut s, concurrent);
    s
}

/// The merged-template cache line (sim-side analog of the executable
/// cache), shown whenever the run exercised the cache at all.
fn push_template_cache(s: &mut String, r: &ServeReport) {
    if r.template_cache_hits + r.template_cache_misses > 0 {
        s.push_str(&format!(
            "template cache: {} hit(s), {} merged block(s) built\n",
            r.template_cache_hits, r.template_cache_misses
        ));
    }
}

/// The per-request rejection block shared by the comparison table and the
/// real-path summary (count, laxity tally, one line per rejection).
fn push_rejections(s: &mut String, r: &ServeReport) {
    if r.rejected.is_empty() {
        return;
    }
    s.push_str(&format!(
        "rejected: {} request(s) ({} laxity-negative at admission)\n",
        r.rejected.len(),
        r.laxity_rejections
    ));
    for (id, why) in &r.rejected {
        s.push_str(&format!("  #{id}: {why}\n"));
    }
}

/// Render the real-path summary: pacing, executable-cache behaviour, and
/// admission-control rejections next to the latency headline.
pub fn format_real_summary(r: &ServeReport) -> String {
    let mut s = format!(
        "real ({} pacing): served {} request(s) in {:.1} ms -> {:.1} req/s  \
         p50 {:.2} ms  p99 {:.2} ms\n",
        r.pacing,
        r.outcomes.len(),
        r.makespan * 1e3,
        r.throughput_rps,
        r.p50_latency * 1e3,
        r.p99_latency * 1e3
    );
    s.push_str(&format!(
        "executable cache: {} hit(s), {} miss(es); cold batch {:.2} ms, warm batch {:.2} ms\n",
        r.exec_cache_hits,
        r.exec_cache_misses,
        r.cold_batch_latency * 1e3,
        r.warm_batch_latency * 1e3
    ));
    if r.deadline_total > 0 {
        s.push_str(&format!(
            "deadlines: {}/{} missed ({:.1}%)\n",
            r.deadline_misses,
            r.deadline_total,
            r.deadline_miss_rate * 100.0
        ));
    }
    push_template_cache(&mut s, r);
    push_rejections(&mut s, r);
    s
}

/// The `BENCH_serve.json` schema: throughput req/s and p50/p99 latency per
/// mode, plus the headline speedup — the perf-trajectory artifact CI uploads.
pub fn serve_bench_json(concurrent: &ServeReport, sequential: &ServeReport) -> Json {
    let speedup = if concurrent.makespan > 0.0 {
        sequential.makespan / concurrent.makespan
    } else {
        0.0
    };
    Json::obj(vec![
        ("schema", Json::str("pyschedcl-serve-bench-v1")),
        ("concurrent", concurrent.to_json()),
        ("sequential", sequential.to_json()),
        ("speedup", Json::num(speedup)),
    ])
}

/// Peak resident-set size of this process in MiB, from `/proc/self/status`
/// `VmHWM` (the kernel's high-water mark — exactly the "did memory stay
/// bounded" witness the soak bench wants). `None` off Linux or when the
/// field is unreadable; the soak artifact then omits `peak_rss_mb` and the
/// baseline's `optional` gate skips it.
pub fn peak_rss_mb() -> Option<f64> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: f64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
                return Some(kb / 1024.0);
            }
        }
        None
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// The `BENCH_serve_soak.json` schema: sustained streaming throughput and
/// the bounded-state witnesses. `wall_seconds` is the bench's wall-clock
/// measurement (virtual-time simulation driven as fast as the host can);
/// `bench_requests_per_second` is requests over that wall time — the CI
/// regression axis. `peak_rss_mb` is present only when the platform can
/// report it ([`peak_rss_mb`]).
pub fn serve_soak_json(r: &StreamReport, wall_seconds: f64, rss_mb: Option<f64>) -> Json {
    let bench_rps = if wall_seconds > 0.0 {
        r.served as f64 / wall_seconds
    } else {
        0.0
    };
    let mut fields = vec![
        ("schema", Json::str("pyschedcl-serve-soak-v1")),
        ("streaming", r.to_json()),
        ("requests", Json::num(r.served as f64)),
        ("window", Json::num(r.window as f64)),
        ("wall_seconds", Json::num(wall_seconds)),
        ("bench_requests_per_second", Json::num(bench_rps)),
        ("throughput_rps", Json::num(r.throughput_rps)),
        ("p99_latency_s", Json::num(r.p99_latency)),
        ("preemptions", Json::num(r.preemptions as f64)),
        ("events", Json::num(r.events as f64)),
        ("peak_live_requests", Json::num(r.peak_live_requests as f64)),
        (
            "peak_live_components",
            Json::num(r.peak_live_components as f64),
        ),
    ];
    if let Some(mb) = rss_mb {
        fields.push(("peak_rss_mb", Json::num(mb)));
    }
    Json::obj(fields)
}

/// The `BENCH_serve_chaos.json` schema: the fault-injected serving gate
/// surface. The headline is `lost` — offered requests unaccounted for by
/// `served + rejected + shed` — which the committed baseline pins to
/// exactly zero: crashes, wedges, and slowdowns may delay or shed work,
/// but may never silently drop it. `max_retries` witnesses that recovery
/// stayed inside the plan's budget, and `fault_events` that the plan
/// actually installed.
pub fn serve_chaos_json(r: &StreamReport, wall_seconds: f64, fault_events: usize) -> Json {
    let lost = r.offered as f64 - r.served as f64 - r.rejected as f64 - r.shed as f64;
    Json::obj(vec![
        ("schema", Json::str("pyschedcl-serve-chaos-v1")),
        ("streaming", r.to_json()),
        ("offered", Json::num(r.offered as f64)),
        ("served", Json::num(r.served as f64)),
        ("rejected", Json::num(r.rejected as f64)),
        ("shed", Json::num(r.shed as f64)),
        ("lost", Json::num(lost)),
        ("max_retries", Json::num(r.max_retries as f64)),
        ("fault_events", Json::num(fault_events as f64)),
        ("wall_seconds", Json::num(wall_seconds)),
        ("p99_latency_s", Json::num(r.p99_latency)),
        ("deadline_miss_rate", Json::num(r.deadline_miss_rate)),
        ("preemptions", Json::num(r.preemptions as f64)),
        ("peak_live_requests", Json::num(r.peak_live_requests as f64)),
        (
            "peak_live_components",
            Json::num(r.peak_live_components as f64),
        ),
    ])
}

/// The `BENCH_serve_real_stream.json` schema: the real-path streaming
/// smoke's gate surface — tail latency, miss rate, backpressure witness,
/// and executable-cache behaviour, with the full [`StreamReport`] nested
/// under `streaming` for inspection.
pub fn serve_real_stream_json(r: &StreamReport, wall_seconds: f64) -> Json {
    Json::obj(vec![
        ("schema", Json::str("pyschedcl-serve-real-stream-v1")),
        ("streaming", r.to_json()),
        ("requests", Json::num(r.served as f64)),
        ("rejected", Json::num(r.rejected as f64)),
        ("window", Json::num(r.window as f64)),
        ("wall_seconds", Json::num(wall_seconds)),
        ("p99_latency_s", Json::num(r.p99_latency)),
        ("deadline_miss_rate", Json::num(r.deadline_miss_rate)),
        ("peak_live_requests", Json::num(r.peak_live_requests as f64)),
        (
            "peak_live_components",
            Json::num(r.peak_live_components as f64),
        ),
        ("exec_cache_hits", Json::num(r.exec_cache_hits as f64)),
        ("exec_cache_misses", Json::num(r.exec_cache_misses as f64)),
        (
            "template_cache_misses",
            Json::num(r.template_cache_misses as f64),
        ),
    ])
}

/// Render the streaming-run summary (the `serve --streaming` footer, both
/// backends: `"virtual"` pacing means the sim backend's virtual clock,
/// `"open"`/`"closed"` the real backend's wall clock).
pub fn format_stream_summary(r: &StreamReport) -> String {
    let util: Vec<String> = r
        .device_util
        .iter()
        .map(|u| format!("{:.0}%", u * 100.0))
        .collect();
    let clock = if r.pacing == "virtual" {
        "virtual".to_string()
    } else {
        format!("wall, {} pacing", r.pacing)
    };
    let mut s = format!(
        "streaming ({}): served {} request(s) in {:.1} ms {clock} -> {:.1} req/s  \
         p50 {:.2} ms  p99 {:.2} ms\n",
        r.policy,
        r.served,
        r.makespan * 1e3,
        r.throughput_rps,
        r.p50_latency * 1e3,
        r.p99_latency * 1e3
    );
    if r.exec_cache_hits + r.exec_cache_misses > 0 {
        s.push_str(&format!(
            "executable cache: {} hit(s), {} miss(es); cold batch {:.2} ms, warm batch {:.2} ms\n",
            r.exec_cache_hits,
            r.exec_cache_misses,
            r.cold_batch_latency * 1e3,
            r.warm_batch_latency * 1e3
        ));
    }
    s.push_str(&format!(
        "bounded state: window {} -> peak {} live request(s), {} live component(s); \
         {} event(s)\n",
        if r.window == 0 {
            "unbounded".to_string()
        } else {
            r.window.to_string()
        },
        r.peak_live_requests,
        r.peak_live_components,
        r.events
    ));
    s.push_str(&format!("device util: {}\n", util.join(" ")));
    if r.shed > 0 || r.max_retries > 0 {
        s.push_str(&format!(
            "faults: {} of {} offered request(s) shed, max {} crash retry(s) on one request\n",
            r.shed, r.offered, r.max_retries
        ));
    }
    if r.deadline_total > 0 {
        s.push_str(&format!(
            "deadlines: {}/{} missed ({:.1}%), {} preemption(s)\n",
            r.deadline_misses,
            r.deadline_total,
            r.deadline_miss_rate * 100.0,
            r.preemptions
        ));
        for (p, l) in &r.per_priority_p99 {
            s.push_str(&format!("  priority {p}: p99 {:.2} ms\n", l * 1e3));
        }
    }
    if r.template_cache_hits + r.template_cache_misses > 0 {
        s.push_str(&format!(
            "template cache: {} hit(s), {} merged block(s) built\n",
            r.template_cache_hits, r.template_cache_misses
        ));
    }
    if r.rejected > 0 {
        s.push_str(&format!(
            "rejected: {} request(s) ({} laxity-negative at admission)\n",
            r.rejected, r.laxity_rejections
        ));
        for (id, why) in &r.rejected_sample {
            s.push_str(&format!("  #{id}: {why}\n"));
        }
        if r.rejected > r.rejected_sample.len() {
            s.push_str(&format!(
                "  ... and {} more\n",
                r.rejected - r.rejected_sample.len()
            ));
        }
    }
    s
}

/// Render the sharded-run summary: router header, one line per shard,
/// then the merged global view via [`format_stream_summary`].
pub fn format_sharded_summary(r: &ShardedReport) -> String {
    let mut s = format!(
        "sharded serving: {} shard(s), spill threshold {} (effective {})\n",
        r.router.shards, r.router.spill_threshold, r.router.effective_spill_threshold
    );
    for sh in &r.shards {
        s.push_str(&format!(
            "  shard {}: routed {} served {} rejected {} shed {} | makespan {:.1} ms \
             thru {:.1} r/s | peak {} live, {} block(s) built\n",
            sh.shard,
            sh.routed,
            sh.served,
            sh.rejected,
            sh.shed,
            sh.makespan * 1e3,
            sh.throughput_rps,
            sh.peak_live_requests,
            sh.template_cache_misses
        ));
    }
    s.push_str(&format!(
        "router: {} spill(s), {} duplicate rejection(s), {} rebalance(s), \
         {:.3} ms routing\n",
        r.router.spills,
        r.router.duplicate_rejections,
        r.router.rebalances,
        r.route_seconds * 1e3
    ));
    s.push_str(&format_stream_summary(&r.merged));
    s
}

/// The `BENCH_serve_shard.json` building block for one sharded run:
/// router counters, per-shard slices, and the merged streaming view (the
/// bench wraps three of these — 4/16/64 GPUs — into the sweep artifact).
pub fn serve_shard_json(r: &ShardedReport, wall_seconds: f64) -> Json {
    let m = &r.merged;
    Json::obj(vec![
        ("schema", Json::str("pyschedcl-serve-shard-v1")),
        ("shards", Json::num(r.router.shards as f64)),
        ("spill_threshold", Json::num(r.router.spill_threshold as f64)),
        (
            "effective_spill_threshold",
            Json::num(r.router.effective_spill_threshold as f64),
        ),
        ("spills", Json::num(r.router.spills as f64)),
        (
            "duplicate_rejections",
            Json::num(r.router.duplicate_rejections as f64),
        ),
        ("rebalances", Json::num(r.router.rebalances as f64)),
        ("route_seconds", Json::num(r.route_seconds)),
        (
            "router_overhead_frac",
            Json::num(if wall_seconds > 0.0 {
                r.route_seconds / wall_seconds
            } else {
                0.0
            }),
        ),
        ("wall_seconds", Json::num(wall_seconds)),
        ("offered", Json::num(m.offered as f64)),
        ("served", Json::num(m.served as f64)),
        ("rejected", Json::num(m.rejected as f64)),
        ("shed", Json::num(m.shed as f64)),
        (
            "lost",
            Json::num(
                (m.offered as f64) - (m.served as f64) - (m.rejected as f64) - (m.shed as f64),
            ),
        ),
        ("throughput_rps", Json::num(m.throughput_rps)),
        ("p99_latency_s", Json::num(m.p99_latency)),
        ("deadline_miss_rate", Json::num(m.deadline_miss_rate)),
        (
            "per_shard",
            Json::Arr(
                r.shards
                    .iter()
                    .map(|sh| {
                        Json::obj(vec![
                            ("shard", Json::num(sh.shard as f64)),
                            ("routed", Json::num(sh.routed as f64)),
                            ("served", Json::num(sh.served as f64)),
                            ("rejected", Json::num(sh.rejected as f64)),
                            ("shed", Json::num(sh.shed as f64)),
                            ("makespan_s", Json::num(sh.makespan)),
                            ("throughput_rps", Json::num(sh.throughput_rps)),
                            (
                                "template_cache_misses",
                                Json::num(sh.template_cache_misses as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("streaming", m.to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::platform::Platform;
    use crate::sched::Clustering;
    use crate::serve::{serve_sequential, serve_sim, ServeConfig, ServeRequest, Workload};

    fn reports() -> (ServeReport, ServeReport) {
        let platform = Platform::paper_testbed(3, 1);
        let requests: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest::new(i, i as f64 * 1e-3, Workload::Head { beta: 64 }))
            .collect();
        let cfg = ServeConfig::default();
        let conc = serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
        let seq =
            serve_sequential(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
        (conc, seq)
    }

    #[test]
    fn table_carries_both_modes_and_speedup() {
        let (conc, seq) = reports();
        let table = format_serve_comparison(&conc, &seq);
        assert!(table.contains("sequential"));
        assert!(table.contains("concurrent"));
        assert!(table.contains("speedup"));
    }

    #[test]
    fn bench_json_schema_fields_present() {
        let (conc, seq) = reports();
        let json = serve_bench_json(&conc, &seq);
        let text = json.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("schema").and_then(|s| s.as_str()),
            Some("pyschedcl-serve-bench-v1")
        );
        for mode in ["concurrent", "sequential"] {
            let m = parsed.get(mode).unwrap();
            assert!(m.get("throughput_rps").and_then(|v| v.as_f64()).is_some());
            assert!(m.get("p50_latency_s").and_then(|v| v.as_f64()).is_some());
            assert!(m.get("p99_latency_s").and_then(|v| v.as_f64()).is_some());
            assert!(m.get("deadline_miss_rate").and_then(|v| v.as_f64()).is_some());
            assert!(m.get("preemptions").and_then(|v| v.as_f64()).is_some());
            assert!(m.get("per_priority_p99_s").is_some());
            // Serving-at-scale fields (PR 3): pacing, admission control,
            // executable-cache accounting.
            assert_eq!(m.get("pacing").and_then(|v| v.as_str()), Some("virtual"));
            assert!(m.get("laxity_rejections").and_then(|v| v.as_f64()).is_some());
            assert!(m.get("exec_cache_hits").and_then(|v| v.as_f64()).is_some());
            assert!(m.get("exec_cache_misses").and_then(|v| v.as_f64()).is_some());
            assert!(m.get("cold_batch_latency_s").and_then(|v| v.as_f64()).is_some());
            assert!(m.get("warm_batch_latency_s").and_then(|v| v.as_f64()).is_some());
            // Merged-template cache accounting (PR 4).
            assert!(m.get("template_cache_hits").and_then(|v| v.as_f64()).is_some());
            assert!(m
                .get("template_cache_misses")
                .and_then(|v| v.as_f64())
                .is_some());
        }
        assert!(parsed.get("speedup").and_then(|v| v.as_f64()).unwrap() > 0.0);
    }

    #[test]
    fn soak_json_carries_bounded_state_witnesses() {
        let platform = Platform::paper_testbed(3, 1);
        let requests: Vec<ServeRequest> = (0..8)
            .map(|i| ServeRequest::new(i, i as f64 * 1e-3, Workload::Head { beta: 64 }))
            .collect();
        let cfg = crate::serve::StreamingConfig::default();
        let mut sink = crate::serve::NullSink;
        let report = crate::serve::serve_stream(
            requests,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            &mut sink,
        )
        .unwrap();
        let summary = format_stream_summary(&report);
        assert!(summary.contains("streaming"), "{summary}");
        assert!(summary.contains("bounded state"), "{summary}");

        let json = serve_soak_json(&report, 0.5, Some(123.0));
        let parsed = Json::parse(&json.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("schema").and_then(|s| s.as_str()),
            Some("pyschedcl-serve-soak-v1")
        );
        assert_eq!(parsed.get("requests").and_then(|v| v.as_f64()), Some(8.0));
        assert_eq!(
            parsed.get("bench_requests_per_second").and_then(|v| v.as_f64()),
            Some(16.0)
        );
        for key in [
            "window",
            "wall_seconds",
            "throughput_rps",
            "p99_latency_s",
            "preemptions",
            "events",
            "peak_live_requests",
            "peak_live_components",
            "peak_rss_mb",
        ] {
            assert!(parsed.get(key).and_then(|v| v.as_f64()).is_some(), "{key}");
        }
        assert!(parsed.get("streaming").is_some());
        // Without an RSS reading the field is omitted, not zeroed — the
        // baseline gate marks it optional for exactly this case.
        let without = serve_soak_json(&report, 0.5, None);
        assert!(Json::parse(&without.to_string_pretty())
            .unwrap()
            .get("peak_rss_mb")
            .is_none());
    }

    #[test]
    fn chaos_json_pins_conservation_and_the_retry_witness() {
        use crate::fault::{FaultEvent, FaultKind, FaultPlan};
        let platform = Platform::paper_testbed(3, 1);
        let requests: Vec<ServeRequest> = (0..8)
            .map(|i| ServeRequest::new(i, i as f64 * 1e-3, Workload::Head { beta: 64 }))
            .collect();
        let plan = FaultPlan {
            events: vec![FaultEvent {
                device: 0,
                at: 2e-3,
                kind: FaultKind::Crash,
            }],
            retry_budget: 4,
            backoff_base: 1e-4,
            ..FaultPlan::default()
        }
        .normalized()
        .unwrap();
        let n_events = plan.events.len();
        let cfg = crate::serve::StreamingConfig {
            faults: Some(plan),
            ..crate::serve::StreamingConfig::default()
        };
        let mut sink = crate::serve::NullSink;
        let report = crate::serve::serve_stream(
            requests,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            &mut sink,
        )
        .unwrap();
        assert_eq!(report.served + report.rejected + report.shed, report.offered);
        let summary = format_stream_summary(&report);
        if report.shed > 0 || report.max_retries > 0 {
            assert!(summary.contains("faults:"), "{summary}");
        }
        let json = serve_chaos_json(&report, 0.25, n_events);
        let parsed = Json::parse(&json.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("schema").and_then(|s| s.as_str()),
            Some("pyschedcl-serve-chaos-v1")
        );
        assert_eq!(parsed.get("offered").and_then(|v| v.as_f64()), Some(8.0));
        assert_eq!(parsed.get("lost").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(parsed.get("fault_events").and_then(|v| v.as_f64()), Some(1.0));
        for key in [
            "served",
            "rejected",
            "shed",
            "max_retries",
            "wall_seconds",
            "p99_latency_s",
            "deadline_miss_rate",
            "preemptions",
            "peak_live_requests",
            "peak_live_components",
        ] {
            assert!(parsed.get(key).and_then(|v| v.as_f64()).is_some(), "{key}");
        }
        assert!(parsed.get("streaming").and_then(|s| s.get("lost")).is_some());
    }

    #[test]
    fn real_stream_json_carries_the_gate_surface() {
        let platform = Platform::paper_testbed(3, 1);
        let requests: Vec<ServeRequest> = (0..6)
            .map(|i| ServeRequest::new(i, i as f64 * 1e-3, Workload::Head { beta: 64 }))
            .collect();
        let cfg = crate::serve::StreamingConfig {
            window: 8,
            ..crate::serve::StreamingConfig::default()
        };
        let mut sink = crate::serve::NullSink;
        let report = crate::serve::serve_stream(
            requests,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            &mut sink,
        )
        .unwrap();
        let json = serve_real_stream_json(&report, 1.5);
        let parsed = Json::parse(&json.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("schema").and_then(|s| s.as_str()),
            Some("pyschedcl-serve-real-stream-v1")
        );
        assert_eq!(parsed.get("requests").and_then(|v| v.as_f64()), Some(6.0));
        assert_eq!(parsed.get("window").and_then(|v| v.as_f64()), Some(8.0));
        for key in [
            "rejected",
            "wall_seconds",
            "p99_latency_s",
            "deadline_miss_rate",
            "peak_live_requests",
            "peak_live_components",
            "exec_cache_hits",
            "exec_cache_misses",
            "template_cache_misses",
        ] {
            assert!(parsed.get(key).and_then(|v| v.as_f64()).is_some(), "{key}");
        }
        assert!(parsed.get("streaming").is_some());
    }

    #[test]
    fn peak_rss_reads_the_linux_high_water_mark() {
        // On Linux (every CI runner) the reading must exist and be sane;
        // elsewhere the function degrades to None by design.
        if cfg!(target_os = "linux") {
            let mb = peak_rss_mb().expect("VmHWM missing from /proc/self/status");
            assert!(mb > 0.0 && mb < 1024.0 * 1024.0, "peak RSS {mb} MiB");
        } else {
            assert!(peak_rss_mb().is_none());
        }
    }

    #[test]
    fn table_reports_deadline_misses_and_preemptions() {
        let platform = Platform::paper_testbed(3, 1);
        let mut requests: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest::new(i, i as f64 * 1e-3, Workload::Head { beta: 64 }))
            .collect();
        for r in &mut requests {
            r.deadline = Some(1e-6); // unmeetably tight: all miss
        }
        // Laxity admission would (correctly) reject these at arrival; turn
        // it off — this test is about miss *accounting*, not admission.
        let cfg = ServeConfig {
            laxity_admission: false,
            ..ServeConfig::default()
        };
        let conc = serve_sim(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
        let seq =
            serve_sequential(&requests, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
        assert_eq!(conc.deadline_total, 4);
        assert_eq!(conc.deadline_misses, 4);
        assert!((conc.deadline_miss_rate - 1.0).abs() < 1e-12);
        let table = format_serve_comparison(&conc, &seq);
        assert!(table.contains("deadlines: 4/4 missed"), "{table}");
        assert!(table.contains("preemption"), "{table}");
    }

    #[test]
    fn table_counts_laxity_rejections_at_admission() {
        let platform = Platform::paper_testbed(3, 1);
        let mut tight = ServeRequest::new(0, 0.0, Workload::Head { beta: 64 });
        tight.deadline = Some(1e-9);
        let ok = ServeRequest::new(1, 0.0, Workload::Head { beta: 64 });
        let cfg = ServeConfig::default(); // laxity admission on
        let conc =
            serve_sim(&[tight.clone(), ok.clone()], &platform, &PaperCost, &mut Clustering, &cfg)
                .unwrap();
        let seq = serve_sequential(&[tight, ok], &platform, &PaperCost, &mut Clustering, &cfg)
            .unwrap();
        assert_eq!(conc.laxity_rejections, 1);
        let table = format_serve_comparison(&conc, &seq);
        assert!(table.contains("1 laxity-negative at admission"), "{table}");
    }
}
