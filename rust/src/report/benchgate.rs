//! The CI bench-regression gate (`pyschedcl bench-check`).
//!
//! A committed **baseline** file (`ci/bench_baselines/BENCH_*.json`) lists
//! dotted metric paths into a `BENCH_*.json` smoke artifact together with a
//! `max` and/or `min` bound. The gate re-reads the freshly produced
//! artifact, resolves each path, widens the bound by the tolerance
//! (relative) plus an optional per-check absolute `slack`, and fails with a
//! typed [`Error::Bench`] when any metric moved beyond it — so a latency or
//! deadline-miss regression fails the CI job instead of silently shipping.
//!
//! Re-baselining intentionally is `bench-check --update`: bounds are
//! rewritten to the observed values (tolerances still apply at check time),
//! and the updated baseline is committed alongside the change that moved
//! the numbers.
//!
//! Baseline schema (`pyschedcl-bench-baseline-v1`):
//!
//! ```json
//! {
//!   "schema": "pyschedcl-bench-baseline-v1",
//!   "tolerance": 0.15,
//!   "checks": [
//!     {"path": "concurrent.p99_latency_s", "max": 0.5},
//!     {"path": "speedup", "min": 1.0, "slack": 0.05}
//!   ]
//! }
//! ```

use crate::error::{Error, Result};
use crate::json::Json;
use std::path::Path;

/// One gated metric: a dotted path into the bench JSON plus bounds.
#[derive(Debug, Clone)]
pub struct CheckSpec {
    /// Dotted path into the bench artifact, e.g. `"concurrent.p99_latency_s"`.
    pub path: String,
    /// Upper bound (higher-is-worse metrics: latency, miss rate).
    pub max: Option<f64>,
    /// Lower bound (lower-is-worse metrics: throughput, speedup, cache hits).
    pub min: Option<f64>,
    /// Absolute slack added on top of the relative tolerance — lets a
    /// zero-valued bound (e.g. `miss_rate` max 0) tolerate noise.
    pub slack: f64,
    /// Per-check tolerance override. Takes precedence over both the
    /// file-level tolerance and the CLI `--tolerance` — exact-count
    /// invariants (served-request counts, cache hits) set `0` so a widened
    /// gate can never accept silently dropped requests.
    pub tolerance: Option<f64>,
    /// Platform-dependent metrics (e.g. `peak_rss_mb`, emitted only on
    /// Linux) set this: a *missing* metric is skipped instead of failed.
    /// A present metric is still checked normally — optional never weakens
    /// the bound, only the presence requirement.
    pub optional: bool,
}

/// A parsed baseline file.
#[derive(Debug, Clone)]
pub struct Baseline {
    /// Relative tolerance applied to every bound (overridable per run).
    pub tolerance: f64,
    pub checks: Vec<CheckSpec>,
}

pub const BASELINE_SCHEMA: &str = "pyschedcl-bench-baseline-v1";

/// Read and parse a committed baseline file with a path-qualified typed
/// error. The CI gate calls this first so a missing, renamed, or
/// unparseable baseline fails the step early with a clear message instead
/// of surfacing as a confusing downstream comparison failure.
pub fn load_baseline(path: &Path) -> Result<Baseline> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        Error::Bench(format!(
            "cannot read committed baseline {}: {e} (was it deleted or renamed?)",
            path.display()
        ))
    })?;
    parse_baseline(&text)
        .map_err(|e| Error::Bench(format!("committed baseline {} is invalid: {e}", path.display())))
}

/// Parse a committed baseline file.
pub fn parse_baseline(text: &str) -> Result<Baseline> {
    let root = Json::parse(text)?;
    let schema = root.get("schema").and_then(|s| s.as_str());
    if schema != Some(BASELINE_SCHEMA) {
        return Err(Error::Bench(format!(
            "baseline schema {:?}, expected {BASELINE_SCHEMA:?}",
            schema.unwrap_or("<missing>")
        )));
    }
    let tolerance = root
        .get("tolerance")
        .and_then(|t| t.as_f64())
        .unwrap_or(0.15);
    let arr = root
        .get("checks")
        .and_then(|c| c.as_arr())
        .ok_or_else(|| Error::Bench("baseline has no 'checks' array".into()))?;
    let mut checks = Vec::with_capacity(arr.len());
    for c in arr {
        let path = c
            .get("path")
            .and_then(|p| p.as_str())
            .ok_or_else(|| Error::Bench("baseline check without 'path'".into()))?
            .to_string();
        let max = c.get("max").and_then(|v| v.as_f64());
        let min = c.get("min").and_then(|v| v.as_f64());
        if max.is_none() && min.is_none() {
            return Err(Error::Bench(format!("check '{path}' has neither max nor min")));
        }
        checks.push(CheckSpec {
            path,
            max,
            min,
            slack: c.get("slack").and_then(|v| v.as_f64()).unwrap_or(0.0),
            tolerance: c.get("tolerance").and_then(|v| v.as_f64()),
            optional: c.get("optional").and_then(|v| v.as_bool()).unwrap_or(false),
        });
    }
    Ok(Baseline { tolerance, checks })
}

/// Resolve a dotted path (`"concurrent.p99_latency_s"`) to a number.
pub fn lookup_metric(root: &Json, path: &str) -> Option<f64> {
    let mut node = root;
    for key in path.split('.') {
        node = node.get(key)?;
    }
    node.as_f64()
}

/// One check's verdict.
#[derive(Debug, Clone)]
pub struct GateResult {
    pub path: String,
    /// `None` when the path is missing from the artifact (schema drift —
    /// a failure unless the check is marked `optional`).
    pub observed: Option<f64>,
    /// Human-readable allowed range after tolerance/slack widening.
    pub allowed: String,
    /// Distance from the observed value to the nearest widened bound
    /// (positive = headroom, negative = overshoot). `None` when the
    /// metric is missing or non-finite.
    pub margin: Option<f64>,
    pub ok: bool,
}

/// Run every baseline check against the freshly produced bench artifact.
/// `tolerance` overrides the baseline's file-level tolerance when given.
pub fn check_bench(baseline: &Baseline, current: &Json, tolerance: Option<f64>) -> Vec<GateResult> {
    baseline
        .checks
        .iter()
        .map(|c| {
            // Per-check tolerance is authoritative (exact-count gates pin
            // it to 0); otherwise the CLI override, then the file default.
            let tol = c
                .tolerance
                .or(tolerance)
                .unwrap_or(baseline.tolerance)
                .max(0.0);
            let observed = lookup_metric(current, &c.path);
            // Widen multiplicatively away from the allowed region, plus
            // absolute slack (a negative bound widens toward -∞ via abs).
            let hi = c.max.map(|m| m + m.abs() * tol + c.slack);
            let lo = c.min.map(|m| m - m.abs() * tol - c.slack);
            let allowed = match (lo, hi) {
                (Some(l), Some(h)) => format!("[{l:.6}, {h:.6}]"),
                (Some(l), None) => format!(">= {l:.6}"),
                (None, Some(h)) => format!("<= {h:.6}"),
                (None, None) => "(unbounded)".into(),
            };
            let ok = match observed {
                None => c.optional,
                Some(v) => {
                    v.is_finite()
                        && hi.map(|h| v <= h).unwrap_or(true)
                        && lo.map(|l| v >= l).unwrap_or(true)
                }
            };
            let margin = observed.filter(|v| v.is_finite()).and_then(|v| {
                match (hi.map(|h| h - v), lo.map(|l| v - l)) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (Some(a), None) => Some(a),
                    (None, Some(b)) => Some(b),
                    (None, None) => None,
                }
            });
            GateResult {
                path: c.path.clone(),
                observed,
                allowed,
                margin,
                ok,
            }
        })
        .collect()
}

fn margin_cell(r: &GateResult) -> String {
    match r.margin {
        Some(m) => format!("{m:+.6}"),
        None => "-".into(),
    }
}

/// Render the verdict table — printed on success as well as failure, so a
/// green CI run still shows how much headroom every gate has left.
pub fn format_gate(results: &[GateResult]) -> String {
    let mut s = String::from(
        "metric                                   | observed     | allowed              \
         | margin       | verdict\n\
         -----------------------------------------+--------------+----------------------\
         +--------------+--------\n",
    );
    for r in results {
        let obs = match r.observed {
            Some(v) => format!("{v:.6}"),
            None => "<missing>".into(),
        };
        s.push_str(&format!(
            "{:<40} | {:>12} | {:<20} | {:>12} | {}\n",
            r.path,
            obs,
            r.allowed,
            margin_cell(r),
            if r.ok { "ok" } else { "FAIL" }
        ));
    }
    s
}

/// Markdown flavor of the verdict table, appended to
/// `$GITHUB_STEP_SUMMARY` by `pyschedcl bench-check` when the variable is
/// set (i.e. inside a GitHub Actions step).
pub fn format_gate_markdown(title: &str, results: &[GateResult]) -> String {
    let mut s = format!(
        "### bench-check: {title}\n\n\
         | metric | observed | allowed | margin | verdict |\n\
         |---|---|---|---|---|\n"
    );
    for r in results {
        let obs = match r.observed {
            Some(v) => format!("{v:.6}"),
            None => "&lt;missing&gt;".into(),
        };
        s.push_str(&format!(
            "| `{}` | {} | `{}` | {} | {} |\n",
            r.path,
            obs,
            r.allowed,
            margin_cell(r),
            if r.ok { "ok" } else { "**FAIL**" }
        ));
    }
    s.push('\n');
    s
}

/// Re-baseline: rewrite every check's bounds to the observed values
/// (tolerance/slack still widen them at check time). Missing metrics are a
/// typed error — re-baselining must not silently drop coverage — except
/// for `optional` checks, whose committed bounds are preserved verbatim
/// when the metric is absent (re-baselining on a platform that cannot emit
/// the metric must not erase the bound other platforms are gated by).
pub fn update_baseline(baseline: &Baseline, current: &Json) -> Result<Json> {
    let mut checks = Vec::with_capacity(baseline.checks.len());
    for c in &baseline.checks {
        let observed = match lookup_metric(current, &c.path) {
            Some(v) => Some(v),
            None if c.optional => None,
            None => {
                return Err(Error::Bench(format!(
                    "cannot re-baseline '{}': metric missing",
                    c.path
                )))
            }
        };
        let mut fields = vec![("path", Json::str(c.path.clone()))];
        if let Some(m) = c.max {
            fields.push(("max", Json::num(observed.unwrap_or(m))));
        }
        if let Some(m) = c.min {
            fields.push(("min", Json::num(observed.unwrap_or(m))));
        }
        if c.slack != 0.0 {
            fields.push(("slack", Json::num(c.slack)));
        }
        if let Some(t) = c.tolerance {
            fields.push(("tolerance", Json::num(t)));
        }
        if c.optional {
            fields.push(("optional", Json::Bool(true)));
        }
        checks.push(Json::obj(fields));
    }
    Ok(Json::obj(vec![
        ("schema", Json::str(BASELINE_SCHEMA)),
        ("tolerance", Json::num(baseline.tolerance)),
        ("checks", Json::Arr(checks)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    const BASE: &str = r#"{
        "schema": "pyschedcl-bench-baseline-v1",
        "tolerance": 0.10,
        "checks": [
            {"path": "concurrent.p99_latency_s", "max": 0.100},
            {"path": "concurrent.throughput_rps", "min": 100.0},
            {"path": "concurrent.deadline_miss_rate", "max": 0.0, "slack": 0.05},
            {"path": "concurrent.requests", "min": 32, "tolerance": 0}
        ]
    }"#;

    fn bench_n(p99: f64, thru: f64, miss: f64, requests: f64) -> Json {
        Json::obj(vec![(
            "concurrent",
            Json::obj(vec![
                ("p99_latency_s", Json::num(p99)),
                ("throughput_rps", Json::num(thru)),
                ("deadline_miss_rate", Json::num(miss)),
                ("requests", Json::num(requests)),
            ]),
        )])
    }

    fn bench(p99: f64, thru: f64, miss: f64) -> Json {
        bench_n(p99, thru, miss, 32.0)
    }

    #[test]
    fn parses_baseline_and_checks_within_tolerance() {
        let b = parse_baseline(BASE).unwrap();
        assert_eq!(b.checks.len(), 4);
        assert!((b.tolerance - 0.10).abs() < 1e-12);
        // p99 10% worse than baseline is exactly at the widened bound.
        let ok = check_bench(&b, &bench(0.109, 100.0, 0.04), None);
        assert!(ok.iter().all(|r| r.ok), "{}", format_gate(&ok));
    }

    #[test]
    fn regressions_beyond_tolerance_fail() {
        let b = parse_baseline(BASE).unwrap();
        // p99 regressed 20% (> 10% tolerance).
        let r = check_bench(&b, &bench(0.120, 100.0, 0.0), None);
        assert!(!r[0].ok, "{}", format_gate(&r));
        assert!(r[1].ok && r[2].ok);
        // Throughput collapsed below min*(1-tol).
        let r = check_bench(&b, &bench(0.05, 80.0, 0.0), None);
        assert!(!r[1].ok);
        // Miss rate beyond the absolute slack of a zero bound.
        let r = check_bench(&b, &bench(0.05, 100.0, 0.2), None);
        assert!(!r[2].ok);
        // The CLI override widens the gate.
        let r = check_bench(&b, &bench(0.120, 100.0, 0.0), Some(0.5));
        assert!(r[0].ok);
    }

    #[test]
    fn exact_count_checks_ignore_relative_tolerance() {
        // "requests min 32, tolerance 0": one dropped request fails even
        // though the file tolerance (10%) — or a generous CLI override —
        // would have widened the bound to ~28.
        let b = parse_baseline(BASE).unwrap();
        let r = check_bench(&b, &bench_n(0.05, 100.0, 0.0, 31.0), None);
        assert!(!r[3].ok, "{}", format_gate(&r));
        let r = check_bench(&b, &bench_n(0.05, 100.0, 0.0, 31.0), Some(0.5));
        assert!(!r[3].ok, "per-check tolerance must beat the CLI override");
        let r = check_bench(&b, &bench_n(0.05, 100.0, 0.0, 32.0), None);
        assert!(r[3].ok);
    }

    #[test]
    fn optional_checks_skip_missing_metrics_but_gate_present_ones() {
        let base = r#"{
            "schema": "pyschedcl-bench-baseline-v1",
            "checks": [
                {"path": "peak_rss_mb", "max": 1024.0, "optional": true},
                {"path": "requests", "min": 32, "tolerance": 0}
            ]
        }"#;
        let b = parse_baseline(base).unwrap();
        assert!(b.checks[0].optional && !b.checks[1].optional);
        // Metric absent (non-Linux runner): the optional check passes, the
        // mandatory one still gates.
        let current = Json::obj(vec![("requests", Json::num(32.0))]);
        let r = check_bench(&b, &current, None);
        assert!(r[0].ok && r[1].ok, "{}", format_gate(&r));
        // Metric present: the bound applies with full force.
        let fat = Json::obj(vec![
            ("peak_rss_mb", Json::num(90000.0)),
            ("requests", Json::num(32.0)),
        ]);
        let r = check_bench(&b, &fat, None);
        assert!(!r[0].ok, "{}", format_gate(&r));
        // Re-baselining without the metric preserves the committed bound
        // and the optional flag.
        let updated = update_baseline(&b, &current).unwrap();
        let b2 = parse_baseline(&updated.to_string_pretty()).unwrap();
        assert!((b2.checks[0].max.unwrap() - 1024.0).abs() < 1e-9);
        assert!(b2.checks[0].optional);
        // Re-baselining with it rewrites the bound as usual.
        let slim = Json::obj(vec![
            ("peak_rss_mb", Json::num(256.0)),
            ("requests", Json::num(32.0)),
        ]);
        let b3 = parse_baseline(&update_baseline(&b, &slim).unwrap().to_string_pretty()).unwrap();
        assert!((b3.checks[0].max.unwrap() - 256.0).abs() < 1e-9);
    }

    #[test]
    fn missing_metric_is_a_failure_not_a_pass() {
        let b = parse_baseline(BASE).unwrap();
        let r = check_bench(&b, &Json::obj(vec![]), None);
        assert!(r.iter().all(|x| !x.ok));
        assert!(format_gate(&r).contains("<missing>"));
    }

    #[test]
    fn malformed_baselines_are_typed_errors() {
        assert!(matches!(parse_baseline("{}"), Err(Error::Bench(_))));
        let wrong_schema = r#"{"schema": "nope", "checks": []}"#;
        assert!(matches!(parse_baseline(wrong_schema), Err(Error::Bench(_))));
        let no_bound = r#"{"schema": "pyschedcl-bench-baseline-v1",
                           "checks": [{"path": "x"}]}"#;
        assert!(matches!(parse_baseline(no_bound), Err(Error::Bench(_))));
    }

    #[test]
    fn margins_report_headroom_and_overshoot() {
        let b = parse_baseline(BASE).unwrap();
        // p99 max 0.100, tol 10% → widened bound 0.110; observed 0.09 →
        // margin +0.02. Throughput min 100, widened 90; observed 100 →
        // margin +10.
        let r = check_bench(&b, &bench(0.09, 100.0, 0.0), None);
        assert!((r[0].margin.unwrap() - 0.02).abs() < 1e-9, "{:?}", r[0]);
        assert!((r[1].margin.unwrap() - 10.0).abs() < 1e-9, "{:?}", r[1]);
        // A failing gate reports a negative margin.
        let r = check_bench(&b, &bench(0.120, 100.0, 0.0), None);
        assert!(r[0].margin.unwrap() < 0.0);
        assert!(!r[0].ok);
        // Missing metrics have no margin and render as "-" / <missing>.
        let r = check_bench(&b, &Json::obj(vec![]), None);
        assert!(r[0].margin.is_none());
        assert!(format_gate(&r).contains(" - "));
        // Both renderers carry the margin column.
        let r = check_bench(&b, &bench(0.09, 100.0, 0.0), None);
        assert!(format_gate(&r).contains("margin"));
        let md = format_gate_markdown("BENCH_x.json", &r);
        assert!(md.contains("| margin |") && md.contains("`concurrent.p99_latency_s`"));
    }

    #[test]
    fn load_baseline_fails_early_with_clear_messages() {
        // Missing file: path-qualified typed error.
        let missing = Path::new("/nonexistent/ci/bench_baselines/BENCH_gone.json");
        match load_baseline(missing) {
            Err(Error::Bench(msg)) => {
                assert!(msg.contains("BENCH_gone.json"), "{msg}");
                assert!(msg.contains("cannot read committed baseline"), "{msg}");
            }
            other => panic!("expected Error::Bench, got {other:?}"),
        }
        // Unparseable file: path-qualified typed error.
        let dir = std::env::temp_dir().join("pyschedcl_benchgate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("BENCH_bad.json");
        std::fs::write(&bad, "{ not json").unwrap();
        match load_baseline(&bad) {
            Err(Error::Bench(msg)) => {
                assert!(msg.contains("BENCH_bad.json"), "{msg}");
                assert!(msg.contains("invalid"), "{msg}");
            }
            other => panic!("expected Error::Bench, got {other:?}"),
        }
        // A good file round-trips.
        let good = dir.join("BENCH_good.json");
        std::fs::write(&good, BASE).unwrap();
        assert_eq!(load_baseline(&good).unwrap().checks.len(), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn update_rewrites_bounds_to_observed_values() {
        let b = parse_baseline(BASE).unwrap();
        let updated = update_baseline(&b, &bench(0.080, 140.0, 0.01)).unwrap();
        let b2 = parse_baseline(&updated.to_string_pretty()).unwrap();
        assert!((b2.checks[0].max.unwrap() - 0.080).abs() < 1e-9);
        assert!((b2.checks[1].min.unwrap() - 140.0).abs() < 1e-9);
        // Slack survives the rewrite; the observed run then passes its own
        // updated baseline.
        assert!((b2.checks[2].slack - 0.05).abs() < 1e-12);
        let r = check_bench(&b2, &bench(0.080, 140.0, 0.01), None);
        assert!(r.iter().all(|x| x.ok), "{}", format_gate(&r));
        // A metric missing from the artifact refuses to re-baseline.
        assert!(matches!(
            update_baseline(&b, &Json::obj(vec![])),
            Err(Error::Bench(_))
        ));
    }
}
