//! Design frontend: the JSON DAG specification file (paper §4A, Fig. 8).
//!
//! A spec bundles: kernel declarations (name, `dev` preference, NDRange
//! geometry, buffer lists with ⟨type, size, pos⟩ tuples, variable args),
//! buffer dependency edges `"ki,br -> kj,bs"`, the task-component partition
//! `tc`, command-queue counts `cq`, and guidance-parameter symbols (the
//! paper's `M*N`-style symbolic sizes).
//!
//! * [`expr`] — the symbolic-expression evaluator for guidance parameters.
//! * [`parse`] — spec → ([`crate::graph::Dag`], [`crate::graph::Partition`],
//!   queue counts).

pub mod expr;
pub mod parse;

pub use expr::eval_expr;
pub use parse::{ApplicationSpec, parse_spec};
