//! Spec-file parser: JSON (Fig. 8 schema) → validated [`Dag`] + [`Partition`]
//! + per-device-type command-queue counts.
//!
//! Schema (all paper fields, plus an `artifact` extension binding kernels to
//! AOT-compiled PJRT executables):
//!
//! ```json
//! {
//!   "symbols": {"M": 256, "N": 256, "K": 256},
//!   "kernels": [
//!     {"id": 0, "name": "matmul", "src": "gemm.cl", "dev": "gpu",
//!      "workDimension": 2, "globalWorkSize": ["M", "N", 1],
//!      "inputBuffers":  [{"type": "float", "size": "M*K", "pos": 0},
//!                        {"type": "float", "size": "K*N", "pos": 1}],
//!      "outputBuffers": [{"type": "float", "size": "M*N", "pos": 2}],
//!      "ioBuffers": [],
//!      "varArgs": [{"type": "int", "pos": 3, "value": "M"}],
//!      "artifact": "gemm_b256"}
//!   ],
//!   "deps": ["0,2 -> 1,0"],
//!   "tc": [[0], [1]],
//!   "cq": {"gpu": 3, "cpu": 1}
//! }
//! ```

use crate::error::{Error, Result};
use crate::graph::{BufferId, Dag, DagBuilder, Partition};
use crate::json::Json;
use crate::platform::DeviceType;
use crate::spec::expr::eval_expr;
use std::collections::HashMap;

/// A fully parsed and validated application specification.
#[derive(Debug)]
pub struct ApplicationSpec {
    pub dag: Dag,
    pub partition: Partition,
    /// Command queues per device type (the spec's `cq` map).
    pub queues: HashMap<DeviceType, usize>,
    pub symbols: HashMap<String, i64>,
}

fn type_size(t: &str) -> u64 {
    match t {
        "double" | "long" | "ulong" => 8,
        "float" | "int" | "uint" => 4,
        "half" | "short" | "ushort" => 2,
        "char" | "uchar" => 1,
        _ => 4,
    }
}

/// Heuristic useful-flops estimate from kernel name + NDRange geometry +
/// symbols, mirroring the LLVM-pass-derived guidance of §4A.
fn estimate_flops(name: &str, gws: &[u64; 3], symbols: &HashMap<String, i64>) -> u64 {
    let items: u64 = gws.iter().map(|&g| g.max(1)).product();
    match name {
        n if n.contains("gemm") || n.contains("matmul") => {
            let k = symbols.get("K").copied().unwrap_or(1).max(1) as u64;
            2 * items * k
        }
        n if n.contains("softmax") => 5 * items,
        n if n.contains("transpose") => items,
        n if n.contains("sin") => 4 * items,
        _ => items,
    }
}

/// Parse a spec file's text.
pub fn parse_spec(text: &str) -> Result<ApplicationSpec> {
    let root = Json::parse(text)?;

    // Symbols (guidance parameters).
    let mut symbols: HashMap<String, i64> = HashMap::new();
    if let Some(Json::Obj(m)) = root.get("symbols") {
        for (k, v) in m {
            symbols.insert(
                k.clone(),
                v.as_f64()
                    .ok_or_else(|| Error::Spec(format!("symbol '{k}' not numeric")))?
                    as i64,
            );
        }
    }
    let eval_dim = |j: &Json| -> Result<u64> {
        match j {
            Json::Num(n) => Ok(*n as u64),
            Json::Str(s) => Ok(eval_expr(s, &symbols)? as u64),
            _ => Err(Error::Spec("dimension must be number or expression".into())),
        }
    };

    // Kernels.
    let kernels = root
        .field("kernels")?
        .as_arr()
        .ok_or_else(|| Error::Spec("'kernels' must be an array".into()))?;
    let mut builder = DagBuilder::new();
    // (kernel_id, pos) -> BufferId for dependency resolution.
    let mut buf_at: HashMap<(usize, usize), BufferId> = HashMap::new();
    let mut declared_ids: Vec<usize> = Vec::new();

    for (idx, kj) in kernels.iter().enumerate() {
        let id = kj
            .field("id")?
            .as_usize()
            .ok_or_else(|| Error::Spec("kernel 'id' must be an integer".into()))?;
        if id != idx {
            return Err(Error::Spec(format!(
                "kernel ids must be dense and ordered: expected {idx}, got {id}"
            )));
        }
        declared_ids.push(id);
        let name = kj
            .field("name")?
            .as_str()
            .ok_or_else(|| Error::Spec("kernel 'name' must be a string".into()))?
            .to_string();
        let dev: DeviceType = kj
            .field("dev")?
            .as_str()
            .ok_or_else(|| Error::Spec("kernel 'dev' must be a string".into()))?
            .parse()?;

        let mut gws = [1u64; 3];
        if let Some(arr) = kj.get("globalWorkSize").and_then(|g| g.as_arr()) {
            for (i, d) in arr.iter().take(3).enumerate() {
                gws[i] = eval_dim(d)?;
            }
        }
        let work_dim = kj
            .get("workDimension")
            .and_then(|w| w.as_u64())
            .unwrap_or(1) as u8;

        let flops = match kj.get("flops") {
            Some(f) => f
                .as_u64()
                .ok_or_else(|| Error::Spec("'flops' must be a non-negative int".into()))?,
            None => estimate_flops(&name, &gws, &symbols),
        };

        let k = builder.kernel(&name, dev, flops, 0);
        builder.ndrange(k, work_dim, gws);
        if let Some(a) = kj.get("artifact").and_then(|a| a.as_str()) {
            builder.artifact(k, a);
        }

        let mut total_bytes = 0u64;
        let mut add_bufs = |builder: &mut DagBuilder,
                            list: &str,
                            mk: fn(&mut DagBuilder, usize, u64) -> BufferId|
         -> Result<u64> {
            let mut bytes = 0;
            if let Some(arr) = kj.get(list).and_then(|b| b.as_arr()) {
                for bj in arr {
                    let ty = bj.get("type").and_then(|t| t.as_str()).unwrap_or("float");
                    let size = match bj.field("size")? {
                        Json::Num(n) => *n as u64,
                        Json::Str(s) => eval_expr(s, &symbols)? as u64,
                        _ => return Err(Error::Spec("buffer 'size' invalid".into())),
                    };
                    let pos = bj
                        .field("pos")?
                        .as_usize()
                        .ok_or_else(|| Error::Spec("buffer 'pos' must be int".into()))?;
                    let size_bytes = size * type_size(ty);
                    let b = mk(builder, k, size_bytes);
                    if buf_at.insert((id, pos), b).is_some() {
                        return Err(Error::Spec(format!(
                            "kernel {id}: duplicate buffer pos {pos}"
                        )));
                    }
                    bytes += size_bytes;
                }
            }
            Ok(bytes)
        };
        total_bytes += add_bufs(&mut builder, "inputBuffers", |b, k, s| b.in_buf(k, s))?;
        total_bytes += add_bufs(&mut builder, "outputBuffers", |b, k, s| b.out_buf(k, s))?;
        total_bytes += add_bufs(&mut builder, "ioBuffers", |b, k, s| b.io_buf(k, s))?;
        // Record transfer volume on the kernel for the cost model.
        // (DagBuilder doesn't expose mutation; we rebuild below via bytes.)
        let _ = total_bytes;
    }

    // Dependencies: "ki,br -> kj,bs" (argument positions, Fig. 8).
    if let Some(arr) = root.get("deps").and_then(|d| d.as_arr()) {
        for dj in arr {
            let s = dj
                .as_str()
                .ok_or_else(|| Error::Spec("dep entries must be strings".into()))?;
            let (lhs, rhs) = s
                .split_once("->")
                .ok_or_else(|| Error::Spec(format!("dep '{s}' missing '->'")))?;
            let parse_pair = |t: &str| -> Result<(usize, usize)> {
                let (a, b) = t
                    .trim()
                    .split_once(',')
                    .ok_or_else(|| Error::Spec(format!("dep side '{t}' not 'k,pos'")))?;
                Ok((
                    a.trim()
                        .parse()
                        .map_err(|_| Error::Spec(format!("bad kernel id in '{t}'")))?,
                    b.trim()
                        .parse()
                        .map_err(|_| Error::Spec(format!("bad buffer pos in '{t}'")))?,
                ))
            };
            let (ki, br) = parse_pair(lhs)?;
            let (kj_, bs) = parse_pair(rhs)?;
            let src = *buf_at.get(&(ki, br)).ok_or_else(|| {
                Error::Spec(format!("dep '{s}': kernel {ki} has no buffer at pos {br}"))
            })?;
            let dst = *buf_at.get(&(kj_, bs)).ok_or_else(|| {
                Error::Spec(format!("dep '{s}': kernel {kj_} has no buffer at pos {bs}"))
            })?;
            builder.edge(src, dst);
        }
    }

    let dag = builder.build()?;

    // Task components.
    let partition = match root.get("tc").and_then(|t| t.as_arr()) {
        Some(groups) => {
            let mut parsed = Vec::new();
            for g in groups {
                let ids: Vec<usize> = g
                    .as_arr()
                    .ok_or_else(|| Error::Spec("'tc' entries must be arrays".into()))?
                    .iter()
                    .map(|x| {
                        x.as_usize()
                            .ok_or_else(|| Error::Spec("'tc' kernel ids must be ints".into()))
                    })
                    .collect::<Result<_>>()?;
                // Device type of a component = shared dev pref of members.
                let dev = ids
                    .first()
                    .map(|&k| dag.kernels[k].dev_pref)
                    .ok_or_else(|| Error::Spec("empty task component".into()))?;
                for &k in &ids {
                    if dag.kernels[k].dev_pref != dev {
                        return Err(Error::Spec(format!(
                            "task component mixes device preferences (kernel {k})"
                        )));
                    }
                }
                parsed.push((ids, dev));
            }
            Partition::new(&dag, parsed)?
        }
        None => Partition::singletons(&dag),
    };

    // Command-queue counts.
    let mut queues = HashMap::new();
    if let Some(Json::Obj(m)) = root.get("cq") {
        for (k, v) in m {
            let dt: DeviceType = k.parse()?;
            queues.insert(
                dt,
                v.as_usize()
                    .ok_or_else(|| Error::Spec("'cq' counts must be ints".into()))?,
            );
        }
    }
    queues.entry(DeviceType::Gpu).or_insert(1);
    queues.entry(DeviceType::Cpu).or_insert(1);

    Ok(ApplicationSpec {
        dag,
        partition,
        queues,
        symbols,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig. 8 example: three kernels, tc = {{0,2},{1}},
    /// dep "0,2 -> 2,0".
    const FIG8: &str = r#"{
      "symbols": {"M": 64, "N": 64, "K": 64},
      "kernels": [
        {"id": 0, "name": "matmul", "src": "gemm.cl", "dev": "gpu",
         "workDimension": 2, "globalWorkSize": ["M", "N", 1],
         "inputBuffers": [{"type": "float", "size": "M*K", "pos": 0},
                           {"type": "float", "size": "K*N", "pos": 1}],
         "outputBuffers": [{"type": "float", "size": "M*N", "pos": 2}],
         "varArgs": [{"type": "int", "pos": 3, "value": "M"}]},
        {"id": 1, "name": "vsin", "dev": "cpu",
         "globalWorkSize": ["M*N"],
         "ioBuffers": [{"type": "float", "size": "M*N", "pos": 0}]},
        {"id": 2, "name": "matmul", "dev": "gpu",
         "workDimension": 2, "globalWorkSize": ["M", "N", 1],
         "inputBuffers": [{"type": "float", "size": "M*K", "pos": 0},
                           {"type": "float", "size": "K*N", "pos": 1}],
         "outputBuffers": [{"type": "float", "size": "M*N", "pos": 2}]}
      ],
      "deps": ["0,2 -> 2,0"],
      "tc": [[0, 2], [1]],
      "cq": {"gpu": 4, "cpu": 2}
    }"#;

    #[test]
    fn parses_fig8() {
        let spec = parse_spec(FIG8).unwrap();
        assert_eq!(spec.dag.num_kernels(), 3);
        assert_eq!(spec.partition.components.len(), 2);
        assert_eq!(spec.partition.components[0].kernels, vec![0, 2]);
        assert_eq!(spec.partition.components[0].dev, DeviceType::Gpu);
        assert_eq!(spec.partition.components[1].dev, DeviceType::Cpu);
        assert_eq!(spec.queues[&DeviceType::Gpu], 4);
        assert_eq!(spec.queues[&DeviceType::Cpu], 2);
        // Dep 0,2 -> 2,0 resolved to buffer ids.
        assert_eq!(spec.dag.buffer_edges.len(), 1);
        let (src, dst) = spec.dag.buffer_edges[0];
        assert_eq!(spec.dag.buffers[src].kernel, 0);
        assert_eq!(spec.dag.buffers[src].pos, 2);
        assert_eq!(spec.dag.buffers[dst].kernel, 2);
        assert_eq!(spec.dag.buffers[dst].pos, 0);
    }

    #[test]
    fn symbolic_sizes_resolve() {
        let spec = parse_spec(FIG8).unwrap();
        let b0 = spec.dag.kernels[0].inputs[0];
        assert_eq!(spec.dag.buffers[b0].size_bytes, 64 * 64 * 4);
        assert_eq!(spec.dag.kernels[0].global_work_size, [64, 64, 1]);
    }

    #[test]
    fn gemm_flops_estimated() {
        let spec = parse_spec(FIG8).unwrap();
        // matmul: 2*M*N*K = 2*64^3.
        assert_eq!(spec.dag.kernels[0].flops, 2 * 64 * 64 * 64);
    }

    #[test]
    fn io_buffers_count_both_ways() {
        let spec = parse_spec(FIG8).unwrap();
        let vsin = &spec.dag.kernels[1];
        assert_eq!(vsin.inputs.len(), 1);
        assert_eq!(vsin.outputs.len(), 1);
        assert_eq!(vsin.inputs[0], vsin.outputs[0]);
    }

    #[test]
    fn missing_tc_defaults_to_singletons() {
        let text = FIG8.replace("\"tc\": [[0, 2], [1]],", "");
        let spec = parse_spec(&text).unwrap();
        assert_eq!(spec.partition.components.len(), 3);
    }

    #[test]
    fn rejects_mixed_device_component() {
        let text = FIG8.replace("\"tc\": [[0, 2], [1]]", "\"tc\": [[0, 1], [2]]");
        assert!(parse_spec(&text).is_err());
    }

    #[test]
    fn rejects_bad_dep_reference() {
        let text = FIG8.replace("0,2 -> 2,0", "0,9 -> 2,0");
        assert!(parse_spec(&text).is_err());
    }

    #[test]
    fn rejects_nondense_ids() {
        let text = FIG8.replace("\"id\": 1", "\"id\": 7");
        assert!(parse_spec(&text).is_err());
    }
}
