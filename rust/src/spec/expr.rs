//! Guidance-parameter expression evaluator.
//!
//! The paper's spec files size buffers with symbolic expressions such as
//! `"M*N"` or `"M*K"`, resolved from user-supplied symbols at dispatch time
//! (Fig. 8). Grammar: `+ - * /` with parentheses, integer literals, and
//! `[A-Za-z_][A-Za-z0-9_]*` symbols; standard precedence.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Evaluate `text` under `symbols`. Returns an error on unknown symbols,
/// malformed syntax, or division by zero.
pub fn eval_expr(text: &str, symbols: &HashMap<String, i64>) -> Result<i64> {
    let mut p = P {
        b: text.as_bytes(),
        i: 0,
        symbols,
    };
    p.ws();
    let v = p.add_expr()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(Error::Spec(format!(
            "trailing characters in expression '{text}'"
        )));
    }
    Ok(v)
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
    symbols: &'a HashMap<String, i64>,
}

impl<'a> P<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn add_expr(&mut self) -> Result<i64> {
        let mut v = self.mul_expr()?;
        loop {
            self.ws();
            match self.b.get(self.i) {
                Some(b'+') => {
                    self.i += 1;
                    v += self.mul_expr()?;
                }
                Some(b'-') => {
                    self.i += 1;
                    v -= self.mul_expr()?;
                }
                _ => return Ok(v),
            }
        }
    }

    fn mul_expr(&mut self) -> Result<i64> {
        let mut v = self.atom()?;
        loop {
            self.ws();
            match self.b.get(self.i) {
                Some(b'*') => {
                    self.i += 1;
                    v *= self.atom()?;
                }
                Some(b'/') => {
                    self.i += 1;
                    let d = self.atom()?;
                    if d == 0 {
                        return Err(Error::Spec("division by zero in expression".into()));
                    }
                    v /= d;
                }
                _ => return Ok(v),
            }
        }
    }

    fn atom(&mut self) -> Result<i64> {
        self.ws();
        match self.b.get(self.i) {
            Some(b'(') => {
                self.i += 1;
                let v = self.add_expr()?;
                self.ws();
                if self.b.get(self.i) != Some(&b')') {
                    return Err(Error::Spec("unbalanced parenthesis".into()));
                }
                self.i += 1;
                Ok(v)
            }
            Some(b'-') => {
                self.i += 1;
                Ok(-self.atom()?)
            }
            Some(c) if c.is_ascii_digit() => {
                let start = self.i;
                while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
                    self.i += 1;
                }
                std::str::from_utf8(&self.b[start..self.i])
                    .unwrap()
                    .parse()
                    .map_err(|_| Error::Spec("bad integer literal".into()))
            }
            Some(c) if c.is_ascii_alphabetic() || *c == b'_' => {
                let start = self.i;
                while self.i < self.b.len()
                    && (self.b[self.i].is_ascii_alphanumeric() || self.b[self.i] == b'_')
                {
                    self.i += 1;
                }
                let name = std::str::from_utf8(&self.b[start..self.i]).unwrap();
                self.symbols.get(name).copied().ok_or_else(|| {
                    Error::Spec(format!("unknown symbol '{name}' in expression"))
                })
            }
            _ => Err(Error::Spec("expected expression atom".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn syms(pairs: &[(&str, i64)]) -> HashMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn paper_style_sizes() {
        let s = syms(&[("M", 256), ("N", 128), ("K", 64)]);
        assert_eq!(eval_expr("M*N", &s).unwrap(), 256 * 128);
        assert_eq!(eval_expr("M*K", &s).unwrap(), 256 * 64);
        assert_eq!(eval_expr("M * N + K", &s).unwrap(), 256 * 128 + 64);
    }

    #[test]
    fn precedence_and_parens() {
        let s = syms(&[]);
        assert_eq!(eval_expr("2+3*4", &s).unwrap(), 14);
        assert_eq!(eval_expr("(2+3)*4", &s).unwrap(), 20);
        assert_eq!(eval_expr("16/4/2", &s).unwrap(), 2);
        assert_eq!(eval_expr("-3 + 5", &s).unwrap(), 2);
    }

    #[test]
    fn errors() {
        let s = syms(&[("M", 4)]);
        assert!(eval_expr("M*", &s).is_err());
        assert!(eval_expr("Q", &s).is_err());
        assert!(eval_expr("4/0", &s).is_err());
        assert!(eval_expr("(1", &s).is_err());
        assert!(eval_expr("1 2", &s).is_err());
    }

    #[test]
    fn plain_integers() {
        assert_eq!(eval_expr("1024", &syms(&[])).unwrap(), 1024);
    }
}
