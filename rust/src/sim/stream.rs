//! The always-on streaming simulator (PR 6 tentpole).
//!
//! [`super::engine`] serves a *finite* stream: every request is admitted up
//! front, all apps merge into one application, and one engine run owns
//! per-component state proportional to the **total** request count. A
//! long-lived server cannot do that — its arrival stream is unbounded.
//! [`StreamSim`] keeps the exact same execution machinery (it reuses the
//! engine's `pub(crate)` substrate: `Dispatch`, `Run`, `Ev`, `CopyEngine`,
//! the identical issue/contention/callback mechanics) but organises state
//! around **units** — one closed admission batch each — that are admitted
//! while earlier units execute and **retired** when they finish:
//!
//! * Component ids are reusable **slots** in a global arena; a retired
//!   unit's slots, dispatch records, and scheduler-heap entries are
//!   reclaimed and reused, so memory is bounded by the peak *live*
//!   population (the admission window), not the stream length.
//! * One persistent slot-mode [`SchedState`]
//!   ([`SchedState::for_streaming`]) is delta-updated across the whole
//!   stream — no per-request rebuild; stale heap entries are compacted
//!   when they outnumber the live frontier.
//! * [`StreamSim::pump`] advances virtual time up to a caller-supplied
//!   horizon so the driver can interleave admission with execution without
//!   ever letting the simulator run past an unadmitted unit's release
//!   instant. Since PR 7 that driver is the unified serve core
//!   ([`crate::serve::serve_core`]), which consumes this simulator through
//!   the `SimBackend` implementation of `ServeBackend` — the admit/pump/
//!   drain trio below is exactly that trait's contract.
//!
//! **Equivalence contract.** For an arrival stream with strictly
//! increasing, distinct arrival instants and a never-binding admission
//! window, the event sequence is identical to the monolithic
//! [`super::simulate_served`] over the merged-everything application:
//! units are admitted before the simulator reaches their release (the
//! driver's horizon rule), per-template ranks equal merged-app ranks
//! (bottom-level ranks are component-local), the slot-mode state returns
//! bit-identical component times/laxities, and the per-unit event pushes
//! preserve the monolithic push order at every shared timestamp. The only
//! divergence surface is exact floating-point ties between events of
//! *different* requests, which have measure zero under continuous
//! arrivals; retirement itself never changes outcomes — it only frees
//! state that the event system provably no longer references (freeing is
//! gated on a per-dispatch outstanding-event refcount). Proven by the
//! in-module tests and the `integration_serve_stream` suite.

use super::engine::{CmdState, CopyEngine, Dispatch, Ev, EvKind, Run, SimConfig, EPS};
use crate::cost::{contention, CostModel};
use crate::error::{Error, Result};
use crate::fault::{FaultClock, FaultEvent, FaultKind, FaultPlan};
use crate::graph::{Dag, KernelId, Partition};
use crate::platform::{DeviceId, DeviceType, Platform};
use crate::queue::{setup_cq, CmdId, CommandKind};
use crate::sched::fuzz::{Ambiguity, OrderSeam};
use crate::sched::{component_ranks, Policy, ResidentTenant, SchedState};
use crate::serve::MergedApp;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::ops::Range;
use std::sync::Arc;

/// The application template a unit executes: a pre-merged batch block
/// (cacheable signatures) or a single app (uncacheable workloads). Both
/// are shared `Arc`s — admission never deep-clones a DAG.
#[derive(Clone)]
pub enum Template {
    Merged(Arc<MergedApp>),
    Single(Arc<(Dag, Partition)>),
}

impl Template {
    pub fn dag(&self) -> &Dag {
        match self {
            Template::Merged(m) => &m.dag,
            Template::Single(a) => &a.0,
        }
    }

    pub fn partition(&self) -> &Partition {
        match self {
            Template::Merged(m) => &m.partition,
            Template::Single(a) => &a.1,
        }
    }
}

/// One request inside a unit, by value (the streaming server does not
/// retain `ServeRequest`s after admission).
#[derive(Debug, Clone)]
pub struct MemberSpec {
    pub id: usize,
    pub arrival: f64,
    /// Relative deadline budget (absolute deadline = arrival + budget).
    pub deadline: Option<f64>,
    pub priority: u32,
    /// Template-local component range owned by this member. Members must
    /// cover `0..ncomp` contiguously and disjointly.
    pub comps: Range<usize>,
}

/// A closed admission batch ready to enter the simulator.
pub struct AdmitUnit {
    pub tmpl: Template,
    /// Batch release instant (max member arrival — the coalescing window
    /// semantics of [`crate::serve::batch_requests`]).
    pub release: f64,
    pub members: Vec<MemberSpec>,
}

/// A completed request, emitted by [`StreamSim::drain_finished_into`] in
/// completion order.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: usize,
    pub arrival: f64,
    pub deadline: Option<f64>,
    pub priority: u32,
    pub release: f64,
    /// Instant the last of the member's components finished.
    pub finish: f64,
    /// Device each of the member's components ran on (last device for
    /// preempted-and-re-dispatched components), in component order. For a
    /// shed request only the components that actually ran are listed.
    pub devices: Vec<DeviceId>,
    /// The request was shed (typed degradation) instead of served;
    /// `finish` is the shed instant.
    pub shed: bool,
    /// Fault-triggered retries this request consumed.
    pub retries: u32,
}

/// Why [`StreamSim::pump`] returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PumpStop {
    /// No pending events and no running kernels — the simulator cannot
    /// advance until more work is admitted. The driver decides whether
    /// this is end-of-stream or a stall.
    Idle,
    /// The next event lies at or beyond the horizon; time was **not**
    /// advanced to it. Admit more work (or raise the horizon) and pump
    /// again.
    Horizon,
}

struct MemberRec {
    id: usize,
    arrival: f64,
    deadline: Option<f64>,
    priority: u32,
    comps: Range<usize>,
    comps_left: usize,
    /// Fault-triggered retries consumed so far (one per crash that
    /// displaced this member's work, not one per displaced component).
    retries: u32,
}

/// One live unit. Every vector is template-local; all of it is freed at
/// retirement.
struct Unit {
    tmpl: Template,
    release: f64,
    /// Local component -> global slot.
    slots: Vec<usize>,
    members: Vec<MemberRec>,
    /// Local component -> member index.
    member_of: Vec<usize>,
    ext_preds_left: Vec<usize>,
    /// Local kernel -> local components it unblocks when globally finished.
    unblocks: Vec<Vec<usize>>,
    kernel_finished: Vec<bool>,
    kernel_frac: Vec<f64>,
    kernel_cmds_left: Vec<usize>,
    is_cb_kernel: Vec<bool>,
    is_async_kernel: Vec<bool>,
    cb_count: Vec<usize>,
    comp_dispatched: Vec<bool>,
    comp_finish: Vec<f64>,
    comp_device: Vec<DeviceId>,
    comp_active_disp: Vec<Option<usize>>,
    comps_done: usize,
    /// Dispatch records (live or cancelled-but-referenced) still allocated
    /// for this unit — retirement waits for all of them.
    disp_live: usize,
}

/// Global slot arena entry. `unit == usize::MAX` marks a free slot.
#[derive(Clone, Copy)]
struct SlotRef {
    unit: usize,
    local: usize,
    /// Global admission order of this binding — the key that keeps
    /// resident-tenant iteration in the monolithic engine's ascending
    /// component-id order even though slot *numbers* are reused.
    seq: u64,
}

const FREE: usize = usize::MAX;

/// A dispatch record plus the bookkeeping that makes freeing it safe.
struct StreamDispatch {
    d: Dispatch,
    unit: usize,
    /// Global creation order — the key that keeps the live-dispatch index
    /// in the monolithic engine's ascending dispatch-id order.
    dseq: u64,
    /// Outstanding references from the event heap and copy-engine queues
    /// (`DispatchReady`/`TransferDone`/`Callback` events, queued or
    /// in-flight DMA entries). The record may only be freed at zero.
    pending: u32,
    /// Terminal: all callbacks fired, or displaced. Freed once `pending`
    /// drains.
    done: bool,
}

/// The long-lived streaming simulator. See the module docs.
pub struct StreamSim<'a> {
    platform: &'a Platform,
    cost: &'a dyn CostModel,
    policy: &'a mut dyn Policy,
    cfg: &'a SimConfig,

    now: f64,
    seq: u64,
    heap: BinaryHeap<Reverse<Ev>>,
    state: SchedState<'a>,

    units: Vec<Option<Unit>>,
    free_units: Vec<usize>,
    slots: Vec<SlotRef>,
    free_slots: Vec<usize>,
    next_comp_seq: u64,
    live_comps: usize,
    live_members: usize,

    /// Slots with a live dispatch, sorted by binding seq (monolithic
    /// component order) — the preemption victim candidates.
    resident_slots: Vec<usize>,
    preemptions: usize,

    dispatches: Vec<Option<StreamDispatch>>,
    free_disps: Vec<usize>,
    next_dseq: u64,
    /// Live-dispatch index, sorted by `dseq` (monolithic dispatch order).
    active_disp: Vec<usize>,
    runs: Vec<Run>,
    runs_per_dev: Vec<usize>,
    copy_engines: Vec<CopyEngine>,
    last_cmd_done: f64,

    /// Σ kernel-seconds per device (the trace-free device_util source:
    /// same spans, same per-device accumulation order as
    /// `Trace::busy_time` over the monolithic trace).
    device_busy: Vec<f64>,

    load_dirty: bool,
    /// A scheduler phase is owed at the current instant (initially, after
    /// every event drain, and after an immediate-release admission). Pump
    /// resumption after a Horizon stop must NOT rerun the phase — the
    /// monolithic loop runs exactly one phase per event step.
    need_phase: bool,
    rates: Vec<f64>,
    scratch_idx: Vec<usize>,
    scratch_us: Vec<f64>,
    scratch_speeds: Vec<f64>,
    scratch_finished: Vec<usize>,
    scratch_ready: Vec<usize>,

    /// Fuzz-only same-instant order permuter ([`crate::sched::fuzz`]),
    /// installed by the fuzz driver. `None` in production: every seam site
    /// then takes the canonical branch, byte-identical to the pre-seam
    /// code. Owned (not borrowed like the engine's) so the long-lived
    /// simulator stays free of extra lifetimes.
    seam: Option<OrderSeam>,

    /// Fault-injection replay state, installed by
    /// [`Self::install_faults`]. `None` in production: every fault hook
    /// then short-circuits and the event loop is byte-identical to the
    /// fault-free build.
    faults: Option<FaultClock>,
    /// Recovery knobs from the installed plan (unused without one).
    retry_budget: u32,
    backoff_base: f64,
    scratch_faults: Vec<FaultEvent>,
    /// Components displaced by device crashes (distinct from policy
    /// preemptions: those count toward `preemptions`).
    fault_displacements: usize,
    shed_count: usize,

    finished: Vec<FinishedRequest>,
    events_total: u64,
    peak_live_comps: usize,
    peak_live_members: usize,
}

impl<'a> StreamSim<'a> {
    /// `empty_dag`/`empty_partition` are caller-owned placeholders for the
    /// slot-mode scheduler state (never read; they exist because
    /// [`SchedState`] borrows its inputs).
    pub fn new(
        empty_dag: &'a Dag,
        empty_partition: &'a Partition,
        platform: &'a Platform,
        cost: &'a dyn CostModel,
        policy: &'a mut dyn Policy,
        cfg: &'a SimConfig,
    ) -> Result<StreamSim<'a>> {
        debug_assert!(
            empty_partition.components.is_empty(),
            "slot-mode placeholders must be empty"
        );
        let state = SchedState::for_streaming(
            empty_dag,
            empty_partition,
            platform,
            cost,
            cfg.max_tenants.max(1),
        )?;
        let ndev = platform.devices.len();
        Ok(StreamSim {
            platform,
            cost,
            policy,
            cfg,
            now: 0.0,
            seq: 0,
            heap: BinaryHeap::new(),
            state,
            units: Vec::new(),
            free_units: Vec::new(),
            slots: Vec::new(),
            free_slots: Vec::new(),
            next_comp_seq: 0,
            live_comps: 0,
            live_members: 0,
            resident_slots: Vec::new(),
            preemptions: 0,
            dispatches: Vec::new(),
            free_disps: Vec::new(),
            next_dseq: 0,
            active_disp: Vec::new(),
            runs: Vec::new(),
            runs_per_dev: vec![0; ndev],
            copy_engines: (0..platform.copy_engines.max(1))
                .map(|_| CopyEngine {
                    queue: std::collections::VecDeque::new(),
                    current: None,
                })
                .collect(),
            last_cmd_done: 0.0,
            device_busy: vec![0.0; ndev],
            load_dirty: false,
            need_phase: true,
            rates: Vec::new(),
            scratch_idx: Vec::new(),
            scratch_us: Vec::new(),
            scratch_speeds: Vec::new(),
            scratch_finished: Vec::new(),
            scratch_ready: Vec::new(),
            seam: None,
            faults: None,
            retry_budget: 0,
            backoff_base: 0.0,
            scratch_faults: Vec::new(),
            fault_displacements: 0,
            shed_count: 0,
            finished: Vec::new(),
            events_total: 0,
            peak_live_comps: 0,
            peak_live_members: 0,
        })
    }

    // ------------------------------------------------------------ accessors

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Makespan so far: the last command completion instant.
    pub fn makespan(&self) -> f64 {
        self.last_cmd_done
    }

    pub fn preemptions(&self) -> usize {
        self.preemptions
    }

    pub fn events(&self) -> u64 {
        self.events_total
    }

    pub fn live_components(&self) -> usize {
        self.live_comps
    }

    pub fn live_members(&self) -> usize {
        self.live_members
    }

    pub fn peak_live_components(&self) -> usize {
        self.peak_live_comps
    }

    pub fn peak_live_members(&self) -> usize {
        self.peak_live_members
    }

    /// Σ kernel-busy seconds per device so far.
    pub fn device_busy(&self) -> &[f64] {
        &self.device_busy
    }

    /// Move all completed requests (completion order) into `out`, leaving
    /// the internal buffer empty with its capacity retained.
    pub fn drain_finished_into(&mut self, out: &mut Vec<FinishedRequest>) {
        out.append(&mut self.finished);
    }

    /// Install a same-instant order permuter for fuzzing (see
    /// [`crate::sched::fuzz`]). Production code never calls this.
    #[doc(hidden)]
    pub fn install_seam(&mut self, seam: OrderSeam) {
        self.seam = Some(seam);
    }

    /// Remove the installed permuter, returning it so the fuzz driver can
    /// read its coverage counters and decision log.
    #[doc(hidden)]
    pub fn take_seam(&mut self) -> Option<OrderSeam> {
        self.seam.take()
    }

    /// Requests shed (typed degradation) so far.
    pub fn shed(&self) -> usize {
        self.shed_count
    }

    /// Components displaced by device crashes so far (policy preemptions
    /// are counted separately by [`Self::preemptions`]).
    pub fn fault_displacements(&self) -> usize {
        self.fault_displacements
    }

    /// Install a fault-injection plan (chaos scenario), validated against
    /// the platform. Call before the first pump. With no plan installed —
    /// or a plan with zero events — every code path below is
    /// byte-identical to the fault-free build.
    pub fn install_faults(&mut self, plan: &FaultPlan) -> Result<()> {
        plan.validate()?;
        plan.validate_devices(self.platform.devices.len())?;
        self.retry_budget = plan.retry_budget;
        self.backoff_base = plan.backoff_base;
        self.faults = Some(FaultClock::new(plan, self.platform.devices.len()));
        Ok(())
    }

    // ------------------------------------------------------------ admission

    /// Admit one closed batch. Precondition (driver's horizon rule): the
    /// simulator has not advanced past `unit.release` unless the admission
    /// window deliberately delayed this unit (backpressure) — in that case
    /// its components enter the frontier immediately, exactly like the
    /// engine's late-release unblock branch.
    pub fn admit(&mut self, a: AdmitUnit) -> Result<()> {
        let ncomp = a.tmpl.partition().components.len();
        let nk = a.tmpl.dag().num_kernels();
        if !a.release.is_finite() || a.release < 0.0 {
            return Err(Error::Sched(format!("invalid release time {}", a.release)));
        }
        // Validate the member cover and build local comp -> member index.
        let mut member_of = vec![usize::MAX; ncomp];
        for (mi, m) in a.members.iter().enumerate() {
            if m.comps.end > ncomp {
                return Err(Error::Sched(format!(
                    "member {} range {:?} exceeds {} components",
                    m.id, m.comps, ncomp
                )));
            }
            for c in m.comps.clone() {
                if member_of[c] != usize::MAX {
                    return Err(Error::Sched(format!("component {c} claimed twice")));
                }
                member_of[c] = mi;
            }
            if let Some(d) = m.deadline {
                if d.is_nan() {
                    return Err(Error::Sched("invalid deadline NaN".into()));
                }
            }
        }
        if member_of.iter().any(|&m| m == usize::MAX) {
            return Err(Error::Sched("unit components not fully covered".into()));
        }

        // Static template facts, built with the exact algorithm of
        // `Engine::new` (sort+dedup preserving first-encounter edge order)
        // so unblock iteration order matches the monolithic engine.
        let (unblocks, ext_preds_left) = {
            let dag = a.tmpl.dag();
            let partition = a.tmpl.partition();
            let mut pairs: Vec<(KernelId, usize, usize)> = Vec::new();
            let mut pred_pairs: Vec<(usize, KernelId)> = Vec::new();
            for (idx, &(src, dst)) in dag.buffer_edges.iter().enumerate() {
                let pk = dag.buffers[src].kernel;
                let ck = dag.buffers[dst].kernel;
                let pc = partition.assignment[pk];
                let cc = partition.assignment[ck];
                if pc != cc {
                    pairs.push((pk, cc, idx));
                    pred_pairs.push((cc, pk));
                }
            }
            pairs.sort_by_key(|&(pk, cc, _)| (pk, cc));
            pairs.dedup_by_key(|p| (p.0, p.1));
            pairs.sort_unstable_by_key(|&(_, _, idx)| idx);
            let mut unblocks: Vec<Vec<usize>> = vec![Vec::new(); nk];
            for &(pk, cc, _) in &pairs {
                unblocks[pk].push(cc);
            }
            pred_pairs.sort_unstable();
            pred_pairs.dedup();
            let mut ext_preds_left = vec![0usize; ncomp];
            for &(cc, _) in &pred_pairs {
                ext_preds_left[cc] += 1;
            }
            (unblocks, ext_preds_left)
        };
        let (is_cb_kernel, is_async_kernel, cb_count) = {
            let dag = a.tmpl.dag();
            let partition = a.tmpl.partition();
            let mut is_cb_kernel = vec![false; nk];
            let mut is_async_kernel = vec![false; nk];
            let mut cb_count = vec![0usize; ncomp];
            for c in 0..ncomp {
                let cbs = partition.callback_kernels(dag, c);
                cb_count[c] = cbs.len();
                for k in cbs {
                    is_cb_kernel[k] = true;
                }
                for k in partition.async_callback_kernels(dag, c) {
                    is_async_kernel[k] = true;
                }
            }
            (is_cb_kernel, is_async_kernel, cb_count)
        };
        // Bottom-level ranks are component-local (max over member kernels
        // of DAG-local kernel ranks), so per-template ranks are the merged
        // ranks bit for bit.
        let ranks = component_ranks(a.tmpl.dag(), a.tmpl.partition(), self.platform, self.cost);

        // Bind slots.
        let uid = match self.free_units.pop() {
            Some(u) => u,
            None => {
                self.units.push(None);
                self.units.len() - 1
            }
        };
        let mut slots = Vec::with_capacity(ncomp);
        for c in 0..ncomp {
            let sref = SlotRef {
                unit: uid,
                local: c,
                seq: self.next_comp_seq,
            };
            self.next_comp_seq += 1;
            let slot = match self.free_slots.pop() {
                Some(s) => {
                    self.slots[s] = sref;
                    s
                }
                None => {
                    self.slots.push(sref);
                    self.slots.len() - 1
                }
            };
            let m = &a.members[member_of[c]];
            let deadline = m
                .deadline
                .map(|d| m.arrival + d)
                .unwrap_or(f64::INFINITY);
            let dev_times: Vec<f64> = {
                let dag = a.tmpl.dag();
                let partition = a.tmpl.partition();
                self.platform
                    .devices
                    .iter()
                    .map(|d| {
                        partition.components[c]
                            .kernels
                            .iter()
                            .map(|&k| self.cost.exec_time(&dag.kernels[k], d))
                            .sum()
                    })
                    .collect()
            };
            self.state.set_slot(
                slot,
                ranks[c],
                a.tmpl.partition().components[c].dev,
                deadline,
                m.priority,
                &dev_times,
            );
            slots.push(slot);
        }
        self.live_comps += ncomp;
        self.peak_live_comps = self.peak_live_comps.max(self.live_comps);
        self.live_members += a.members.len();
        self.peak_live_members = self.peak_live_members.max(self.live_members);

        let members: Vec<MemberRec> = a
            .members
            .into_iter()
            .map(|m| MemberRec {
                id: m.id,
                arrival: m.arrival,
                deadline: m.deadline,
                priority: m.priority,
                comps_left: m.comps.len(),
                comps: m.comps,
                retries: 0,
            })
            .collect();
        let release = a.release;
        self.units[uid] = Some(Unit {
            tmpl: a.tmpl,
            release,
            slots,
            members,
            member_of,
            ext_preds_left,
            unblocks,
            kernel_finished: vec![false; nk],
            kernel_frac: vec![0.0; nk],
            kernel_cmds_left: vec![0; nk],
            is_cb_kernel,
            is_async_kernel,
            cb_count,
            comp_dispatched: vec![false; ncomp],
            comp_finish: vec![f64::NAN; ncomp],
            comp_device: vec![usize::MAX; ncomp],
            comp_active_disp: vec![None; ncomp],
            comps_done: 0,
            disp_live: 0,
        });

        // Root components wake at release — the engine prologue's Release
        // events. Under backpressure (release already passed) they enter
        // the frontier right away, mirroring the engine's late-release
        // unblock branch.
        let mut immediate: Vec<usize> = Vec::new();
        for c in 0..ncomp {
            if self.unit(uid).ext_preds_left[c] != 0 {
                continue;
            }
            let slot = self.unit(uid).slots[c];
            if release > self.now + EPS {
                self.push_ev(release, EvKind::Release { comp: slot });
            } else {
                immediate.push(slot);
            }
        }
        // A backpressured unit's roots all become ready at this same
        // instant — a dispatch-tie ambiguity under fuzzing.
        if let Some(s) = self.seam.as_mut() {
            s.shuffle(Ambiguity::DispatchTie, &mut immediate);
        }
        for &slot in &immediate {
            self.enter_frontier(slot);
            self.need_phase = true;
        }

        // Bounded-memory upkeep: lazily deleted scheduler-heap entries may
        // outnumber the live frontier under churn — compact when they do.
        if self.state.heap_entries() > 4 * self.state.frontier_len() + 1024 {
            self.state.compact_heaps();
        }

        // Chaos degradation: with every device crashed nothing admitted
        // can ever run — shed on arrival instead of stalling the stream.
        if self.all_devices_down() {
            let n = self.unit(uid).members.len();
            for mi in 0..n {
                if self.units[uid].is_none() {
                    break;
                }
                self.shed_member(uid, mi);
            }
        }
        Ok(())
    }

    // ------------------------------------------------------ arena plumbing

    fn unit(&self, u: usize) -> &Unit {
        self.units[u].as_ref().expect("retired unit")
    }

    fn unit_mut(&mut self, u: usize) -> &mut Unit {
        self.units[u].as_mut().expect("retired unit")
    }

    fn disp(&self, di: usize) -> &StreamDispatch {
        self.dispatches[di].as_ref().expect("freed dispatch")
    }

    fn disp_mut(&mut self, di: usize) -> &mut StreamDispatch {
        self.dispatches[di].as_mut().expect("freed dispatch")
    }

    fn push_ev(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Reverse(Ev {
            t,
            seq: self.seq,
            kind,
        }));
    }

    /// Free a terminal dispatch record once nothing references it, and
    /// retire its unit if that was the last piece of live state.
    fn try_free_dispatch(&mut self, di: usize) {
        let sd = self.disp(di);
        if !sd.done || sd.pending > 0 {
            return;
        }
        let u = sd.unit;
        self.dispatches[di] = None;
        self.free_disps.push(di);
        self.unit_mut(u).disp_live -= 1;
        self.maybe_retire_unit(u);
    }

    /// Retire `u` when every component finished and every dispatch record
    /// drained: slots return to the arena (their heap entries are already
    /// stale-by-seq), and the whole unit — template Arc, kernel tables,
    /// member records — is dropped. This is the bounded-memory step.
    fn maybe_retire_unit(&mut self, u: usize) {
        {
            let unit = self.unit(u);
            if unit.comps_done < unit.slots.len() || unit.disp_live != 0 {
                return;
            }
        }
        let unit = self.units[u].take().expect("retired unit");
        for &s in &unit.slots {
            self.slots[s] = SlotRef {
                unit: FREE,
                local: 0,
                seq: 0,
            };
            self.free_slots.push(s);
        }
        self.live_comps -= unit.slots.len();
        self.free_units.push(u);
    }

    /// Insert `di` into the live-dispatch index, ordered by creation seq
    /// (no-op if present) — the monolithic ascending-dispatch-id order.
    fn active_insert(&mut self, di: usize) {
        let dseqs = &self.dispatches;
        let key = dseqs[di].as_ref().expect("freed dispatch").dseq;
        if let Err(pos) = self
            .active_disp
            .binary_search_by(|&x| dseqs[x].as_ref().expect("freed dispatch").dseq.cmp(&key))
        {
            self.active_disp.insert(pos, di);
        }
    }

    /// Remove `di` from the live-dispatch index (no-op if absent).
    fn active_remove(&mut self, di: usize) {
        let dseqs = &self.dispatches;
        let key = dseqs[di].as_ref().expect("freed dispatch").dseq;
        if let Ok(pos) = self
            .active_disp
            .binary_search_by(|&x| dseqs[x].as_ref().expect("freed dispatch").dseq.cmp(&key))
        {
            self.active_disp.remove(pos);
        }
    }

    /// Insert `slot` into the resident list, ordered by binding seq (the
    /// monolithic ascending-component-id order).
    fn resident_insert(&mut self, slot: usize) {
        let slots = &self.slots;
        let key = slots[slot].seq;
        if let Err(pos) = self
            .resident_slots
            .binary_search_by(|&x| slots[x].seq.cmp(&key))
        {
            self.resident_slots.insert(pos, slot);
        }
    }

    /// Remove `slot` from the resident list (no-op if absent).
    fn resident_remove(&mut self, slot: usize) {
        let slots = &self.slots;
        let key = slots[slot].seq;
        if let Ok(pos) = self
            .resident_slots
            .binary_search_by(|&x| slots[x].seq.cmp(&key))
        {
            self.resident_slots.remove(pos);
        }
    }

    // ---------------------------------------------------------- scheduling

    fn refresh_device_load(&mut self) {
        for l in self.state.device_load.iter_mut() {
            *l = 0.0;
        }
        for r in &self.runs {
            self.state.device_load[r.device] += r.occupancy;
        }
        self.load_dirty = false;
    }

    fn scheduler_phase(&mut self) {
        // Same preemption budget rationale as the engine; legitimate
        // displace chains are bounded by the resident population, which
        // live_comps dominates.
        let mut preempt_budget = self.live_comps.max(8);
        let mut retry_after_preempt = false;
        self.state.now = self.now;
        let mut deferred: Vec<usize> = Vec::new();
        loop {
            loop {
                if self.load_dirty {
                    self.refresh_device_load();
                }
                if let Some((slot, dev)) = self.policy.select(&mut self.state) {
                    retry_after_preempt = false;
                    self.dispatch(slot, dev);
                    continue;
                }
                if retry_after_preempt
                    || preempt_budget == 0
                    || self.state.frontier_is_empty()
                    || !self.policy.can_preempt()
                {
                    break;
                }
                let mut resident: Vec<ResidentTenant> = self
                    .resident_slots
                    .iter()
                    .filter_map(|&s| {
                        let sr = self.slots[s];
                        self.unit(sr.unit).comp_active_disp[sr.local]
                            .filter(|&d| self.disp(d).d.cmds_remaining > 0)
                            .map(|d| ResidentTenant {
                                comp: s,
                                device: self.disp(d).d.device,
                            })
                    })
                    .collect();
                if resident.is_empty() {
                    break;
                }
                // Which of several equally-preemptable tenants the policy
                // scans first is an ordering accident — the preempt-race
                // ambiguity under fuzzing.
                if let Some(s) = self.seam.as_mut() {
                    s.shuffle(Ambiguity::PreemptRace, &mut resident);
                }
                match self.policy.preempt(&mut self.state, &resident) {
                    Some(victim) if self.displace(victim, &mut deferred) => {
                        preempt_budget -= 1;
                        retry_after_preempt = true;
                    }
                    _ => break,
                }
            }
            if deferred.is_empty() {
                break;
            }
            // Deferred-reentry victims (fuzz only) join the frontier now,
            // in a permuted order, and scheduling resumes — a victim whose
            // frontier re-entry raced the post-preemption dispatch pass.
            let mut batch = std::mem::take(&mut deferred);
            if let Some(s) = self.seam.as_mut() {
                s.shuffle(Ambiguity::DispatchTie, &mut batch);
            }
            for slot in batch {
                self.enter_frontier(slot);
            }
            retry_after_preempt = false;
        }
    }

    fn dispatch(&mut self, slot: usize, dev: DeviceId) {
        let sr = self.slots[slot];
        let (u, local) = (sr.unit, sr.local);
        let tmpl = self.unit(u).tmpl.clone();
        assert!(
            !self.unit(u).comp_dispatched[local],
            "slot {slot} re-dispatched"
        );
        self.unit_mut(u).comp_dispatched[local] = true;
        self.state.on_dispatch(slot, dev);
        self.unit_mut(u).comp_device[local] = dev;

        let mut device = self.platform.device(dev).clone();
        device.num_queues = self.policy.queues_for(&device);
        let cq = setup_cq(tmpl.dag(), tmpl.partition(), local, &device);
        let setup = cq.num_commands() as f64 * self.platform.enqueue_overhead;
        let ready_at = self.now + setup;

        let solo: f64 = tmpl.partition().components[local]
            .kernels
            .iter()
            .map(|&k| self.cost.exec_time(&tmpl.dag().kernels[k], &device))
            .sum();
        let transfers: f64 = cq
            .commands
            .iter()
            .filter_map(|c| c.transfer_buffer())
            .map(|b| {
                self.platform
                    .transfer_time(dev, tmpl.dag().buffers[b].size_bytes)
            })
            .sum();
        let est_committed = solo + transfers + self.platform.callback_latency;
        self.state.est_free[dev] = self.state.est_free[dev].max(ready_at) + est_committed;

        for c in &cq.commands {
            self.unit_mut(u).kernel_cmds_left[c.kernel] = 0;
        }
        for c in &cq.commands {
            self.unit_mut(u).kernel_cmds_left[c.kernel] += 1;
        }
        let d = Dispatch {
            state: vec![CmdState::Pending; cq.num_commands()],
            queue_next: vec![0; cq.queues.len()],
            cmds_remaining: cq.num_commands(),
            callbacks_left: self.unit(u).cb_count[local],
            cq,
            device: dev,
            ready_at,
            cancelled: false,
            est_committed,
        };
        let sd = StreamDispatch {
            d,
            unit: u,
            dseq: self.next_dseq,
            pending: 0,
            done: false,
        };
        self.next_dseq += 1;
        let di = match self.free_disps.pop() {
            Some(i) => {
                self.dispatches[i] = Some(sd);
                i
            }
            None => {
                self.dispatches.push(Some(sd));
                self.dispatches.len() - 1
            }
        };
        self.unit_mut(u).disp_live += 1;
        self.unit_mut(u).comp_active_disp[local] = Some(di);
        self.resident_insert(slot);
        if ready_at <= self.now + EPS {
            self.active_insert(di);
        }
        self.disp_mut(di).pending += 1;
        self.push_ev(ready_at, EvKind::DispatchReady(di));
    }

    /// Preempt `victim` (a slot) at command-queue granularity — the exact
    /// engine semantics, plus terminal marking so the dead dispatch record
    /// is reclaimed once its in-flight references drain. Under fuzzing the
    /// victim's frontier re-entry may be deferred into `deferred` (the
    /// re-entry ambiguity); canonically it re-enters immediately.
    fn displace(&mut self, victim: usize, deferred: &mut Vec<usize>) -> bool {
        if !self.cancel_resident(victim) {
            return false;
        }
        self.preemptions += 1;
        let defer = match self.seam.as_mut() {
            Some(s) => s.flip(Ambiguity::Reentry),
            None => false,
        };
        if defer {
            deferred.push(victim);
        } else {
            self.enter_frontier(victim);
        }
        true
    }

    /// The re-stage core shared by policy preemption ([`Self::displace`])
    /// and fault recovery: pull `victim`'s live dispatch off the device —
    /// completed kernels stay completed (`kernel_frac`), in-flight
    /// transfers re-stage, scheduler tenancy/`est_free` roll back
    /// ([`SchedState::on_preempt`]) — leaving re-entry (or shedding) to
    /// the caller.
    fn cancel_resident(&mut self, victim: usize) -> bool {
        let sr = self.slots[victim];
        if sr.unit == FREE {
            return false;
        }
        let (u, local) = (sr.unit, sr.local);
        let Some(di) = self.unit(u).comp_active_disp[local] else {
            return false;
        };
        let tmpl = self.unit(u).tmpl.clone();
        let mut i = 0;
        while i < self.runs.len() {
            if self.runs[i].disp != di {
                i += 1;
                continue;
            }
            let r = self.runs.swap_remove(i);
            self.runs_per_dev[r.device] -= 1;
            self.load_dirty = true;
            let device = self.platform.device(r.device);
            let full = self.cost.exec_time(&tmpl.dag().kernels[r.kernel], device);
            let done = if full > 0.0 {
                (1.0 - r.remaining / full).clamp(0.0, 1.0)
            } else {
                1.0
            };
            let cur = self.unit(u).kernel_frac[r.kernel];
            self.unit_mut(u).kernel_frac[r.kernel] = cur.max(done);
            if self.now > r.started {
                self.device_busy[r.device] += self.now - r.started;
            }
        }
        for e in 0..self.copy_engines.len() {
            let before = self.copy_engines[e].queue.len();
            self.copy_engines[e].queue.retain(|&(d, _)| d != di);
            let removed = (before - self.copy_engines[e].queue.len()) as u32;
            self.disp_mut(di).pending -= removed;
        }
        let dev = self.disp(di).d.device;
        self.disp_mut(di).d.cancelled = true;
        self.disp_mut(di).done = true;
        self.active_remove(di);
        self.unit_mut(u).comp_active_disp[local] = None;
        self.resident_remove(victim);
        self.unit_mut(u).comp_dispatched[local] = false;
        self.state.on_preempt(dev);
        let est = self.disp(di).d.est_committed;
        self.state.est_free[dev] = (self.state.est_free[dev] - est).max(self.now);
        if self.state.tenants[dev] == 0 {
            self.state.est_free[dev] = self.now;
        }
        self.try_free_dispatch(di);
        true
    }

    // ------------------------------------------------------------- faults

    /// True when every schedulable device has crashed — nothing admitted
    /// can ever run again. Always false without an installed plan.
    fn all_devices_down(&self) -> bool {
        self.faults.is_some()
            && (0..self.platform.devices.len())
                .all(|d| self.state.is_down(d) || self.platform.devices[d].num_queues == 0)
    }

    /// Replay every fault event due at the current instant. Wedges and
    /// slowdowns only update the rate clock (the next
    /// [`Self::compute_run_rates`] sees them); a crash additionally takes
    /// the device out of the scheduler and displaces its resident work
    /// through the recovery path. Only reachable with a plan installed.
    fn apply_due_faults(&mut self) {
        let mut due = std::mem::take(&mut self.scratch_faults);
        due.clear();
        self.faults
            .as_mut()
            .expect("faults installed")
            .take_due(self.now, &mut due);
        for ev in &due {
            self.faults.as_mut().expect("faults installed").apply(ev);
            self.need_phase = true;
            if let FaultKind::Crash = ev.kind {
                self.crash_device(ev.device);
            }
        }
        self.scratch_faults = due;
    }

    /// Crash `dev`: mark it down in the scheduler
    /// ([`SchedState::on_device_down`] — it never returns to the
    /// available set), displace every resident component on it through
    /// the preemption re-stage semantics, and either re-enter each victim
    /// after exponential backoff or shed its request once the retry
    /// budget is exhausted. A request is charged one retry per crash, not
    /// one per displaced component.
    fn crash_device(&mut self, dev: DeviceId) {
        self.state.on_device_down(dev);
        let mut victims: Vec<usize> = self
            .resident_slots
            .iter()
            .copied()
            .filter(|&s| {
                let sr = self.slots[s];
                sr.unit != FREE
                    && self.unit(sr.unit).comp_active_disp[sr.local]
                        .map(|di| self.disp(di).d.device == dev)
                        .unwrap_or(false)
            })
            .collect();
        // Which victim recovery walks first is an ordering accident —
        // part of the fault-race ambiguity class.
        if let Some(s) = self.seam.as_mut() {
            s.shuffle(Ambiguity::FaultRace, &mut victims);
        }
        let mut charged: Vec<(usize, usize)> = Vec::new();
        for slot in victims {
            let sr = self.slots[slot];
            if sr.unit == FREE {
                continue; // unit retired by an earlier shed in this sweep
            }
            let (u, local) = (sr.unit, sr.local);
            if self.unit(u).comp_active_disp[local].is_none() {
                continue; // cancelled by an earlier shed in this sweep
            }
            let mi = self.unit(u).member_of[local];
            if !charged.contains(&(u, mi)) {
                charged.push((u, mi));
                self.unit_mut(u).members[mi].retries += 1;
            }
            let retries = self.unit(u).members[mi].retries;
            if !self.cancel_resident(slot) {
                continue;
            }
            self.fault_displacements += 1;
            if retries > self.retry_budget {
                self.shed_member(u, mi);
            } else {
                // Exponential backoff before the victim re-enters the
                // frontier: retry k waits backoff_base * 2^(k-1). The
                // Recover event carries the slot's binding seq so a stale
                // wakeup can never touch a reused slot.
                let wait = self.backoff_base * (1u64 << (retries - 1).min(62)) as f64;
                if wait > 0.0 {
                    self.push_ev(self.now + wait, EvKind::Recover { comp: slot, seq: sr.seq });
                } else {
                    self.enter_frontier(slot);
                    self.need_phase = true;
                }
            }
        }
        if self.all_devices_down() {
            self.shed_all_live();
        }
    }

    /// Shed member `mi` of unit `u`: cancel any still-resident component,
    /// leave the frontier, terminally mark every unfinished component
    /// done, and emit a `shed` outcome record. Other members of the unit
    /// are untouched.
    fn shed_member(&mut self, u: usize, mi: usize) {
        let comps = self.unit(u).members[mi].comps.clone();
        for local in comps {
            if !self.unit(u).comp_finish[local].is_nan() {
                continue;
            }
            let slot = self.unit(u).slots[local];
            self.cancel_resident(slot);
            self.state.on_shed(slot);
            self.unit_mut(u).comp_dispatched[local] = true;
            self.unit_mut(u).comp_finish[local] = self.now;
            self.unit_mut(u).comps_done += 1;
            self.unit_mut(u).members[mi].comps_left -= 1;
        }
        let rec = {
            let unit = self.unit(u);
            let m = &unit.members[mi];
            debug_assert_eq!(m.comps_left, 0, "shed member with unfinished comps");
            let devices: Vec<DeviceId> = m
                .comps
                .clone()
                .map(|c| unit.comp_device[c])
                .filter(|&d| d != usize::MAX)
                .collect();
            FinishedRequest {
                id: m.id,
                arrival: m.arrival,
                deadline: m.deadline,
                priority: m.priority,
                release: unit.release,
                finish: self.now.max(unit.release),
                devices,
                shed: true,
                retries: m.retries,
            }
        };
        self.finished.push(rec);
        self.live_members -= 1;
        self.shed_count += 1;
        self.maybe_retire_unit(u);
    }

    /// Terminal degradation: every live member of every live unit is shed
    /// (reachable only when a crash leaves no schedulable device).
    fn shed_all_live(&mut self) {
        for u in 0..self.units.len() {
            if self.units[u].is_none() {
                continue;
            }
            let n = self.unit(u).members.len();
            for mi in 0..n {
                if self.units[u].is_none() {
                    break;
                }
                if self.unit(u).members[mi].comps_left > 0 {
                    self.shed_member(u, mi);
                }
            }
        }
    }

    // ------------------------------------------------------------- issuing

    fn issue_phase(&mut self) {
        let mut progressed = true;
        while progressed {
            progressed = false;
            let mut ai = 0;
            while ai < self.active_disp.len() {
                let di = self.active_disp[ai];
                ai += 1;
                debug_assert!(
                    !self.disp(di).d.cancelled
                        && self.disp(di).d.cmds_remaining > 0
                        && self.disp(di).d.ready_at <= self.now + EPS,
                    "stale dispatch {di} in live index"
                );
                for q in 0..self.disp(di).d.cq.queues.len() {
                    loop {
                        let d = &self.disp(di).d;
                        let Some(&cmd) = d.cq.queues[q].get(d.queue_next[q]) else {
                            break;
                        };
                        match d.state[cmd] {
                            CmdState::Done => {
                                self.disp_mut(di).d.queue_next[q] += 1;
                                continue;
                            }
                            CmdState::Issued => break,
                            CmdState::Pending => {}
                        }
                        let deps_ok = d
                            .cq
                            .e_q
                            .iter()
                            .filter(|&&(_, a)| a == cmd)
                            .all(|&(b, _)| d.state[b] == CmdState::Done);
                        if !deps_ok || !self.try_issue(di, cmd) {
                            break;
                        }
                        progressed = true;
                        break;
                    }
                }
            }
        }
    }

    fn try_issue(&mut self, di: usize, cmd: CmdId) -> bool {
        let sd = self.disp(di);
        let dev_id = sd.d.device;
        let kind = sd.d.cq.commands[cmd].kind;
        let kernel = sd.d.cq.commands[cmd].kernel;
        let queue = sd.d.cq.commands[cmd].queue;
        let u = sd.unit;
        match kind {
            CommandKind::NdRange => {
                if self.runs_per_dev[dev_id] >= self.platform.device(dev_id).hw_queues {
                    return false;
                }
                let tmpl = self.unit(u).tmpl.clone();
                let device = self.platform.device(dev_id);
                let node = &tmpl.dag().kernels[kernel];
                let full = self.cost.exec_time(node, device);
                let remaining = full * (1.0 - self.unit(u).kernel_frac[kernel]).max(0.0);
                self.runs.push(Run {
                    disp: di,
                    cmd,
                    kernel,
                    device: dev_id,
                    queue,
                    remaining,
                    occupancy: contention::occupancy(node, device),
                    started: self.now,
                });
                self.runs_per_dev[dev_id] += 1;
                self.load_dirty = true;
                self.disp_mut(di).d.state[cmd] = CmdState::Issued;
                true
            }
            CommandKind::Write { .. } | CommandKind::Read { .. } => {
                self.disp_mut(di).d.state[cmd] = CmdState::Issued;
                if self.platform.device(dev_id).shares_host_memory {
                    let t = self.now + self.platform.transfer_time(dev_id, 0);
                    self.disp_mut(di).pending += 1;
                    self.push_ev(t, EvKind::TransferDone { disp: di, cmd });
                } else {
                    let e = dev_id % self.copy_engines.len();
                    self.copy_engines[e].queue.push_back((di, cmd));
                    self.disp_mut(di).pending += 1;
                    self.pump_copy_engine(e);
                }
                true
            }
        }
    }

    fn pump_copy_engine(&mut self, e: usize) {
        if self.copy_engines[e].current.is_some() {
            return;
        }
        let Some((di, cmd)) = self.copy_engines[e].queue.pop_front() else {
            return;
        };
        // The queue-membership reference transfers to `current` + the
        // CopyDone event: net zero change to `pending`.
        let (u, buffer, dev) = {
            let sd = self.disp(di);
            (
                sd.unit,
                sd.d.cq.commands[cmd].transfer_buffer().expect("transfer cmd"),
                sd.d.device,
            )
        };
        let bytes = self.unit(u).tmpl.dag().buffers[buffer].size_bytes;
        let dt = self.platform.transfer_time(dev, bytes);
        self.copy_engines[e].current = Some((di, cmd));
        self.push_ev(self.now + dt, EvKind::CopyDone { engine: e });
    }

    // ---------------------------------------------------------- completion

    fn command_done(&mut self, di: usize, cmd: CmdId) {
        if self.disp(di).d.cancelled {
            return;
        }
        debug_assert_eq!(self.disp(di).d.state[cmd], CmdState::Issued);
        self.disp_mut(di).d.state[cmd] = CmdState::Done;
        self.disp_mut(di).d.cmds_remaining -= 1;
        if self.disp(di).d.cmds_remaining == 0 {
            self.active_remove(di);
        }
        self.last_cmd_done = self.last_cmd_done.max(self.now);
        let kernel = self.disp(di).d.cq.commands[cmd].kernel;
        let u = self.disp(di).unit;
        self.unit_mut(u).kernel_cmds_left[kernel] -= 1;
        if self.unit(u).kernel_cmds_left[kernel] == 0 {
            if self.unit(u).is_cb_kernel[kernel] {
                let delay = if self.unit(u).is_async_kernel[kernel] {
                    let cpu_remaining = self
                        .runs
                        .iter()
                        .filter(|r| self.platform.device(r.device).dtype == DeviceType::Cpu)
                        .map(|r| r.remaining)
                        .fold(0.0, f64::max);
                    self.platform.callback_latency
                        + self.cfg.host_starvation_fraction * cpu_remaining
                } else {
                    self.platform.wait_latency
                };
                self.disp_mut(di).pending += 1;
                self.push_ev(self.now + delay, EvKind::Callback { disp: di, kernel });
            } else {
                self.unit_mut(u).kernel_finished[kernel] = true;
            }
        }
    }

    fn handle_callback(&mut self, di: usize, kernel: KernelId) {
        let u = self.disp(di).unit;
        let first_completion = !self.unit(u).kernel_finished[kernel];
        self.unit_mut(u).kernel_finished[kernel] = true;
        let comp_local = self.disp(di).d.cq.component;
        if first_completion {
            let mut newly_ready = std::mem::take(&mut self.scratch_ready);
            newly_ready.clear();
            #[allow(clippy::needless_range_loop)]
            for i in 0..self.unit(u).unblocks[kernel].len() {
                let uc = self.unit(u).unblocks[kernel][i];
                self.unit_mut(u).ext_preds_left[uc] -= 1;
                if self.unit(u).ext_preds_left[uc] == 0 && !self.unit(u).comp_dispatched[uc] {
                    let release = self.unit(u).release;
                    let slot = self.unit(u).slots[uc];
                    if release > self.now + EPS {
                        self.push_ev(release, EvKind::Release { comp: slot });
                    } else {
                        newly_ready.push(slot);
                    }
                }
            }
            // Components unblocked by the same completion become ready at
            // the same instant — a dispatch-tie ambiguity under fuzzing.
            if let Some(s) = self.seam.as_mut() {
                s.shuffle(Ambiguity::DispatchTie, &mut newly_ready);
            }
            for &slot in &newly_ready {
                self.enter_frontier(slot);
            }
            self.scratch_ready = newly_ready;
        }
        if self.disp(di).d.cancelled {
            return;
        }
        self.disp_mut(di).d.callbacks_left -= 1;
        if self.disp(di).d.callbacks_left == 0 {
            debug_assert_eq!(
                self.disp(di).d.cmds_remaining,
                0,
                "callbacks after all commands"
            );
            let dev = self.disp(di).d.device;
            self.state.on_complete(dev);
            if self.state.tenants[dev] == 0 {
                self.state.est_free[dev] = self.now;
            }
            let slot = self.unit(u).slots[comp_local];
            self.unit_mut(u).comp_finish[comp_local] = self.now;
            self.unit_mut(u).comp_active_disp[comp_local] = None;
            self.resident_remove(slot);
            self.unit_mut(u).comps_done += 1;
            self.disp_mut(di).done = true;
            // Member completion: emit the outcome record (same fold-max
            // finish the monolithic serving path computes) and release the
            // request's bookkeeping.
            let mi = self.unit(u).member_of[comp_local];
            self.unit_mut(u).members[mi].comps_left -= 1;
            if self.unit(u).members[mi].comps_left == 0 {
                let unit = self.unit(u);
                let m = &unit.members[mi];
                let finish = m
                    .comps
                    .clone()
                    .map(|c| unit.comp_finish[c])
                    .fold(0.0f64, f64::max);
                let devices: Vec<DeviceId> =
                    m.comps.clone().map(|c| unit.comp_device[c]).collect();
                let rec = FinishedRequest {
                    id: m.id,
                    arrival: m.arrival,
                    deadline: m.deadline,
                    priority: m.priority,
                    release: unit.release,
                    finish,
                    devices,
                    shed: false,
                    retries: m.retries,
                };
                self.finished.push(rec);
                self.live_members -= 1;
            }
        }
    }

    fn enter_frontier(&mut self, slot: usize) {
        let sr = self.slots[slot];
        if self.unit(sr.unit).comp_dispatched[sr.local] {
            return;
        }
        self.state.on_ready(slot);
    }

    /// Fuzz-only event drain: pops every due event at the current instant
    /// as one batch and processes it in a seam-permuted order, preserving
    /// the relative order of events that target the same dispatch record
    /// (their sequencing is causal, not ambiguous — a `Callback` must not
    /// overtake the `TransferDone` completing its last command). Events
    /// pushed while processing (e.g. a repumped copy engine) land in the
    /// next batch. Only reachable with a seam installed; the canonical
    /// drain loop in [`Self::pump`] is untouched.
    fn drain_due_events_seamed(&mut self) {
        loop {
            let mut batch: Vec<Ev> = Vec::new();
            while let Some(Reverse(e)) = self.heap.peek() {
                if e.t > self.now + EPS {
                    break;
                }
                let Reverse(e) = self.heap.pop().expect("peeked event");
                batch.push(e);
            }
            if batch.is_empty() {
                return;
            }
            // Group key: the dispatch record an event targets. A CopyDone
            // resolves through its engine's in-flight transfer (at most
            // one CopyDone per engine per batch, so the lookup is stable);
            // Release events are free-floating.
            let keys: Vec<Option<usize>> = batch
                .iter()
                .map(|e| match e.kind {
                    EvKind::DispatchReady(di) => Some(di),
                    EvKind::TransferDone { disp, .. } => Some(disp),
                    EvKind::Callback { disp, .. } => Some(disp),
                    EvKind::CopyDone { engine } => {
                        self.copy_engines[engine].current.map(|(di, _)| di)
                    }
                    EvKind::Release { .. } | EvKind::Recover { .. } => None,
                })
                .collect();
            let mut order: Vec<usize> = (0..batch.len()).collect();
            if let Some(s) = self.seam.as_mut() {
                s.shuffle_grouped(Ambiguity::Callback, &mut order, |&i| keys[i]);
            }
            for &bi in &order {
                match batch[bi].kind {
                    EvKind::DispatchReady(di) => {
                        self.disp_mut(di).pending -= 1;
                        if !self.disp(di).d.cancelled && self.disp(di).d.cmds_remaining > 0 {
                            self.active_insert(di);
                        }
                        self.try_free_dispatch(di);
                    }
                    EvKind::TransferDone { disp, cmd } => {
                        self.disp_mut(disp).pending -= 1;
                        self.command_done(disp, cmd);
                        self.try_free_dispatch(disp);
                    }
                    EvKind::CopyDone { engine } => {
                        let (di, cmd) = self.copy_engines[engine]
                            .current
                            .take()
                            .expect("engine busy");
                        self.disp_mut(di).pending -= 1;
                        self.command_done(di, cmd);
                        self.try_free_dispatch(di);
                        self.pump_copy_engine(engine);
                    }
                    EvKind::Callback { disp, kernel } => {
                        self.disp_mut(disp).pending -= 1;
                        self.handle_callback(disp, kernel);
                        self.try_free_dispatch(disp);
                    }
                    EvKind::Release { comp } => {
                        let sr = self.slots[comp];
                        if sr.unit != FREE && self.unit(sr.unit).ext_preds_left[sr.local] == 0 {
                            self.enter_frontier(comp);
                        }
                    }
                    EvKind::Recover { comp, seq } => {
                        let sr = self.slots[comp];
                        if sr.unit != FREE
                            && sr.seq == seq
                            && self.unit(sr.unit).ext_preds_left[sr.local] == 0
                        {
                            self.enter_frontier(comp);
                        }
                    }
                }
            }
        }
    }

    // ------------------------------------------------------------- kernels

    fn compute_run_rates(&mut self) {
        self.rates.clear();
        self.rates.resize(self.runs.len(), 1.0);
        for dev in 0..self.platform.devices.len() {
            if self.runs_per_dev[dev] == 0 {
                continue;
            }
            self.scratch_idx.clear();
            self.scratch_us.clear();
            for (i, r) in self.runs.iter().enumerate() {
                if r.device == dev {
                    self.scratch_idx.push(i);
                    self.scratch_us.push(r.occupancy);
                }
            }
            contention::shared_speeds_into(
                &self.scratch_us,
                self.cfg.contention_efficiency,
                &mut self.scratch_speeds,
            );
            for (j, &i) in self.scratch_idx.iter().enumerate() {
                self.rates[i] = self.scratch_speeds[j] / self.scratch_us[j];
            }
        }
        // Injected device conditions: wedged devices run at rate 0, slowed
        // devices at their factor. Multiplying by exactly 1.0 on healthy
        // devices keeps the fault-free rates bit-identical.
        if let Some(clock) = &self.faults {
            for (i, r) in self.runs.iter().enumerate() {
                self.rates[i] *= clock.rate_factor(r.device, self.now);
            }
        }
    }

    fn next_kernel_completion(&self) -> Option<f64> {
        self.runs
            .iter()
            .zip(&self.rates)
            .map(|(r, &rate)| self.now + r.remaining / rate)
            .min_by(|a, b| a.total_cmp(b))
    }

    // ------------------------------------------------------------ main loop

    /// Advance the simulation, processing every event strictly below
    /// `horizon` — the same scheduler/issue/advance/retire/drain cadence as
    /// the monolithic engine, stopping *before* any event at or past the
    /// horizon (time is left where the last processed step put it). The
    /// per-call event budget is `SimConfig::max_events` (runaway guard —
    /// one pump covers one admission window, not the whole stream).
    pub fn pump(&mut self, horizon: f64) -> Result<PumpStop> {
        let mut events = 0usize;
        loop {
            if self.need_phase {
                self.scheduler_phase();
                self.issue_phase();
                self.need_phase = false;
            }
            self.compute_run_rates();
            let t_kernel = self.next_kernel_completion();
            let t_heap = self.heap.peek().map(|Reverse(e)| e.t);
            let t_fault = self.faults.as_ref().and_then(|c| c.next_change_at(self.now));
            let t_work = match (t_kernel, t_heap) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (Some(a), None) => Some(a),
                (None, Some(b)) => Some(b),
                (None, None) => None,
            };
            let t_next = match (t_work, t_fault) {
                (Some(a), Some(f)) => a.min(f),
                (Some(a), None) => a,
                (None, Some(f)) => f,
                (None, None) => return Ok(PumpStop::Idle),
            };
            if t_next >= horizon {
                return Ok(PumpStop::Horizon);
            }
            events += 1;
            if events > self.cfg.max_events {
                return Err(Error::Sched(format!(
                    "streaming pump exceeded {} events (deadlock?)",
                    self.cfg.max_events
                )));
            }
            self.events_total += 1;
            debug_assert!(t_next >= self.now - EPS, "time went backwards");
            let dt = (t_next - self.now).max(0.0);
            for (r, &rate) in self.runs.iter_mut().zip(&self.rates) {
                r.remaining -= dt * rate;
            }
            self.now = t_next;

            // Fault instants due now. The fault-vs-completion interleaving
            // at a shared instant is an ordering accident: canonically the
            // same-instant completions land first (faults apply after the
            // retire+drain step below); under fuzzing the seam may flip
            // the order, letting a crash void completions due at its own
            // instant.
            let faults_due = self
                .faults
                .as_ref()
                .map(|c| c.any_due(self.now))
                .unwrap_or(false);
            let faults_first = faults_due
                && match self.seam.as_mut() {
                    Some(s) => s.flip(Ambiguity::FaultRace),
                    None => false,
                };
            if faults_first {
                self.apply_due_faults();
            }

            self.scratch_finished.clear();
            for i in 0..self.runs.len() {
                if self.runs[i].remaining <= 1e-9 {
                    self.scratch_finished.push(i);
                }
            }
            self.scratch_finished.sort_unstable_by(|a, b| b.cmp(a));
            if self.seam.is_some() {
                // Simultaneous kernel completions: remove every finished
                // run first (descending index, as canonically), then
                // process them in a seam-permuted order — the
                // completion-race ambiguity.
                let mut done_runs: Vec<Run> = Vec::with_capacity(self.scratch_finished.len());
                #[allow(clippy::needless_range_loop)]
                for fi in 0..self.scratch_finished.len() {
                    done_runs.push(self.runs.swap_remove(self.scratch_finished[fi]));
                }
                let mut order: Vec<usize> = (0..done_runs.len()).collect();
                if let Some(s) = self.seam.as_mut() {
                    s.shuffle(Ambiguity::Completion, &mut order);
                }
                for &fi in &order {
                    let r = &done_runs[fi];
                    let (device, kernel, started, disp, cmd) =
                        (r.device, r.kernel, r.started, r.disp, r.cmd);
                    self.runs_per_dev[device] -= 1;
                    self.load_dirty = true;
                    let u = self.disp(disp).unit;
                    self.unit_mut(u).kernel_frac[kernel] = 1.0;
                    self.device_busy[device] += self.now - started;
                    self.command_done(disp, cmd);
                }
            } else {
                #[allow(clippy::needless_range_loop)]
                for fi in 0..self.scratch_finished.len() {
                    let i = self.scratch_finished[fi];
                    let r = self.runs.swap_remove(i);
                    self.runs_per_dev[r.device] -= 1;
                    self.load_dirty = true;
                    let u = self.disp(r.disp).unit;
                    self.unit_mut(u).kernel_frac[r.kernel] = 1.0;
                    self.device_busy[r.device] += self.now - r.started;
                    self.command_done(r.disp, r.cmd);
                }
            }

            if self.seam.is_some() {
                self.drain_due_events_seamed();
            } else {
                while let Some(Reverse(e)) = self.heap.peek() {
                    if e.t > self.now + EPS {
                        break;
                    }
                    let Reverse(e) = self.heap.pop().expect("peeked event");
                    match e.kind {
                        EvKind::DispatchReady(di) => {
                            self.disp_mut(di).pending -= 1;
                            if !self.disp(di).d.cancelled && self.disp(di).d.cmds_remaining > 0 {
                                self.active_insert(di);
                            }
                            self.try_free_dispatch(di);
                        }
                        EvKind::TransferDone { disp, cmd } => {
                            self.disp_mut(disp).pending -= 1;
                            self.command_done(disp, cmd);
                            self.try_free_dispatch(disp);
                        }
                        EvKind::CopyDone { engine } => {
                            let (di, cmd) = self.copy_engines[engine]
                                .current
                                .take()
                                .expect("engine busy");
                            self.disp_mut(di).pending -= 1;
                            self.command_done(di, cmd);
                            self.try_free_dispatch(di);
                            self.pump_copy_engine(engine);
                        }
                        EvKind::Callback { disp, kernel } => {
                            self.disp_mut(disp).pending -= 1;
                            self.handle_callback(disp, kernel);
                            self.try_free_dispatch(disp);
                        }
                        EvKind::Release { comp } => {
                            let sr = self.slots[comp];
                            if sr.unit != FREE
                                && self.unit(sr.unit).ext_preds_left[sr.local] == 0
                            {
                                self.enter_frontier(comp);
                            }
                        }
                        EvKind::Recover { comp, seq } => {
                            let sr = self.slots[comp];
                            if sr.unit != FREE
                                && sr.seq == seq
                                && self.unit(sr.unit).ext_preds_left[sr.local] == 0
                            {
                                self.enter_frontier(comp);
                            }
                        }
                    }
                }
            }
            if faults_due && !faults_first {
                self.apply_due_faults();
            }
            self.need_phase = true;
        }
    }
}

/// Shard ownership is Send-safe by construction: a [`StreamSim`] never
/// crosses threads (its borrows of the per-shard `Dag`/`Partition` pin it
/// to the worker that built it), but everything a shard worker needs to
/// *construct* one — the sub-platform, the sim config, a boxed policy —
/// must transfer into the spawned thread, and the shared references the
/// worker reads through (`Platform`, `CostModel`) must be `Sync`. The
/// sharded server ([`crate::serve::shard`]) relies on these bounds; assert
/// them at compile time so a future non-Send field (an `Rc` cache, a
/// thread-local handle) fails here, next to the simulator, instead of as
/// an opaque `thread::scope` inference error three layers up.
#[allow(dead_code)]
fn _assert_shard_inputs_transferable(
    platform: Platform,
    cfg: SimConfig,
    policy: Box<dyn Policy>,
    request: crate::serve::ServeRequest,
) -> impl Send {
    (platform, cfg, policy, request)
}

/// Companion to [`_assert_shard_inputs_transferable`]: `&T: Send` holds
/// exactly when `T: Sync`, so returning the shared references a worker
/// reads through as `impl Send` asserts their `Sync` bounds.
#[allow(dead_code)]
fn _assert_shard_shared_refs_sync<'a>(
    platform: &'a Platform,
    cfg: &'a SimConfig,
    cost: &'a dyn CostModel,
) -> impl Send + 'a {
    (platform, cfg, cost)
}

#[cfg(test)]
mod tests {
    use super::engine::{simulate_served, CompMeta};
    use super::*;
    use crate::cost::PaperCost;
    use crate::sched::{Edf, LeastLoaded};
    use crate::serve::{merge_apps_refs, MergedAssembly};
    use crate::transformer::{cluster_by_head, head_dag, vadd_vsin_dag};

    fn head_app() -> (Dag, Partition) {
        let (dag, io) = head_dag(64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, std::slice::from_ref(&io), 0);
        (dag, part)
    }

    fn vadd_app() -> (Dag, Partition) {
        let (dag, _) = vadd_vsin_dag(4096);
        let part = Partition::singletons(&dag);
        (dag, part)
    }

    fn head_block() -> Arc<MergedApp> {
        let a = head_app();
        Arc::new(merge_apps_refs(&[&a, &a]).unwrap())
    }

    fn empty_placeholders() -> (Dag, Partition) {
        (
            Dag::default(),
            Partition {
                components: Vec::new(),
                assignment: Vec::new(),
            },
        )
    }

    /// Drive the same five-request stream (two 2-member batch units + one
    /// uncacheable two-component app with an external dependency) through
    /// the streaming simulator and through the monolithic build-once
    /// pipeline (`MergedAssembly` + `simulate_served`), and assert
    /// bit-identical finish times, device assignments, makespan, and
    /// preemption count. Returns the preemption count.
    fn run_equiv(
        pol_stream: &mut dyn Policy,
        pol_mono: &mut dyn Policy,
        cfg: &SimConfig,
        deadlines: [Option<f64>; 3],
        prios: [u32; 3],
    ) -> usize {
        let platform = Platform::scaled(2, 1, 3, 1);
        let cost = PaperCost;
        let block = head_block();
        let vapp = Arc::new(vadd_app());

        // Streaming path: three units admitted before time advances, with
        // distinct future releases (the driver's horizon rule holds
        // trivially), then pumped to idle.
        let (empty_dag, empty_part) = empty_placeholders();
        let mut sim = StreamSim::new(
            &empty_dag,
            &empty_part,
            &platform,
            &cost,
            pol_stream,
            cfg,
        )
        .unwrap();
        sim.admit(AdmitUnit {
            tmpl: Template::Merged(block.clone()),
            release: 0.002,
            members: vec![
                MemberSpec {
                    id: 0,
                    arrival: 0.001,
                    deadline: deadlines[0],
                    priority: prios[0],
                    comps: 0..1,
                },
                MemberSpec {
                    id: 1,
                    arrival: 0.002,
                    deadline: deadlines[0],
                    priority: prios[0],
                    comps: 1..2,
                },
            ],
        })
        .unwrap();
        sim.admit(AdmitUnit {
            tmpl: Template::Single(vapp.clone()),
            release: 0.003,
            members: vec![MemberSpec {
                id: 2,
                arrival: 0.003,
                deadline: deadlines[1],
                priority: prios[1],
                comps: 0..2,
            }],
        })
        .unwrap();
        sim.admit(AdmitUnit {
            tmpl: Template::Merged(block.clone()),
            release: 0.005,
            members: vec![
                MemberSpec {
                    id: 3,
                    arrival: 0.004,
                    deadline: deadlines[2],
                    priority: prios[2],
                    comps: 0..1,
                },
                MemberSpec {
                    id: 4,
                    arrival: 0.005,
                    deadline: deadlines[2],
                    priority: prios[2],
                    comps: 1..2,
                },
            ],
        })
        .unwrap();
        assert!(matches!(sim.pump(f64::INFINITY).unwrap(), PumpStop::Idle));
        let mut fin = Vec::new();
        sim.drain_finished_into(&mut fin);
        fin.sort_by_key(|f| f.id);
        assert_eq!(fin.len(), 5);
        // Retirement: every unit, slot, and dispatch record was reclaimed.
        assert_eq!(sim.live_components(), 0);
        assert_eq!(sim.live_members(), 0);
        assert_eq!(sim.free_slots.len(), sim.slots.len());
        assert!(sim.dispatches.iter().all(|d| d.is_none()));
        assert!(sim.units.iter().all(|u| u.is_none()));
        assert_eq!(sim.peak_live_components(), 6);

        // Monolithic build-once pipeline over the same stream.
        let mut asm = MergedAssembly::new();
        let r_a = asm.append_merged(&block);
        let r_b = asm.append_app(vapp.as_ref());
        let r_c = asm.append_merged(&block);
        let merged = asm.finish().unwrap();
        let ranges: Vec<Range<usize>> =
            vec![r_a[0].clone(), r_a[1].clone(), r_b, r_c[0].clone(), r_c[1].clone()];
        let arrivals = [0.001, 0.002, 0.003, 0.004, 0.005];
        let releases = [0.002, 0.002, 0.003, 0.005, 0.005];
        let which = [0usize, 0, 1, 2, 2];
        let mut meta = vec![CompMeta::default(); merged.partition.components.len()];
        for (req, range) in ranges.iter().enumerate() {
            for c in range.clone() {
                meta[c] = CompMeta {
                    release: releases[req],
                    deadline: deadlines[which[req]]
                        .map(|d| arrivals[req] + d)
                        .unwrap_or(f64::INFINITY),
                    priority: prios[which[req]],
                };
            }
        }
        let res = simulate_served(
            &merged.dag,
            &merged.partition,
            &platform,
            &cost,
            pol_mono,
            cfg,
            &meta,
        )
        .unwrap();

        assert_eq!(
            sim.makespan().to_bits(),
            res.makespan.to_bits(),
            "makespan diverged: {} vs {}",
            sim.makespan(),
            res.makespan
        );
        assert_eq!(sim.preemptions(), res.preemptions, "preemption count");
        for (req, range) in ranges.iter().enumerate() {
            let want_finish = range
                .clone()
                .map(|c| res.component_finish[c])
                .fold(0.0f64, f64::max);
            let want_devs: Vec<DeviceId> =
                range.clone().map(|c| res.component_device[c]).collect();
            assert_eq!(
                fin[req].finish.to_bits(),
                want_finish.to_bits(),
                "request {req} finish: {} vs {}",
                fin[req].finish,
                want_finish
            );
            assert_eq!(fin[req].devices, want_devs, "request {req} devices");
            assert_eq!(fin[req].release, releases[req]);
        }
        sim.preemptions()
    }

    #[test]
    fn streaming_matches_monolithic_least_loaded() {
        let cfg = SimConfig {
            max_tenants: 2,
            ..SimConfig::default()
        };
        let mut p1 = LeastLoaded;
        let mut p2 = LeastLoaded;
        run_equiv(&mut p1, &mut p2, &cfg, [None, None, None], [0, 0, 0]);
    }

    #[test]
    fn streaming_matches_monolithic_edf_with_preemption() {
        let cfg = SimConfig {
            max_tenants: 1,
            ..SimConfig::default()
        };
        let mut p1 = Edf;
        let mut p2 = Edf;
        // Tight, staggered deadlines: the late urgent unit displaces a
        // resident, so the equivalence covers the displaced-dispatch
        // reclamation path, not just clean completions.
        let n = run_equiv(
            &mut p1,
            &mut p2,
            &cfg,
            [Some(0.5), Some(0.01), Some(0.002)],
            [0, 1, 2],
        );
        assert!(n > 0, "expected the urgent late unit to preempt a resident");
    }

    #[test]
    fn pump_stops_at_horizon_without_advancing_time() {
        let platform = Platform::scaled(1, 1, 3, 1);
        let cost = PaperCost;
        let cfg = SimConfig::default();
        let mut pol = LeastLoaded;
        let (empty_dag, empty_part) = empty_placeholders();
        let tmpl = Arc::new(head_app());
        let mut sim =
            StreamSim::new(&empty_dag, &empty_part, &platform, &cost, &mut pol, &cfg).unwrap();
        sim.admit(AdmitUnit {
            tmpl: Template::Single(tmpl),
            release: 1.0,
            members: vec![MemberSpec {
                id: 7,
                arrival: 1.0,
                deadline: None,
                priority: 0,
                comps: 0..1,
            }],
        })
        .unwrap();
        assert!(matches!(sim.pump(0.5).unwrap(), PumpStop::Horizon));
        assert_eq!(sim.now(), 0.0);
        assert_eq!(sim.live_components(), 1);
        assert!(matches!(sim.pump(f64::INFINITY).unwrap(), PumpStop::Idle));
        let mut fin = Vec::new();
        sim.drain_finished_into(&mut fin);
        assert_eq!(fin.len(), 1);
        assert_eq!(fin[0].id, 7);
        assert!(fin[0].finish > 1.0);
        assert_eq!(sim.live_components(), 0);
    }

    #[test]
    fn retirement_reclaims_slots_across_a_long_stream() {
        let platform = Platform::scaled(1, 1, 3, 1);
        let cost = PaperCost;
        let cfg = SimConfig::default();
        let mut pol = LeastLoaded;
        let (empty_dag, empty_part) = empty_placeholders();
        let tmpl = Arc::new(head_app());
        let mut sim =
            StreamSim::new(&empty_dag, &empty_part, &platform, &cost, &mut pol, &cfg).unwrap();
        // 40 one-component units streamed strictly sequentially: each is
        // admitted only after the previous one retired, so the arena must
        // never grow past a single slot — memory is O(live), not O(total).
        let mut t = 0.0;
        for i in 0..40 {
            t += 0.001;
            sim.admit(AdmitUnit {
                tmpl: Template::Single(tmpl.clone()),
                release: t,
                members: vec![MemberSpec {
                    id: i,
                    arrival: t,
                    deadline: None,
                    priority: 0,
                    comps: 0..1,
                }],
            })
            .unwrap();
            assert!(matches!(sim.pump(f64::INFINITY).unwrap(), PumpStop::Idle));
            assert_eq!(sim.live_components(), 0, "unit {i} not retired");
        }
        assert_eq!(sim.peak_live_components(), 1);
        assert_eq!(sim.slots.len(), 1, "slot arena grew despite retirement");
        assert_eq!(sim.free_slots.len(), 1);
        assert_eq!(sim.units.len(), 1);
        assert!(sim.dispatches.iter().all(|d| d.is_none()));
        let mut fin = Vec::new();
        sim.drain_finished_into(&mut fin);
        assert_eq!(fin.len(), 40);
        for w in fin.windows(2) {
            assert!(w[1].finish > w[0].finish, "units must run in stream order");
        }
    }

    /// Drive `n` single-component units (releases 1 ms apart) through a
    /// fresh simulator, optionally with a fault plan installed, pump to
    /// idle, and return the finished records (sorted by id) plus the
    /// fault counters. Asserts full retirement: every admitted request
    /// surfaces exactly once and no live state survives.
    fn run_faulted(
        n: usize,
        plan: Option<&crate::fault::FaultPlan>,
    ) -> (Vec<FinishedRequest>, f64, usize, usize, usize) {
        let platform = Platform::scaled(1, 1, 3, 1);
        let cost = PaperCost;
        let cfg = SimConfig::default();
        let mut pol = LeastLoaded;
        let (empty_dag, empty_part) = empty_placeholders();
        let tmpl = Arc::new(head_app());
        let mut sim =
            StreamSim::new(&empty_dag, &empty_part, &platform, &cost, &mut pol, &cfg).unwrap();
        if let Some(p) = plan {
            sim.install_faults(p).unwrap();
        }
        for i in 0..n {
            let t = 0.001 * (i as f64 + 1.0);
            sim.admit(AdmitUnit {
                tmpl: Template::Single(tmpl.clone()),
                release: t,
                members: vec![MemberSpec {
                    id: i,
                    arrival: t,
                    deadline: None,
                    priority: 0,
                    comps: 0..1,
                }],
            })
            .unwrap();
        }
        assert!(matches!(sim.pump(f64::INFINITY).unwrap(), PumpStop::Idle));
        let mut fin = Vec::new();
        sim.drain_finished_into(&mut fin);
        fin.sort_by_key(|f| f.id);
        assert_eq!(fin.len(), n, "conservation: every request surfaces once");
        assert_eq!(sim.live_components(), 0);
        assert_eq!(sim.live_members(), 0);
        (
            fin,
            sim.makespan(),
            sim.preemptions(),
            sim.fault_displacements(),
            sim.shed(),
        )
    }

    #[test]
    fn mid_flight_crash_recovers_on_the_surviving_device() {
        use crate::fault::{FaultEvent, FaultKind, FaultPlan};
        // Fault-free run pins down when and where the request executes.
        let (base, ..) = run_faulted(1, None);
        let dev = base[0].devices[0];
        let crash_at = (base[0].release + base[0].finish) / 2.0;
        let plan = FaultPlan {
            events: vec![FaultEvent {
                device: dev,
                at: crash_at,
                kind: FaultKind::Crash,
            }],
            retry_budget: 3,
            backoff_base: 0.0,
            ..FaultPlan::default()
        }
        .normalized()
        .unwrap();
        let (fin, _, _, displaced, shed) = run_faulted(1, Some(&plan));
        assert!(!fin[0].shed, "within budget: the request must be served");
        assert!(fin[0].retries >= 1, "the crash must charge a retry");
        assert_ne!(
            fin[0].devices[0], dev,
            "recovery must re-dispatch to the surviving device"
        );
        assert!(
            fin[0].finish > base[0].finish,
            "the restarted run cannot finish before the fault-free one"
        );
        assert!(displaced >= 1);
        assert_eq!(shed, 0);
    }

    #[test]
    fn exhausted_retry_budget_sheds_the_request() {
        use crate::fault::{FaultEvent, FaultKind, FaultPlan};
        let (base, ..) = run_faulted(1, None);
        let dev = base[0].devices[0];
        let crash_at = (base[0].release + base[0].finish) / 2.0;
        let plan = FaultPlan {
            events: vec![FaultEvent {
                device: dev,
                at: crash_at,
                kind: FaultKind::Crash,
            }],
            retry_budget: 0,
            backoff_base: 0.0,
            ..FaultPlan::default()
        }
        .normalized()
        .unwrap();
        let (fin, _, _, displaced, shed) = run_faulted(1, Some(&plan));
        assert!(fin[0].shed, "budget 0: first displacement must shed");
        assert_eq!(fin[0].retries, 1);
        assert_eq!(shed, 1);
        assert!(displaced >= 1);
    }

    #[test]
    fn crashing_every_device_sheds_all_live_work() {
        use crate::fault::{FaultEvent, FaultKind, FaultPlan};
        let plan = FaultPlan {
            events: vec![
                FaultEvent {
                    device: 0,
                    at: 0.0,
                    kind: FaultKind::Crash,
                },
                FaultEvent {
                    device: 1,
                    at: 0.0,
                    kind: FaultKind::Crash,
                },
            ],
            retry_budget: 3,
            backoff_base: 0.0,
            ..FaultPlan::default()
        }
        .normalized()
        .unwrap();
        let (fin, _, _, _, shed) = run_faulted(3, Some(&plan));
        assert_eq!(shed, 3, "no schedulable device left: everything sheds");
        for f in &fin {
            assert!(f.shed, "request {} escaped the terminal shed", f.id);
        }
    }

    #[test]
    fn zero_event_fault_plan_is_bitwise_identical_to_no_plan() {
        use crate::fault::FaultPlan;
        let (base, mk0, pre0, ..) = run_faulted(3, None);
        let plan = FaultPlan::default().normalized().unwrap();
        let (fin, mk1, pre1, displaced, shed) = run_faulted(3, Some(&plan));
        assert_eq!(mk0.to_bits(), mk1.to_bits(), "makespan diverged");
        assert_eq!(pre0, pre1);
        assert_eq!(displaced, 0);
        assert_eq!(shed, 0);
        for (a, b) in base.iter().zip(&fin) {
            assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "id {}", a.id);
            assert_eq!(a.devices, b.devices, "id {}", a.id);
            assert!(!b.shed);
            assert_eq!(b.retries, 0);
        }
    }
}
