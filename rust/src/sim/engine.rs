//! The event-driven execution engine.
//!
//! Processor-sharing kernels don't have fixed completion times (speeds
//! change whenever the running set changes), so the loop alternates:
//! advance all running kernels to the next event instant, deduct progress,
//! then handle every event due at that instant.
//!
//! §Perf (PR 4): the engine is the serving hot path — every `serve --mode
//! sim` decision and every bench runs through it, and a 10k-request merged
//! application has ~10k components and dispatches. The inner loop is
//! therefore **index-based and allocation-free in steady state**:
//!
//! * `issue_phase` walks a sorted *live-dispatch index* (`active_disp`)
//!   instead of every dispatch ever created (was O(total dispatches) per
//!   event — quadratic over a serving run);
//! * membership tests use boolean bitsets (`in_frontier`, `dev_available`,
//!   `is_cb_kernel`, `is_async_kernel`) and per-kernel counters
//!   (`kernel_cmds_left`) instead of `Vec::contains` / linear
//!   `(KernelId, usize)` walks;
//! * `unblocks` / external-predecessor counts are built by sort+dedup over
//!   the edge list (was O(E·deg) repeated `contains`), preserving the
//!   first-encounter order the old dedup produced;
//! * the cross-DAG `device_load` signal is a cached per-device accumulator
//!   refreshed only when the running set actually changed (was a fresh
//!   Vec + full `runs` scan per policy call);
//! * per-event kernel-rate computation reuses scratch buffers
//!   ([`contention::shared_speeds_into`]) instead of allocating four
//!   vectors per event.
//!
//! Every change preserves the exact event order and floating-point
//! operation order of the pre-refactor engine — proven byte-identical
//! against the verbatim copy in [`super::reference`] by the
//! `integration_sim_equiv` suite.
//!
//! §Scheduler core (PR 5): the engine no longer owns the frontier/device
//! scheduler bookkeeping — it drives the shared, incrementally indexed
//! [`SchedState`] with the deltas its event loop already computes
//! (`on_ready` on release/unblock, `on_dispatch`, `on_complete` on the
//! final callback, `on_preempt` on displacement), and policies query that
//! state in O(log frontier) instead of scanning a per-call `SchedView`.
//! The real executor ([`crate::exec`]) drives the *same* state type, so
//! sim and real share one scheduler core. Decision equivalence against
//! the view-based reference policies is proven by `prop_policy_equiv` and
//! the bit-identical `integration_sim_equiv` suite.

use crate::cost::{contention, CostModel};
use crate::error::{Error, Result};
use crate::fault::{FaultClock, FaultEvent, FaultKind, FaultPlan};
use crate::graph::{Dag, KernelId, Partition};
use crate::platform::{DeviceId, Platform};
use crate::queue::{setup_cq, CmdId, CommandKind, CommandQueues};
use crate::sched::fuzz::{Ambiguity, OrderSeam};
use crate::sched::{Policy, ResidentTenant, SchedState};
use crate::trace::{Lane, Span, Trace};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

/// Per-component serving metadata for [`simulate_served`]: when the
/// component may start, how urgent it is, and by when it must finish.
#[derive(Debug, Clone, Copy)]
pub struct CompMeta {
    /// Earliest instant the component may join the frontier (its request's
    /// coalesced arrival).
    pub release: f64,
    /// Absolute deadline, seconds since the serving epoch
    /// (`f64::INFINITY` when the request carries none).
    pub deadline: f64,
    /// Request priority (larger = more urgent; 0 default).
    pub priority: u32,
}

impl Default for CompMeta {
    fn default() -> Self {
        CompMeta {
            release: 0.0,
            deadline: f64::INFINITY,
            priority: 0,
        }
    }
}

/// Simulation tuning knobs beyond what [`Platform`] carries.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Host-starvation model for the *asynchronous* callback path: when the
    /// CPU device is busy running kernels at callback time, the callback
    /// thread cannot be scheduled until the OpenCL CPU driver yields cores.
    /// The stall is modeled as this fraction of the largest remaining CPU
    /// kernel time (the paper's Fig. 13(a) analysis: "either the master
    /// thread running schedule is swapped out ... or there are not enough
    /// resources to spawn the thread for running the callback function").
    pub host_starvation_fraction: f64,
    /// Round-robin interference efficiency once a device is oversubscribed
    /// (ablation knob; default [`contention::CONTENTION_EFFICIENCY`]).
    pub contention_efficiency: f64,
    /// Hard cap on simulated events (runaway guard).
    pub max_events: usize,
    /// Maximum task components resident on one device at a time. The paper's
    /// Algorithm 1 leases a device exclusively per component (`1`, the
    /// default); the multi-DAG serving layer raises this so independent
    /// requests share a device, bounded by the hardware concurrency cap.
    pub max_tenants: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            host_starvation_fraction: 0.5,
            contention_efficiency: contention::CONTENTION_EFFICIENCY,
            max_events: 4_000_000,
            max_tenants: 1,
        }
    }
}

/// Result of one simulated schedule.
#[derive(Debug)]
pub struct SimResult {
    /// Time the last command completed (the paper's Gantt makespan).
    pub makespan: f64,
    pub trace: Trace,
    /// Policy name that produced this schedule.
    pub policy: String,
    /// Per-component completion times.
    pub component_finish: Vec<f64>,
    /// Which device each component ran on (the last device for components
    /// that were preempted and re-dispatched).
    pub component_device: Vec<DeviceId>,
    /// Number of preemptions (resident components displaced mid-flight by
    /// [`Policy::preempt`]).
    pub preemptions: usize,
}

// The execution substrate below (`CmdState`, `Dispatch`, `Run`, `EvKind`,
// `Ev`, `CopyEngine`, `EPS`) is `pub(crate)`: the always-on streaming
// simulator ([`super::stream`]) reuses the exact same command/dispatch/run
// state machine, adding only unit indirection and retirement on top, so
// the two engines cannot drift apart mechanically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum CmdState {
    Pending,
    Issued,
    Done,
}

pub(crate) struct Dispatch {
    pub(crate) cq: CommandQueues,
    pub(crate) device: DeviceId,
    /// Commands become issuable after this instant (select + setup_cq).
    pub(crate) ready_at: f64,
    /// Set when the component was preempted: the dispatch is dead — no
    /// further commands issue, in-flight completions are dropped, and a
    /// fresh dispatch is created when the component is re-selected.
    pub(crate) cancelled: bool,
    /// EFT booking added to `est_free[device]` at dispatch — rolled back
    /// on displacement so repeated preemptions don't inflate the device's
    /// estimated backlog.
    pub(crate) est_committed: f64,
    pub(crate) state: Vec<CmdState>,
    /// Next unissued index per queue (in-order execution).
    pub(crate) queue_next: Vec<usize>,
    pub(crate) cmds_remaining: usize,
    /// Callback firings still outstanding (the count comes from the
    /// engine-wide per-component `cb_count`; per-kernel classification
    /// lives in the engine-wide `is_cb_kernel` / `is_async_kernel`
    /// bitsets — the former per-dispatch `Vec` walks were a per-completion
    /// linear scan).
    pub(crate) callbacks_left: usize,
}

pub(crate) struct Run {
    pub(crate) disp: usize,
    pub(crate) cmd: CmdId,
    /// Kernel id in the owning application DAG (the merged DAG here; the
    /// streaming engine reuses `Run` with *unit-local* kernel ids — the
    /// unit is reachable through `disp`).
    pub(crate) kernel: KernelId,
    pub(crate) device: DeviceId,
    pub(crate) queue: usize,
    /// Remaining work in solo-seconds.
    pub(crate) remaining: f64,
    pub(crate) occupancy: f64,
    pub(crate) started: f64,
}

#[derive(Debug, Clone, Copy)]
pub(crate) enum EvKind {
    /// setup_cq finished; the dispatch joins the live-dispatch index and
    /// its commands may issue.
    DispatchReady(usize),
    /// A host-side (CPU shared-memory) transfer completed.
    TransferDone { disp: usize, cmd: CmdId },
    /// The DMA copy engine finished its current transfer.
    CopyDone { engine: usize },
    /// A kernel's completion callback ran on the host.
    Callback { disp: usize, kernel: KernelId },
    /// A served DAG request arrived: its component may now join the frontier
    /// (multi-DAG serving; never emitted when all release times are zero).
    Release { comp: usize },
    /// Fault-recovery wakeup: a crash-displaced component's exponential
    /// backoff expired and it may re-enter the frontier. `seq` is the
    /// component's slot-binding seq in the streaming arena (a stale wakeup
    /// for a reused slot is dropped); the monolithic engine never rebinds
    /// component ids and passes 0.
    Recover { comp: usize, seq: u64 },
}

pub(crate) struct Ev {
    pub(crate) t: f64,
    pub(crate) seq: u64,
    pub(crate) kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Ev {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&o.t)
            .then_with(|| self.seq.cmp(&o.seq))
    }
}

pub(crate) struct CopyEngine {
    /// FIFO of queued transfers.
    pub(crate) queue: VecDeque<(usize, CmdId)>,
    /// Currently transferring, if any.
    pub(crate) current: Option<(usize, CmdId)>,
}

/// Simulate `policy` scheduling `partition` of `dag` onto `platform`.
pub fn simulate(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &SimConfig,
) -> Result<SimResult> {
    Engine::new(dag, partition, platform, cost, policy, cfg, None, None, None)?.run()
}

/// Multi-DAG serving entry point: like [`simulate`], but component `c` may
/// not enter the frontier before `releases[c]` (its request's coalesced
/// arrival instant). With all-zero releases this is exactly [`simulate`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_released(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &SimConfig,
    releases: &[f64],
) -> Result<SimResult> {
    let meta: Vec<CompMeta> = releases
        .iter()
        .map(|&release| CompMeta {
            release,
            ..CompMeta::default()
        })
        .collect();
    simulate_served(dag, partition, platform, cost, policy, cfg, &meta)
}

/// Deadline-aware serving entry point: [`simulate_released`] plus absolute
/// deadlines and priorities per component, exposed to every policy through
/// the shared [`SchedState`] and consulted by the preemption hook
/// ([`Policy::preempt`]). With default metadata this is exactly
/// [`simulate`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_served(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &SimConfig,
    meta: &[CompMeta],
) -> Result<SimResult> {
    validate_meta(partition, meta)?;
    Engine::new(dag, partition, platform, cost, policy, cfg, Some(meta), None, None)?.run()
}

/// Chaos-testing entry point: [`simulate_served`] under a fault-injection
/// plan ([`crate::fault::FaultPlan`]). Crashed devices leave the available
/// set ([`SchedState::on_device_down`]) and their resident components are
/// displaced through the preemption re-stage machinery — completed kernels
/// stay completed, transfers re-stage — re-entering the frontier for a
/// surviving device after exponential backoff; wedges and slowdowns scale
/// kernel progress rates through the contention model. The finite batch
/// simulated here has no shedding outlet, so exhausting a component's
/// retry budget — or losing every schedulable device — is a typed
/// [`Error::Sched`]; graceful degradation lives in the streaming server
/// ([`super::stream::StreamSim::install_faults`]). Every other entry point
/// passes no plan and is byte-identical to the fault-free engine.
#[allow(clippy::too_many_arguments)]
pub fn simulate_served_faulted(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &SimConfig,
    meta: &[CompMeta],
    plan: &FaultPlan,
) -> Result<SimResult> {
    validate_meta(partition, meta)?;
    plan.validate()?;
    plan.validate_devices(platform.devices.len())?;
    Engine::new(
        dag,
        partition,
        platform,
        cost,
        policy,
        cfg,
        Some(meta),
        None,
        Some(plan),
    )?
    .run()
}

/// Concurrency-fuzzer entry point ([`crate::sched::fuzz`]): exactly
/// [`simulate_served`], but every same-instant ordering ambiguity in the
/// event loop is resolved by `seam` instead of the canonical fixed order.
/// Coverage and the deviation log accumulate in `seam`. Not a serving API.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn simulate_served_fuzzed(
    dag: &Dag,
    partition: &Partition,
    platform: &Platform,
    cost: &dyn CostModel,
    policy: &mut dyn Policy,
    cfg: &SimConfig,
    meta: &[CompMeta],
    seam: &mut OrderSeam,
) -> Result<SimResult> {
    validate_meta(partition, meta)?;
    Engine::new(
        dag,
        partition,
        platform,
        cost,
        policy,
        cfg,
        Some(meta),
        Some(seam),
        None,
    )?
    .run()
}

fn validate_meta(partition: &Partition, meta: &[CompMeta]) -> Result<()> {
    if meta.len() != partition.components.len() {
        return Err(Error::Sched(format!(
            "serving metadata for {} components, partition has {}",
            meta.len(),
            partition.components.len()
        )));
    }
    for m in meta {
        if !m.release.is_finite() || m.release < 0.0 {
            return Err(Error::Sched(format!("invalid release time {}", m.release)));
        }
        // Deadlines are absolute instants: zero or even negative just means
        // "already due" (an ordinary miss), so only NaN is malformed.
        // Relative-budget validation (> 0) belongs to admission.
        if m.deadline.is_nan() {
            return Err(Error::Sched("invalid deadline NaN".into()));
        }
    }
    Ok(())
}

struct Engine<'a> {
    dag: &'a Dag,
    partition: &'a Partition,
    platform: &'a Platform,
    cost: &'a dyn CostModel,
    policy: &'a mut dyn Policy,
    cfg: &'a SimConfig,

    now: f64,
    seq: u64,
    heap: BinaryHeap<Reverse<Ev>>,
    trace: Trace,

    // Scheduler state (Algorithm 1): the shared incrementally indexed
    // core — frontier buckets, availability, tenancy, est_free, load.
    state: SchedState<'a>,
    /// Earliest instant each component may join the frontier (serving).
    release: Vec<f64>,
    /// Outstanding external predecessor kernels per component.
    ext_preds_left: Vec<usize>,
    /// comp list each kernel unblocks when globally finished.
    unblocks: Vec<Vec<usize>>,
    kernel_finished: Vec<bool>,
    comp_dispatched: Vec<bool>,
    comp_finish: Vec<f64>,
    comp_device: Vec<DeviceId>,
    comps_done: usize,
    /// Fraction of each kernel's solo execution already performed —
    /// preserved across preemption so displaced work re-runs only its
    /// remaining solo-seconds (transfers are re-staged in full).
    kernel_frac: Vec<f64>,
    /// Live dispatch index per component (None once finished/displaced).
    comp_active_disp: Vec<Option<usize>>,
    /// Components with a live dispatch, ascending — the preemption victim
    /// candidates, maintained incrementally instead of scanning every
    /// component per blocked select.
    resident_comps: Vec<usize>,
    preemptions: usize,

    // Execution state.
    dispatches: Vec<Dispatch>,
    /// Live-dispatch index: dispatch ids that are ready, uncancelled, and
    /// still have commands to issue — sorted ascending so `issue_phase`
    /// visits dispatches in exactly the order the former full scan did.
    active_disp: Vec<usize>,
    runs: Vec<Run>,
    /// Running-kernel count per device (the hardware concurrency gate —
    /// was a full `runs` filter per NdRange issue).
    runs_per_dev: Vec<usize>,
    copy_engines: Vec<CopyEngine>,
    last_cmd_done: f64,

    // Per-kernel bookkeeping, engine-wide (each kernel belongs to exactly
    // one component, so a flat per-kernel slot replaces the former
    // per-dispatch association lists).
    /// Remaining commands per kernel (callback firing condition); reset at
    /// (re-)dispatch of the owning component.
    kernel_cmds_left: Vec<usize>,
    /// Kernel carries a completion callback (END ∪ terminal sinks).
    is_cb_kernel: Vec<bool>,
    /// Callback must take the asynchronous clSetEventCallback path.
    is_async_kernel: Vec<bool>,
    /// Callback-kernel count per component (`callbacks_left` seed).
    cb_count: Vec<usize>,

    // Cross-DAG load refresh flag + reusable per-event scratch. The load
    // itself lives in `SchedState::device_load`; it is refreshed from
    // `runs` (same iteration order as the former per-call recompute, so
    // values are bit-identical) only when the running set changed.
    load_dirty: bool,
    rates: Vec<f64>,
    scratch_idx: Vec<usize>,
    scratch_us: Vec<f64>,
    scratch_speeds: Vec<f64>,
    scratch_finished: Vec<usize>,
    scratch_ready: Vec<usize>,

    /// Concurrency-fuzzer seam ([`crate::sched::fuzz`]): when installed,
    /// every same-instant ambiguity — simultaneous completions, due-event
    /// batches, frontier-entry batches, the preemption victim list, victim
    /// re-entry timing — is routed through it as an explicit ordering
    /// choice. `None` (every production entry point) keeps the canonical
    /// deterministic order, byte-identically to the un-instrumented loop.
    seam: Option<&'a mut OrderSeam>,

    /// Fault-injection replay state ([`simulate_served_faulted`] only).
    /// `None` everywhere else: every fault hook then short-circuits and
    /// the loop is byte-identical to the fault-free engine.
    faults: Option<FaultClock>,
    /// Recovery knobs from the installed plan (unused without one).
    retry_budget: u32,
    backoff_base: f64,
    /// Fault-triggered displacements charged per component.
    comp_retries: Vec<u32>,
    scratch_faults: Vec<FaultEvent>,
}

pub(crate) const EPS: f64 = 1e-12;

impl<'a> Engine<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        dag: &'a Dag,
        partition: &'a Partition,
        platform: &'a Platform,
        cost: &'a dyn CostModel,
        policy: &'a mut dyn Policy,
        cfg: &'a SimConfig,
        meta: Option<&[CompMeta]>,
        mut seam: Option<&'a mut OrderSeam>,
        fault_plan: Option<&FaultPlan>,
    ) -> Result<Self> {
        let ncomp = partition.components.len();
        let nk = dag.num_kernels();
        // Kernel-level unblock lists (producer kernel -> consumer
        // components) and external-predecessor counts, deduplicated by
        // sort+dedup over the edge list instead of the former per-edge
        // `Vec::contains` walk (O(E·deg)). `unblocks` preserves the
        // first-encounter edge order the old dedup produced: stable-sort by
        // (kernel, component) keeps the earliest edge of every pair, and
        // the re-sort by edge index restores encounter order.
        let mut pairs: Vec<(KernelId, usize, usize)> = Vec::new();
        let mut pred_pairs: Vec<(usize, KernelId)> = Vec::new();
        for (idx, &(src, dst)) in dag.buffer_edges.iter().enumerate() {
            let pk = dag.buffers[src].kernel;
            let ck = dag.buffers[dst].kernel;
            let pc = partition.assignment[pk];
            let cc = partition.assignment[ck];
            if pc != cc {
                pairs.push((pk, cc, idx));
                pred_pairs.push((cc, pk));
            }
        }
        pairs.sort_by_key(|&(pk, cc, _)| (pk, cc));
        pairs.dedup_by_key(|p| (p.0, p.1));
        pairs.sort_unstable_by_key(|&(_, _, idx)| idx);
        let mut unblocks: Vec<Vec<usize>> = vec![Vec::new(); nk];
        for &(pk, cc, _) in &pairs {
            unblocks[pk].push(cc);
        }
        pred_pairs.sort_unstable();
        pred_pairs.dedup();
        let mut ext_preds_left = vec![0usize; ncomp];
        for &(cc, _) in &pred_pairs {
            ext_preds_left[cc] += 1;
        }
        // Callback classification is static per kernel (each kernel belongs
        // to exactly one component): compute once up front instead of per
        // dispatch, into O(1) bitsets.
        let mut is_cb_kernel = vec![false; nk];
        let mut is_async_kernel = vec![false; nk];
        let mut cb_count = vec![0usize; ncomp];
        for c in 0..ncomp {
            let cbs = partition.callback_kernels(dag, c);
            cb_count[c] = cbs.len();
            for k in cbs {
                is_cb_kernel[k] = true;
            }
            for k in partition.async_callback_kernels(dag, c) {
                is_async_kernel[k] = true;
            }
        }
        let release: Vec<f64> = meta
            .map(|m| m.iter().map(|c| c.release).collect())
            .unwrap_or_else(|| vec![0.0; ncomp]);
        let deadline: Vec<f64> = meta
            .map(|m| m.iter().map(|c| c.deadline).collect())
            .unwrap_or_else(|| vec![f64::INFINITY; ncomp]);
        let priority: Vec<u32> = meta
            .map(|m| m.iter().map(|c| c.priority).collect())
            .unwrap_or_else(|| vec![0; ncomp]);
        let mut state = SchedState::new(
            dag,
            partition,
            platform,
            cost,
            cfg.max_tenants.max(1),
            deadline,
            priority,
        )?;
        // Initially ready components enter in ascending id order, which
        // assigns FIFO seqs matching the stable rank sort the pre-indexed
        // engine applied (equal ranks stay in component-id order). Under a
        // fuzz seam the batch is a DispatchTie ambiguity: requests arriving
        // "together" have no canonical order on real hardware, and the
        // entry order decides every bitwise rank/deadline tie downstream.
        let mut initial: Vec<usize> = (0..ncomp)
            .filter(|&c| ext_preds_left[c] == 0 && release[c] <= 0.0)
            .collect();
        if let Some(s) = seam.as_deref_mut() {
            s.shuffle(Ambiguity::DispatchTie, &mut initial);
        }
        for &c in &initial {
            state.on_ready(c);
        }
        let ndev = platform.devices.len();
        let (faults, retry_budget, backoff_base) = match fault_plan {
            Some(p) => (Some(FaultClock::new(p, ndev)), p.retry_budget, p.backoff_base),
            None => (None, 0, 0.0),
        };
        Ok(Engine {
            dag,
            partition,
            platform,
            cost,
            policy,
            cfg,
            now: 0.0,
            seq: 0,
            heap: BinaryHeap::new(),
            trace: Trace::default(),
            state,
            release,
            ext_preds_left,
            unblocks,
            kernel_finished: vec![false; nk],
            comp_dispatched: vec![false; ncomp],
            comp_finish: vec![f64::NAN; ncomp],
            comp_device: vec![usize::MAX; ncomp],
            comps_done: 0,
            kernel_frac: vec![0.0; nk],
            comp_active_disp: vec![None; ncomp],
            resident_comps: Vec::new(),
            preemptions: 0,
            dispatches: Vec::new(),
            active_disp: Vec::new(),
            runs: Vec::new(),
            runs_per_dev: vec![0; ndev],
            copy_engines: (0..platform.copy_engines.max(1))
                .map(|_| CopyEngine {
                    queue: VecDeque::new(),
                    current: None,
                })
                .collect(),
            last_cmd_done: 0.0,
            kernel_cmds_left: vec![0; nk],
            is_cb_kernel,
            is_async_kernel,
            cb_count,
            load_dirty: false,
            rates: Vec::new(),
            scratch_idx: Vec::new(),
            scratch_us: Vec::new(),
            scratch_speeds: Vec::new(),
            scratch_finished: Vec::new(),
            scratch_ready: Vec::new(),
            seam,
            faults,
            retry_budget,
            backoff_base,
            comp_retries: vec![0; ncomp],
            scratch_faults: Vec::new(),
        })
    }

    fn push_ev(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Reverse(Ev {
            t,
            seq: self.seq,
            kind,
        }));
    }

    // ------------------------------------------------------ index upkeep

    /// Insert `di` into the sorted live-dispatch index (no-op if present).
    fn active_insert(&mut self, di: usize) {
        if let Err(pos) = self.active_disp.binary_search(&di) {
            self.active_disp.insert(pos, di);
        }
    }

    /// Remove `di` from the live-dispatch index (no-op if absent).
    fn active_remove(&mut self, di: usize) {
        if let Ok(pos) = self.active_disp.binary_search(&di) {
            self.active_disp.remove(pos);
        }
    }

    /// Insert `comp` into the sorted resident-component list.
    fn resident_insert(&mut self, comp: usize) {
        if let Err(pos) = self.resident_comps.binary_search(&comp) {
            self.resident_comps.insert(pos, comp);
        }
    }

    /// Remove `comp` from the resident-component list (no-op if absent).
    fn resident_remove(&mut self, comp: usize) {
        if let Ok(pos) = self.resident_comps.binary_search(&comp) {
            self.resident_comps.remove(pos);
        }
    }

    // ---------------------------------------------------------- scheduling

    /// Refresh the per-device load cached in the scheduler state
    /// (Σ occupancy of running kernels — the cross-DAG load signal exposed
    /// to policies). Iterates `runs` in the same order the former per-call
    /// recompute did, so the sums are bit-identical; the cache is only
    /// invalidated when the running set actually changes.
    fn refresh_device_load(&mut self) {
        for l in self.state.device_load.iter_mut() {
            *l = 0.0;
        }
        for r in &self.runs {
            self.state.device_load[r.device] += r.occupancy;
        }
        self.load_dirty = false;
    }

    fn scheduler_phase(&mut self) {
        // One preemption is allowed per blocked `select`; if the policy
        // displaces a tenant but *still* cannot place anything, stop —
        // otherwise a misbehaving policy could spin displacing tenants.
        // The budget additionally bounds displace→select→displace churn
        // within one phase: a Policy violating the strict-dominance
        // contract (preempting a victim it immediately re-selects) would
        // otherwise livelock here at a fixed timestamp, out of reach of
        // run()'s max_events backstop. Legitimate chains are bounded by
        // the component count.
        let mut preempt_budget = self.partition.components.len().max(8);
        let mut retry_after_preempt = false;
        // One clock update per phase: every select/preempt in this phase
        // sees the same `now` the former per-call view carried.
        self.state.now = self.now;
        // Reentry-class deviations park displaced victims here until the
        // phase's select/preempt loop settles (empty on the canonical
        // path — victims re-enter the frontier inside `displace`).
        let mut deferred: Vec<usize> = Vec::new();
        loop {
            loop {
                if self.load_dirty {
                    self.refresh_device_load();
                }
                if let Some((comp, dev)) = self.policy.select(&mut self.state) {
                    retry_after_preempt = false;
                    self.dispatch(comp, dev);
                    continue;
                }
                if retry_after_preempt
                    || preempt_budget == 0
                    || self.state.frontier_is_empty()
                    || !self.policy.can_preempt()
                {
                    break;
                }
                // Candidate victims: resident components with commands still
                // outstanding. A component that only awaits its completion
                // callbacks frees no compute when displaced — its tenant slot
                // returns within ~callback_latency anyway, while a displacement
                // would force a full transfer re-stage. `resident_comps` is
                // maintained sorted ascending, matching the component order the
                // former full `comp_active_disp` scan produced; under a fuzz
                // seam the list order is a PreemptRace ambiguity (it decides
                // which of several equally urgent victims is displaced).
                let mut resident: Vec<ResidentTenant> = self
                    .resident_comps
                    .iter()
                    .filter_map(|&c| {
                        self.comp_active_disp[c]
                            .filter(|&d| self.dispatches[d].cmds_remaining > 0)
                            .map(|d| ResidentTenant {
                                comp: c,
                                device: self.dispatches[d].device,
                            })
                    })
                    .collect();
                if resident.is_empty() {
                    break;
                }
                if let Some(s) = self.seam.as_deref_mut() {
                    s.shuffle(Ambiguity::PreemptRace, &mut resident);
                }
                match self.policy.preempt(&mut self.state, &resident) {
                    Some(victim) if self.displace(victim, &mut deferred) => {
                        preempt_budget -= 1;
                        retry_after_preempt = true;
                    }
                    _ => break,
                }
            }
            if deferred.is_empty() {
                break;
            }
            // Deferred victim re-entries: apply as a (permutable) frontier
            // batch, then give the policy another look at the refreshed
            // frontier. Terminates: refills require displacements, and each
            // displacement spends preemption budget.
            let mut batch = std::mem::take(&mut deferred);
            if let Some(s) = self.seam.as_deref_mut() {
                s.shuffle(Ambiguity::DispatchTie, &mut batch);
            }
            for c in batch {
                self.enter_frontier(c);
            }
            retry_after_preempt = false;
        }
    }

    fn dispatch(&mut self, comp: usize, dev: DeviceId) {
        assert!(!self.comp_dispatched[comp], "component {comp} re-dispatched");
        self.comp_dispatched[comp] = true;
        // Frontier exit + tenant accounting + availability, in one event.
        self.state.on_dispatch(comp, dev);
        self.comp_device[comp] = dev;

        // setup_cq runs on a child thread: commands are issuable after the
        // per-command enqueue overhead has elapsed.
        let mut device = self.platform.device(dev).clone();
        device.num_queues = self.policy.queues_for(&device);
        let cq = setup_cq(self.dag, self.partition, comp, &device);
        let setup = cq.num_commands() as f64 * self.platform.enqueue_overhead;
        let ready_at = self.now + setup;
        self.trace.push(Span {
            label: format!("setup c{comp}"),
            lane: Lane::Host,
            start: self.now,
            end: ready_at,
            cmd: None,
            kernel: None,
        });

        // Commit an EFT estimate for HEFT's est_free bookkeeping. Under
        // multi-tenancy the device backlog accumulates across residents.
        let solo: f64 = self.partition.components[comp]
            .kernels
            .iter()
            .map(|&k| self.cost.exec_time(&self.dag.kernels[k], &device))
            .sum();
        let transfers: f64 = cq
            .commands
            .iter()
            .filter_map(|c| c.transfer_buffer())
            .map(|b| self.platform.transfer_time(dev, self.dag.buffers[b].size_bytes))
            .sum();
        let est_committed = solo + transfers + self.platform.callback_latency;
        self.state.est_free[dev] = self.state.est_free[dev].max(ready_at) + est_committed;

        // Per-kernel outstanding-command counts, in the engine-wide flat
        // table (zeroed first: a preempted component's stale counts die
        // with its cancelled dispatch).
        for c in &cq.commands {
            self.kernel_cmds_left[c.kernel] = 0;
        }
        for c in &cq.commands {
            self.kernel_cmds_left[c.kernel] += 1;
        }
        let d = Dispatch {
            state: vec![CmdState::Pending; cq.num_commands()],
            queue_next: vec![0; cq.queues.len()],
            cmds_remaining: cq.num_commands(),
            callbacks_left: self.cb_count[comp],
            cq,
            device: dev,
            ready_at,
            cancelled: false,
            est_committed,
        };
        let idx = self.dispatches.len();
        self.dispatches.push(d);
        self.comp_active_disp[comp] = Some(idx);
        self.resident_insert(comp);
        if ready_at <= self.now + EPS {
            // Zero setup overhead: issuable in this very phase, exactly as
            // the former ready_at scan would have found it.
            self.active_insert(idx);
        }
        self.push_ev(ready_at, EvKind::DispatchReady(idx));
    }

    /// Preempt `victim` at command-queue granularity: kernels that already
    /// completed stay completed (their callbacks still unblock successors),
    /// running kernels are stopped with their progress credited to
    /// [`Engine::kernel_frac`] (remaining solo-seconds preserved), queued
    /// commands are cancelled, the tenant slot is returned, and the
    /// component re-enters the frontier for a later re-dispatch (which
    /// re-stages its transfers — the preemption penalty). Returns false if
    /// `victim` is not currently resident. Under a fuzz seam the victim's
    /// frontier re-entry may be deferred into `deferred` (Reentry
    /// ambiguity: immediate vs phase-end re-entry); the canonical path
    /// always re-enters immediately.
    fn displace(&mut self, victim: usize, deferred: &mut Vec<usize>) -> bool {
        if !self.cancel_resident(victim) {
            return false;
        }
        self.preemptions += 1;
        self.trace.push(Span {
            label: format!("preempt c{victim}"),
            lane: Lane::Host,
            start: self.now,
            end: self.now,
            cmd: None,
            kernel: None,
        });
        let defer = match self.seam.as_deref_mut() {
            Some(s) => s.flip(Ambiguity::Reentry),
            None => false,
        };
        if defer {
            deferred.push(victim);
        } else {
            self.enter_frontier(victim);
        }
        true
    }

    /// The re-stage core shared by policy preemption ([`Self::displace`])
    /// and fault recovery: pull `victim`'s live dispatch off its device —
    /// completed kernels stay completed (`kernel_frac`), transfers
    /// re-stage, tenancy/`est_free` roll back — leaving re-entry (or
    /// failure) to the caller. Returns false if `victim` is not resident.
    fn cancel_resident(&mut self, victim: usize) -> bool {
        let Some(di) = self.comp_active_disp.get(victim).copied().flatten() else {
            return false;
        };
        // Stop running kernels of this dispatch, crediting partial work.
        let mut i = 0;
        while i < self.runs.len() {
            if self.runs[i].disp != di {
                i += 1;
                continue;
            }
            let r = self.runs.swap_remove(i);
            self.runs_per_dev[r.device] -= 1;
            self.load_dirty = true;
            let device = self.platform.device(r.device);
            let full = self.cost.exec_time(&self.dag.kernels[r.kernel], device);
            let done = if full > 0.0 {
                (1.0 - r.remaining / full).clamp(0.0, 1.0)
            } else {
                1.0
            };
            self.kernel_frac[r.kernel] = self.kernel_frac[r.kernel].max(done);
            if self.now > r.started {
                let name = &self.dag.kernels[r.kernel].name;
                self.trace.push(Span {
                    label: format!("{name}{}!", r.kernel),
                    lane: Lane::Device {
                        dev: r.device,
                        slot: r.queue,
                    },
                    start: r.started,
                    end: self.now,
                    cmd: Some(r.cmd),
                    kernel: Some(r.kernel),
                });
            }
        }
        // Drop queued (not yet started) DMA transfers; an in-flight one
        // finishes physically but its completion is ignored (`cancelled`).
        for e in &mut self.copy_engines {
            e.queue.retain(|&(d, _)| d != di);
        }
        let dev = self.dispatches[di].device;
        self.dispatches[di].cancelled = true;
        self.active_remove(di);
        self.comp_active_disp[victim] = None;
        self.resident_remove(victim);
        self.comp_dispatched[victim] = false;
        self.state.on_preempt(dev);
        // Roll back the EFT booking made at dispatch (the re-dispatch will
        // book afresh); partial progress is forfeited with it.
        self.state.est_free[dev] =
            (self.state.est_free[dev] - self.dispatches[di].est_committed).max(self.now);
        if self.state.tenants[dev] == 0 {
            self.state.est_free[dev] = self.now;
        }
        true
    }

    // ------------------------------------------------------------- faults

    /// Replay every fault event due at the current instant (canonical
    /// order: after the retire+drain step — the engine's fault path is
    /// never fuzzed; the seamed fault-race coverage lives in the streaming
    /// simulator). Only reachable with a plan installed.
    fn apply_due_faults(&mut self) -> Result<()> {
        let mut due = std::mem::take(&mut self.scratch_faults);
        due.clear();
        self.faults
            .as_mut()
            .expect("faults installed")
            .take_due(self.now, &mut due);
        let mut res = Ok(());
        for ev in &due {
            self.faults.as_mut().expect("faults installed").apply(ev);
            if let FaultKind::Crash = ev.kind {
                if let Err(e) = self.crash_device(ev.device) {
                    res = Err(e);
                    break;
                }
            }
        }
        self.scratch_faults = due;
        res
    }

    /// Crash `dev`: mark it down in the scheduler, displace every resident
    /// component on it through the re-stage machinery, and re-enter each
    /// victim after exponential backoff. The finite batch has no shedding
    /// outlet, so an exhausted retry budget — or losing every schedulable
    /// device — is a typed error.
    fn crash_device(&mut self, dev: DeviceId) -> Result<()> {
        self.state.on_device_down(dev);
        let victims: Vec<usize> = self
            .resident_comps
            .iter()
            .copied()
            .filter(|&c| {
                self.comp_active_disp[c]
                    .map(|di| self.dispatches[di].device == dev)
                    .unwrap_or(false)
            })
            .collect();
        for victim in victims {
            self.comp_retries[victim] += 1;
            let retries = self.comp_retries[victim];
            if retries > self.retry_budget {
                return Err(Error::Sched(format!(
                    "component {victim} lost to crash of device {dev}: retry budget {} exhausted",
                    self.retry_budget
                )));
            }
            if !self.cancel_resident(victim) {
                continue;
            }
            self.trace.push(Span {
                label: format!("fault c{victim}"),
                lane: Lane::Host,
                start: self.now,
                end: self.now,
                cmd: None,
                kernel: None,
            });
            // Exponential backoff before re-entry: retry k waits
            // backoff_base * 2^(k-1). Monolithic component ids never
            // rebind, so the Recover seq is unused here (0).
            let wait = self.backoff_base * (1u64 << (retries - 1).min(62)) as f64;
            if wait > 0.0 {
                self.push_ev(self.now + wait, EvKind::Recover { comp: victim, seq: 0 });
            } else {
                self.enter_frontier(victim);
            }
        }
        if self.comps_done < self.partition.components.len()
            && (0..self.platform.devices.len())
                .all(|d| self.state.is_down(d) || self.platform.devices[d].num_queues == 0)
        {
            return Err(Error::Sched(format!(
                "device {dev} crash leaves no schedulable device with {} component(s) unfinished",
                self.partition.components.len() - self.comps_done
            )));
        }
        Ok(())
    }

    // ------------------------------------------------------------- issuing

    /// Issue every currently eligible command. In-order queues: only each
    /// queue's head candidate is considered; cross-queue deps must be Done.
    /// Walks the live-dispatch index only — drained, cancelled, and
    /// not-yet-ready dispatches never enter it, so a serving run with
    /// thousands of completed dispatches pays nothing for them (the former
    /// full scan made this O(total dispatches) per event).
    fn issue_phase(&mut self) {
        let mut progressed = true;
        while progressed {
            progressed = false;
            let mut ai = 0;
            while ai < self.active_disp.len() {
                let di = self.active_disp[ai];
                ai += 1;
                debug_assert!(
                    !self.dispatches[di].cancelled
                        && self.dispatches[di].cmds_remaining > 0
                        && self.dispatches[di].ready_at <= self.now + EPS,
                    "stale dispatch {di} in live index"
                );
                for q in 0..self.dispatches[di].cq.queues.len() {
                    // In-order queue: a command may issue only once every
                    // earlier command in the same queue has *completed*.
                    loop {
                        let d = &self.dispatches[di];
                        let Some(&cmd) = d.cq.queues[q].get(d.queue_next[q]) else {
                            break;
                        };
                        match d.state[cmd] {
                            CmdState::Done => {
                                self.dispatches[di].queue_next[q] += 1;
                                continue;
                            }
                            CmdState::Issued => break, // head still running
                            CmdState::Pending => {}
                        }
                        // Inline over `e_q` (deps_of would allocate a Vec
                        // per probe — this runs once per issue attempt).
                        let deps_ok = d
                            .cq
                            .e_q
                            .iter()
                            .filter(|&&(_, a)| a == cmd)
                            .all(|&(b, _)| d.state[b] == CmdState::Done);
                        if !deps_ok || !self.try_issue(di, cmd) {
                            break;
                        }
                        progressed = true;
                        break; // issued: wait for completion before the next
                    }
                }
            }
        }
    }

    /// Attempt to issue one command; false if a resource gate blocks it.
    fn try_issue(&mut self, di: usize, cmd: CmdId) -> bool {
        let d = &self.dispatches[di];
        let dev_id = d.device;
        let kind = d.cq.commands[cmd].kind;
        let kernel = d.cq.commands[cmd].kernel;
        let queue = d.cq.commands[cmd].queue;
        match kind {
            CommandKind::NdRange => {
                // Hardware concurrency cap (Hyper-Q / CPU fission width),
                // from the per-device running counter.
                if self.runs_per_dev[dev_id] >= self.platform.device(dev_id).hw_queues {
                    return false;
                }
                let device = self.platform.device(dev_id);
                let node = &self.dag.kernels[kernel];
                // Preempted-and-re-dispatched kernels only owe their
                // remaining solo-seconds (kernel_frac credits prior runs;
                // fully finished kernels replay instantly).
                let full = self.cost.exec_time(node, device);
                let remaining = full * (1.0 - self.kernel_frac[kernel]).max(0.0);
                self.runs.push(Run {
                    disp: di,
                    cmd,
                    kernel,
                    device: dev_id,
                    queue,
                    remaining,
                    occupancy: contention::occupancy(node, device),
                    started: self.now,
                });
                self.runs_per_dev[dev_id] += 1;
                self.load_dirty = true;
                self.dispatches[di].state[cmd] = CmdState::Issued;
                true
            }
            CommandKind::Write { buffer } | CommandKind::Read { buffer } => {
                self.dispatches[di].state[cmd] = CmdState::Issued;
                if self.platform.device(dev_id).shares_host_memory {
                    // Zero-copy map: completes after a token latency, no DMA.
                    let t = self.now + self.platform.transfer_time(dev_id, 0);
                    self.push_ev(t, EvKind::TransferDone { disp: di, cmd });
                } else {
                    let _ = buffer;
                    // Route to a DMA engine (one per GPU on scaled platforms).
                    let e = dev_id % self.copy_engines.len();
                    self.copy_engines[e].queue.push_back((di, cmd));
                    self.pump_copy_engine(e);
                }
                true
            }
        }
    }

    fn pump_copy_engine(&mut self, e: usize) {
        if self.copy_engines[e].current.is_some() {
            return;
        }
        let Some((di, cmd)) = self.copy_engines[e].queue.pop_front() else {
            return;
        };
        let d = &self.dispatches[di];
        let buffer = d.cq.commands[cmd].transfer_buffer().expect("transfer cmd");
        let bytes = self.dag.buffers[buffer].size_bytes;
        let dt = self.platform.transfer_time(d.device, bytes);
        let dir = match d.cq.commands[cmd].kind {
            CommandKind::Write { .. } => "w",
            _ => "r",
        };
        self.trace.push(Span {
            label: format!("{dir}{buffer}"),
            lane: Lane::CopyEngine { idx: e },
            start: self.now,
            end: self.now + dt,
            cmd: Some(cmd),
            kernel: Some(d.cq.commands[cmd].kernel),
        });
        self.copy_engines[e].current = Some((di, cmd));
        self.push_ev(self.now + dt, EvKind::CopyDone { engine: e });
    }

    // ---------------------------------------------------------- completion

    fn command_done(&mut self, di: usize, cmd: CmdId) {
        if self.dispatches[di].cancelled {
            // Completion belonging to a preempted dispatch (e.g. an
            // in-flight DMA or a zero-copy map that outlived displacement):
            // the work is void, the re-dispatch replays it.
            return;
        }
        debug_assert_eq!(self.dispatches[di].state[cmd], CmdState::Issued);
        self.dispatches[di].state[cmd] = CmdState::Done;
        self.dispatches[di].cmds_remaining -= 1;
        if self.dispatches[di].cmds_remaining == 0 {
            // Drained: out of the live index (callbacks may still fire).
            self.active_remove(di);
        }
        self.last_cmd_done = self.last_cmd_done.max(self.now);
        let kernel = self.dispatches[di].cq.commands[cmd].kernel;
        self.kernel_cmds_left[kernel] -= 1;
        if self.kernel_cmds_left[kernel] == 0 {
            if self.is_cb_kernel[kernel] {
                let delay = if self.is_async_kernel[kernel] {
                    // clSetEventCallback path: base thread latency plus host
                    // starvation while the CPU device crunches kernels
                    // (Fig. 13(a)): the callback thread waits for a share of
                    // the largest remaining CPU kernel.
                    let cpu_remaining = self
                        .runs
                        .iter()
                        .filter(|r| {
                            self.platform.device(r.device).dtype
                                == crate::platform::DeviceType::Cpu
                        })
                        .map(|r| r.remaining)
                        .fold(0.0, f64::max);
                    self.platform.callback_latency
                        + self.cfg.host_starvation_fraction * cpu_remaining
                } else {
                    // Blocking-wait path (no inter-edge reads): the dispatch
                    // child thread wakes straight out of clFinish — the
                    // clustering advantage (§5 comparative evaluation).
                    self.platform.wait_latency
                };
                self.push_ev(self.now + delay, EvKind::Callback { disp: di, kernel });
            } else {
                // IN(T) kernels finish silently (intra deps only).
                self.kernel_finished[kernel] = true;
            }
        }
    }

    fn handle_callback(&mut self, di: usize, kernel: KernelId) {
        // A preempted-and-re-run kernel fires its callback again; only the
        // first firing may decrement successor dependency counts.
        let first_completion = !self.kernel_finished[kernel];
        self.kernel_finished[kernel] = true;
        let comp = self.dispatches[di].cq.component;
        if first_completion {
            // update_task_queue: successors that became ready join F —
            // unless their request has not arrived yet (serving), in which
            // case the release event re-examines them. (Index loop: the
            // former per-callback `unblocks` clone is gone; the list is
            // never mutated after construction, but the &mut self calls in
            // the body forbid holding an iterator over it.) Frontier entry
            // is batched after the dependency decrements: the targets are
            // distinct and entries push no events, so the canonical order
            // is unchanged — and the batch is the unblock-time DispatchTie
            // ambiguity a fuzz seam permutes.
            let mut newly_ready = std::mem::take(&mut self.scratch_ready);
            newly_ready.clear();
            #[allow(clippy::needless_range_loop)]
            for u in 0..self.unblocks[kernel].len() {
                let uc = self.unblocks[kernel][u];
                // A component is ready when all external producers are done.
                self.ext_preds_left[uc] -= 1;
                if self.ext_preds_left[uc] == 0 && !self.comp_dispatched[uc] {
                    if self.release[uc] > self.now + EPS {
                        self.push_ev(self.release[uc], EvKind::Release { comp: uc });
                    } else {
                        newly_ready.push(uc);
                    }
                }
            }
            if let Some(s) = self.seam.as_deref_mut() {
                s.shuffle(Ambiguity::DispatchTie, &mut newly_ready);
            }
            #[allow(clippy::needless_range_loop)]
            for u in 0..newly_ready.len() {
                self.enter_frontier(newly_ready[u]);
            }
            self.scratch_ready = newly_ready;
        }
        if self.dispatches[di].cancelled {
            // Callback of a displaced dispatch: the tenant slot was already
            // returned at displacement; completed-kernel bookkeeping above
            // still counts (command-queue-granularity preemption).
            return;
        }
        // return_device (one tenant slot) once the component has finished.
        self.dispatches[di].callbacks_left -= 1;
        if self.dispatches[di].callbacks_left == 0 {
            debug_assert_eq!(
                self.dispatches[di].cmds_remaining, 0,
                "callbacks after all commands"
            );
            let dev = self.dispatches[di].device;
            self.state.on_complete(dev);
            if self.state.tenants[dev] == 0 {
                self.state.est_free[dev] = self.now;
            }
            self.comp_finish[comp] = self.now;
            self.comp_active_disp[comp] = None;
            self.resident_remove(comp);
            self.comps_done += 1;
        }
    }

    /// Add a ready, released component to the indexed frontier. The state
    /// assigns a fresh FIFO seq, so equal ranks order behind existing
    /// entries — the same stable order the pre-indexed sorted `Vec` kept.
    fn enter_frontier(&mut self, comp: usize) {
        if self.comp_dispatched[comp] {
            return;
        }
        self.state.on_ready(comp);
    }

    /// Fuzz-path event drain: collect the whole batch of events due at this
    /// instant and process it in a seam-permuted inter-dispatch order (the
    /// Callback ambiguity class). Events belonging to one dispatch keep
    /// their relative order — a command queue cannot race itself — while
    /// events of different dispatches (and releases) permute freely. Events
    /// the processing schedules due at the same instant form the next
    /// sub-batch, as the canonical drain would pick them up after the
    /// already-queued ones.
    fn drain_due_events_seamed(&mut self) {
        loop {
            let mut batch: Vec<Ev> = Vec::new();
            while let Some(Reverse(e)) = self.heap.peek() {
                if e.t > self.now + EPS {
                    break;
                }
                let Reverse(e) = self.heap.pop().unwrap();
                batch.push(e);
            }
            if batch.is_empty() {
                return;
            }
            let keys: Vec<Option<usize>> = batch
                .iter()
                .map(|e| match e.kind {
                    EvKind::DispatchReady(di) => Some(di),
                    EvKind::TransferDone { disp, .. } => Some(disp),
                    EvKind::Callback { disp, .. } => Some(disp),
                    // At most one CopyDone per engine per batch (the next
                    // transfer's completion is only scheduled once this one
                    // is processed), so `current` is this event's dispatch.
                    EvKind::CopyDone { engine } => {
                        self.copy_engines[engine].current.map(|(di, _)| di)
                    }
                    EvKind::Release { .. } | EvKind::Recover { .. } => None,
                })
                .collect();
            let mut order: Vec<usize> = (0..batch.len()).collect();
            if let Some(s) = self.seam.as_deref_mut() {
                s.shuffle_grouped(Ambiguity::Callback, &mut order, |&i| keys[i]);
            }
            for &bi in &order {
                match batch[bi].kind {
                    EvKind::DispatchReady(di) => {
                        if !self.dispatches[di].cancelled
                            && self.dispatches[di].cmds_remaining > 0
                        {
                            self.active_insert(di);
                        }
                    }
                    EvKind::TransferDone { disp, cmd } => self.command_done(disp, cmd),
                    EvKind::CopyDone { engine } => {
                        let (di, cmd) = self.copy_engines[engine]
                            .current
                            .take()
                            .expect("engine busy");
                        self.command_done(di, cmd);
                        self.pump_copy_engine(engine);
                    }
                    EvKind::Callback { disp, kernel } => self.handle_callback(disp, kernel),
                    EvKind::Release { comp } | EvKind::Recover { comp, .. } => {
                        if self.ext_preds_left[comp] == 0 {
                            self.enter_frontier(comp);
                        }
                    }
                }
            }
        }
    }

    // ------------------------------------------------------------- kernels

    /// Per-run speed multipliers (relative to solo execution) per device,
    /// into the reusable `rates` buffer. Gather order per device matches
    /// the former allocating version (ascending `runs` index), so the
    /// contention math is bit-identical.
    fn compute_run_rates(&mut self) {
        self.rates.clear();
        self.rates.resize(self.runs.len(), 1.0);
        for dev in 0..self.platform.devices.len() {
            if self.runs_per_dev[dev] == 0 {
                continue;
            }
            self.scratch_idx.clear();
            self.scratch_us.clear();
            for (i, r) in self.runs.iter().enumerate() {
                if r.device == dev {
                    self.scratch_idx.push(i);
                    self.scratch_us.push(r.occupancy);
                }
            }
            contention::shared_speeds_into(
                &self.scratch_us,
                self.cfg.contention_efficiency,
                &mut self.scratch_speeds,
            );
            for (j, &i) in self.scratch_idx.iter().enumerate() {
                self.rates[i] = self.scratch_speeds[j] / self.scratch_us[j];
            }
        }
        // Injected device conditions: wedged devices run at rate 0, slowed
        // devices at their factor. Multiplying by exactly 1.0 on healthy
        // devices keeps the fault-free rates bit-identical.
        if let Some(clock) = &self.faults {
            for (i, r) in self.runs.iter().enumerate() {
                self.rates[i] *= clock.rate_factor(r.device, self.now);
            }
        }
    }

    fn next_kernel_completion(&self) -> Option<f64> {
        self.runs
            .iter()
            .zip(&self.rates)
            .map(|(r, &rate)| self.now + r.remaining / rate)
            .min_by(|a, b| a.total_cmp(b))
    }

    // ------------------------------------------------------------ main loop

    fn run(mut self) -> Result<SimResult> {
        let total = self.partition.components.len();
        // Withheld components (request not yet arrived) wake via events.
        for c in 0..total {
            if self.ext_preds_left[c] == 0 && self.release[c] > 0.0 {
                self.push_ev(self.release[c], EvKind::Release { comp: c });
            }
        }
        let mut events = 0usize;
        while self.comps_done < total {
            events += 1;
            if events > self.cfg.max_events {
                return Err(Error::Sched(format!(
                    "simulation exceeded {} events (deadlock?)",
                    self.cfg.max_events
                )));
            }
            self.scheduler_phase();
            self.issue_phase();
            if self.comps_done == total {
                break;
            }

            self.compute_run_rates();
            let t_kernel = self.next_kernel_completion();
            let t_heap = self.heap.peek().map(|Reverse(e)| e.t);
            let t_fault = self.faults.as_ref().and_then(|c| c.next_change_at(self.now));
            let t_work = match (t_kernel, t_heap) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (Some(a), None) => Some(a),
                (None, Some(b)) => Some(b),
                (None, None) => None,
            };
            let t_next = match (t_work, t_fault) {
                (Some(a), Some(f)) => a.min(f),
                (Some(a), None) => a,
                (None, Some(f)) => f,
                (None, None) => {
                    return Err(Error::Sched(
                        "simulation stalled: no events, no running kernels".into(),
                    ))
                }
            };
            debug_assert!(t_next >= self.now - EPS, "time went backwards");
            let dt = (t_next - self.now).max(0.0);

            // Advance all running kernels by dt at their current rates.
            for (r, &rate) in self.runs.iter_mut().zip(&self.rates) {
                r.remaining -= dt * rate;
            }
            self.now = t_next;

            // Retire kernels that finished exactly now (descending index
            // order keeps swap_remove targets valid; scratch reused).
            self.scratch_finished.clear();
            for i in 0..self.runs.len() {
                if self.runs[i].remaining <= 1e-9 {
                    self.scratch_finished.push(i);
                }
            }
            self.scratch_finished.sort_unstable_by(|a, b| b.cmp(a));
            if self.seam.is_some() {
                // Fuzz path: simultaneous completions are a Completion
                // ambiguity. Remove every finished run first (canonical
                // descending order keeps swap_remove targets valid), then
                // retire in a permuted order.
                let mut finished: Vec<Run> = Vec::with_capacity(self.scratch_finished.len());
                for fi in 0..self.scratch_finished.len() {
                    let i = self.scratch_finished[fi];
                    finished.push(self.runs.swap_remove(i));
                }
                let mut order: Vec<usize> = (0..finished.len()).collect();
                if let Some(s) = self.seam.as_deref_mut() {
                    s.shuffle(Ambiguity::Completion, &mut order);
                }
                for &fi in &order {
                    let (device, kernel, queue, started, cmd, disp) = {
                        let r = &finished[fi];
                        (r.device, r.kernel, r.queue, r.started, r.cmd, r.disp)
                    };
                    self.runs_per_dev[device] -= 1;
                    self.load_dirty = true;
                    self.kernel_frac[kernel] = 1.0;
                    let name = &self.dag.kernels[kernel].name;
                    self.trace.push(Span {
                        label: format!("{name}{kernel}"),
                        lane: Lane::Device { dev: device, slot: queue },
                        start: started,
                        end: self.now,
                        cmd: Some(cmd),
                        kernel: Some(kernel),
                    });
                    self.command_done(disp, cmd);
                }
            } else {
                // Index loop: command_done below needs &mut self, so no
                // iterator over the scratch buffer may be live.
                #[allow(clippy::needless_range_loop)]
                for fi in 0..self.scratch_finished.len() {
                    let i = self.scratch_finished[fi];
                    let r = self.runs.swap_remove(i);
                    self.runs_per_dev[r.device] -= 1;
                    self.load_dirty = true;
                    self.kernel_frac[r.kernel] = 1.0;
                    let name = &self.dag.kernels[r.kernel].name;
                    self.trace.push(Span {
                        label: format!("{name}{}", r.kernel),
                        lane: Lane::Device {
                            dev: r.device,
                            slot: r.queue,
                        },
                        start: r.started,
                        end: self.now,
                        cmd: Some(r.cmd),
                        kernel: Some(r.kernel),
                    });
                    self.command_done(r.disp, r.cmd);
                }
            }

            if self.seam.is_some() {
                self.drain_due_events_seamed();
            } else {
                // Handle all heap events due now.
                while let Some(Reverse(e)) = self.heap.peek() {
                    if e.t > self.now + EPS {
                        break;
                    }
                    let Reverse(e) = self.heap.pop().unwrap();
                    match e.kind {
                        EvKind::DispatchReady(di) => {
                            // Joins the live index unless it was displaced (or
                            // somehow drained) before its setup completed.
                            if !self.dispatches[di].cancelled
                                && self.dispatches[di].cmds_remaining > 0
                            {
                                self.active_insert(di);
                            }
                        }
                        EvKind::TransferDone { disp, cmd } => self.command_done(disp, cmd),
                        EvKind::CopyDone { engine } => {
                            let (di, cmd) = self.copy_engines[engine]
                                .current
                                .take()
                                .expect("engine busy");
                            self.command_done(di, cmd);
                            self.pump_copy_engine(engine);
                        }
                        EvKind::Callback { disp, kernel } => self.handle_callback(disp, kernel),
                        EvKind::Release { comp } => {
                            if self.ext_preds_left[comp] == 0 {
                                self.enter_frontier(comp);
                            }
                        }
                        EvKind::Recover { comp, .. } => {
                            if self.ext_preds_left[comp] == 0 {
                                self.enter_frontier(comp);
                            }
                        }
                    }
                }
            }
            if self
                .faults
                .as_ref()
                .map(|c| c.any_due(self.now))
                .unwrap_or(false)
            {
                self.apply_due_faults()?;
            }
        }

        Ok(SimResult {
            makespan: self.last_cmd_done,
            trace: self.trace,
            policy: self.policy.name().to_string(),
            component_finish: self.comp_finish,
            component_device: self.comp_device,
            preemptions: self.preemptions,
        })
    }
}
#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::PaperCost;
    use crate::platform::DeviceType;
    use crate::sched::{Clustering, Eager, Heft};
    use crate::transformer::{cluster_by_head, head_dag, transformer_dag, vadd_vsin_dag};

    fn sim_clustering(
        q_gpu: usize,
        q_cpu: usize,
        heads: usize,
        beta: u64,
        h_cpu: usize,
    ) -> SimResult {
        let (dag, ios) = transformer_dag(heads, beta, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, h_cpu);
        let platform = Platform::paper_testbed(q_gpu, q_cpu);
        let mut pol = Clustering;
        simulate(&dag, &part, &platform, &PaperCost, &mut pol, &SimConfig::default())
            .unwrap()
    }

    #[test]
    fn single_head_coarse_near_paper_105ms() {
        // Fig. 4: one head, β=256, single GPU queue => ≈105 ms.
        let r = sim_clustering(1, 0, 1, 256, 0);
        assert!(
            r.makespan > 0.085 && r.makespan < 0.125,
            "expected ≈105ms, got {:.1}ms",
            r.makespan * 1e3
        );
    }

    #[test]
    fn fine_grained_beats_coarse_by_paper_margin() {
        // Fig. 5: 3 queues => ≈8–17% faster than 1 queue.
        let coarse = sim_clustering(1, 0, 1, 256, 0).makespan;
        let fine = sim_clustering(3, 0, 1, 256, 0).makespan;
        let speedup = coarse / fine;
        assert!(
            speedup > 1.05 && speedup < 1.30,
            "speedup {speedup:.3} out of paper range"
        );
    }

    #[test]
    fn fine_grained_overlaps_kernels_and_transfers() {
        let r = sim_clustering(3, 0, 1, 256, 0);
        assert!(r.trace.device_overlap(0) > 0.0, "no kernel concurrency");
        assert!(r.trace.copy_compute_overlap(0) > 0.0, "no transfer overlap");
        // Coarse single queue: no kernel concurrency possible.
        let c = sim_clustering(1, 0, 1, 256, 0);
        assert_eq!(c.trace.device_overlap(0), 0.0);
    }

    #[test]
    fn concurrent_kernels_individually_slower() {
        // Paper §2.1: individual times increase under interleaving.
        let coarse = sim_clustering(1, 0, 1, 256, 0);
        let fine = sim_clustering(3, 0, 1, 256, 0);
        let max_span = |r: &SimResult| -> f64 {
            r.trace
                .spans
                .iter()
                .filter(|s| matches!(s.lane, Lane::Device { .. }))
                .map(|s| s.end - s.start)
                .fold(0.0, f64::max)
        };
        assert!(max_span(&fine) > max_span(&coarse) * 1.05);
    }

    #[test]
    fn offloading_one_head_helps_at_large_h() {
        // Fig. 11: h_cpu=1 beats all-GPU for H > 10.
        let all_gpu = sim_clustering(3, 1, 12, 256, 0).makespan;
        let one_cpu = sim_clustering(3, 1, 12, 256, 1).makespan;
        assert!(
            one_cpu < all_gpu,
            "offload should help at H=12: {one_cpu} vs {all_gpu}"
        );
        // ... but NOT at H=4.
        let all_gpu4 = sim_clustering(3, 1, 4, 256, 0).makespan;
        let one_cpu4 = sim_clustering(3, 1, 4, 256, 1).makespan;
        assert!(one_cpu4 > all_gpu4, "offload should hurt at H=4");
    }

    #[test]
    fn clustering_beats_eager_in_paper_range() {
        // Expt 2 config: H=16, best clustering mapping (h_cpu = 1).
        let (dag, ios) = transformer_dag(16, 256, DeviceType::Gpu);
        let platform = Platform::paper_testbed(3, 1);
        let part = cluster_by_head(&dag, &ios, 1);
        let cl = simulate(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
        )
        .unwrap();
        let singles = Partition::singletons(&dag);
        let platform1 = Platform::paper_testbed(1, 1);
        let eg = simulate(&dag, &singles, &platform1, &PaperCost, &mut Eager, &SimConfig::default())
            .unwrap();
        let speedup = eg.makespan / cl.makespan;
        assert!(
            speedup > 1.3 && speedup < 4.5,
            "clustering vs eager = {speedup:.2}x (paper: 1.4–3.4x)"
        );
    }

    #[test]
    fn heft_between_eager_and_clustering() {
        let (dag, ios) = transformer_dag(8, 256, DeviceType::Gpu);
        let platform1 = Platform::paper_testbed(1, 1);
        let singles = Partition::singletons(&dag);
        let cfg = SimConfig::default();
        let eg = simulate(&dag, &singles, &platform1, &PaperCost, &mut Eager, &cfg).unwrap();
        let hf = simulate(&dag, &singles, &platform1, &PaperCost, &mut Heft, &cfg).unwrap();
        let part = cluster_by_head(&dag, &ios, 1);
        let platform = Platform::paper_testbed(3, 1);
        let cl = simulate(&dag, &part, &platform, &PaperCost, &mut Clustering, &cfg).unwrap();
        assert!(hf.makespan < eg.makespan, "heft should beat eager");
        assert!(cl.makespan < hf.makespan, "clustering should beat heft");
    }

    #[test]
    fn heft_keeps_gemms_on_gpu() {
        let (dag, _) = transformer_dag(4, 256, DeviceType::Gpu);
        let singles = Partition::singletons(&dag);
        let platform = Platform::paper_testbed(1, 1);
        let r = simulate(&dag, &singles, &platform, &PaperCost, &mut Heft, &SimConfig::default())
            .unwrap();
        for (c, &dev) in r.component_device.iter().enumerate() {
            let k = singles.components[c].kernels[0];
            if dag.kernels[k].name == "gemm" {
                assert_eq!(
                    platform.device(dev).dtype,
                    DeviceType::Gpu,
                    "HEFT put GEMM {k} on the CPU"
                );
            }
        }
    }

    #[test]
    fn eager_puts_some_gemms_on_cpu() {
        // Fig. 13(a): greedy device grabbing strands GEMMs on the CPU.
        let (dag, _) = transformer_dag(4, 256, DeviceType::Gpu);
        let singles = Partition::singletons(&dag);
        let platform = Platform::paper_testbed(1, 1);
        let r = simulate(&dag, &singles, &platform, &PaperCost, &mut Eager, &SimConfig::default())
            .unwrap();
        let cpu_gemms = r
            .component_device
            .iter()
            .enumerate()
            .filter(|&(c, &dev)| {
                let k = singles.components[c].kernels[0];
                dag.kernels[k].name == "gemm" && platform.device(dev).dtype == DeviceType::Cpu
            })
            .count();
        assert!(cpu_gemms > 0, "eager never used the CPU?");
    }

    #[test]
    fn makespan_at_least_critical_path() {
        let (dag, ios) = head_dag(128, DeviceType::Gpu);
        let platform = Platform::paper_testbed(3, 0);
        let part = cluster_by_head(&dag, std::slice::from_ref(&ios), 0);
        let r = simulate(&dag, &part, &platform, &PaperCost, &mut Clustering, &SimConfig::default())
            .unwrap();
        let gpu = platform.device(0);
        let weights: Vec<f64> = dag
            .kernels
            .iter()
            .map(|k| PaperCost.exec_time(k, gpu))
            .collect();
        let cp = crate::graph::rank::critical_path(&dag, &weights);
        assert!(r.makespan >= cp - 1e-9, "makespan {} < cp {}", r.makespan, cp);
    }

    #[test]
    fn small_chain_runs_and_orders() {
        let (dag, ks) = vadd_vsin_dag(4096);
        let singles = Partition::singletons(&dag);
        let platform = Platform::paper_testbed(2, 1);
        let r = simulate(
            &dag,
            &singles,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
        )
        .unwrap();
        // vsin must start after vadd's component finished (inter dep).
        let span_of = |k: usize| {
            r.trace
                .spans
                .iter()
                .find(|s| s.kernel == Some(k) && matches!(s.lane, Lane::Device { .. }))
                .unwrap()
                .clone()
        };
        assert!(span_of(ks[1]).start >= span_of(ks[0]).end);
    }

    #[test]
    fn zero_queue_platform_errors() {
        let (dag, _) = vadd_vsin_dag(4096);
        let singles = Partition::singletons(&dag);
        let platform = Platform::paper_testbed(0, 0);
        let res = simulate(
            &dag,
            &singles,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
        );
        assert!(res.is_err());
    }

    #[test]
    fn zero_releases_match_plain_simulate() {
        let (dag, ios) = transformer_dag(2, 128, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 1);
        let cfg = SimConfig::default();
        let plain = simulate(&dag, &part, &platform, &PaperCost, &mut Clustering, &cfg)
            .unwrap()
            .makespan;
        let released = simulate_released(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            &[0.0, 0.0],
        )
        .unwrap()
        .makespan;
        assert_eq!(plain, released);
    }

    #[test]
    fn released_components_wait_for_arrival() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 0);
        let release_t = 0.050;
        let r = simulate_released(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
            &[0.0, release_t],
        )
        .unwrap();
        let head1_start = r
            .trace
            .spans
            .iter()
            .filter(|s| {
                matches!(s.lane, Lane::Device { .. })
                    && s.kernel.map(|k| ios[1].kernels.contains(&k)).unwrap_or(false)
            })
            .map(|s| s.start)
            .fold(f64::INFINITY, f64::min);
        assert!(
            head1_start >= release_t - 1e-9,
            "head 1 started at {head1_start} before its release {release_t}"
        );
        assert!(r.component_finish.iter().all(|t| t.is_finite()));
    }

    #[test]
    fn release_length_mismatch_errors() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 0);
        let res = simulate_released(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
            &[0.0],
        );
        assert!(res.is_err());
    }

    #[test]
    fn served_default_meta_matches_plain_simulate() {
        let (dag, ios) = transformer_dag(2, 128, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 1);
        let cfg = SimConfig::default();
        let plain = simulate(&dag, &part, &platform, &PaperCost, &mut Clustering, &cfg)
            .unwrap();
        let served = simulate_served(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &cfg,
            &[CompMeta::default(), CompMeta::default()],
        )
        .unwrap();
        assert_eq!(plain.makespan, served.makespan);
        assert_eq!(served.preemptions, 0);
    }

    #[test]
    fn served_meta_rejects_nan_deadline_accepts_already_due() {
        let (dag, ios) = transformer_dag(2, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 0);
        let bad = CompMeta {
            deadline: f64::NAN,
            ..CompMeta::default()
        };
        let res = simulate_served(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
            &[bad, CompMeta::default()],
        );
        assert!(res.is_err());
        // An absolute deadline of 0 is "already due", not a config error —
        // the run proceeds and simply misses it.
        let due = CompMeta {
            deadline: 0.0,
            ..CompMeta::default()
        };
        let r = simulate_served(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut Clustering,
            &SimConfig::default(),
            &[due, CompMeta::default()],
        )
        .unwrap();
        assert!(r.component_finish[0] > 0.0);
    }

    /// Exclusive single-GPU platform, a long-running low-priority resident
    /// and an urgent late arrival: EDF must displace the resident, the
    /// urgent request must finish first, and the displaced component must
    /// still complete (remaining work preserved).
    #[test]
    fn edf_preempts_resident_for_urgent_arrival() {
        use crate::sched::Edf;
        let (dag, ios) = transformer_dag(2, 256, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 0);
        let cfg = SimConfig::default(); // max_tenants = 1: GPU is exclusive
        // Calibrate the scenario in solo-head units so it survives
        // cost-model changes: one head run exclusively takes `head_t`.
        let (hdag, hios) = transformer_dag(1, 256, DeviceType::Gpu);
        let hpart = cluster_by_head(&hdag, &hios, 0);
        let head_t = simulate(&hdag, &hpart, &platform, &PaperCost, &mut Clustering, &cfg)
            .unwrap()
            .makespan;
        // Component 0: released at 0, no deadline. Component 1: arrives 5%
        // into component 0's run with a tight deadline and high priority.
        let meta = [
            CompMeta::default(),
            CompMeta {
                release: 0.05 * head_t,
                deadline: 1.5 * head_t,
                priority: 1,
            },
        ];
        let r = simulate_served(&dag, &part, &platform, &PaperCost, &mut Edf, &cfg, &meta)
            .unwrap();
        assert!(r.preemptions >= 1, "no preemption happened");
        assert!(
            r.component_finish.iter().all(|t| t.is_finite()),
            "displaced component never completed: {:?}",
            r.component_finish
        );
        assert!(
            r.component_finish[1] < r.component_finish[0],
            "urgent component should finish first ({} !< {})",
            r.component_finish[1],
            r.component_finish[0]
        );
        // Without preemption (least-loaded ignores deadlines), the urgent
        // request waits behind the resident — strictly later finish.
        let blind = simulate_served(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut crate::sched::LeastLoaded,
            &cfg,
            &meta,
        )
        .unwrap();
        assert_eq!(blind.preemptions, 0);
        assert!(
            r.component_finish[1] < blind.component_finish[1],
            "preemption should speed up the urgent request ({} !< {})",
            r.component_finish[1],
            blind.component_finish[1]
        );
    }

    /// A preempted component's already-finished kernels stay finished: the
    /// total simulated makespan with preemption stays bounded (no work is
    /// silently redone from scratch) and every component completes.
    #[test]
    fn preemption_preserves_remaining_work() {
        use crate::sched::Edf;
        let (dag, ios) = transformer_dag(3, 128, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 0);
        let cfg = SimConfig::default();
        let (hdag, hios) = transformer_dag(1, 128, DeviceType::Gpu);
        let hpart = cluster_by_head(&hdag, &hios, 0);
        let head_t = simulate(&hdag, &hpart, &platform, &PaperCost, &mut Clustering, &cfg)
            .unwrap()
            .makespan;
        let meta = [
            CompMeta::default(),
            CompMeta {
                release: 0.02 * head_t,
                deadline: 1.5 * head_t,
                priority: 2,
            },
            CompMeta {
                release: 0.04 * head_t,
                deadline: 1.8 * head_t,
                priority: 1,
            },
        ];
        let r = simulate_served(&dag, &part, &platform, &PaperCost, &mut Edf, &cfg, &meta)
            .unwrap();
        assert!(r.component_finish.iter().all(|t| t.is_finite()));
        // Solo makespan of the whole partition without any arrivals gives a
        // generous upper bound when multiplied by the re-staging overhead.
        let solo = simulate(&dag, &part, &platform, &PaperCost, &mut Clustering, &cfg)
            .unwrap()
            .makespan;
        assert!(
            r.makespan < solo * 3.0,
            "preemption re-ran too much work: {} vs solo {}",
            r.makespan,
            solo
        );
    }

    #[test]
    fn multi_tenancy_overlaps_independent_components() {
        // Four small heads on one GPU: with max_tenants = 4 the components
        // share the device and finish faster than the exclusive-lease default.
        let (dag, ios) = transformer_dag(4, 64, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let platform = Platform::paper_testbed(3, 0);
        let run = |tenants: usize| {
            let cfg = SimConfig {
                max_tenants: tenants,
                ..SimConfig::default()
            };
            simulate(&dag, &part, &platform, &PaperCost, &mut Clustering, &cfg).unwrap()
        };
        let exclusive = run(1);
        let shared = run(4);
        assert!(
            shared.makespan < exclusive.makespan,
            "tenancy 4 {} !< tenancy 1 {}",
            shared.makespan,
            exclusive.makespan
        );
        assert!(shared.trace.device_overlap(0) > 0.0);
    }

    /// The indexed engine must be byte-identical to the verbatim
    /// pre-refactor copy in [`crate::sim::reference`] — same makespan
    /// bits, same per-component finish/device, same preemption count —
    /// including under EDF preemption (the full equivalence matrix over
    /// seeded serve streams lives in `tests/integration_sim_equiv.rs`).
    #[test]
    fn optimized_engine_matches_reference_bitwise() {
        use crate::sim::reference::simulate_served_ref;
        let (dag, ios) = transformer_dag(3, 128, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 1);
        let platform = Platform::paper_testbed(3, 1);
        let cfg = SimConfig {
            max_tenants: 2,
            ..SimConfig::default()
        };
        let meta = [
            CompMeta::default(),
            CompMeta {
                release: 0.002,
                deadline: 0.5,
                priority: 1,
            },
            CompMeta {
                release: 0.004,
                deadline: 0.4,
                priority: 0,
            },
        ];
        let new = simulate_served(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut crate::sched::Edf,
            &cfg,
            &meta,
        )
        .unwrap();
        let old = simulate_served_ref(
            &dag,
            &part,
            &platform,
            &PaperCost,
            &mut crate::sched::reference::Edf,
            &cfg,
            &meta,
        )
        .unwrap();
        assert_eq!(new.makespan.to_bits(), old.makespan.to_bits());
        assert_eq!(new.preemptions, old.preemptions);
        assert_eq!(new.component_device, old.component_device);
        let bits = |v: &[f64]| -> Vec<u64> { v.iter().map(|x| x.to_bits()).collect() };
        assert_eq!(bits(&new.component_finish), bits(&old.component_finish));
    }

    fn two_head_served(
        platform: &Platform,
        plan: Option<&FaultPlan>,
    ) -> Result<SimResult> {
        let (dag, ios) = transformer_dag(2, 128, DeviceType::Gpu);
        let part = cluster_by_head(&dag, &ios, 0);
        let cfg = SimConfig::default();
        let meta = [CompMeta::default(), CompMeta::default()];
        let mut pol = crate::sched::LeastLoaded;
        match plan {
            Some(p) => simulate_served_faulted(
                &dag, &part, platform, &PaperCost, &mut pol, &cfg, &meta, p,
            ),
            None => simulate_served(&dag, &part, platform, &PaperCost, &mut pol, &cfg, &meta),
        }
    }

    #[test]
    fn faulted_zero_event_plan_matches_served_bitwise() {
        let platform = Platform::paper_testbed(3, 1);
        let plain = two_head_served(&platform, None).unwrap();
        let plan = FaultPlan::default().normalized().unwrap();
        let faulted = two_head_served(&platform, Some(&plan)).unwrap();
        assert_eq!(plain.makespan.to_bits(), faulted.makespan.to_bits());
        assert_eq!(plain.component_device, faulted.component_device);
        assert_eq!(plain.preemptions, faulted.preemptions);
        let bits = |v: &[f64]| -> Vec<u64> { v.iter().map(|x| x.to_bits()).collect() };
        assert_eq!(bits(&plain.component_finish), bits(&faulted.component_finish));
    }

    #[test]
    fn faulted_slowdown_stretches_the_makespan() {
        // Single GPU at half speed from t=0: everything takes roughly
        // twice as long; no retries, no displacement.
        let platform = Platform::paper_testbed(3, 0);
        let plain = two_head_served(&platform, None).unwrap();
        let plan = FaultPlan {
            events: vec![FaultEvent {
                device: 0,
                at: 0.0,
                kind: FaultKind::Slowdown { factor: 0.5 },
            }],
            ..FaultPlan::default()
        }
        .normalized()
        .unwrap();
        let slow = two_head_served(&platform, Some(&plan)).unwrap();
        assert!(
            slow.makespan > plain.makespan * 1.3,
            "slowdown 0.5x did not stretch the run: {} vs {}",
            slow.makespan,
            plain.makespan
        );
        assert!(slow.component_finish.iter().all(|t| t.is_finite()));
    }

    #[test]
    fn faulted_wedge_stalls_then_resumes() {
        let platform = Platform::paper_testbed(3, 0);
        let plain = two_head_served(&platform, None).unwrap();
        let dur = plain.makespan * 0.5;
        let plan = FaultPlan {
            events: vec![FaultEvent {
                device: 0,
                at: plain.makespan * 0.3,
                kind: FaultKind::Wedge { dur },
            }],
            ..FaultPlan::default()
        }
        .normalized()
        .unwrap();
        let wedged = two_head_served(&platform, Some(&plan)).unwrap();
        assert!(
            wedged.makespan > plain.makespan + 0.25 * dur,
            "wedge of {dur}s barely moved the makespan: {} vs {}",
            wedged.makespan,
            plain.makespan
        );
        assert!(wedged.component_finish.iter().all(|t| t.is_finite()));
    }

    #[test]
    fn faulted_crash_recovers_on_the_surviving_device() {
        let platform = Platform::paper_testbed(3, 1);
        let plain = two_head_served(&platform, None).unwrap();
        // Pick a component the fault-free run placed on the GPU and crash
        // that device halfway through the component's run: the victim must
        // re-stage and complete on the surviving CPU.
        let victim = plain
            .component_device
            .iter()
            .position(|&d| d == 0)
            .expect("no component ran on the GPU");
        let plan = FaultPlan {
            events: vec![FaultEvent {
                device: 0,
                at: 0.5 * plain.component_finish[victim],
                kind: FaultKind::Crash,
            }],
            retry_budget: 3,
            backoff_base: 1e-4,
            ..FaultPlan::default()
        }
        .normalized()
        .unwrap();
        let r = two_head_served(&platform, Some(&plan)).unwrap();
        assert!(r.component_finish.iter().all(|t| t.is_finite()));
        assert_ne!(
            r.component_device[victim], 0,
            "victim must finish on the surviving device"
        );
        assert!(
            r.component_finish[victim] > plain.component_finish[victim],
            "restarted victim cannot beat its fault-free finish"
        );
    }

    #[test]
    fn faulted_batch_run_has_no_shedding_outlet() {
        // Budget 0 on a crash mid-run: the finite batch cannot degrade
        // gracefully, so the retry-budget exhaustion is a typed error.
        let platform = Platform::paper_testbed(3, 1);
        let plain = two_head_served(&platform, None).unwrap();
        let victim = plain
            .component_device
            .iter()
            .position(|&d| d == 0)
            .expect("no component ran on the GPU");
        let plan = FaultPlan {
            events: vec![FaultEvent {
                device: 0,
                at: 0.5 * plain.component_finish[victim],
                kind: FaultKind::Crash,
            }],
            retry_budget: 0,
            backoff_base: 0.0,
            ..FaultPlan::default()
        }
        .normalized()
        .unwrap();
        let e = two_head_served(&platform, Some(&plan)).unwrap_err();
        assert!(
            matches!(&e, Error::Sched(m) if m.contains("retry budget")),
            "unexpected error: {e}"
        );

        // Crashing the only device on a single-device platform is the
        // other terminal path: no schedulable device left.
        let solo = Platform::paper_testbed(3, 0);
        let base = two_head_served(&solo, None).unwrap();
        let plan = FaultPlan {
            events: vec![FaultEvent {
                device: 0,
                at: 0.5 * base.makespan,
                kind: FaultKind::Crash,
            }],
            retry_budget: 8,
            backoff_base: 0.0,
            ..FaultPlan::default()
        }
        .normalized()
        .unwrap();
        let e = two_head_served(&solo, Some(&plan)).unwrap_err();
        assert!(
            matches!(&e, Error::Sched(m) if m.contains("no schedulable device")),
            "unexpected error: {e}"
        );
    }
}
