//! Discrete-event simulator of the paper's heterogeneous testbed.
//!
//! Executes Algorithm 1 end to end over virtual time: the scheduling loop
//! (frontier/device-set/select), `setup_cq` latency, in-order command
//! queues with cross-queue event waits, the single DMA copy engine, the
//! processor-sharing kernel-concurrency contention model
//! ([`crate::cost::contention`]), and callback latency for completion
//! notification — the five mechanisms that generate every effect the
//! paper measures (Figs. 4, 5, 11, 12, 13).
//!
//! [`simulate_released`] is the multi-DAG serving entry point: components
//! carry release times (request arrivals) and devices admit several resident
//! components at once (`SimConfig::max_tenants`) — see [`crate::serve`].
//! [`simulate_served`] additionally threads absolute deadlines and
//! priorities ([`CompMeta`]) to deadline-aware policies, and honours
//! [`crate::sched::Policy::preempt`]: an urgent component may displace a
//! less urgent resident tenant at command-queue granularity, the displaced
//! work re-entering the frontier with its remaining solo-seconds preserved.
//!
//! [`stream`] is the always-on variant: [`StreamSim`] runs the same
//! execution machinery over an *unbounded* admission stream with bounded
//! memory — units are admitted while earlier ones execute and fully
//! retired (slots, dispatch records, scheduler entries reclaimed) when
//! they finish — see [`crate::serve`]'s streaming driver.

pub mod engine;
#[doc(hidden)]
pub mod reference;
pub mod stream;

#[doc(hidden)]
pub use engine::simulate_served_fuzzed;
pub use engine::{simulate, simulate_released, simulate_served, CompMeta, SimConfig, SimResult};
pub use stream::{AdmitUnit, FinishedRequest, MemberSpec, PumpStop, StreamSim, Template};
